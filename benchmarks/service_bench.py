"""Serving-layer benchmark: compacted supersteps + PulseService throughput.

Three experiments:

  1. **Compacted routing** -- a skewed distributed workload (half the batch
     finishes early, the rest keep walking) on an 8-way mesh.  Reports the
     per-superstep wire payload (int32 words shipped through the all_to_all)
     for the bulk-synchronous baseline vs compacted execution, and checks the
     paper-style claim: once half the batch has finished, the compacted
     fabric carries >= 30% fewer record-words per superstep.

  2. **PulseService** -- a mixed 4-structure workload (list walk, B-tree
     lookup, hash-chain probe, skiplist search) from 3 tenants served
     end-to-end through continuous batching; reports p50/p99 latency,
     throughput, utilization, and per-tenant counts.

  3. **LM batched prefill** -- the ContinuousBatcher's admission path:
     batched full-sequence prefill (one jitted call per admission) vs the
     legacy token-by-token slot prefill, on a reduced LM config.  Checks
     outputs are identical and reports the prefill-call reduction + wall
     clock for both.

Run:  PYTHONPATH=src python benchmarks/service_bench.py
      PYTHONPATH=src python benchmarks/service_bench.py --small --json BENCH_service.json
      PYTHONPATH=src python benchmarks/service_bench.py --arrival poisson:500
      # open-loop Poisson arrivals (offered rate in req/s) instead of the
      # closed-loop logical rounds; the realized arrival process is emitted
      # into the JSON (first step toward the Fig. 7 tail-latency runs)
"""

from __future__ import annotations

import os

# must be set before jax initializes: experiment 1 needs a multi-device host
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.arena import ArenaBuilder
from repro.core.engine import PulseEngine
from repro.core.structures import btree, hash_table, linked_list, skiplist
from repro.serving.admission import TraversalRequest
from repro.serving.traversal_service import PulseService, StructureSpec

RNG = np.random.default_rng(42)
P = 8


def bench_compacted_routing(n=2048, B=512, k_local=4):
    """Skewed list-walk workload: half shallow (retire fast), half deep."""
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = np.concatenate(
        [
            RNG.integers(0, n // 16, B // 2),  # shallow: finish early
            RNG.integers(n // 2, n, B // 2),  # deep: keep walking
        ]
    ).astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))

    runs = {}
    for compact in (False, True):
        t0 = time.perf_counter()
        rec, st = routing.distributed_execute(
            it, ar, ptr0, scr0, mesh=mesh, axis_name="mem",
            max_iters=1 << 20, k_local=k_local, compact=compact,
        )
        dt = time.perf_counter() - t0
        runs[compact] = (rec, st, dt)
        print(
            f"  {'compacted' if compact else 'baseline '}: "
            f"supersteps={st.supersteps} wire_words={st.total_wire_words:,} "
            f"local_only={st.local_only_steps} wall={dt:.1f}s"
        )

    (rec_b, st_b, _), (rec_c, st_c, _) = runs[False], runs[True]
    np.testing.assert_array_equal(
        rec_b[:, routing.F_SCRATCH:], rec_c[:, routing.F_SCRATCH:]
    )
    np.testing.assert_array_equal(rec_b[:, routing.F_STATUS], rec_c[:, routing.F_STATUS])
    print("  results identical (compaction is schedule-only)")

    # the acceptance claim: compare per-superstep wire once half the batch
    # finished.  Baseline wire is constant, so its half-done wire == any step.
    half = B // 2
    base_wire = st_b.wire_words_per_step[0]
    idx = next(i for i, a in enumerate(st_c.active_per_step) if a <= half)
    # average compacted payload over the post-half-done tail (routed + skipped)
    tail = st_c.wire_words_per_step[idx:]
    tail_mean = float(np.mean(tail))
    reduction = 1.0 - tail_mean / base_wire
    print(
        f"  per-superstep wire once half finished: baseline={base_wire:,} "
        f"compacted(mean)={tail_mean:,.0f} reduction={reduction:.0%}"
    )
    assert reduction >= 0.30, (
        f"compacted routing must cut the half-done per-superstep payload by "
        f">=30%, got {reduction:.0%}"
    )
    total_red = 1.0 - st_c.total_wire_words / st_b.total_wire_words
    print(f"  total wire reduction: {total_red:.0%}")
    return {
        "baseline_wire_words": st_b.total_wire_words,
        "compacted_wire_words": st_c.total_wire_words,
        "half_done_reduction": reduction,
        "total_reduction": total_red,
    }


def build_mixed_heap(n_per=2048):
    """One pooled arena hosting all four structure families (paper S2: the
    memory pool is shared; the switch routes by address range)."""
    b = ArenaBuilder(1 << 16, 20)
    lkeys = np.arange(n_per, dtype=np.int32)
    lvals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    head = linked_list.build_into(b, lkeys, lvals)
    bkeys = RNG.choice(np.arange(10**6, 2 * 10**6), n_per, replace=False).astype(np.int32)
    bvals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    root, _ = btree.build_into(b, bkeys, bvals)
    hkeys = RNG.choice(np.arange(2 * 10**6, 3 * 10**6), n_per, replace=False).astype(np.int32)
    hvals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    heads = hash_table.build_into(b, hkeys, hvals, 256)
    skeys = RNG.choice(np.arange(3 * 10**6, 4 * 10**6), n_per, replace=False).astype(np.int32)
    svals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    shead = skiplist.build_into(b, skeys, svals)
    arena = b.finish()
    structures = {
        "list": StructureSpec(linked_list.find_iterator(), (head,)),
        "btree": StructureSpec(btree.find_iterator(), (root,)),
        "hash": StructureSpec(hash_table.find_iterator(256), (jnp.asarray(heads),)),
        "skip": StructureSpec(skiplist.find_iterator(), (shead,)),
    }
    keysets = {"list": lkeys, "btree": bkeys, "hash": hkeys, "skip": skeys}
    return arena, structures, keysets


def parse_arrival(spec: str | None):
    """``--arrival=poisson:<rps>`` -> ("poisson", rps); None -> closed loop."""
    if spec is None:
        return None
    kind, _, rate = spec.partition(":")
    if kind != "poisson" or not rate:
        raise ValueError(f"unknown arrival spec {spec!r} (want poisson:<rps>)")
    rps = float(rate)
    if rps <= 0:
        raise ValueError("poisson rate must be > 0")
    return ("poisson", rps)


def bench_service(n_requests=600, slots=64, quantum=16, arrival=None):
    arena, structures, keysets = build_mixed_heap()
    engine = PulseEngine(arena)
    svc = PulseService(
        engine, structures, slots_per_structure=slots, quantum=quantum
    )

    names = list(structures)
    tenants = ["tenant-a", "tenant-b", "tenant-c"]
    reqs = []
    for i in range(n_requests):
        s = names[RNG.integers(0, len(names))]
        ks = keysets[s]
        # 10% misses exercise the not-found path
        key = (
            int(ks[RNG.integers(0, len(ks))])
            if RNG.random() > 0.1
            else int(RNG.integers(5 * 10**6, 6 * 10**6))
        )
        reqs.append(
            TraversalRequest(
                req_id=i,
                structure=s,
                query=key,
                tenant=tenants[i % len(tenants)],
                deadline_ms=2000.0 if i % 3 == 0 else None,
                arrive_round=i // (2 * slots),  # closed-loop trickle default
            )
        )

    # warm the per-group compile so latency numbers reflect steady state
    warm = [
        TraversalRequest(10**6 + j, s, int(keysets[s][0]))
        for j, s in enumerate(names)
    ]
    svc.run(warm)
    svc.metrics = type(svc.metrics)()  # reset accounting after warmup

    arrival_info = {"process": "closed-loop", "rounds_per_wave": 1}
    if arrival is None:
        m = svc.run(reqs)
    else:
        # open-loop Poisson: exponential inter-arrivals in *wall-clock* time,
        # submitted when due regardless of service backlog (the Fig. 7
        # tail-latency regime: the arrival process never waits for the server)
        _, rps = arrival
        gaps = RNG.exponential(1.0 / rps, n_requests)
        t_arr = np.cumsum(gaps)
        for r in reqs:
            r.arrive_round = 0
        t0 = time.perf_counter()
        nxt = 0
        while nxt < n_requests or svc._busy():
            now = time.perf_counter() - t0
            while nxt < n_requests and t_arr[nxt] <= now:
                svc.submit(reqs[nxt])
                nxt += 1
            if nxt < n_requests and not svc._busy():
                # idle server, next arrival in the future: wait for it
                time.sleep(max(0.0, t_arr[nxt] - (time.perf_counter() - t0)))
                continue
            svc.step()
        m = svc.metrics
        m.wall_s += time.perf_counter() - t0
        arrival_info = {
            "process": "poisson",
            "offered_rps": rps,
            "achieved_arrival_rps": float(n_requests / t_arr[-1]),
            "interarrival_mean_ms": float(np.mean(gaps) * 1e3),
            "interarrival_p99_ms": float(np.percentile(gaps, 99) * 1e3),
            "arrival_span_s": float(t_arr[-1]),
        }
    print(f"  {m.summary()}")
    if arrival is not None:
        print(
            f"  open-loop poisson: offered={arrival_info['offered_rps']:.0f} rps "
            f"achieved={arrival_info['achieved_arrival_rps']:.0f} rps "
            f"span={arrival_info['arrival_span_s']:.2f}s"
        )
    for t, d in sorted(m.per_tenant.items()):
        lat = np.asarray(d["latencies_ms"])
        print(
            f"    {t}: completed={d['completed']} "
            f"p50={np.percentile(lat, 50):.1f}ms p99={np.percentile(lat, 99):.1f}ms"
        )
    if m.deadlines_met + m.deadlines_missed:
        print(f"    deadline hit rate: {m.deadline_hit_rate:.0%}")
    assert m.completed == n_requests
    # NOTE: no wire_words here -- this experiment serves through a single-node
    # engine (no mesh), so the distributed wire accounting is structurally 0;
    # the JSON's wire trajectory comes from the compacted-routing experiment.
    return {
        "completed": m.completed,
        "p50_ms": m.p50_ms,
        "p99_ms": m.p99_ms,
        "throughput_rps": m.throughput_rps,
        "utilization": m.utilization,
        "arrival": arrival_info,
    }


def bench_batched_prefill(n_requests=12, prompt_len=8, max_new=6):
    """Admission throughput: batched prefill vs token-by-token slot prefill.

    The legacy path runs one full-batch decode_step per prompt token per
    admitted request; the batched path absorbs a whole admission's prompts
    in one jitted prefill call per distinct prompt length.
    """
    import jax

    from repro.configs import get_reduced_config
    from repro.models.model_zoo import build_model
    from repro.serving.batching import ContinuousBatcher, Request

    cfg = get_reduced_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [
        RNG.integers(2, cfg.vocab, prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]

    results = {}
    outputs = {}
    for mode in ("token", "batched"):
        reqs = [
            Request(req_id=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)
        ]
        b = ContinuousBatcher(model, max_batch=4, max_len=32, prefill_mode=mode)
        b.model_params = params
        b.serve(list(reqs))  # warm the compiles
        for r in reqs:
            r.output, r.finished_step = [], -1
        t0 = time.perf_counter()
        m = b.serve(list(reqs))
        wall = time.perf_counter() - t0
        outputs[mode] = [list(r.output) for r in reqs]
        results[mode] = {
            "wall_s": wall,
            "tokens_per_s": m.tokens_out / wall,
            "prefill_calls": m.prefill_calls,
            "prompt_tokens": int(sum(len(p) for p in prompts)),
        }
        print(
            f"  {mode:8s}: wall={wall*1e3:7.1f}ms "
            f"decode_tokens/s={m.tokens_out / wall:7.0f} "
            f"prefill_calls={m.prefill_calls}"
        )
    assert outputs["token"] == outputs["batched"], (
        "batched prefill must produce identical decodes"
    )
    speedup = results["token"]["wall_s"] / results["batched"]["wall_s"]
    results["prefill_speedup"] = speedup
    print(f"  batched-prefill admission speedup: {speedup:.2f}x (identical outputs)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_service.json",
        default=None,
        metavar="PATH",
        help="write machine-readable results (default path: BENCH_service.json)",
    )
    ap.add_argument(
        "--small",
        action="store_true",
        help="CI smoke sizes (faster, same assertions)",
    )
    ap.add_argument(
        "--arrival",
        default=None,
        metavar="SPEC",
        help="open-loop arrival process for the service experiment, e.g. "
        "'poisson:500' (500 req/s offered); default is closed-loop rounds",
    )
    args = ap.parse_args(argv)
    arrival = parse_arrival(args.arrival)

    print("[1/3] compacted supersteps vs bulk-synchronous baseline")
    r1 = bench_compacted_routing(
        **({"n": 512, "B": 128} if args.small else {})
    )
    print(
        "[2/3] PulseService: mixed 4-structure workload"
        + (f" (open-loop {args.arrival})" if arrival else "")
    )
    r2 = bench_service(
        arrival=arrival,
        **({"n_requests": 150, "slots": 32} if args.small else {}),
    )
    print("[3/3] LM admission: batched prefill vs token-by-token")
    r3 = bench_batched_prefill(
        **({"n_requests": 8, "prompt_len": 6, "max_new": 4} if args.small else {})
    )
    summary = {**r1, **r2, "prefill_speedup": r3["prefill_speedup"]}
    print("\nsummary:", summary)
    if args.json:
        payload = {
            "benchmark": "service_bench",
            "config": {"shards": P, "small": bool(args.small)},
            "compacted_routing": r1,
            "service": r2,
            "batched_prefill": r3,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
