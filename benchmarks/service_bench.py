"""Serving-layer benchmark: compacted supersteps + PulseService throughput
and the Fig. 7 tail-latency reproduction (sync vs async pipeline).

Four experiments:

  1. **Compacted routing** -- a skewed distributed workload (half the batch
     finishes early, the rest keep walking) on an 8-way mesh.  Reports the
     per-superstep wire payload (int32 words shipped through the all_to_all)
     for the bulk-synchronous baseline vs compacted execution, and checks the
     paper-style claim: once half the batch has finished, the compacted
     fabric carries >= 30% fewer record-words per superstep.

  2. **PulseService async vs sync** -- the same open-loop Poisson arrival
     trace (seeded; the seed is recorded in the JSON) served twice at an
     offered rate above saturation: once by the legacy synchronous loop,
     once by the async device-runner pipeline with SLO-aware quantum
     sizing.  Under ``--check`` this gates async throughput >= 1.3x sync
     with p99 <= 1.1x at the matched load, then sweeps an offered-RPS
     ladder (multiples of the measured sync service rate) recording
     p50/p99/p999 per rung -- the Fig. 7 curves -- and gates the async
     saturation point at >= 2x the sync service rate.  A final overload
     rung exercises per-tenant rate limiting + bounded-queue shedding.

  3. **PulseService mixed workload** -- a mixed 4-structure workload (list
     walk, B-tree lookup, hash-chain probe, skiplist search) from 3
     tenants served end-to-end through continuous batching; reports
     p50/p99 latency, throughput, utilization, and per-tenant counts.

  4. **LM batched prefill** -- the ContinuousBatcher's admission path:
     batched full-sequence prefill (one jitted call per admission) vs the
     legacy token-by-token slot prefill, on a reduced LM config.  Checks
     outputs are identical and reports the prefill-call reduction + wall
     clock for both.

Run:  PYTHONPATH=src python benchmarks/service_bench.py
      PYTHONPATH=src python benchmarks/service_bench.py --small --json BENCH_service.json
      PYTHONPATH=src python benchmarks/service_bench.py --arrival poisson:500 --seed 7
      PYTHONPATH=src python benchmarks/service_bench.py --small --arrival poisson:300 --check
      # open-loop Poisson arrivals (offered rate in req/s) instead of the
      # closed-loop logical rounds; arrival generation is seeded by --seed
      # and the realized process is emitted into the JSON (Fig. 7 runs)
"""

from __future__ import annotations

import os

# must be set before jax initializes: experiment 1 needs a multi-device host
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.arena import ArenaBuilder
from repro.core.engine import PulseEngine
from repro.core.structures import btree, hash_table, linked_list, skiplist
from repro.serving.admission import TraversalRequest
from repro.serving.traversal_service import PulseService, StructureSpec

RNG = np.random.default_rng(42)
P = 8


def bench_compacted_routing(n=2048, B=512, k_local=4):
    """Skewed list-walk workload: half shallow (retire fast), half deep."""
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = np.concatenate(
        [
            RNG.integers(0, n // 16, B // 2),  # shallow: finish early
            RNG.integers(n // 2, n, B // 2),  # deep: keep walking
        ]
    ).astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))

    runs = {}
    for compact in (False, True):
        t0 = time.perf_counter()
        rec, st = routing.distributed_execute(
            it, ar, ptr0, scr0, mesh=mesh, axis_name="mem",
            max_iters=1 << 20, k_local=k_local, compact=compact,
        )
        dt = time.perf_counter() - t0
        runs[compact] = (rec, st, dt)
        print(
            f"  {'compacted' if compact else 'baseline '}: "
            f"supersteps={st.supersteps} wire_words={st.total_wire_words:,} "
            f"local_only={st.local_only_steps} wall={dt:.1f}s"
        )

    (rec_b, st_b, _), (rec_c, st_c, _) = runs[False], runs[True]
    np.testing.assert_array_equal(
        rec_b[:, routing.F_SCRATCH:], rec_c[:, routing.F_SCRATCH:]
    )
    np.testing.assert_array_equal(rec_b[:, routing.F_STATUS], rec_c[:, routing.F_STATUS])
    print("  results identical (compaction is schedule-only)")

    # the acceptance claim: compare per-superstep wire once half the batch
    # finished.  Baseline wire is constant, so its half-done wire == any step.
    half = B // 2
    base_wire = st_b.wire_words_per_step[0]
    idx = next(i for i, a in enumerate(st_c.active_per_step) if a <= half)
    # average compacted payload over the post-half-done tail (routed + skipped)
    tail = st_c.wire_words_per_step[idx:]
    tail_mean = float(np.mean(tail))
    reduction = 1.0 - tail_mean / base_wire
    print(
        f"  per-superstep wire once half finished: baseline={base_wire:,} "
        f"compacted(mean)={tail_mean:,.0f} reduction={reduction:.0%}"
    )
    assert reduction >= 0.30, (
        f"compacted routing must cut the half-done per-superstep payload by "
        f">=30%, got {reduction:.0%}"
    )
    total_red = 1.0 - st_c.total_wire_words / st_b.total_wire_words
    print(f"  total wire reduction: {total_red:.0%}")
    return {
        "baseline_wire_words": st_b.total_wire_words,
        "compacted_wire_words": st_c.total_wire_words,
        "half_done_reduction": reduction,
        "total_reduction": total_red,
    }


def build_mixed_heap(n_per=2048):
    """One pooled arena hosting all four structure families (paper S2: the
    memory pool is shared; the switch routes by address range)."""
    b = ArenaBuilder(1 << 16, 20)
    lkeys = np.arange(n_per, dtype=np.int32)
    lvals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    head = linked_list.build_into(b, lkeys, lvals)
    bkeys = RNG.choice(np.arange(10**6, 2 * 10**6), n_per, replace=False).astype(np.int32)
    bvals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    root, _ = btree.build_into(b, bkeys, bvals)
    hkeys = RNG.choice(np.arange(2 * 10**6, 3 * 10**6), n_per, replace=False).astype(np.int32)
    hvals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    heads = hash_table.build_into(b, hkeys, hvals, 256)
    skeys = RNG.choice(np.arange(3 * 10**6, 4 * 10**6), n_per, replace=False).astype(np.int32)
    svals = RNG.integers(0, 10**6, n_per).astype(np.int32)
    shead = skiplist.build_into(b, skeys, svals)
    arena = b.finish()
    structures = {
        "list": StructureSpec(linked_list.find_iterator(), (head,)),
        "btree": StructureSpec(btree.find_iterator(), (root,)),
        "hash": StructureSpec(hash_table.find_iterator(256), (jnp.asarray(heads),)),
        "skip": StructureSpec(skiplist.find_iterator(), (shead,)),
    }
    keysets = {"list": lkeys, "btree": bkeys, "hash": hkeys, "skip": skeys}
    return arena, structures, keysets


def parse_arrival(spec: str | None):
    """``--arrival=poisson:<rps>`` -> ("poisson", rps); None -> closed loop."""
    if spec is None:
        return None
    kind, _, rate = spec.partition(":")
    if kind != "poisson" or not rate:
        raise ValueError(f"unknown arrival spec {spec!r} (want poisson:<rps>)")
    rps = float(rate)
    if rps <= 0:
        raise ValueError("poisson rate must be > 0")
    return ("poisson", rps)


def _make_request_specs(keysets, n, rng, deadline_ms=2000.0):
    """Immutable request blueprints -- materialized fresh per serving run so
    sync and async modes see byte-identical workloads."""
    names = list(keysets)
    tenants = ["tenant-a", "tenant-b", "tenant-c"]
    specs = []
    for i in range(n):
        s = names[rng.integers(0, len(names))]
        ks = keysets[s]
        # 10% misses exercise the not-found path
        key = (
            int(ks[rng.integers(0, len(ks))])
            if rng.random() > 0.1
            else int(rng.integers(5 * 10**6, 6 * 10**6))
        )
        specs.append((s, key, tenants[i % len(tenants)], deadline_ms))
    return specs


def _materialize(specs):
    return [
        TraversalRequest(req_id=i, structure=s, query=k, tenant=t, deadline_ms=d)
        for i, (s, k, t, d) in enumerate(specs)
    ]


def drive_open_loop(svc, reqs, t_arr):
    """Open-loop driver: exponential inter-arrivals in *wall-clock* time,
    submitted when due regardless of service backlog (the Fig. 7
    tail-latency regime: the arrival process never waits for the server)."""
    t0 = time.perf_counter()
    nxt, n = 0, len(reqs)
    while nxt < n or svc._busy():
        now = time.perf_counter() - t0
        while nxt < n and t_arr[nxt] <= now:
            svc.submit(reqs[nxt])
            nxt += 1
        if nxt < n and not svc._busy():
            # idle server, next arrival in the future: wait for it
            time.sleep(max(0.0, t_arr[nxt] - (time.perf_counter() - t0)))
            continue
        svc.step()
    svc.close()
    svc._drain_emit()
    m = svc.metrics
    m.wall_s += time.perf_counter() - t0
    return m


def _run_mode(
    engine,
    structures,
    specs,
    t_arr,
    *,
    mode,
    slots,
    quantum,
    max_quantum,
    max_pending=None,
    rate_limit_rps=None,
    rate_limit_burst=None,
):
    """One serving run over a fixed arrival trace.  The engine is shared
    across runs (read-only workload), so its compiled executables stay warm
    and every run measures steady-state serving."""
    kw = {}
    if mode == "async":
        kw.update(
            pipeline="async",
            min_quantum=max(1, quantum // 2),
            max_quantum=max_quantum,
        )
    if max_pending is not None:
        kw["max_pending"] = max_pending
    if rate_limit_rps is not None:
        kw["rate_limit_rps"] = rate_limit_rps
        kw["rate_limit_burst"] = rate_limit_burst
    svc = PulseService(
        engine, structures, slots_per_structure=slots, quantum=quantum, **kw
    )
    reqs = _materialize(specs)
    return drive_open_loop(svc, reqs, t_arr), reqs


def _point(m, offered):
    return {
        "offered_rps": float(offered),
        "throughput_rps": float(m.throughput_rps),
        "p50_ms": float(m.p50_ms),
        "p99_ms": float(m.p99_ms),
        "p999_ms": float(m.p999_ms),
        "completed": int(m.completed),
        "shed": int(m.shed),
        "rounds": int(m.rounds),
        "deadline_hit_rate": float(m.deadline_hit_rate),
        "quantum_range": [int(m.quantum_min_used), int(m.quantum_max_used)],
    }


def bench_async_pipeline(
    offered_rps, n_requests=240, slots=32, quantum=8, max_quantum=256,
    seed=42, check=False, sweep_requests=None,
):
    """Async device-runner pipeline vs the synchronous loop, then the Fig. 7
    offered-RPS ladder.  All arrival traces derive from ``seed``."""
    arena, structures, keysets = build_mixed_heap()
    engine = PulseEngine(arena)
    arr = np.random.default_rng(seed)
    specs = _make_request_specs(keysets, n_requests, arr)
    t_arr = np.cumsum(arr.exponential(1.0 / offered_rps, n_requests))
    out = {
        "seed": int(seed),
        "offered_rps": float(offered_rps),
        "n_requests": int(n_requests),
        "quantum": int(quantum),
        "max_quantum": int(max_quantum),
    }
    # warm the per-structure compiles once; every run below reuses them
    warm_svc = PulseService(
        engine, structures, slots_per_structure=slots, quantum=quantum
    )
    warm_svc.run(
        [
            TraversalRequest(10**6 + j, s, int(keysets[s][0]))
            for j, s in enumerate(structures)
        ]
    )

    # --- matched-load comparison (offered above saturation for both) -------
    res = {}
    for mode in ("sync", "async"):
        m, _ = _run_mode(
            engine, structures, specs, t_arr,
            mode=mode, slots=slots, quantum=quantum, max_quantum=max_quantum,
        )
        assert m.completed == n_requests, (mode, m.completed)
        res[mode] = m
        out[mode] = _point(m, offered_rps)
        print(
            f"  {mode:5s}: throughput={m.throughput_rps:6.0f} rps "
            f"p50={m.p50_ms:7.1f}ms p99={m.p99_ms:7.1f}ms "
            f"p999={m.p999_ms:7.1f}ms rounds={m.rounds} "
            f"quantum=[{m.quantum_min_used},{m.quantum_max_used}]"
        )
    speedup = res["async"].throughput_rps / res["sync"].throughput_rps
    p99_ratio = res["async"].p99_ms / res["sync"].p99_ms
    out["throughput_speedup"] = float(speedup)
    out["p99_ratio"] = float(p99_ratio)
    print(
        f"  async/sync at matched {offered_rps:.0f} rps: "
        f"throughput {speedup:.2f}x, p99 {p99_ratio:.2f}x"
    )
    if check:
        assert speedup >= 1.3, (
            f"async pipeline must serve >=1.3x sync throughput, got {speedup:.2f}x"
        )
        assert p99_ratio <= 1.1, (
            f"async p99 must stay within 1.1x of sync, got {p99_ratio:.2f}x"
        )

    # --- Fig. 7 ladder: p50/p99/p999 vs offered RPS ------------------------
    # rungs are multiples of the measured sync service rate, so the sweep is
    # machine-speed-invariant; sync's saturation throughput IS its service
    # rate (open-loop overload), and the async gate is "sustain 2x that".
    sync_rate = res["sync"].throughput_rps
    n_sweep = sweep_requests or max(60, n_requests // 2)
    # one workload spec set for every rung -- only the arrival rate varies,
    # so the rungs trace a load-latency curve, not workload noise
    sweep_rng = np.random.default_rng([seed, 1])
    sweep_specs = _make_request_specs(keysets, n_sweep, sweep_rng)
    rungs = []
    for ri, mult in enumerate((0.5, 1.0, 2.0, 3.0)):
        rate = mult * sync_rate
        rung_t = np.cumsum(
            np.random.default_rng([seed, 2, ri]).exponential(1.0 / rate, n_sweep)
        )
        modes = ("sync", "async") if mult <= 1.0 else ("async",)
        for mode in modes:
            m, _ = _run_mode(
                engine, structures, sweep_specs, rung_t,
                mode=mode, slots=slots, quantum=quantum,
                max_quantum=max_quantum,
            )
            pt = _point(m, rate)
            pt.update(mode=mode, multiple_of_sync_rate=mult)
            pt["sustained"] = bool(m.throughput_rps >= 0.8 * rate)
            rungs.append(pt)
            print(
                f"  fig7 {mode:5s} @ {mult:3.1f}x sync ({rate:5.0f} rps): "
                f"tput={m.throughput_rps:5.0f} p50={m.p50_ms:7.1f}ms "
                f"p99={m.p99_ms:7.1f}ms p999={m.p999_ms:7.1f}ms "
                f"{'sustained' if pt['sustained'] else 'SATURATED'}"
            )
    out["fig7"] = rungs
    async_sat = max(
        (r["offered_rps"] for r in rungs if r["mode"] == "async" and r["sustained"]),
        default=0.0,
    )
    out["sync_saturation_rps"] = float(sync_rate)
    out["async_saturation_rps"] = float(async_sat)
    print(
        f"  saturation: sync={sync_rate:.0f} rps async>={async_sat:.0f} rps "
        f"({async_sat / sync_rate:.1f}x)"
    )
    if check:
        assert async_sat >= 2.0 * sync_rate, (
            f"async must sustain >=2x sync saturation "
            f"({async_sat:.0f} vs {sync_rate:.0f} rps)"
        )

    # --- overload rung: rate limiting + bounded-queue shedding -------------
    over_rate = 6.0 * sync_rate
    over_t = np.cumsum(
        np.random.default_rng([seed, 99]).exponential(1.0 / over_rate, n_sweep)
    )
    max_pending = 2 * slots
    # per-tenant bucket well under each tenant's offered share (over_rate/3),
    # with a small burst so the bucket actually empties within the run
    m, reqs = _run_mode(
        engine, structures, sweep_specs, over_t,
        mode="async", slots=slots, quantum=quantum, max_quantum=max_quantum,
        max_pending=max_pending,
        rate_limit_rps=max(1.0, sync_rate / 2), rate_limit_burst=4,
    )
    assert m.completed + m.shed == n_sweep, (m.completed, m.shed)
    assert m.queue_depth_max <= max_pending, m.queue_depth_max
    out["overload"] = {
        **_point(m, over_rate),
        "max_pending": max_pending,
        "queue_depth_max": int(m.queue_depth_max),
        "shed_frac": float(m.shed / n_sweep),
    }
    print(
        f"  overload @ {over_rate:.0f} rps: completed={m.completed} "
        f"shed={m.shed} ({m.shed / n_sweep:.0%}) "
        f"queue_max={m.queue_depth_max}/{max_pending} "
        f"p99={m.p99_ms:.1f}ms deadline_hit={m.deadline_hit_rate:.0%}"
    )
    if check:
        assert m.shed > 0, "overload rung must shed load"
    return out


def bench_service(n_requests=600, slots=64, quantum=16, arrival=None, seed=42):
    arena, structures, keysets = build_mixed_heap()
    engine = PulseEngine(arena)
    svc = PulseService(
        engine, structures, slots_per_structure=slots, quantum=quantum
    )

    names = list(structures)
    tenants = ["tenant-a", "tenant-b", "tenant-c"]
    reqs = []
    for i in range(n_requests):
        s = names[RNG.integers(0, len(names))]
        ks = keysets[s]
        # 10% misses exercise the not-found path
        key = (
            int(ks[RNG.integers(0, len(ks))])
            if RNG.random() > 0.1
            else int(RNG.integers(5 * 10**6, 6 * 10**6))
        )
        reqs.append(
            TraversalRequest(
                req_id=i,
                structure=s,
                query=key,
                tenant=tenants[i % len(tenants)],
                deadline_ms=2000.0 if i % 3 == 0 else None,
                arrive_round=i // (2 * slots),  # closed-loop trickle default
            )
        )

    # warm the per-group compile so latency numbers reflect steady state
    warm = [
        TraversalRequest(10**6 + j, s, int(keysets[s][0]))
        for j, s in enumerate(names)
    ]
    svc.run(warm)
    svc.metrics = type(svc.metrics)()  # reset accounting after warmup

    arrival_info = {"process": "closed-loop", "rounds_per_wave": 1}
    if arrival is None:
        m = svc.run(reqs)
    else:
        # open-loop Poisson: exponential inter-arrivals in *wall-clock* time,
        # submitted when due regardless of service backlog (the Fig. 7
        # tail-latency regime: the arrival process never waits for the server)
        # arrival generation is seeded independently of the workload RNG so
        # overload runs replay bit-identically under the same --seed
        _, rps = arrival
        arr_rng = np.random.default_rng(seed)
        gaps = arr_rng.exponential(1.0 / rps, n_requests)
        t_arr = np.cumsum(gaps)
        for r in reqs:
            r.arrive_round = 0
        m = drive_open_loop(svc, reqs, t_arr)
        arrival_info = {
            "process": "poisson",
            "seed": int(seed),
            "offered_rps": rps,
            "achieved_arrival_rps": float(n_requests / t_arr[-1]),
            "interarrival_mean_ms": float(np.mean(gaps) * 1e3),
            "interarrival_p99_ms": float(np.percentile(gaps, 99) * 1e3),
            "arrival_span_s": float(t_arr[-1]),
        }
    print(f"  {m.summary()}")
    if arrival is not None:
        print(
            f"  open-loop poisson: offered={arrival_info['offered_rps']:.0f} rps "
            f"achieved={arrival_info['achieved_arrival_rps']:.0f} rps "
            f"span={arrival_info['arrival_span_s']:.2f}s"
        )
    for t, d in sorted(m.per_tenant.items()):
        lat = np.asarray(d["latencies_ms"])
        print(
            f"    {t}: completed={d['completed']} "
            f"p50={np.percentile(lat, 50):.1f}ms p99={np.percentile(lat, 99):.1f}ms"
        )
    if m.deadlines_met + m.deadlines_missed:
        print(f"    deadline hit rate: {m.deadline_hit_rate:.0%}")
    assert m.completed == n_requests
    # NOTE: no wire_words here -- this experiment serves through a single-node
    # engine (no mesh), so the distributed wire accounting is structurally 0;
    # the JSON's wire trajectory comes from the compacted-routing experiment.
    return {
        "completed": m.completed,
        "p50_ms": m.p50_ms,
        "p99_ms": m.p99_ms,
        "throughput_rps": m.throughput_rps,
        "utilization": m.utilization,
        "arrival": arrival_info,
    }


def bench_batched_prefill(n_requests=12, prompt_len=8, max_new=6):
    """Admission throughput: batched prefill vs token-by-token slot prefill.

    The legacy path runs one full-batch decode_step per prompt token per
    admitted request; the batched path absorbs a whole admission's prompts
    in one jitted prefill call per distinct prompt length.
    """
    import jax

    from repro.configs import get_reduced_config
    from repro.models.model_zoo import build_model
    from repro.serving.batching import ContinuousBatcher, Request

    cfg = get_reduced_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [
        RNG.integers(2, cfg.vocab, prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]

    results = {}
    outputs = {}
    for mode in ("token", "batched"):
        reqs = [
            Request(req_id=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)
        ]
        b = ContinuousBatcher(model, max_batch=4, max_len=32, prefill_mode=mode)
        b.model_params = params
        b.serve(list(reqs))  # warm the compiles
        for r in reqs:
            r.output, r.finished_step = [], -1
        t0 = time.perf_counter()
        m = b.serve(list(reqs))
        wall = time.perf_counter() - t0
        outputs[mode] = [list(r.output) for r in reqs]
        results[mode] = {
            "wall_s": wall,
            "tokens_per_s": m.tokens_out / wall,
            "prefill_calls": m.prefill_calls,
            "prompt_tokens": int(sum(len(p) for p in prompts)),
        }
        print(
            f"  {mode:8s}: wall={wall*1e3:7.1f}ms "
            f"decode_tokens/s={m.tokens_out / wall:7.0f} "
            f"prefill_calls={m.prefill_calls}"
        )
    assert outputs["token"] == outputs["batched"], (
        "batched prefill must produce identical decodes"
    )
    speedup = results["token"]["wall_s"] / results["batched"]["wall_s"]
    results["prefill_speedup"] = speedup
    print(f"  batched-prefill admission speedup: {speedup:.2f}x (identical outputs)")
    return results


def _drive_rounds(svc, reqs, max_rounds=10_000):
    """Closed-loop driver that records (completed, recoveries) after every
    scheduling round -- the per-round completion trajectory the chaos gates
    are computed from."""
    for r in reqs:
        svc.submit(r)
    hist = []
    while svc._busy():
        if len(hist) >= max_rounds:
            raise RuntimeError(f"service did not drain in {max_rounds} rounds")
        svc.step()
        m = svc.metrics
        hist.append((int(m.completed), int(m.recoveries)))
    svc.close()
    svc._drain_emit()
    hist.append((int(svc.metrics.completed), int(svc.metrics.recoveries)))
    return hist


def bench_chaos(
    n_requests=360, n_keys=256, slots=8, quantum=6, wave=8,
    kill_call=60, kill_shard=3, recovery_window=12, seed=42, check=False,
):
    """Kill-one-shard-mid-stream under the full fault-tolerant serving stack.

    An 8-shard meshed engine serves a mixed read/write stream (every 4th
    request an insert) twice from identical pre-states: a failure-free
    reference, then a run where ``kill_shard`` dies at engine call
    ``kill_call``.  Gates (``--check``):

      * exactly one recovery; degraded-mode retries observed;
      * zero acknowledged commits lost -- the recovered run's final arena
        (data + heap) and every request's (status, result) are bit-identical
        to the failure-free reference;
      * throughput recovers: mean completions/round over the
        ``recovery_window`` rounds after service resumes >= 90% of the
        pre-fault rate, and service resumes within a bounded number of
        rounds of the fault.
    """
    import tempfile

    from repro.core.faults import FaultInjector, FaultPlan
    from repro.distributed.arena_ft import ArenaStore, FaultToleranceConfig

    rng = np.random.default_rng(seed)
    keys = np.arange(100, 100 + n_keys, dtype=np.int32)
    # one blueprint, materialized fresh per run: the reference and chaos
    # runs must see byte-identical workloads (requests mutate in place)
    read_keys = [int(keys[int(rng.integers(0, n_keys))]) for _ in range(n_requests)]

    def serve(tmp, plan):
        b = ArenaBuilder(4 * n_keys, 4, num_shards=P, policy="interleaved")
        head = linked_list.build_into(b, keys, keys * 2)
        inj = FaultInjector(plan) if plan is not None else None
        eng = PulseEngine(
            b.finish(), mesh=jax.make_mesh((P,), ("mem",)), fault_injector=inj
        )
        ft = FaultToleranceConfig(store=ArenaStore(tmp))
        svc = PulseService(
            eng,
            {
                "list": StructureSpec(
                    linked_list.find_iterator(), (head,), group="list"
                ),
                "list_ins": StructureSpec(
                    linked_list.insert_iterator(), (head,), group="list",
                    takes_value=True,
                ),
            },
            slots_per_structure=slots,
            quantum=quantum,
            pipeline="async",
            fault_tolerance=ft,
        )
        reqs = []
        for i in range(n_requests):
            if i % 4 == 2:
                reqs.append(
                    TraversalRequest(
                        i, "list_ins", 10_000 + i, value=i * 13,
                        tenant="writer", arrive_round=i // wave,
                    )
                )
            else:
                reqs.append(
                    TraversalRequest(
                        i, "list", read_keys[i],
                        tenant="reader", arrive_round=i // wave,
                    )
                )
        hist = _drive_rounds(svc, reqs)
        ft.store.close()
        return reqs, svc.metrics, eng.arena, hist

    plan = FaultPlan(
        kill_shard=kill_shard, kill_call=kill_call, kill_superstep=2
    )
    with tempfile.TemporaryDirectory() as d0, tempfile.TemporaryDirectory() as d1:
        t0 = time.perf_counter()
        r_ref, m_ref, ar_ref, hist_ref = serve(d0, None)
        t_ref = time.perf_counter() - t0
        t0 = time.perf_counter()
        r_kill, m_kill, ar_kill, hist_kill = serve(d1, plan)
        t_kill = time.perf_counter() - t0

    assert m_ref.recoveries == 0 and m_ref.retries == 0
    assert m_kill.completed == m_ref.completed == n_requests

    # zero acknowledged commits lost: bit-identical arena + results
    arena_identical = bool(
        np.array_equal(np.asarray(ar_ref.data), np.asarray(ar_kill.data))
        and np.array_equal(np.asarray(ar_ref.heap), np.asarray(ar_kill.heap))
    )
    results_identical = all(
        a.status == b.status and np.array_equal(a.result, b.result)
        for a, b in zip(r_ref, r_kill)
    )

    # per-round completion deltas; the fault round is where recoveries flips
    done = np.asarray([c for c, _ in hist_kill])
    rec = np.asarray([v for _, v in hist_kill])
    delta = np.diff(np.concatenate([[0], done]))
    fault_round = int(np.argmax(rec > 0)) if (rec > 0).any() else -1
    pre_rate = float(delta[:fault_round].mean()) if fault_round > 0 else 0.0
    # completion granularity: a request retires only after ~depth/quantum
    # quanta, so both the resume bound and the measurement window must cover
    # at least one full request lifetime plus backoff slack
    depth_quanta = -(-n_keys // quantum)
    lag_bound = depth_quanta + 8
    win = max(recovery_window, lag_bound)
    # service resumes at the first post-fault round that retires anything
    # (the failed group sits out its backoff, then in-flight re-execution
    # must finish a request's remaining quanta)
    post = np.nonzero(delta[fault_round + 1:])[0]
    resume_round = fault_round + 1 + int(post[0]) if len(post) else -1
    window = delta[resume_round: resume_round + win]
    post_rate = float(window.mean()) if len(window) else 0.0
    ratio = post_rate / pre_rate if pre_rate > 0 else 0.0
    resume_lag = resume_round - fault_round if resume_round >= 0 else -1

    print(
        f"  reference : rounds={m_ref.rounds} commits={m_ref.commits} "
        f"wall={t_ref:.1f}s"
    )
    print(
        f"  chaos     : rounds={m_kill.rounds} commits={m_kill.commits} "
        f"recoveries={m_kill.recoveries} replayed={m_kill.replayed_commits} "
        f"retries={m_kill.retries} mean_recovery={m_kill.mean_recovery_ms:.0f}ms "
        f"wall={t_kill:.1f}s"
    )
    print(
        f"  fault@round {fault_round}, resumed +{resume_lag} rounds: "
        f"pre-fault {pre_rate:.2f} req/round -> "
        f"post-recovery {post_rate:.2f} req/round ({ratio:.0%})"
    )
    print(
        f"  acked-commit safety: arena {'identical' if arena_identical else 'DIVERGED'}, "
        f"results {'identical' if results_identical else 'DIVERGED'}"
    )
    if check:
        assert m_kill.recoveries == 1, m_kill.recoveries
        assert m_kill.retries > 0, "degraded mode must re-queue hit requests"
        assert arena_identical, "recovery lost acknowledged commits (arena)"
        assert results_identical, "recovery changed request results"
        assert 0 <= resume_lag <= lag_bound, (
            f"service must resume within {lag_bound} rounds of the fault "
            f"(one request lifetime + backoff), took {resume_lag}"
        )
        assert ratio >= 0.9, (
            f"post-recovery throughput must reach >=90% of pre-fault "
            f"within {win} rounds, got {ratio:.0%}"
        )
    return {
        "n_requests": int(n_requests),
        "kill_shard": int(kill_shard),
        "kill_call": int(kill_call),
        "recoveries": int(m_kill.recoveries),
        "replayed_commits": int(m_kill.replayed_commits),
        "retries": int(m_kill.retries),
        "retry_exhausted": int(m_kill.retry_exhausted),
        "mean_recovery_ms": float(m_kill.mean_recovery_ms),
        "fault_round": fault_round,
        "resume_lag_rounds": int(resume_lag),
        "pre_fault_rate": pre_rate,
        "post_recovery_rate": post_rate,
        "recovery_ratio": float(ratio),
        "recovery_window_rounds": int(win),
        "resume_lag_bound_rounds": int(lag_bound),
        "zero_acked_commits_lost": bool(arena_identical and results_identical),
        "reference_rounds": int(m_ref.rounds),
        "chaos_rounds": int(m_kill.rounds),
        "reference_wall_s": float(t_ref),
        "chaos_wall_s": float(t_kill),
    }


def bench_reshard(
    n_requests=400, n_keys=256, slots=8, quantum=6, wave=8,
    reshard_round=6, recovery_window=12, seed=42, check=False,
):
    """Live 2x reshard (4 -> 8 shards) mid-stream under load.

    A 4-shard meshed engine serves a mixed BST find/update stream (updates
    are alloc-free, so committed state is partition-independent); at
    scheduling round ``reshard_round`` the service is asked to double its
    shard count online (drain in-flight quanta -> remap -> new mesh ->
    resume).  A cold run serves the same stream at 8 shards from the start,
    seeded from the offline ``remap_shards`` of the identical 4-shard build
    -- the partition the live path must converge to.  Gates (``--check``):

      * exactly one reshard; the drain is bounded;
      * bit-identical to the cold 8-shard run: every request's (status,
        result), the arena payload (data/bounds/perms), the allocator
        registers (free head + bump frontier), and the commit count;
      * throughput recovers: mean completions/round over the
        ``recovery_window`` rounds after serving resumes >= 90% of the
        pre-reshard rate.
    """
    from repro.core.arena import remap_shards
    from repro.core.structures import bst

    rng = np.random.default_rng(seed)
    keys = np.arange(100, 100 + n_keys, dtype=np.int32)
    read_keys = [int(keys[int(rng.integers(0, n_keys))]) for _ in range(n_requests)]
    upd_keys = [int(keys[int(rng.integers(0, n_keys))]) for _ in range(n_requests)]

    def build4():
        b = ArenaBuilder(4 * n_keys, 4, num_shards=4, policy="interleaved")
        root, _h = bst.build_into(b, keys, keys * 2)
        return b.finish(), root

    def serve(arena, root, nshards, reshard_at=None):
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:nshards]), ("mem",))
        eng = PulseEngine(arena, mesh=mesh)
        svc = PulseService(
            eng,
            {
                "bst": StructureSpec(bst.find_iterator(), (root,), group="bst"),
                "bst_upd": StructureSpec(
                    bst.update_iterator(), (root,), group="bst", takes_value=True
                ),
            },
            slots_per_structure=slots,
            quantum=quantum,
            pipeline="async",
        )
        reqs = []
        for i in range(n_requests):
            if i % 4 == 3:
                reqs.append(
                    TraversalRequest(
                        i, "bst_upd", upd_keys[i], value=9000 + i,
                        tenant="writer", arrive_round=i // wave,
                    )
                )
            else:
                reqs.append(
                    TraversalRequest(
                        i, "bst", read_keys[i],
                        tenant="reader", arrive_round=i // wave,
                    )
                )
        for r in reqs:
            svc.submit(r)
        hist = []
        try:
            while svc._busy():
                if reshard_at is not None and svc.metrics.rounds == reshard_at:
                    svc.request_reshard(2 * nshards)
                if len(hist) >= 10_000:
                    raise RuntimeError("service did not drain in 10000 rounds")
                svc.step()
                m = svc.metrics
                hist.append((int(m.completed), int(m.reshards)))
        finally:
            svc.close()
            svc._drain_emit()
        hist.append((int(svc.metrics.completed), int(svc.metrics.reshards)))
        return reqs, svc.metrics, eng.arena, hist

    a4, root = build4()
    t0 = time.perf_counter()
    r_cold, m_cold, ar_cold, _ = serve(remap_shards(a4, 8), root, 8)
    t_cold = time.perf_counter() - t0
    a4b, root_b = build4()
    assert root_b == root
    t0 = time.perf_counter()
    r_live, m_live, ar_live, hist = serve(a4b, root, 4, reshard_at=reshard_round)
    t_live = time.perf_counter() - t0

    assert m_live.completed == m_cold.completed == n_requests

    results_identical = all(
        a.status == b.status and np.array_equal(a.result, b.result)
        for a, b in zip(r_cold, r_live)
    )
    # payload + partition tables + allocator registers (free head, bump
    # frontier); epoch/commit heap counters are commit-placement metadata
    # that legitimately differs when early quanta committed at 4 shards
    arena_identical = bool(
        np.array_equal(np.asarray(ar_cold.data), np.asarray(ar_live.data))
        and np.array_equal(np.asarray(ar_cold.bounds), np.asarray(ar_live.bounds))
        and np.array_equal(np.asarray(ar_cold.perms), np.asarray(ar_live.perms))
        and np.array_equal(
            np.asarray(ar_cold.heap)[:, :2], np.asarray(ar_live.heap)[:, :2]
        )
    )

    done = np.asarray([c for c, _ in hist])
    rs = np.asarray([v for _, v in hist])
    delta = np.diff(np.concatenate([[0], done]))
    cut_round = int(np.argmax(rs > 0)) if (rs > 0).any() else -1
    pre_rate = float(delta[:cut_round].mean()) if cut_round > 0 else 0.0
    post = np.nonzero(delta[cut_round + 1:])[0]
    resume_round = cut_round + 1 + int(post[0]) if len(post) else -1
    window = delta[resume_round: resume_round + recovery_window]
    post_rate = float(window.mean()) if len(window) else 0.0
    ratio = post_rate / pre_rate if pre_rate > 0 else 0.0
    resume_lag = resume_round - cut_round if resume_round >= 0 else -1

    print(
        f"  cold 8-shard : rounds={m_cold.rounds} commits={m_cold.commits} "
        f"wall={t_cold:.1f}s"
    )
    print(
        f"  live 4->8    : rounds={m_live.rounds} commits={m_live.commits} "
        f"reshards={m_live.reshards} drain={m_live.reshard_drain_rounds} "
        f"wall={t_live:.1f}s"
    )
    print(
        f"  cutover@round {cut_round}, resumed +{resume_lag} rounds: "
        f"pre-reshard {pre_rate:.2f} req/round -> "
        f"post-cutover {post_rate:.2f} req/round ({ratio:.0%})"
    )
    print(
        f"  cold-equivalence: arena {'identical' if arena_identical else 'DIVERGED'}, "
        f"results {'identical' if results_identical else 'DIVERGED'}"
    )
    if check:
        assert m_live.reshards == 1, m_live.reshards
        assert arena_identical, "live reshard diverged from the cold 8-shard run"
        assert results_identical, "live reshard changed request results"
        assert m_live.commits == m_cold.commits > 0, (
            m_live.commits, m_cold.commits,
        )
        assert ratio >= 0.9, (
            f"post-reshard throughput must reach >=90% of the pre-reshard "
            f"rate within {recovery_window} rounds, got {ratio:.0%}"
        )
    return {
        "n_requests": int(n_requests),
        "reshard_round": int(reshard_round),
        "reshards": int(m_live.reshards),
        "drain_rounds": int(m_live.reshard_drain_rounds),
        "cutover_round": int(cut_round),
        "resume_lag_rounds": int(resume_lag),
        "pre_reshard_rate": pre_rate,
        "post_cutover_rate": post_rate,
        "recovery_ratio": float(ratio),
        "recovery_window_rounds": int(recovery_window),
        "commits": int(m_live.commits),
        "bit_identical_to_cold": bool(arena_identical and results_identical),
        "cold_rounds": int(m_cold.rounds),
        "live_rounds": int(m_live.rounds),
        "cold_wall_s": float(t_cold),
        "live_wall_s": float(t_live),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_service.json",
        default=None,
        metavar="PATH",
        help="write machine-readable results (default path: BENCH_service.json)",
    )
    ap.add_argument(
        "--small",
        action="store_true",
        help="CI smoke sizes (faster, same assertions)",
    )
    ap.add_argument(
        "--arrival",
        default=None,
        metavar="SPEC",
        help="open-loop arrival process, e.g. 'poisson:500' (500 req/s "
        "offered); sets the matched-load rate for the async-vs-sync "
        "experiment and switches the mixed-workload experiment off "
        "closed-loop rounds",
    )
    ap.add_argument(
        "--seed",
        type=int,
        default=42,
        help="seed for arrival-trace and workload generation (recorded in "
        "the JSON artifact so overload runs are reproducible)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="enforce the serving gates: async >= 1.3x sync throughput with "
        "p99 <= 1.1x at matched load, async saturation >= 2x sync "
        "(--chaos: recovery + zero-acked-loss + throughput-recovery gates)",
    )
    ap.add_argument(
        "--chaos",
        action="store_true",
        help="chaos mode only: kill one shard mid-stream under the "
        "fault-tolerant serving stack and gate recovery (skips the four "
        "standard experiments; pair with --json BENCH_chaos.json)",
    )
    ap.add_argument(
        "--reshard",
        action="store_true",
        help="reshard mode only: live 4 -> 8 shard change mid-stream, gated "
        "on bit-identity to a cold 8-shard run + >=90%% throughput "
        "recovery (skips the four standard experiments; pair with "
        "--json BENCH_reshard.json)",
    )
    args = ap.parse_args(argv)
    arrival = parse_arrival(args.arrival)

    if args.chaos:
        print("[1/1] chaos: kill-one-shard-mid-stream recovery")
        rc = bench_chaos(
            seed=args.seed,
            check=args.check,
            **(
                {"n_requests": 120, "n_keys": 64, "kill_call": 24}
                if args.small
                else {}
            ),
        )
        print("\nsummary:", rc)
        if args.json:
            payload = {
                "benchmark": "service_bench_chaos",
                "config": {
                    "shards": P,
                    "small": bool(args.small),
                    "seed": int(args.seed),
                    "checked": bool(args.check),
                },
                "chaos": rc,
            }
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"wrote {args.json}")
        return

    if args.reshard:
        print("[1/1] reshard: live 4 -> 8 shard change mid-stream")
        rr = bench_reshard(
            seed=args.seed,
            check=args.check,
            **(
                {"n_requests": 120, "n_keys": 64, "reshard_round": 4}
                if args.small
                else {}
            ),
        )
        print("\nsummary:", rr)
        if args.json:
            payload = {
                "benchmark": "service_bench_reshard",
                "config": {
                    "shards": P,
                    "small": bool(args.small),
                    "seed": int(args.seed),
                    "checked": bool(args.check),
                },
                "reshard": rr,
            }
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"wrote {args.json}")
        return

    print("[1/4] compacted supersteps vs bulk-synchronous baseline")
    r1 = bench_compacted_routing(
        **({"n": 512, "B": 128} if args.small else {})
    )
    matched_rps = arrival[1] if arrival else 300.0
    print(
        f"[2/4] PulseService: async pipeline vs sync loop "
        f"(open-loop poisson:{matched_rps:.0f}, seed={args.seed})"
    )
    r2 = bench_async_pipeline(
        matched_rps,
        seed=args.seed,
        check=args.check,
        **(
            {"n_requests": 120, "sweep_requests": 60, "max_quantum": 128}
            if args.small
            else {}
        ),
    )
    print(
        "[3/4] PulseService: mixed 4-structure workload"
        + (f" (open-loop {args.arrival})" if arrival else "")
    )
    r3 = bench_service(
        arrival=arrival,
        seed=args.seed,
        **({"n_requests": 150, "slots": 32} if args.small else {}),
    )
    print("[4/4] LM admission: batched prefill vs token-by-token")
    r4 = bench_batched_prefill(
        **({"n_requests": 8, "prompt_len": 6, "max_new": 4} if args.small else {})
    )
    summary = {
        **r1,
        **r3,
        "async_speedup": r2["throughput_speedup"],
        "async_p99_ratio": r2["p99_ratio"],
        "sync_saturation_rps": r2["sync_saturation_rps"],
        "async_saturation_rps": r2["async_saturation_rps"],
        "prefill_speedup": r4["prefill_speedup"],
    }
    print("\nsummary:", summary)
    if args.json:
        payload = {
            "benchmark": "service_bench",
            "config": {
                "shards": P,
                "small": bool(args.small),
                "seed": int(args.seed),
                "checked": bool(args.check),
            },
            "compacted_routing": r1,
            "async_pipeline": r2,
            "service": r3,
            "batched_prefill": r4,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
