"""Workload profiles: REAL engine measurements for the paper's applications.

Builds the three S6 applications' data structures at benchmark scale, runs
real traversals through the PULSE engine / iterator executor, and extracts:
iterations per request, node-boundary crossings (per node count), CPU-cache
hit rates (LRU sim), and the dispatch model's t_c/t_d.  These feed the
Fig. 7/8/9/11 latency/energy models in hw_model.py.
"""

from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from repro.core import dispatch as dispatch_mod
from repro.core import translation
from repro.core.engine import cpu_node_execute
from repro.core.iterator import execute_batched
from repro.core.structures import btree, hash_table
from benchmarks.hw_model import WorkloadProfile

RNG = np.random.default_rng(0)


def zipf_keys(keys: np.ndarray, n: int, s: float = 0.99) -> np.ndarray:
    ranks = np.arange(1, len(keys) + 1, dtype=np.float64)
    p = ranks ** (-s)
    p /= p.sum()
    return RNG.choice(keys, size=n, p=p)


def _crossings(arena, visit_fn, queries_ptr0_scr0, node_counts):
    """Mean owner-boundary crossings per request for several node counts.

    Host-walks each traversal recording the pointer path (the engine's
    cpu_node path gives identical semantics), then counts owner changes
    under a range partition into ``n`` nodes.
    """
    it, arena_obj, ptr0, scr0 = queries_ptr0_scr0
    paths = visit_fn(it, arena_obj, ptr0, scr0)
    out = {}
    cap = arena_obj.capacity
    for n in node_counts:
        bounds = np.linspace(0, cap, n + 1).astype(np.int64)
        total = 0
        for path in paths:
            owners = np.searchsorted(bounds, np.asarray(path), side="right") - 1
            total += int((np.diff(owners) != 0).sum())
        out[n] = total / max(len(paths), 1)
    return out


def _trace_paths(it, arena, ptr0, scr0, max_iters=4096):
    """Pointer path per request (host walk, numpy)."""
    import jax

    data = np.asarray(arena.data)
    B = ptr0.shape[0]
    ptr = np.asarray(ptr0, np.int64).copy()
    scratch = np.asarray(scr0, np.int32).copy()
    done = np.zeros(B, bool)
    paths = [[] for _ in range(B)]

    def fused(node, p, s):
        if it.step_fn is not None:
            return it.step_fn(node, p, s)
        d, ss = it.end_fn(node, p, s)
        np_, ns = it.next_fn(node, p, ss)
        return d, jnp.where(d, p, np_), jnp.where(d, ss, ns)

    step = jax.jit(jax.vmap(fused))
    for _ in range(max_iters):
        live = ~done & (ptr >= 0)
        if not live.any():
            break
        for b in np.nonzero(live)[0]:
            paths[b].append(int(ptr[b]))
        node = data[np.clip(ptr, 0, data.shape[0] - 1)]
        d, np_, ns = step(jnp.asarray(node), jnp.asarray(ptr, jnp.int32), jnp.asarray(scratch))
        d, np_, ns = np.asarray(d), np.asarray(np_), np.asarray(ns)
        scratch[live] = ns[live]
        newly = live & (d | (np_ < 0))
        ptr[live & ~newly] = np_[live & ~newly]
        done |= newly
    return paths


def _hit_rates(it, arena, ptr0, scr0, fracs, working_set_nodes):
    out = {}
    for f in fracs:
        cache_nodes = int(working_set_nodes * f)
        _, _, _, trace = cpu_node_execute(
            it, arena, ptr0, scr0, cache_nodes=cache_nodes
        )
        out[f] = trace.cache_hits / max(trace.total_fetches, 1)
    return out


@functools.lru_cache(maxsize=None)
def webservice_profile(n_keys=50_000, n_buckets=1024, n_queries=512) -> WorkloadProfile:
    """Hash-table lookups, YCSB-C style zipfian reads (paper: ~48 iters)."""
    keys = RNG.choice(np.arange(10**7), size=n_keys, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, n_keys).astype(np.int32)
    ar, heads = hash_table.build(keys, values, n_buckets)
    it = hash_table.find_iterator(n_buckets)
    q = zipf_keys(keys, n_queries)
    ptr0, scr0 = it.init(jnp.asarray(q), jnp.asarray(heads))
    _, _, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=4096)
    d = dispatch_mod.offload_decision(it, hash_table.NODE_WORDS)
    paths = _trace_paths(it, ar, ptr0, scr0)
    cross = _crossings(ar, lambda *a: paths, (it, ar, ptr0, scr0), (1, 2, 3, 4))
    hits = _hit_rates(it, ar, ptr0, scr0, (0.0625, 0.25, 1.0), n_keys)
    return WorkloadProfile(
        name="webservice",
        iters_mean=float(np.asarray(iters).mean()),
        node_bytes=hash_table.NODE_WORDS * 4,
        response_bytes=8192,  # 8 KB objects (S6)
        crossings_mean=cross,
        cache_hit_rate=hits,
        t_c_ns=d.t_c_ns,
        t_d_ns=d.t_d_ns,
    )


@functools.lru_cache(maxsize=None)
def wiredtiger_profile(n_keys=200_000, n_queries=512) -> WorkloadProfile:
    """B+tree point lookups (YCSB-E-ish on 8 B keys)."""
    keys = RNG.choice(np.arange(10**7), size=n_keys, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, n_keys).astype(np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    q = zipf_keys(np.sort(keys), n_queries)
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    _, _, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=64)
    d = dispatch_mod.offload_decision(it, btree.NODE_WORDS)
    paths = _trace_paths(it, ar, ptr0, scr0)
    cross = _crossings(ar, lambda *a: paths, (it, ar, ptr0, scr0), (1, 2, 3, 4))
    hits = _hit_rates(it, ar, ptr0, scr0, (0.0625, 0.25, 1.0), n_keys // btree.FANOUT)
    return WorkloadProfile(
        name="wiredtiger",
        iters_mean=float(np.asarray(iters).mean()),
        node_bytes=btree.NODE_WORDS * 4,
        response_bytes=248,  # 8 B key + 240 B value
        crossings_mean=cross,
        cache_hit_rate=hits,
        t_c_ns=d.t_c_ns,
        t_d_ns=d.t_d_ns,
    )


@functools.lru_cache(maxsize=None)
def btrdb_profile(n_keys=200_000, n_queries=128, window=1024) -> WorkloadProfile:
    """Time-series range aggregation over chronologically ordered keys."""
    keys = np.arange(n_keys, dtype=np.int32)  # time-ordered
    values = RNG.integers(0, 1000, n_keys).astype(np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.range_aggregate_iterator()
    lo = RNG.integers(0, n_keys - window, n_queries).astype(np.int32)
    hi = (lo + window).astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(lo), jnp.asarray(hi), root)
    _, _, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=8192)
    d = dispatch_mod.offload_decision(it, btree.NODE_WORDS)
    paths = _trace_paths(it, ar, ptr0, scr0, max_iters=8192)
    cross = _crossings(ar, lambda *a: paths, (it, ar, ptr0, scr0), (1, 2, 3, 4))
    hits = _hit_rates(it, ar, ptr0, scr0, (0.0625, 0.25, 1.0), n_keys // btree.FANOUT)
    return WorkloadProfile(
        name="btrdb",
        iters_mean=float(np.asarray(iters).mean()),
        node_bytes=btree.NODE_WORDS * 4,
        response_bytes=32,
        crossings_mean=cross,
        cache_hit_rate=hits,
        t_c_ns=d.t_c_ns,
        t_d_ns=d.t_d_ns,
    )


ALL_PROFILES = {
    "webservice": webservice_profile,
    "wiredtiger": wiredtiger_profile,
    "btrdb": btrdb_profile,
}
