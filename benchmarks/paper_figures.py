"""One benchmark function per paper table/figure (assignment d).

Engine-side quantities (iterations, crossings, cache hit rates, t_c/t_d,
pipeline schedules) are REAL measurements; hardware quantities (FPGA
latency, power) come from the calibrated models in hw_model.py and are
labeled ``modeled``.  Each function returns CSV-ish rows.
"""

from __future__ import annotations

import numpy as np

from repro.configs import pulse_paper
from repro.core.dispatch import AcceleratorSpec
from repro.core.scheduler import area_coupled, area_pulse, simulate, PowerModel
from benchmarks import hw_model as hw
from benchmarks.profiles import ALL_PROFILES

ACCEL = AcceleratorSpec()


def table3_workloads():
    """Table 3: t_c/t_d ratio + iterations per application."""
    rows = []
    for name, make in ALL_PROFILES.items():
        p = make()
        exp = pulse_paper.WORKLOADS[name]
        rows.append(
            dict(
                name=f"table3/{name}",
                tc_td=round(p.t_c_ns / p.t_d_ns, 3),
                paper_tc_td=exp.expected_tc_td,
                iters=round(p.iters_mean, 1),
                paper_iters=str(exp.expected_iters),
                offloaded=p.t_c_ns <= 0.75 * p.t_d_ns,
            )
        )
    return rows


def fig7_latency_throughput():
    """Fig. 7: latency + throughput per system x app x node count."""
    rows = []
    for name, make in ALL_PROFILES.items():
        p = make()
        for nodes in (1, 2, 4):
            lat = {
                "pulse": hw.pulse_latency_ns(p, ACCEL, nodes),
                "rpc": hw.rpc_latency_ns(p, ACCEL, nodes),
                "rpc_arm": hw.rpc_latency_ns(p, ACCEL, nodes, clock_ratio=hw.ARM_CLOCK_RATIO, handling_ns=hw.ARM_HANDLING_NS),
                "cache": hw.cache_latency_ns(p, 0.0625),
            }
            thr_pulse, _ = hw.pulse_throughput_mops(p, num_nodes=nodes)
            thr = {
                "pulse": thr_pulse,
                "rpc": hw.rpc_throughput_mops(p, nodes),
                "rpc_arm": hw.rpc_throughput_mops(
                    p, nodes, cores=hw.ARM_CORES_PER_NODE,
                    clock_ratio=hw.ARM_CLOCK_RATIO, handling_ns=hw.ARM_HANDLING_NS,
                ),
                "cache": hw.cache_throughput_mops(p, 0.0625),
            }
            for sys_ in ("pulse", "rpc", "rpc_arm", "cache"):
                rows.append(
                    dict(
                        name=f"fig7/{name}/{sys_}/n{nodes}",
                        latency_us=round(lat[sys_] / 1e3, 2),
                        throughput_mops=round(thr[sys_], 4),
                    )
                )
            rows.append(
                dict(
                    name=f"fig7/{name}/speedup_vs_cache/n{nodes}",
                    latency_x=round(lat["cache"] / lat["pulse"], 1),
                    throughput_x=round(thr["pulse"] / max(thr["cache"], 1e-9), 1),
                    paper_range="9-34x lat, 28-171x thr",
                )
            )
    return rows


def fig8_energy():
    """Fig. 8: energy per op (modeled power / measured-profile throughput)."""
    rows = []
    for name, make in ALL_PROFILES.items():
        p = make()
        e = {s: hw.energy_per_op_uj(p, s) for s in ("pulse", "pulse_asic", "rpc", "rpc_arm")}
        rows.append(
            dict(
                name=f"fig8/{name}",
                pulse_uj=round(e["pulse"], 3),
                pulse_asic_uj=round(e["pulse_asic"], 3),
                rpc_uj=round(e["rpc"], 3),
                rpc_arm_uj=round(e["rpc_arm"], 3),
                rpc_over_pulse=round(e["rpc"] / e["pulse"], 2),
                paper="4.5-5x",
            )
        )
    return rows


def fig9_pulse_acc():
    """Fig. 9: in-network routing vs return-to-CPU, from REAL crossing
    counts (the distributed-routing subprocess test validates the identical
    results + ~2x crossings; here the latency impact)."""
    rows = []
    for name, make in ALL_PROFILES.items():
        p = make()
        for nodes in (2, 4):
            a = hw.pulse_latency_ns(p, ACCEL, nodes)
            b = hw.pulse_acc_latency_ns(p, ACCEL, nodes)
            rows.append(
                dict(
                    name=f"fig9/{name}/n{nodes}",
                    pulse_us=round(a / 1e3, 2),
                    pulse_acc_us=round(b / 1e3, 2),
                    acc_over_pulse=round(b / a, 3),
                    paper="1.02-1.15x",
                    crossings=round(p.crossings_mean.get(nodes, 0.0), 2),
                )
            )
    return rows


def fig10_breakdown():
    """Fig. 10: accelerator latency components (prototype constants)."""
    comps = dict(
        network_stack_ns=ACCEL.network_ns, scheduler_ns=ACCEL.scheduler_ns,
        tcam_ns=22.0, memory_controller_ns=110.0,
        interconnect_ns=ACCEL.interconnect_ns, logic_ns=ACCEL.logic_ns,
    )
    return [dict(name="fig10/breakdown", **comps)]


def table4_pipelines():
    """Table 4: coupled vs disaggregated area/throughput/latency across
    (m, n).  Throughput/latency from the event-driven pipeline simulator on
    the WebService profile; area from the documented FPGA fits."""
    p = ALL_PROFILES["webservice"]()
    rows = []
    base_thr = None
    net = ACCEL.network_ns * 2 + hw.WIRE_RTT_NS
    for cores in (1, 2, 3, 4):
        ss = hw.coupled_steady_state(p, cores)
        lut, bram = area_coupled(cores)
        if cores == 1:
            base_thr = ss.throughput_mops
        lat = net + p.iters_mean * (p.t_d_ns + p.t_c_ns)
        rows.append(
            dict(name=f"table4/coupled/{cores}x{cores}", lut_pct=round(lut, 2),
                 bram_pct=round(bram, 2), thr_mops=round(ss.throughput_mops, 3),
                 vs_1x1=f"{(ss.throughput_mops / base_thr - 1) * 100:+.0f}%",
                 lat_us=round(lat / 1e3, 2), bound=ss.bound)
        )
    base_thr_d = None
    for m in (1, 2, 3, 4):
        for n in (1, 2, 3, 4):
            ss = hw.pulse_steady_state(p, m, n)
            lut, bram = area_pulse(m, n)
            if m == 1 and n == 1:
                base_thr_d = ss.throughput_mops
            lat = net + p.iters_mean * (
                p.t_d_ns + p.t_c_ns + ACCEL.scheduler_ns + ACCEL.interconnect_ns
            )
            rows.append(
                dict(name=f"table4/pulse/{m}x{n}", lut_pct=round(lut, 2),
                     bram_pct=round(bram, 2), thr_mops=round(ss.throughput_mops, 3),
                     vs_1x1=f"{(ss.throughput_mops / base_thr_d - 1) * 100:+.0f}%",
                     lat_us=round(lat / 1e3, 2), bound=ss.bound)
            )
    # the paper's headline: PULSE 1x4 ~ coupled 4x4 throughput at ~40% less area
    c44 = next(r for r in rows if r["name"] == "table4/coupled/4x4")
    p14 = next(r for r in rows if r["name"] == "table4/pulse/1x4")
    rows.append(
        dict(
            name="table4/headline",
            pulse_1x4_vs_coupled_4x4_thr=round(p14["thr_mops"] / c44["thr_mops"], 3),
            area_saving_pct=round((1 - p14["lut_pct"] / c44["lut_pct"]) * 100, 1),
            paper="~equal thr, 38% area saving",
        )
    )
    return rows


def fig11_eta():
    """Fig. 11: performance-per-watt vs eta (m=1, n varies)."""
    p = ALL_PROFILES["webservice"]()
    pm = PowerModel()
    rows = []
    base = None
    for n in (1, 2, 4, 8, 16):
        eta = 1.0 / n
        ss = hw.pulse_steady_state(p, 1, n)
        watts = pm.pulse_power_w(1, n, ss.logic_util, ss.mem_util)
        ppw = ss.throughput_mops / watts
        if n == 1:
            base = ppw
        rows.append(
            dict(name=f"fig11/eta_{eta:.4f}", n_mem_pipes=n,
                 thr_mops=round(ss.throughput_mops, 3),
                 watts=round(watts, 2), perf_per_watt_norm=round(ppw / base, 3),
                 logic_util=round(ss.logic_util, 3), mem_util=round(ss.mem_util, 3),
                 workload_tc_td=round(p.t_c_ns / p.t_d_ns, 3))
        )
    return rows


def fig5_allocation():
    """Appendix Fig. 5: partitioned vs uniform (interleaved) allocation --
    REAL crossing counts on two memory nodes, modeled latency ratio."""
    import jax.numpy as jnp
    from repro.core.structures import btree as bt
    from benchmarks.profiles import RNG, _trace_paths, _crossings

    n = 20_000
    keys = np.sort(RNG.choice(np.arange(10**6), size=n, replace=False).astype(np.int32))
    values = RNG.integers(0, 1000, n).astype(np.int32)
    rows = []
    lat = {}
    for policy in ("sequential", "interleaved"):
        ar, root, _ = bt.build(keys, values, num_shards=2, policy=policy)
        it = bt.find_iterator()
        q = RNG.choice(keys, 256)
        ptr0, scr0 = it.init(jnp.asarray(q), root)
        paths = _trace_paths(it, ar, ptr0, scr0)
        cross = _crossings(ar, lambda *a: paths, (it, ar, ptr0, scr0), (2,))[2]
        iters = np.mean([len(pp) for pp in paths])
        p = ALL_PROFILES["wiredtiger"]()
        lat[policy] = hw.pulse_latency_ns(
            type(p)(**{**p.__dict__, "iters_mean": iters, "crossings_mean": {2: cross}}),
            ACCEL, 2,
        )
        rows.append(
            dict(name=f"fig5/{policy}", crossings=round(cross, 2),
                 latency_us=round(lat[policy] / 1e3, 2))
        )
    rows.append(
        dict(name="fig5/ratio", interleaved_over_partitioned=round(
            lat["interleaved"] / lat["sequential"], 2), paper="3.7-10.8x")
    )
    return rows


def appendix_traversal_length():
    """Appendix: latency scales linearly with traversal length -- REAL
    engine wall time (CPU JAX) + modeled accelerator latency."""
    import time
    import jax
    import jax.numpy as jnp
    from repro.core.structures import linked_list as ll
    from repro.core.iterator import execute_batched

    rows = []
    for n in (64, 256, 1024, 4096):
        keys = np.arange(n, dtype=np.int32)
        values = np.ones(n, np.int32)
        ar, head = ll.build(keys, values)
        it = ll.sum_iterator()
        ptr0, scr0 = it.init(jnp.asarray([head] * 64, jnp.int32))
        run = jax.jit(
            lambda p, s, it=it, ar=ar, n=n: execute_batched(
                it, ar, p, s, max_iters=n + 2
            )
        )
        run(ptr0, scr0)[0].block_until_ready()
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            run(ptr0, scr0)[0].block_until_ready()
        wall_us = (time.perf_counter() - t0) / reps * 1e6
        model_ns = n * (ACCEL.scheduler_ns + ACCEL.mem_latency_ns + 16 / 25 + ACCEL.logic_ns)
        rows.append(
            dict(name=f"traversal_len/{n}", nodes=n,
                 engine_wall_us_cpu=round(wall_us, 1),
                 modeled_accel_us=round(model_ns / 1e3, 2))
        )
    return rows


def appendix_bandwidth():
    """Appendix Fig. 2: memory-bandwidth utilization per system (modeled
    from measured bytes/request)."""
    rows = []
    for name, make in ALL_PROFILES.items():
        p = make()
        thr_pulse, _ = hw.pulse_throughput_mops(p)
        bytes_per_req = p.iters_mean * p.node_bytes
        for sys_, thr in (
            ("pulse", thr_pulse),
            ("rpc", hw.rpc_throughput_mops(p)),
            ("cache", hw.cache_throughput_mops(p, 0.0625)),
        ):
            util = thr * 1e6 * bytes_per_req / (hw.MEM_BW_GBPS * 1e9)
            rows.append(
                dict(name=f"bandwidth/{name}/{sys_}",
                     mem_bw_util=round(min(util, 1.0), 3))
            )
    return rows
