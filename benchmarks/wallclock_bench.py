"""Wall-clock benchmark: superstep schedules on an 8-shard mesh.

PR 1's active-set compaction cut *wire words*; the paper's headline claim
(Fig. 7-9) is wall-clock latency/throughput.  This harness measures exactly
that on an 8-shard mesh: the same compacted superstep schedule executed

  * **dispatched** -- one jitted superstep program per hop, the local-vs-
    fabric decision and the capacity ladder re-decided on the host between
    hops (PR 1 behavior);
  * **fused**      -- the whole traversal as a single device-resident
    ``lax.while_loop`` program (``core.routing`` ``schedule="fused"``): no
    host round-trip per hop, but each superstep still serializes local
    chase -> all_to_all -> wait;
  * **pipelined**  -- the fused loop's active set split into two wavefronts
    (``schedule="pipelined"``): the in-flight wavefront rides the fabric as
    carried loop state while the resident wavefront chases locally, and
    fabric-side coordination collapses to one stacked psum per superstep;
  * **ring**       -- the pipelined schedule on the ``lax.ppermute`` ring
    fabric (P-1 distance classes instead of one dense all_to_all).

All schedules are bit-identical to the single-node BSP oracle (asserted here
on every config); only the wall clock differs.  Reports per-superstep and
end-to-end latency for each config plus an end-to-end mixed-structure total.

Run:  PYTHONPATH=src python benchmarks/wallclock_bench.py
      PYTHONPATH=src python benchmarks/wallclock_bench.py --small --check \
          --json BENCH_wallclock.json
"""

from __future__ import annotations

import os

# must be set before jax initializes: the mesh needs a multi-device host
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.arena import ArenaBuilder
from repro.core.iterator import execute_batched
from repro.core.structures import btree, hash_table, linked_list, skiplist

P = 8
RNG = np.random.default_rng(42)
N_BUCKETS = 64


def _unique(n, lo, hi):
    return RNG.choice(np.arange(lo, hi, dtype=np.int64), n, replace=False).astype(
        np.int32
    )


def build_configs(small: bool):
    """Each config: (iterator, arena, ptr0, scratch0, max_iters).

    ``chain-skewed`` is the acceptance config: an interleaved linked list
    where half the batch retires in a few hops and half walks deep -- the
    schedule where per-hop host dispatch hurts most (hundreds of supersteps,
    each shipping almost nothing by the end).
    """
    n = 256 if small else 640
    B = 64 if small else 160
    cfgs = {}

    keys = np.arange(n, dtype=np.int32)
    vals = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, vals, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = np.concatenate(
        [RNG.integers(0, n // 16, B // 2), RNG.integers(n // 2, n, B // 2)]
    ).astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    cfgs["chain-skewed"] = (it, ar, ptr0, scr0, 1 << 16)

    bkeys = _unique(n, 0, 10**6)
    ar, root, _ = btree.build(bkeys, vals, num_shards=P, policy="interleaved")
    it = btree.find_iterator()
    q = np.concatenate([bkeys[: B // 2], _unique(B // 2, 10**6, 2 * 10**6)])
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    cfgs["btree-lookup"] = (it, ar, ptr0, scr0, 64)

    hkeys = _unique(n, 0, 10**6)
    ar, heads = hash_table.build(hkeys, vals, N_BUCKETS, num_shards=P, policy="interleaved")
    it = hash_table.find_iterator(N_BUCKETS)
    q = np.concatenate([hkeys[: B // 2], _unique(B // 2, 10**6, 2 * 10**6)])
    ptr0, scr0 = it.init(jnp.asarray(q), jnp.asarray(heads))
    cfgs["hash-probe"] = (it, ar, ptr0, scr0, 1 << 12)

    skeys = np.sort(_unique(n, 0, 10**6))
    ar, shead = skiplist.build(skeys, vals, num_shards=P, policy="interleaved")
    it = skiplist.find_iterator()
    q = np.concatenate([skeys[: B // 2], _unique(B // 2, 10**6, 2 * 10**6)])
    ptr0, scr0 = it.init(jnp.asarray(q), shead)
    cfgs["skiplist-search"] = (it, ar, ptr0, scr0, 1 << 12)

    return cfgs


MODES = {
    "dispatched": dict(schedule="dispatched"),
    "fused": dict(schedule="fused"),
    "pipelined": dict(schedule="pipelined"),
    "ring": dict(schedule="pipelined", fabric="ring"),
}


def bench_config(name, it, ar, ptr0, scr0, mesh, *, max_iters, repeats):
    o_ptr, o_scr, o_status, o_iters = execute_batched(
        it, ar, ptr0, scr0, max_iters=max_iters
    )
    B = int(np.asarray(ptr0).shape[0])
    out = {"batch": B}
    for mode, mode_kw in MODES.items():
        kw = dict(
            mesh=mesh, axis_name="mem", max_iters=max_iters, k_local=4,
            compact=True, **mode_kw,
        )
        rec, st = routing.distributed_execute(it, ar, ptr0, scr0, **kw)  # warmup
        np.testing.assert_array_equal(rec[:, routing.F_SCRATCH:], np.asarray(o_scr))
        np.testing.assert_array_equal(rec[:, routing.F_STATUS], np.asarray(o_status))
        np.testing.assert_array_equal(rec[:, routing.F_ITERS], np.asarray(o_iters))
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            rec, st = routing.distributed_execute(it, ar, ptr0, scr0, **kw)
            walls.append(time.perf_counter() - t0)
        p50 = float(np.percentile(walls, 50))
        out[mode] = {
            "wall_s_p50": p50,
            "wall_s_p99": float(np.percentile(walls, 99)),
            "per_superstep_ms": p50 / st.supersteps * 1e3,
            "supersteps": st.supersteps,
            "local_only_steps": st.local_only_steps,
            "wire_words": st.total_wire_words,
            "throughput_rps": B / p50,
        }
    # schedule-identity across modes (the bit-identity contract, stats side)
    ss = {m: out[m]["supersteps"] for m in MODES}
    ww = {m: out[m]["wire_words"] for m in MODES}
    assert len(set(ss.values())) == 1, f"superstep counts diverged: {ss}"
    assert len(set(ww.values())) == 1, f"wire accounting diverged: {ww}"
    out["speedup"] = out["dispatched"]["wall_s_p50"] / out["fused"]["wall_s_p50"]
    out["speedup_pipelined"] = (
        out["fused"]["wall_s_p50"] / out["pipelined"]["wall_s_p50"]
    )
    out["speedup_ring"] = out["fused"]["wall_s_p50"] / out["ring"]["wall_s_p50"]
    f, p = out["fused"], out["pipelined"]
    print(
        f"  {name:16s} steps={f['supersteps']:4d} "
        f"dispatched={out['dispatched']['wall_s_p50']*1e3:8.1f}ms "
        f"fused={f['wall_s_p50']*1e3:8.1f}ms "
        f"pipelined={p['wall_s_p50']*1e3:8.1f}ms "
        f"ring={out['ring']['wall_s_p50']*1e3:8.1f}ms "
        f"fused/disp={out['speedup']:.2f}x pipe/fused={out['speedup_pipelined']:.2f}x"
    )
    return out


def bench_rw_mixed(mesh, *, small: bool, repeats: int):
    """Mixed 50/50 read-write series: finds racing tail-inserts in one batch
    on an interleaved chain (the write path's commit supersteps on every
    schedule).  Asserts schedule identity -- supersteps, wire words, commit
    counts, AND the final arena contents (data + heap registers) must be
    bit-identical across dispatched/fused/pipelined x dense/ring."""
    n = 128 if small else 256
    B = 32 if small else 64
    b = ArenaBuilder(4 * n, 4, num_shards=P, policy="interleaved")
    keys = np.arange(10, 10 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys * 3)
    ar = b.finish()
    it = linked_list.rw_iterator()
    ops = np.tile([1, 0], B // 2).astype(np.int32)  # 50% insert / 50% find
    qk = np.empty(B, np.int32)
    qk[ops == 1] = np.arange(B // 2) + 10_000
    qk[ops == 0] = keys[RNG.permutation(n)[: B // 2]]
    qv = (np.arange(B) + 5).astype(np.int32)
    ptr0, scr0 = it.init(ops, qk, qv, head)

    out = {"batch": B, "writes": int((ops == 1).sum())}
    arenas = {}
    for mode, mode_kw in MODES.items():
        kw = dict(
            mesh=mesh, axis_name="mem", max_iters=1 << 14, k_local=4,
            compact=True, **mode_kw,
        )
        rec, st, ar_out = routing.distributed_execute(it, ar, ptr0, scr0, **kw)
        arenas[mode] = (np.asarray(ar_out.data), np.asarray(ar_out.heap))
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            rec, st, ar_out = routing.distributed_execute(it, ar, ptr0, scr0, **kw)
            walls.append(time.perf_counter() - t0)
        p50 = float(np.percentile(walls, 50))
        out[mode] = {
            "wall_s_p50": p50,
            "supersteps": st.supersteps,
            "wire_words": st.total_wire_words,
            "commits": st.commits,
            "epochs": st.epochs,
            "throughput_rps": B / p50,
        }
    # schedule identity: stats AND the post-commit heap must agree bit-for-bit
    for field in ("supersteps", "wire_words", "commits"):
        vals = {m: out[m][field] for m in MODES}
        assert len(set(vals.values())) == 1, f"rw {field} diverged: {vals}"
    base_data, base_heap = arenas["dispatched"]
    for mode, (d, h) in arenas.items():
        np.testing.assert_array_equal(d, base_data, err_msg=f"rw arena: {mode}")
        np.testing.assert_array_equal(h, base_heap, err_msg=f"rw heap: {mode}")
    out["speedup_pipelined"] = (
        out["fused"]["wall_s_p50"] / out["pipelined"]["wall_s_p50"]
    )
    f = out["fused"]
    print(
        f"  {'rw-mixed 50/50':16s} steps={f['supersteps']:4d} "
        f"commits={f['commits']} "
        f"dispatched={out['dispatched']['wall_s_p50']*1e3:8.1f}ms "
        f"fused={f['wall_s_p50']*1e3:8.1f}ms "
        f"pipelined={out['pipelined']['wall_s_p50']*1e3:8.1f}ms "
        f"(arena + stats bit-identical across schedules)"
    )
    return out


def bench_verify_specialization(mesh, *, small: bool, repeats: int):
    """Read-only wire-word reduction from pulse-verify certificates.

    Same traversal twice: the verified ``list_find`` ISA program (read-only
    certificate => mutation record lanes skipped, per-hop access probe
    elided) against a dead-store variant admitted with ``verify=False`` --
    the conservative opcode scan routes it down the write path, arming the
    mutation lanes on every fabric crossing even though the store is
    unreachable.  Results are bit-identical; the wire-word gap is what the
    certificate buys."""
    from repro.core import isa
    from repro.core.structures import isa_programs

    n = 128 if small else 320
    B = 32 if small else 64
    keys = np.arange(n, dtype=np.int32)
    vals = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, vals, num_shards=P, policy="interleaved")
    q = np.concatenate(
        [keys[RNG.permutation(n)[: B // 2]], RNG.integers(n, 2 * n, B // 2)]
    ).astype(np.int32)
    ptr0, scr0 = linked_list.find_iterator().init(jnp.asarray(q), head)

    prog = isa_programs.list_find_program()
    vm_ro = isa.as_pulse_iterator(prog)  # carries the read-only certificate
    dead = isa.Program(
        np.vstack([prog.code, [[isa.STOREN, 2, 0, 1]]]),
        prog.scratch_words, prog.node_words, name="list_find_dead_store",
    )
    vm_rw = isa.as_pulse_iterator(dead, verify=False)  # opcode-scan fallback
    assert routing.can_elide_access_check(vm_ro, ar)

    S = vm_ro.scratch_words
    payload_cols = [routing.F_ID, routing.F_PTR, routing.F_STATUS, routing.F_ITERS]

    def payload(rec):
        rec = np.asarray(rec)
        return np.concatenate(
            [rec[:, payload_cols], rec[:, routing.F_SCRATCH: routing.F_SCRATCH + S]],
            axis=1,
        )

    out = {"batch": B}
    recs = {}
    for label, vm in (("verified_ro", vm_ro), ("unverified_rw", vm_rw)):
        kw = dict(
            mesh=mesh, axis_name="mem", max_iters=1 << 14, k_local=4,
            compact=True, schedule="fused",
        )
        res = routing.distributed_execute(vm, ar, ptr0, scr0, **kw)  # warmup
        rec, st = res[0], res[1]
        recs[label] = payload(rec)
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = routing.distributed_execute(vm, ar, ptr0, scr0, **kw)
            walls.append(time.perf_counter() - t0)
        out[label] = {
            "wall_s_p50": float(np.percentile(walls, 50)),
            "supersteps": st.supersteps,
            "wire_words": st.total_wire_words,
        }
    np.testing.assert_array_equal(recs["verified_ro"], recs["unverified_rw"])
    out["wire_reduction"] = 1 - (
        out["verified_ro"]["wire_words"] / out["unverified_rw"]["wire_words"]
    )
    print(
        f"  {'verify-readonly':16s} steps={out['verified_ro']['supersteps']:4d} "
        f"wire={out['verified_ro']['wire_words']} vs "
        f"{out['unverified_rw']['wire_words']} unverified "
        f"(-{out['wire_reduction']:.0%}, results bit-identical)"
    )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_wallclock.json",
        default=None,
        metavar="PATH",
        help="write machine-readable results (default path: BENCH_wallclock.json)",
    )
    ap.add_argument("--small", action="store_true", help="CI smoke sizes")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument(
        "--check",
        action="store_true",
        help="fail unless fused beats per-hop dispatch (>=1.3x on chain-skewed, "
        ">=1x end-to-end) -- the CI perf gate",
    )
    args = ap.parse_args(argv)

    mesh = jax.make_mesh((P,), ("mem",))
    assert jax.device_count() >= P, jax.devices()
    cfgs = build_configs(args.small)
    print(
        f"superstep schedules (dispatched/fused/pipelined/ring), {P} shards, "
        f"repeats={args.repeats}"
    )
    results = {}
    for name, (it, ar, ptr0, scr0, max_iters) in cfgs.items():
        results[name] = bench_config(
            name, it, ar, ptr0, scr0, mesh, max_iters=max_iters, repeats=args.repeats
        )

    # read-only configs drive the e2e aggregate; the rw series reports (and
    # asserts schedule identity) separately -- its commit phases serialize by
    # design, a different regime than the read-path overlap being gated
    e2e = {
        mode: sum(r[mode]["wall_s_p50"] for r in results.values())
        for mode in MODES
    }
    results["rw-mixed"] = bench_rw_mixed(mesh, small=args.small, repeats=args.repeats)
    results["verify-readonly"] = bench_verify_specialization(
        mesh, small=args.small, repeats=args.repeats
    )
    e2e["speedup"] = e2e["dispatched"] / e2e["fused"]
    e2e["speedup_pipelined"] = e2e["fused"] / e2e["pipelined"]
    e2e["speedup_ring"] = e2e["fused"] / e2e["ring"]
    print(
        f"  end-to-end mixed: dispatched={e2e['dispatched']*1e3:.1f}ms "
        f"fused={e2e['fused']*1e3:.1f}ms pipelined={e2e['pipelined']*1e3:.1f}ms "
        f"ring={e2e['ring']*1e3:.1f}ms "
        f"fused/disp={e2e['speedup']:.2f}x pipe/fused={e2e['speedup_pipelined']:.2f}x"
    )

    if args.json:
        payload = {
            "benchmark": "wallclock_bench",
            "config": {
                "shards": P,
                "small": bool(args.small),
                "repeats": args.repeats,
            },
            "results": results,
            "end_to_end": e2e,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")

    if args.check:
        chain = results["chain-skewed"]["speedup"]
        assert chain >= 1.3, (
            f"fused routing must beat per-hop dispatch by >=1.3x on the "
            f"skewed-depth chain, got {chain:.2f}x"
        )
        assert e2e["speedup"] >= 1.0, (
            f"fused routing slower than per-hop dispatch end-to-end: "
            f"{e2e['speedup']:.2f}x"
        )
        # the wavefront-pipelined gate: 1.2x on CI smoke sizes (collectives
        # are cheap relative to dispatch at tiny pools), 1.5x -- the
        # acceptance target -- at full size where hundreds of supersteps
        # amortize the compile
        need = 1.2 if args.small else 1.5
        pipe = results["chain-skewed"]["speedup_pipelined"]
        assert pipe >= need, (
            f"pipelined schedule must beat fused-serialized by >={need}x on "
            f"the skewed-depth chain, got {pipe:.2f}x"
        )
        assert e2e["speedup_pipelined"] >= 1.0, (
            f"pipelined schedule slower than fused end-to-end: "
            f"{e2e['speedup_pipelined']:.2f}x"
        )
        rw = results["rw-mixed"]
        assert rw["dispatched"]["commits"] > 0, "rw series committed nothing"
        vr = results["verify-readonly"]["wire_reduction"]
        assert vr >= 0.2, (
            f"read-only certificate must skip the mutation record lanes "
            f"(expected >=20% wire-word reduction, got {vr:.0%})"
        )
        print(
            f"  perf gate ok: chain-skewed fused/disp {chain:.2f}x (>=1.3), "
            f"pipelined/fused {pipe:.2f}x (>={need}), end-to-end "
            f"{e2e['speedup']:.2f}x / {e2e['speedup_pipelined']:.2f}x (>=1.0); "
            f"rw-mixed identity ok ({rw['dispatched']['commits']} commits, "
            f"stats + final arena bit-identical across schedules); "
            f"verify-readonly wire -{vr:.0%} (>=20%)"
        )


if __name__ == "__main__":
    main()
