"""Shared hardware/latency/energy models for the paper-figure benchmarks.

Constants are the paper's measured prototype numbers (Fig. 10 component
latencies, S6 testbed).  Where this container cannot measure real hardware
(FPGA power, 100 Gbps NIC RTTs), figures are produced from these models and
clearly labeled ``modeled``; engine-side counts (iterations, node crossings,
bytes moved, cache hit rates) are REAL measurements from the PULSE engine
running the actual data structures.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dispatch import AcceleratorSpec
from repro.core.scheduler import PowerModel, simulate

NS = 1e-9

# paper S6 testbed
WIRE_RTT_NS = 5_000.0  # one network round trip (5-10 us in the paper; Fig 9)
HOP_NS = WIRE_RTT_NS / 2  # switch-routed node crossing = half RTT (S5)
MEM_BW_GBPS = 25.0  # per memory node (FPGA cap, S6)
PAGE_BYTES = 4096  # swap granularity for the Cache-based baseline
CPU_CLOCK_RATIO = 9.0  # 'RPCs observe 1-1.4x lower latency due to 9x clock'
ARM_CLOCK_RATIO = 0.7  # A72: lower clock AND lower IPC than the accelerator path
CPU_CORES_PER_NODE = 4  # cores needed to saturate 25 GB/s (paper S6)
ARM_CORES_PER_NODE = 8  # BlueField-2
# per-request RPC software cost (DPDK RPC framework op handling; eRPC-class
# frameworks measure 1-2 us/op on x86, far higher on wimpy cores)
RPC_HANDLING_NS = 2_000.0
ARM_HANDLING_NS = 12_000.0


@dataclasses.dataclass
class WorkloadProfile:
    """REAL measurements extracted from engine runs."""

    name: str
    iters_mean: float  # pointer hops per request
    node_bytes: int  # aggregated LOAD size
    response_bytes: int
    crossings_mean: dict  # {num_nodes: mean crossings per request}
    cache_hit_rate: dict  # {cache_frac: hit rate} from the LRU sim
    t_c_ns: float  # dispatch-model compute time per iteration
    t_d_ns: float  # dispatch-model fetch time per iteration


def pulse_latency_ns(p: WorkloadProfile, accel: AcceleratorSpec, num_nodes: int = 1):
    per_iter = (
        accel.scheduler_ns + accel.mem_latency_ns
        + p.node_bytes / MEM_BW_GBPS + accel.interconnect_ns + accel.logic_ns
    )
    cross = p.crossings_mean.get(num_nodes, 0.0)
    return WIRE_RTT_NS + accel.network_ns * 2 + p.iters_mean * per_iter + cross * HOP_NS


def pulse_acc_latency_ns(p, accel, num_nodes=1):
    """PULSE-ACC (Fig. 9): each crossing returns to the CPU node first."""
    base = pulse_latency_ns(p, accel, 1)
    cross = p.crossings_mean.get(num_nodes, 0.0)
    return base + cross * (WIRE_RTT_NS + 2 * accel.network_ns)


def rpc_latency_ns(p: WorkloadProfile, accel, num_nodes: int = 1,
                   clock_ratio=CPU_CLOCK_RATIO, handling_ns=RPC_HANDLING_NS):
    """Offload to a CPU (or ARM) on the memory node: same fetch time, faster
    (x86) or slower (ARM) compute, plus per-request RPC software handling;
    crossings bounce through the CPU node (no in-network routing)."""
    per_iter = 100.0 + p.node_bytes / MEM_BW_GBPS + p.t_c_ns / clock_ratio
    cross = p.crossings_mean.get(num_nodes, 0.0)
    return (
        WIRE_RTT_NS + handling_ns + p.iters_mean * per_iter
        + cross * (WIRE_RTT_NS + handling_ns)
    )


def cache_latency_ns(p: WorkloadProfile, cache_frac: float = 1.0):
    """Cache-based far memory: every pointer hop that misses the CPU-side
    cache pays a page-granular remote fetch through the swap path."""
    hit = p.cache_hit_rate.get(cache_frac, 0.0)
    swap_overhead_ns = 10_000.0  # fault handling + eviction (Fastswap-style)
    miss_cost = WIRE_RTT_NS + PAGE_BYTES / MEM_BW_GBPS + swap_overhead_ns
    hit_cost = 150.0  # local DRAM + lookup
    return p.iters_mean * (hit * hit_cost + (1 - hit) * miss_cost)


@dataclasses.dataclass
class SteadyState:
    throughput_mops: float
    logic_util: float
    mem_util: float
    bound: str


def pulse_steady_state(p: WorkloadProfile, m=3, n=4) -> SteadyState:
    """Analytic steady-state of the disaggregated pipelines: with >= m+n
    traversals multiplexed (S4.2, Alg. 1), iteration service rate is
    min(n/t_d, m/t_c); the slower pool is saturated.  Memory bandwidth caps
    the whole node."""
    mem_rate = n / p.t_d_ns  # iterations/ns
    logic_rate = m / p.t_c_ns
    rate = min(mem_rate, logic_rate)
    thr = rate / p.iters_mean / NS / 1e6  # Mops
    bw_bound = MEM_BW_GBPS / (p.iters_mean * p.node_bytes) * 1e3
    bound = "memory_pipes" if mem_rate <= logic_rate else "logic_pipes"
    if thr > bw_bound:
        thr, bound = bw_bound, "hbm_bw"
        rate = thr * 1e6 * NS * p.iters_mean
    return SteadyState(
        throughput_mops=thr,
        logic_util=min(rate * p.t_c_ns / m, 1.0),
        mem_util=min(rate * p.t_d_ns / n, 1.0),
        bound=bound,
    )


def pulse_throughput_mops(p: WorkloadProfile, m=3, n=4, num_nodes=1):
    ss = pulse_steady_state(p, m, n)
    return ss.throughput_mops * num_nodes, ss


def coupled_steady_state(p: WorkloadProfile, cores: int) -> SteadyState:
    """Traditional multi-core (Table 4 top): logic+memory fused per core, a
    request's fetch and compute serialize on its core (Fig. 4 top)."""
    per_iter = p.t_d_ns + p.t_c_ns
    thr = cores / (p.iters_mean * per_iter) / NS / 1e6
    bw_bound = MEM_BW_GBPS / (p.iters_mean * p.node_bytes) * 1e3
    thr2 = min(thr, bw_bound)
    return SteadyState(
        throughput_mops=thr2,
        logic_util=(p.t_c_ns / per_iter) * (thr2 / thr),
        mem_util=(p.t_d_ns / per_iter) * (thr2 / thr),
        bound="cores" if thr2 == thr else "hbm_bw",
    )


def rpc_throughput_mops(p, num_nodes=1, cores=CPU_CORES_PER_NODE,
                        clock_ratio=CPU_CLOCK_RATIO, handling_ns=RPC_HANDLING_NS):
    per_req = handling_ns + p.iters_mean * (
        100.0 + p.node_bytes / MEM_BW_GBPS + p.t_c_ns / clock_ratio
    )
    core_bound = cores / (per_req * NS) / 1e6
    bw_bound = MEM_BW_GBPS / (p.iters_mean * p.node_bytes) * 1e3
    return min(core_bound, bw_bound) * num_nodes


def cache_throughput_mops(p, cache_frac=1.0, outstanding=8):
    lat = cache_latency_ns(p, cache_frac)
    return outstanding / (lat * NS) / 1e6  # swap path limits concurrency


def energy_per_op_uj(p: WorkloadProfile, system: str, num_nodes=1):
    pm = PowerModel()
    if system in ("pulse", "pulse_asic"):
        thr, ss = pulse_throughput_mops(p)
        watts = (
            pm.pulse_power_w(3, 4, ss.logic_util, ss.mem_util)
            if system == "pulse"
            else pm.pulse_asic_power_w(3, 4, ss.logic_util, ss.mem_util)
        )
        return watts / (thr * 1e6) * 1e6
    if system == "rpc":
        thr = rpc_throughput_mops(p)
        return pm.cpu_power_w(CPU_CORES_PER_NODE) / (thr * 1e6) * 1e6
    if system == "rpc_arm":
        thr = rpc_throughput_mops(
            p, cores=ARM_CORES_PER_NODE, clock_ratio=ARM_CLOCK_RATIO,
            handling_ns=ARM_HANDLING_NS,
        )
        return pm.arm_power_w(ARM_CORES_PER_NODE) / (thr * 1e6) * 1e6
    raise ValueError(system)
