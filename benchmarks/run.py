"""Benchmark harness: one function per paper table/figure + kernel benches.

Prints ``name,us_per_call,derived`` style CSV (extra keys folded into the
derived column).  Run: ``PYTHONPATH=src python -m benchmarks.run``.
"""

from __future__ import annotations

import sys
import time


def _emit(rows):
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = r.pop("derived", "")
        extras = ";".join(f"{k}={v}" for k, v in r.items())
        derived = f"{derived};{extras}".strip(";")
        print(f"{name},{us},{derived}")


def main() -> None:
    from benchmarks import kernel_bench, paper_figures as pf

    t0 = time.time()
    print("name,us_per_call,derived")
    benches = [
        ("table3", pf.table3_workloads),
        ("fig7", pf.fig7_latency_throughput),
        ("fig8", pf.fig8_energy),
        ("fig9", pf.fig9_pulse_acc),
        ("fig10", pf.fig10_breakdown),
        ("table4", pf.table4_pipelines),
        ("fig11", pf.fig11_eta),
        ("fig5", pf.fig5_allocation),
        ("traversal_length", pf.appendix_traversal_length),
        ("bandwidth", pf.appendix_bandwidth),
        ("kernels", kernel_bench.bench_kernels),
    ]
    failed = []
    for name, fn in benches:
        try:
            _emit(fn())
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            import traceback

            traceback.print_exc()
    print(f"# benchmarks done in {time.time() - t0:.1f}s; failures: {failed or 'none'}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
