"""Kernel micro-benchmarks: CPU wall time of the Pallas kernels (interpret
mode) vs the pure-jnp references.  These validate plumbing and give an
apples-to-apples CPU baseline; TPU timings require real hardware (the
roofline analysis covers the TPU story)."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

RNG = np.random.default_rng(0)


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels():
    rows = []

    # pulse_chase: btree descent, 64 lanes
    from repro.core.structures import btree
    from repro.kernels.pulse_chase import ops as chase_ops

    keys = RNG.choice(np.arange(10**6), size=4096, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, 4096).astype(np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    ptr0, scr0 = it.init(jnp.asarray(keys[:64]), root)
    st0 = jnp.zeros(64, jnp.int32)
    logic = chase_ops.iterator_logic(it)
    for mode, use_pallas in (("interp", True), ("ref", False)):
        us = _time(
            lambda up=use_pallas: chase_ops.pulse_chase(
                ar.data, ptr0, scr0, st0, logic_fn=logic, num_steps=height,
                use_pallas=up, interpret=True,
            )
        )
        rows.append(dict(name=f"kernel/pulse_chase/{mode}", us_per_call=round(us, 1),
                         derived=f"lanes=64 steps={height}"))

    # flash attention
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_reference

    q = jnp.asarray(RNG.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    rows.append(dict(name="kernel/flash_attention/interp",
                     us_per_call=round(_time(lambda: flash_attention(q, k, v, True, 128, 128, True, True)), 1),
                     derived="B1 H4 L256 D64"))
    rows.append(dict(name="kernel/flash_attention/ref",
                     us_per_call=round(_time(lambda: mha_reference(q, k, v, causal=True)), 1),
                     derived="B1 H4 L256 D64"))

    # paged attention
    from repro.kernels.paged_attention.ops import paged_attention

    qd = jnp.asarray(RNG.standard_normal((4, 8, 64)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((64, 16, 4, 64)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((64, 16, 4, 64)), jnp.float32)
    pt = jnp.asarray(RNG.integers(0, 64, (4, 8)), jnp.int32)
    ln = jnp.asarray([100, 80, 128, 60], jnp.int32)
    for mode, use_pallas in (("interp", True), ("ref", False)):
        rows.append(dict(
            name=f"kernel/paged_attention/{mode}",
            us_per_call=round(_time(lambda up=use_pallas: paged_attention(qd, kp, vp, pt, ln, interpret=True, use_pallas=up)), 1),
            derived="B4 H8 P8x16",
        ))

    # ssd scan
    from repro.kernels.ssd_scan.ops import ssd_scan

    x = jnp.asarray(RNG.standard_normal((2, 512, 4, 64)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (2, 512, 4)), jnp.float32)
    A = jnp.asarray(RNG.uniform(-1, -0.1, (4,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((2, 512, 64)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((2, 512, 64)) * 0.5, jnp.float32)
    for mode, use_pallas in (("interp", True), ("ref", False)):
        rows.append(dict(
            name=f"kernel/ssd_scan/{mode}",
            us_per_call=round(_time(lambda up=use_pallas: ssd_scan(x, dt, A, B, C, chunk=128, interpret=True, use_pallas=up)), 1),
            derived="B2 L512 H4 N64",
        ))
    return rows
