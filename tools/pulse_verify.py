#!/usr/bin/env python
"""pulse-verify CLI: static verification + annotated disassembly for PULSE
ISA traversal programs.

The same admission pass the serving layer runs (``core.verify``), as a
standalone tool -- point it at the shipped structure programs (or extend
``--all`` with your own registry) and it prints a per-program verdict with
instruction-level diagnostics, or the fully annotated disassembly.

Usage:

    PYTHONPATH=src python tools/pulse_verify.py --all
        verify every shipped ``isa_programs`` entry; exit 1 on any rejection

    PYTHONPATH=src python tools/pulse_verify.py list_find bst_update
        verify the named shipped programs

    PYTHONPATH=src python tools/pulse_verify.py --all --disasm
        print annotated disassembly (the golden-file format) instead of the
        one-line verdicts

    PYTHONPATH=src python tools/pulse_verify.py --all --golden tests/golden/pulse_verify
        check each program's annotated disassembly against
        ``<dir>/<name>.disasm``; exit 1 on drift (``--write-golden``
        regenerates the files)
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.core.structures import isa_programs
from repro.core.verify import analyze_program, annotate_disasm


def _registry() -> dict:
    return dict(isa_programs.all_programs())


def _verdict_line(name: str, prog) -> tuple[str, bool]:
    facts, diags = analyze_program(prog)
    if diags:
        codes = ", ".join(sorted({d.code for d in diags}))
        return f"REJECT {name}: {len(diags)} finding(s) [{codes}]", False
    return f"OK     {name}: {facts.summary()}", True


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="pulse_verify", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("names", nargs="*", help="shipped program names to verify")
    ap.add_argument(
        "--all", action="store_true",
        help="verify every shipped isa_programs entry",
    )
    ap.add_argument(
        "--list", action="store_true", help="list shipped program names"
    )
    ap.add_argument(
        "--disasm", action="store_true",
        help="print annotated disassembly instead of one-line verdicts",
    )
    ap.add_argument(
        "--golden", metavar="DIR", default=None,
        help="compare annotated disassembly against DIR/<name>.disasm",
    )
    ap.add_argument(
        "--write-golden", metavar="DIR", default=None,
        help="(re)write DIR/<name>.disasm golden files and exit",
    )
    args = ap.parse_args(argv)

    registry = _registry()
    if args.list:
        for name in registry:
            print(name)
        return 0

    if args.all:
        names = list(registry)
    else:
        names = args.names
    if not names:
        ap.error("nothing to do: pass program names or --all")
    unknown = [n for n in names if n not in registry]
    if unknown:
        ap.error(
            f"unknown program(s) {unknown}; shipped: {sorted(registry)}"
        )

    if args.write_golden:
        out = Path(args.write_golden)
        out.mkdir(parents=True, exist_ok=True)
        for name in names:
            path = out / f"{name}.disasm"
            path.write_text(annotate_disasm(registry[name]))
            print(f"wrote {path}")
        return 0

    failures = 0
    for name in names:
        prog = registry[name]
        if args.golden:
            path = Path(args.golden) / f"{name}.disasm"
            got = annotate_disasm(prog)
            if not path.exists():
                print(f"DRIFT  {name}: missing golden {path}")
                failures += 1
            elif path.read_text() != got:
                print(
                    f"DRIFT  {name}: annotated disasm differs from {path} "
                    f"(regenerate with --write-golden)"
                )
                failures += 1
            else:
                print(f"OK     {name}: matches {path}")
            continue
        if args.disasm:
            print(annotate_disasm(prog))
            _, diags = analyze_program(prog)
            failures += bool(diags)
            continue
        line, ok = _verdict_line(name, prog)
        print(line)
        failures += not ok
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
