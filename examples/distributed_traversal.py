"""Distributed pointer traversals across 8 memory nodes (paper S5).

Range queries on a B+tree whose nodes are range-partitioned across an
8-shard mesh; in-flight requests are routed between shards by the switch
superstep (all_to_all), never bouncing through the CPU node.  Also runs the
PULSE-ACC ablation (Fig. 9) showing the extra crossings.

Needs 8 XLA host devices, so it re-execs itself with XLA_FLAGS if needed.
Run: PYTHONPATH=src python examples/distributed_traversal.py
"""

import os
import sys

if os.environ.get("_PULSE_EXAMPLE_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_PULSE_EXAMPLE_CHILD"] = "1"
    os.execve(sys.executable, [sys.executable] + sys.argv, env)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import routing  # noqa: E402
from repro.core.structures import btree  # noqa: E402

P = 8
rng = np.random.default_rng(1)
mesh = jax.make_mesh((P,), ("mem",))
print(f"mesh: {P} memory nodes")

# time-ordered keys (the BTrDB shape), partitioned allocation
n = 50_000
keys = np.arange(n, dtype=np.int32)
values = rng.integers(0, 1000, n).astype(np.int32)
arena, root, height = btree.build(keys, values, num_shards=P, policy="sequential")
print(f"b+tree: {n} keys, height {height}, arena sharded {P} ways "
      f"(switch table = {np.asarray(arena.bounds)})")

# stateful range aggregations (sum/min/max/count in the scratch pad)
it = btree.range_aggregate_iterator()
lo = rng.integers(0, n - 2048, 64).astype(np.int32)
hi = (lo + 2048).astype(np.int32)
ptr0, scr0 = it.init(jnp.asarray(lo), jnp.asarray(hi), root)

rec, stats = routing.distributed_execute(
    it, arena, ptr0, scr0, mesh=mesh, axis_name="mem", max_iters=4096, k_local=8,
)
print(f"switch-routed: {stats.supersteps} supersteps, "
      f"mean crossings/request {stats.crossings.mean():.2f}")

# verify against the oracle
ref = btree.ref_range_aggregate(keys, values, lo, hi)
for i, (s, mn, mx, c) in enumerate(ref):
    got = rec[i, routing.F_SCRATCH:]
    assert int(got[btree.RA_SUM]) % 2**32 == s and int(got[btree.RA_COUNT]) == c
print("results match the single-node oracle exactly")

# PULSE-ACC ablation (Fig. 9): crossings bounce via the home node
rec2, stats2 = routing.distributed_execute(
    it, arena, ptr0, scr0, mesh=mesh, axis_name="mem", max_iters=4096,
    k_local=8, return_to_cpu=True,
)
np.testing.assert_array_equal(rec[:, routing.F_SCRATCH:], rec2[:, routing.F_SCRATCH:])
print(f"PULSE-ACC: identical results, {stats2.crossings.sum()} crossings vs "
      f"{stats.crossings.sum()} with in-network routing "
      f"({stats2.crossings.sum() / max(stats.crossings.sum(), 1):.2f}x)")
