"""Serving with a PULSE-paged KV cache: the page-table walk IS a pointer
traversal (DESIGN.md S3).

Decodes from a small GQA model with per-sequence page chains living in a
PULSE arena; every step walks the chains with the batched iterator executor
and runs decode attention over the gathered pages (validated against the
kernel reference).  Also serves a request batch via continuous batching.

Run: PYTHONPATH=src python examples/serve_paged.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.kernels.paged_attention.ops import paged_attention
from repro.models.model_zoo import build_model
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.kv_cache import PagedKVCache

rng = np.random.default_rng(0)
cfg = get_reduced_config("qwen3_4b")
print(f"model: reduced qwen3-4b ({cfg.n_layers}L d{cfg.d_model} GQA "
      f"{cfg.n_heads}/{cfg.n_kv_heads})")

# --- 1) the paged cache: chains in a PULSE arena ---------------------------
B, page_size, n_pages = 4, 8, 64
cache = PagedKVCache(cfg, n_pages=n_pages, page_size=page_size, max_batch=B)
lens = [27, 9, 40, 16]
for b, ln in enumerate(lens):
    cache.ensure_capacity(b, ln)
    cache.lengths[b] = ln
pt, lengths = cache.walk_page_tables(max_pages=8)
print(f"page tables (PULSE chain walk): lengths={np.asarray(lengths)}")
print(np.asarray(pt))

# fill pages with random KV and check paged attention against dense math
Hk, hd = cfg.n_kv_heads, cfg.hd
k_pages = jnp.asarray(rng.standard_normal(cache.k_pages.shape[1:]), jnp.float32)
v_pages = jnp.asarray(rng.standard_normal(cache.v_pages.shape[1:]), jnp.float32)
q = jnp.asarray(rng.standard_normal((B, cfg.n_heads, hd)), jnp.float32)
o = paged_attention(q, k_pages, v_pages, pt, lengths, interpret=True, use_pallas=True)
o_ref = paged_attention(q, k_pages, v_pages, pt, lengths, use_pallas=False)
err = float(jnp.abs(o - o_ref).max())
print(f"paged decode attention (pulse_chase + flash-decode kernel): "
      f"max |kernel - ref| = {err:.2e}")
assert err < 1e-4

# --- 2) continuous batching over the model zoo -----------------------------
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
reqs = [
    Request(req_id=i, prompt=rng.integers(2, cfg.vocab, 6).astype(np.int32),
            max_new_tokens=12)
    for i in range(6)
]
b = ContinuousBatcher(model, max_batch=3, max_len=32)
b.model_params = params
m = b.serve(reqs)
done = sum(1 for r in reqs if r.finished_step >= 0)
print(f"continuous batching: {done}/{len(reqs)} requests, {m.tokens_out} tokens "
      f"in {m.steps} decode steps ({m.tokens_per_s:.1f} tok/s CPU)")
