"""Serve mixed pointer-traversal traffic through PulseService.

A minimal end-to-end tour of the serving layer (paper S4-S5 as a request
server):

  * four structure families live in ONE pooled arena (the disaggregated
    heap);
  * tenants submit find() traffic, one with tight deadlines -- and a writer
    tenant inserts fresh list keys through the write path (staged mutations
    + commit supersteps), barriered per structure group so its batch owns
    the "list" group exclusively while it runs;
  * PulseService admits requests into per-structure slot groups, runs each
    group a quantum of iterations per round, retires finished traversals
    (backfilling the slot), and resumes the rest as continuations.

Run:  PYTHONPATH=src python examples/serve_traversals.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.arena import ArenaBuilder
from repro.core.engine import PulseEngine
from repro.core.structures import btree, hash_table, linked_list, skiplist
from repro.serving.admission import TraversalRequest
from repro.serving.traversal_service import PulseService, StructureSpec

RNG = np.random.default_rng(0)
N = 1024

# -- one pooled heap, four resident structures --------------------------------
b = ArenaBuilder(1 << 14, 20)
lkeys = np.arange(N, dtype=np.int32)
head = linked_list.build_into(b, lkeys, RNG.integers(0, 10**6, N).astype(np.int32))
bkeys = RNG.choice(np.arange(10**6, 2 * 10**6), N, replace=False).astype(np.int32)
root, _ = btree.build_into(b, bkeys, RNG.integers(0, 10**6, N).astype(np.int32))
hkeys = RNG.choice(np.arange(2 * 10**6, 3 * 10**6), N, replace=False).astype(np.int32)
heads = hash_table.build_into(b, hkeys, RNG.integers(0, 10**6, N).astype(np.int32), 128)
skeys = RNG.choice(np.arange(3 * 10**6, 4 * 10**6), N, replace=False).astype(np.int32)
shead = skiplist.build_into(b, skeys, RNG.integers(0, 10**6, N).astype(np.int32))
arena = b.finish()

# -- the service --------------------------------------------------------------
service = PulseService(
    PulseEngine(arena),
    {
        "list": StructureSpec(linked_list.find_iterator(), (head,), group="list"),
        "list_insert": StructureSpec(
            linked_list.insert_iterator(), (head,), group="list", takes_value=True
        ),
        "btree": StructureSpec(btree.find_iterator(), (root,)),
        "hash": StructureSpec(hash_table.find_iterator(128), (jnp.asarray(heads),)),
        "skip": StructureSpec(skiplist.find_iterator(), (shead,)),
    },
    slots_per_structure=32,
    quantum=16,
)

# -- traffic ------------------------------------------------------------------
keysets = {"list": lkeys, "btree": bkeys, "hash": hkeys, "skip": skeys}
names = list(keysets)
requests = []
for i in range(200):
    s = names[i % 4]
    requests.append(
        TraversalRequest(
            req_id=i,
            structure=s,
            query=int(keysets[s][RNG.integers(0, N)]),
            tenant=("latency-sensitive" if i % 5 == 0 else "batch"),
            deadline_ms=1000.0 if i % 5 == 0 else None,
        )
    )

# a writer tenant appends fresh keys, then reads them back in the same run
inserts = [
    TraversalRequest(
        req_id=1000 + j, structure="list_insert", query=10**7 + j,
        value=j * 11, tenant="writer",
    )
    for j in range(16)
]
readbacks = [
    TraversalRequest(
        req_id=2000 + j, structure="list", query=10**7 + j, tenant="writer"
    )
    for j in range(16)
]

metrics = service.run(requests + inserts + readbacks)
print(metrics.summary())
found = sum(
    int(r.result[2]) for r in requests if r.structure != "btree"
)
print(f"found flags set on {found} non-btree find requests")
print(
    f"write path: {metrics.writes_retired} inserts retired, "
    f"{metrics.commits} mutations committed"
)
ok = sum(int(r.result[1] == (r.req_id - 2000) * 11) for r in readbacks)
print(f"read-your-writes: {ok}/16 readbacks saw the inserted value")
for tenant, d in sorted(metrics.per_tenant.items()):
    lat = np.asarray(d["latencies_ms"])
    print(f"  {tenant}: {d['completed']} done, p50 {np.percentile(lat, 50):.1f} ms")
