"""Quickstart: the PULSE core in 60 lines.

Builds a hash table in a disaggregated arena, expresses ``find`` as a PULSE
iterator (init/next/end + scratch pad), lets the dispatch engine decide
offload (t_c <= eta * t_d), and runs a batch of lookups through the
accelerator executor -- including a continuation (max-iteration) resume.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import PulseEngine, STATUS_DONE, STATUS_MAXED
from repro.core.iterator import execute_batched, resume
from repro.core.structures import hash_table

rng = np.random.default_rng(0)

# 1) build a bucket-chained hash table in the arena (the "memory node" heap)
n_keys, n_buckets = 20_000, 256
keys = rng.choice(np.arange(10**6), size=n_keys, replace=False).astype(np.int32)
values = rng.integers(0, 10**6, n_keys).astype(np.int32)
arena, bucket_heads = hash_table.build(keys, values, n_buckets)
print(f"arena: {arena.capacity} nodes x {arena.node_words} words "
      f"({arena.node_words * 4} B/record, single aggregated LOAD)")

# 2) the traversal as a PULSE iterator
it = hash_table.find_iterator(n_buckets)

# 3) dispatch decision: is this memory-bound enough to offload?
engine = PulseEngine(arena)
decision = engine.dispatch(it)
print(f"dispatch: {decision.reason} (t_c/t_d = {decision.ratio:.3f})")

# 4) run a batch of lookups on the accelerator path
queries = np.concatenate([keys[:64], rng.integers(10**6, 2 * 10**6, 64).astype(np.int32)])
ptr0, scr0 = it.init(jnp.asarray(queries), jnp.asarray(bucket_heads))
res = engine.execute(it, ptr0, scr0, max_iters=4096)
found = res.scratch[:, 2].astype(bool)
print(f"lookups: {found[:64].sum()}/64 hits on known keys, "
      f"{found[64:].sum()}/64 on absent keys, "
      f"mean chain hops {res.iters.mean():.1f}")

# 5) continuations: bound the per-request iteration budget and resume
ptr, scr, status, iters = execute_batched(it, arena, ptr0, scr0, max_iters=8)
n_maxed = int((status == STATUS_MAXED).sum())
print(f"with max_iters=8: {n_maxed} traversals suspended (scratch_pad returned)")
ptr, scr, status, iters = execute_batched(it, arena, ptr, scr, max_iters=4096)
assert int((np.asarray(status) == STATUS_DONE).sum()) == len(queries)
print("resumed to completion: all done -- continuation semantics OK")
