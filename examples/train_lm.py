"""End-to-end training driver: train a ~10M-param Qwen3-family model for a
few hundred steps on the synthetic pipeline, with checkpoint + kill/resume.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
(At full scale the same loop runs via `python -m repro.launch.train
--arch qwen3_0_6b --steps ...` on a pod.)
"""

import argparse
import shutil
import tempfile

import jax

from repro.configs import get_reduced_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed.checkpoint import CheckpointManager
from repro.models.model_zoo import build_model
from repro.training import optimizer as opt_mod
from repro.training.train_loop import TrainConfig, TrainLoop, init_state

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

cfg = get_reduced_config("qwen3_0_6b").replace(
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, d_ff=512, head_dim=16,
    vocab=2048,
)
model = build_model(cfg)
n_params = sum(x.size for x in jax.tree.leaves(model.init(jax.random.PRNGKey(0))))
print(f"model: {n_params/1e6:.1f}M params ({cfg.n_layers}L d{cfg.d_model})")

tcfg = TrainConfig(
    opt=opt_mod.OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
)
workdir = tempfile.mkdtemp(prefix="pulse_train_")
ckpt = CheckpointManager(workdir, async_save=True)
data = DataIterator(DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8))

state = init_state(model, tcfg, jax.random.PRNGKey(0))
loop = TrainLoop(model, tcfg, data, ckpt_manager=ckpt, ckpt_every=100)

half = args.steps // 2
state, log1 = loop.run(state, 0, half)
print(f"[phase 1] step {half}: loss {log1[-1]['loss']:.4f}")
ckpt.save(state, half, extra=data.state_dict(), block=True)

# simulate a node failure: throw everything away, restore, continue
print("[failure] killing training state; restoring from checkpoint...")
del state
state2 = init_state(model, tcfg, jax.random.PRNGKey(99))  # junk init
state2, extra, step0 = ckpt.restore(state2)
data2 = DataIterator(DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8))
data2.load_state_dict(extra)
loop2 = TrainLoop(model, tcfg, data2, ckpt_manager=ckpt, ckpt_every=100)
state2, log2 = loop2.run(state2, step0, args.steps - step0)
print(f"[phase 2] resumed at {step0}, finished step {args.steps - 1}: "
      f"loss {log2[-1]['loss']:.4f}")
first = log1[0]["loss"]
last = log2[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} ({'OK' if last < first - 0.5 else 'WARN'})")
ckpt.wait()
shutil.rmtree(workdir, ignore_errors=True)
