import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, traceback
sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell

cells = [
    ("granite_moe_1b_a400m", "train_4k", False),
    ("mamba2_780m", "long_500k", False),
    ("zamba2_7b", "decode_32k", False),
    ("whisper_large_v3", "prefill_32k", False),
    ("internvl2_2b", "train_4k", True),
    ("kimi_k2_1t_a32b", "train_4k", True),
]
for arch, shape, mp in cells:
    try:
        run_cell(arch, shape, multi_pod=mp)
    except Exception:
        print(f"FAILED {arch} x {shape}")
        traceback.print_exc()
print("PREFLIGHT DONE")
