"""Completion pass: remaining cells. Decode/long cells run the full probe
pipeline; the slow-compiling SSM train/prefill cells run compile-only
(memory analysis + reported cost, flagged probeless=True) to fit the wall
clock -- lower+compile success is the hard deliverable."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time, traceback
from pathlib import Path
sys.path.insert(0, "src")
import jax
from repro.configs import SHAPES
from repro.launch.dryrun import run_cell, _dryrun_cfg, _compile, _cost_of
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh

FULL = [  # fast cells: full probe pipeline
    ("zamba2_7b", "decode_32k"), ("zamba2_7b", "long_500k"),
    ("mamba2_780m", "decode_32k"), ("mamba2_780m", "long_500k"),
]
PROBELESS = [  # slow SSD-backward compiles: compile-only
    ("zamba2_7b", "prefill_32k"),
    ("mamba2_780m", "train_4k"), ("mamba2_780m", "prefill_32k"),
]
out = Path("results/dryrun_complete.json")
results = json.loads(out.read_text()) if out.exists() else {}

for arch, shape_name in FULL + PROBELESS:
    probeless = (arch, shape_name) in PROBELESS
    for mp in (False, True):
        key = f"{arch}|{shape_name}|{'2x16x16' if mp else '16x16'}"
        if results.get(key, {}).get("ok"):
            continue
        t0 = time.time()
        try:
            if not probeless:
                report, dt = run_cell(arch, shape_name, multi_pod=mp)
                results[key] = {"ok": True, "compile_s": dt, **report.to_json()}
            else:
                cfg = _dryrun_cfg(arch)
                shape = SHAPES[shape_name]
                mesh = make_production_mesh(multi_pod=mp)
                compiled = _compile(cfg, shape, mesh)
                mem = compiled.memory_analysis()
                rep = _cost_of(compiled)
                dt = time.time() - t0
                print(f"=== {key} compile-only OK ({dt:.1f}s)")
                print(f"memory_analysis: {mem}")
                r = rl.analyze(
                    arch=arch, shape_name=shape_name,
                    mesh_name="2x16x16" if mp else "16x16",
                    chips=512 if mp else 256,
                    cost={"flops": rep["flops"], "bytes accessed": rep["bytes"]},
                    hlo_text="", memory_stats=mem,
                    model_flops=rl.model_flops_for(cfg, shape),
                    note="probeless: scan-body costs counted once (undercounted)",
                )
                r.collective_bytes = rep["coll"]
                r.collective_s = rep["coll"] / rl.ICI_BW
                results[key] = {"ok": True, "compile_s": dt, "probeless": True,
                                **r.to_json()}
        except Exception as e:
            traceback.print_exc()
            results[key] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        out.write_text(json.dumps(results, indent=1))
print("COMPLETE-SWEEP DONE", sum(1 for v in results.values() if v.get("ok")), "/", len(results))
