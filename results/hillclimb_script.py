"""Hillclimb runner: re-measures the three chosen cells after each change.

Writes results/hillclimb.json keyed by iteration label.  Run AFTER the
baseline sweep:
    PYTHONPATH=src python results/hillclimb_script.py <label> [cell ...]
cells: whisper | kimi | qwen_decode (default: all three)
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402
import sys  # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402

CELLS = {
    "whisper": ("whisper_large_v3", "prefill_32k", False),
    "kimi": ("kimi_k2_1t_a32b", "train_4k", True),
    "qwen_decode": ("qwen3_4b", "decode_32k", False),
}


def main():
    label = sys.argv[1]
    names = sys.argv[2:] or list(CELLS)
    out_path = Path("results/hillclimb.json")
    data = json.loads(out_path.read_text()) if out_path.exists() else {}
    for name in names:
        arch, shape, mp = CELLS[name]
        report, dt = run_cell(arch, shape, multi_pod=mp)
        data[f"{label}|{name}"] = {"compile_s": dt, **report.to_json()}
        out_path.write_text(json.dumps(data, indent=1))
    print(f"recorded {label} for {names}")


if __name__ == "__main__":
    main()
