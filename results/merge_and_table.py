"""Merges the sweep result files and regenerates the roofline table into
EXPERIMENTS.md (between the ROOFLINE_TABLE marker and the next section)."""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

files = ["results/dryrun.json", "results/dryrun_fast.json", "results/dryrun_complete.json"]
merged = {}
for f in files:
    p = Path(f)
    if p.exists():
        for k, v in json.loads(p.read_text()).items():
            if v.get("ok") or k not in merged:
                merged[k] = v
# hillclimb after-rows for reference
hc = Path("results/hillclimb.json")
if hc.exists():
    for k, v in json.loads(hc.read_text()).items():
        if k.startswith(("after_h2v2", "after_h3")):
            label, cell = k.split("|")
            arch = v["arch"]; shape = v["shape"]; mesh = v["mesh"]
            merged[f"{arch}|{shape}|{mesh}+OPT"] = {"ok": True, **v}

Path("results/dryrun_merged.json").write_text(json.dumps(merged, indent=1))

from repro.launch.report import render  # noqa: E402

table = render("results/dryrun_merged.json")
md = Path("EXPERIMENTS.md").read_text()
marker = "<!-- ROOFLINE_TABLE -->"
head, rest = md.split(marker)
# keep everything after the next section header
tail = rest[rest.index("\n## "):]
Path("EXPERIMENTS.md").write_text(head + marker + "\n\n" + table + "\n" + tail)
n_ok = sum(1 for v in merged.values() if v.get("ok"))
print(f"merged {n_ok} ok entries; table inserted")
