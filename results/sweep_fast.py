import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, traceback
from pathlib import Path
sys.path.insert(0, "src")
from repro.configs import SHAPES, all_cells
from repro.launch.dryrun import run_cell

ARCHS = ["qwen3_0_6b", "qwen1_5_4b", "qwen3_4b", "olmo_1b", "mamba2_780m",
         "internvl2_2b"]  # internvl2: retry the fixed prefill cells
out = Path("results/dryrun_fast.json")
results = json.loads(out.read_text()) if out.exists() else {}
done = json.loads(Path("results/dryrun.json").read_text())
for arch, shape in all_cells():
    if arch not in ARCHS:
        continue
    for mp in (False, True):
        key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
        if results.get(key, {}).get("ok") or done.get(key, {}).get("ok"):
            continue
        try:
            report, dt = run_cell(arch, shape, multi_pod=mp)
            results[key] = {"ok": True, "compile_s": dt, **report.to_json()}
        except Exception as e:
            traceback.print_exc()
            results[key] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        out.write_text(json.dumps(results, indent=1))
print("FAST SWEEP DONE", sum(1 for v in results.values() if v.get("ok")), "/", len(results))
