"""Paged KV cache backed by a PULSE arena (DESIGN.md S3 integration).

Physical layout:
  * ``k_pages`` / ``v_pages``: (layers, n_pages, page_size, Hk, hd) page
    pools in HBM.  One *physical page id* indexes every layer's pool (vLLM
    block-table convention).
  * page tables: per-sequence **linked lists in a PULSE arena** -- node
    ``[phys_page, next, seq_id, pad]``.  Walking a sequence's chain IS a
    pointer traversal; the serving engine executes it with the PULSE batched
    executor, and on the paper's hardware each walk would ship as an
    iterator with a scratch-pad of page ids (the 256 B scratch bounds ~56
    pages per continuation round -- the S3 max-iteration resume handles
    longer chains; the software path materializes the whole table at once).

The walked table feeds ``repro.kernels.paged_attention`` (decode) --
pointer-chase fetch fused with flash-decode logic.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import arena as arena_mod
from repro.core.iterator import PulseIterator, execute_batched

NODE_WORDS = 4
PHYS, NEXT, SEQ = 0, 1, 2


def page_walk_iterator(max_pages: int) -> PulseIterator:
    """Collect the chain's physical page ids into the scratch pad.

    scratch: [count, pages[0..max_pages-1]]
    """
    S = 1 + max_pages

    def init(head_ptrs):
        B = head_ptrs.shape[0]
        return jnp.asarray(head_ptrs, jnp.int32), jnp.full((B, S), -1, jnp.int32).at[:, 0].set(0)

    def next_fn(node, ptr, scratch):
        return node[NEXT], scratch

    def end_fn(node, ptr, scratch):
        cnt = scratch[0]
        scratch = scratch.at[jnp.clip(cnt + 1, 1, S - 1)].set(node[PHYS])
        scratch = scratch.at[0].set(cnt + 1)
        done = (node[NEXT] == arena_mod.NULL) | (cnt + 1 >= max_pages)
        return done, scratch

    return PulseIterator(S, next_fn, end_fn, init, name="page_walk")


class PagedKVCache:
    """Host-managed page allocator + device page pools."""

    def __init__(self, cfg, *, n_pages: int, page_size: int, max_batch: int,
                 arena_capacity: int | None = None, dtype=None):
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_batch = max_batch
        L, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        dtype = dtype or cfg.compute_dtype
        self.k_pages = jnp.zeros((L, n_pages, page_size, Hk, hd), dtype)
        self.v_pages = jnp.zeros((L, n_pages, page_size, Hk, hd), dtype)
        cap = arena_capacity or (n_pages + 8)
        self.builder = arena_mod.ArenaBuilder(cap, NODE_WORDS)
        # page 0 is reserved as the trash page (inactive-slot writes land
        # there), so it is never handed out
        self.free_pages = list(range(n_pages - 1, 0, -1))
        self.heads = np.full(max_batch, arena_mod.NULL, np.int32)
        self.tails = np.full(max_batch, arena_mod.NULL, np.int32)
        self.lengths = np.zeros(max_batch, np.int64)

    # --------------------------- host management ----------------------------

    def reset_seq(self, slot: int):
        """Frees a sequence's pages + chain (host-side, between steps)."""
        ptr = int(self.heads[slot])
        while ptr != arena_mod.NULL:
            node = self.builder.data[ptr]
            self.free_pages.append(int(node[PHYS]))
            nxt = int(node[NEXT])
            node[:] = 0
            ptr = nxt
        self.heads[slot] = self.tails[slot] = arena_mod.NULL
        self.lengths[slot] = 0

    def _append_page(self, slot: int) -> int:
        if not self.free_pages:
            raise MemoryError("KV page pool exhausted")
        phys = self.free_pages.pop()
        node_ptr = int(self.builder.alloc(1)[0])
        self.builder.data[node_ptr] = [phys, arena_mod.NULL, slot, 0]
        if self.tails[slot] == arena_mod.NULL:
            self.heads[slot] = node_ptr
        else:
            self.builder.data[self.tails[slot], NEXT] = node_ptr
        self.tails[slot] = node_ptr
        return phys

    def ensure_capacity(self, slot: int, new_len: int):
        """Appends pages until the sequence fits ``new_len`` tokens."""
        needed = -(-new_len // self.page_size)
        while self.n_alloc_pages(slot) < needed:
            self._append_page(slot)

    def n_alloc_pages(self, slot: int) -> int:
        n, ptr = 0, int(self.heads[slot])
        while ptr != arena_mod.NULL:
            n += 1
            ptr = int(self.builder.data[ptr, NEXT])
        return n

    # ------------------------- PULSE page-table walk -------------------------

    def walk_page_tables(self, max_pages: int):
        """Batched PULSE traversal of every active chain.

        Returns (page_table (B, max_pages) int32, lengths (B,) int32).
        """
        ar = self.builder.finish()
        it = page_walk_iterator(max_pages)
        heads = jnp.asarray(self.heads, jnp.int32)
        ptr0, scr0 = it.init(heads)
        # empty chains (NULL head) fault immediately -- their count stays 0
        _, scratch, status, _ = execute_batched(
            it, ar, ptr0, scr0, max_iters=max_pages + 1
        )
        table = np.asarray(scratch[:, 1 : 1 + max_pages])
        counts = np.asarray(scratch[:, 0])
        counts = np.where(np.asarray(self.heads) == arena_mod.NULL, 0, counts)
        return (
            jnp.asarray(np.where(table < 0, 0, table), jnp.int32),
            jnp.asarray(self.lengths.astype(np.int32)),
        )

    # ----------------------------- device writes ----------------------------

    def write_token(self, layer_kv, active=None):
        """Writes one new token's K/V for every active slot.

        ``layer_kv``: (k, v) each (L, B, Hk, hd) -- from the decode step.
        Must be called AFTER ensure_capacity; position = lengths[slot].
        Inactive slots write to the reserved trash page 0.
        """
        k_new, v_new = layer_kv
        B = k_new.shape[1]
        if active is None:
            active = np.ones(B, bool)
        phys = np.zeros(B, np.int32)
        offs = np.zeros(B, np.int32)
        for b in range(B):
            if not active[b] or self.heads[b] == arena_mod.NULL:
                continue  # trash page 0, offset 0
            lp = int(self.lengths[b]) // self.page_size  # logical page index
            ptr = int(self.heads[b])
            for _ in range(lp):
                ptr = int(self.builder.data[ptr, NEXT])
            phys[b] = int(self.builder.data[ptr, PHYS])
            offs[b] = int(self.lengths[b]) % self.page_size
        phys_j = jnp.asarray(phys)
        offs_j = jnp.asarray(offs)
        # (L, N, page, Hk, hd): ADJACENT advanced indices (axes 1, 2) keep
        # the broadcast (B,) dim in place -> value shape is (L, B, Hk, hd)
        self.k_pages = self.k_pages.at[:, phys_j, offs_j].set(k_new)
        self.v_pages = self.v_pages.at[:, phys_j, offs_j].set(v_new)
        self.lengths[:B] += np.asarray(active, np.int64)

    def advance(self, slots):
        for s in slots:
            self.ensure_capacity(s, int(self.lengths[s]) + 1)
