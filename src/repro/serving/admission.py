"""Admission control for the traversal serving layer (PulseService).

The CPU node in the paper (S4.1) is where requests are born: ``init()`` runs
there, and the dispatch engine decides what gets offloaded.  At serving
scale the CPU node needs an *admission* policy too -- which of the queued
traversal requests get the accelerator's finite slot budget next.

Policy implemented here:

  * **per-tenant FIFO queues** -- arrival order is preserved within a
    tenant, so a tenant's own requests never reorder;
  * **deadline-aware (EDF) selection across tenants** -- the head request
    with the earliest absolute deadline wins a free slot;
  * **fairness credits** -- ties (including the common all-deadline-free
    case) go to the tenant that has been served least, so a flooding tenant
    cannot starve a trickle tenant;
  * **per-structure capacity** -- a SIMD slot group executes one iterator
    program, so admission respects the free-slot budget of each structure
    group and skips past requests whose group is full (they keep their
    queue position).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class TraversalRequest:
    """One pointer-traversal request (the wire-format record's CPU-side twin).

    ``query`` is the structure-specific init argument (search key for
    find-style iterators, head pointer for aggregations).  ``deadline_ms``
    is relative to arrival; ``None`` means best-effort.
    """

    req_id: int
    structure: str
    query: int
    tenant: str = "default"
    deadline_ms: float | None = None
    arrive_round: int = 0  # logical arrival time (service rounds)
    value: int = 0  # write payload (inserts/updates; ignored by reads)

    # filled in by the service
    arrival_s: float = -1.0
    admit_s: float = -1.0
    finish_s: float = -1.0
    admit_round: int = -1
    finish_round: int = -1
    status: int = -1
    iters: int = 0
    result: np.ndarray | None = None  # final scratch pad

    @property
    def latency_ms(self) -> float:
        if self.finish_s < 0 or self.arrival_s < 0:
            return float("nan")
        return (self.finish_s - self.arrival_s) * 1e3

    @property
    def deadline_met(self) -> bool | None:
        if self.deadline_ms is None:
            return None
        return self.latency_ms <= self.deadline_ms


def apply_write_barriers(
    free_slots: dict[str, int],
    group_of: dict[str, str],
    writes: dict[str, bool],
    occupied: dict[str, bool],
    pending: dict[str, int],
) -> dict[str, int]:
    """Write-path admission barrier: per structure *group*, writers get the
    group exclusively.

    Rules (G = group of a slot-group; a "writer" runs a mutating iterator):

      * a write slot-group admits only while NO other slot-group of G is
        occupied -- one write batch owns the group at a time, so its commit
        supersteps never interleave with that group's reads mid-flight;
      * a read slot-group admits only while no write slot-group of G is
        occupied AND no write request for G is queued -- queued writers
        drain the readers out first (anti-starvation: a write behind a
        steady read stream would otherwise never see the group empty).

    Readers of *other* groups are untouched: the barrier is per structure
    group, exactly the scope one per-structure lock would cover.
    Returns a copy of ``free_slots`` with blocked structures zeroed.
    """
    write_occupied = {
        group_of[n] for n, occ in occupied.items() if occ and writes.get(n)
    }
    read_occupied = {
        group_of[n] for n, occ in occupied.items() if occ and not writes.get(n)
    }
    write_pending = {
        group_of[n] for n in pending if writes.get(n)
    }
    # one writer per group per round: the occupied writer keeps the group;
    # otherwise the pending writer with the OLDEST queued request (arrival
    # sequence, name as tiebreak) wins the claim -- FIFO-consistent, so the
    # winner is the writer admission would reach first, and two write
    # slot-groups of one group are never admitted into the same round
    write_winner: dict[str, str] = {}
    claims: dict[str, tuple] = {}
    for n in sorted(free_slots):
        if not writes.get(n):
            continue
        g = group_of[n]
        if n in pending:
            key = (pending[n], n)
            if g not in claims or key < claims[g]:
                claims[g] = key
                write_winner[g] = n
    for n in free_slots:  # occupied writers override pending claims
        if writes.get(n) and occupied.get(n):
            write_winner[group_of[n]] = n
    out = dict(free_slots)
    for name in out:
        g = group_of[name]
        if writes.get(name):
            if g in read_occupied or write_winner.get(g) != name:
                out[name] = 0
        else:
            if g in write_occupied or g in write_pending:
                out[name] = 0
    return out


class AdmissionController:
    """Per-tenant queues + EDF-with-fairness slot assignment."""

    def __init__(self):
        self._queues: dict[str, deque[TraversalRequest]] = {}
        self._served: dict[str, int] = {}
        self._seq = 0  # global arrival tiebreak

    def submit(self, req: TraversalRequest, now_s: float) -> None:
        req.arrival_s = now_s
        req._seq = self._seq  # type: ignore[attr-defined]
        self._seq += 1
        self._queues.setdefault(req.tenant, deque()).append(req)
        self._served.setdefault(req.tenant, 0)

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pending_by_structure(self) -> dict[str, int]:
        """Earliest queued arrival sequence per structure (presence in the
        dict == has pending work).  Drives the write barriers: the winning
        writer of a group is the one whose request has waited longest, which
        keeps the barrier consistent with FIFO admission order (a name-order
        winner could deadlock against a tenant whose queue head is the other
        writer)."""
        out: dict[str, int] = {}
        for q in self._queues.values():
            for r in q:
                s = getattr(r, "_seq", 0)
                cur = out.get(r.structure)
                out[r.structure] = s if cur is None else min(cur, s)
        return out

    def __len__(self) -> int:
        return self.pending()

    def admit(self, free_slots: dict[str, int]) -> list[TraversalRequest]:
        """Fill free slots from the queues; returns the admitted requests.

        Selection loop: among every tenant's head request whose structure
        group still has room, pick the earliest (deadline, served-credit,
        arrival) triple.  A head whose group is full blocks its tenant for
        this round (FIFO within tenant is preserved) -- the tenant's later
        requests for non-full groups wait their turn.
        """
        free = {k: int(v) for k, v in free_slots.items() if v > 0}
        admitted: list[TraversalRequest] = []
        while free:
            best_key = None
            best_tenant = None
            for tenant, q in self._queues.items():
                if not q:
                    continue
                head = q[0]
                if free.get(head.structure, 0) <= 0:
                    continue
                deadline = (
                    float("inf")
                    if head.deadline_ms is None
                    else head.arrival_s + head.deadline_ms / 1e3
                )
                key = (deadline, self._served[tenant], head._seq)  # type: ignore[attr-defined]
                if best_key is None or key < best_key:
                    best_key, best_tenant = key, tenant
            if best_tenant is None:
                break
            req = self._queues[best_tenant].popleft()
            self._served[best_tenant] += 1
            free[req.structure] -= 1
            if free[req.structure] <= 0:
                del free[req.structure]
            admitted.append(req)
        return admitted
