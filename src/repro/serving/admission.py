"""Admission control for the traversal serving layer (PulseService).

The CPU node in the paper (S4.1) is where requests are born: ``init()`` runs
there, and the dispatch engine decides what gets offloaded.  At serving
scale the CPU node needs an *admission* policy too -- which of the queued
traversal requests get the accelerator's finite slot budget next.

Policy implemented here:

  * **per-tenant FIFO queues** -- arrival order is preserved within a
    tenant, so a tenant's own requests never reorder;
  * **deadline-aware (EDF) selection across tenants** -- the head request
    with the earliest absolute deadline wins a free slot;
  * **fairness credits** -- ties (including the common all-deadline-free
    case) go to the tenant that has been served least, so a flooding tenant
    cannot starve a trickle tenant;
  * **per-structure capacity** -- a SIMD slot group executes one iterator
    program, so admission respects the free-slot budget of each structure
    group and skips past requests whose group is full (they keep their
    queue position).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque

import numpy as np


@dataclasses.dataclass
class TraversalRequest:
    """One pointer-traversal request (the wire-format record's CPU-side twin).

    ``query`` is the structure-specific init argument (search key for
    find-style iterators, head pointer for aggregations).  ``deadline_ms``
    is relative to arrival; ``None`` means best-effort.
    """

    req_id: int
    structure: str
    query: int
    tenant: str = "default"
    deadline_ms: float | None = None
    arrive_round: int = 0  # logical arrival time (service rounds)
    value: int = 0  # write payload (inserts/updates; ignored by reads)

    # filled in by the service
    arrival_s: float = -1.0
    admit_s: float = -1.0
    finish_s: float = -1.0
    admit_round: int = -1
    finish_round: int = -1
    status: int = -1
    iters: int = 0
    result: np.ndarray | None = None  # final scratch pad
    # preemption: a MAXED continuation evicted from its slot carries its
    # complete traversal state (cur_ptr + scratch_pad, paper S3/S5) back to
    # the queue and resumes from it when re-admitted
    cont_ptr: int | None = None
    cont_scratch: np.ndarray | None = None
    preemptions: int = 0
    # fault tolerance: times this request was re-queued because its shard
    # group hit a dead shard; past the retry budget it retires STATUS_RETRY
    retries: int = 0

    @property
    def latency_ms(self) -> float:
        if self.finish_s < 0 or self.arrival_s < 0:
            return float("nan")
        return (self.finish_s - self.arrival_s) * 1e3

    @property
    def deadline_met(self) -> bool | None:
        if self.deadline_ms is None:
            return None
        return self.latency_ms <= self.deadline_ms


def apply_write_barriers(
    free_slots: dict[str, int],
    group_of: dict[str, str],
    writes: dict[str, bool],
    occupied: dict[str, bool],
    pending: dict[str, int],
) -> dict[str, int]:
    """Write-path admission barrier: per structure *group*, writers get the
    group exclusively.

    Rules (G = group of a slot-group; a "writer" runs a mutating iterator):

      * a write slot-group admits only while NO other slot-group of G is
        occupied -- one write batch owns the group at a time, so its commit
        supersteps never interleave with that group's reads mid-flight;
      * a read slot-group admits only while no write slot-group of G is
        occupied AND no write request for G is queued -- queued writers
        drain the readers out first (anti-starvation: a write behind a
        steady read stream would otherwise never see the group empty).

    Readers of *other* groups are untouched: the barrier is per structure
    group, exactly the scope one per-structure lock would cover.
    Returns a copy of ``free_slots`` with blocked structures zeroed.
    """
    write_occupied = {
        group_of[n] for n, occ in occupied.items() if occ and writes.get(n)
    }
    read_occupied = {
        group_of[n] for n, occ in occupied.items() if occ and not writes.get(n)
    }
    write_pending = {
        group_of[n] for n in pending if writes.get(n)
    }
    # one writer per group per round: the occupied writer keeps the group;
    # otherwise the pending writer with the OLDEST queued request (arrival
    # sequence, name as tiebreak) wins the claim -- FIFO-consistent, so the
    # winner is the writer admission would reach first, and two write
    # slot-groups of one group are never admitted into the same round
    write_winner: dict[str, str] = {}
    claims: dict[str, tuple] = {}
    for n in sorted(free_slots):
        if not writes.get(n):
            continue
        g = group_of[n]
        if n in pending:
            key = (pending[n], n)
            if g not in claims or key < claims[g]:
                claims[g] = key
                write_winner[g] = n
    for n in free_slots:  # occupied writers override pending claims
        if writes.get(n) and occupied.get(n):
            write_winner[group_of[n]] = n
    out = dict(free_slots)
    for name in out:
        g = group_of[name]
        if writes.get(name):
            if g in read_occupied or write_winner.get(g) != name:
                out[name] = 0
        else:
            if g in write_occupied or g in write_pending:
                out[name] = 0
    return out


class TenantRateLimiter:
    """Per-tenant token bucket: ``rate_rps`` sustained, ``burst`` headroom.

    One flooding tenant drains its own bucket and gets shed at the door;
    other tenants' buckets (and therefore their admission latency) are
    untouched.  Buckets are created lazily, full, on first sight."""

    def __init__(self, rate_rps: float, burst: float | None = None):
        if rate_rps <= 0:
            raise ValueError("rate_rps must be > 0")
        self.rate = float(rate_rps)
        self.burst = float(burst) if burst is not None else max(1.0, self.rate)
        self._tokens: dict[str, float] = {}
        self._stamp: dict[str, float] = {}

    def allow(self, tenant: str, now_s: float) -> bool:
        last = self._stamp.get(tenant, now_s)
        tok = self._tokens.get(tenant, self.burst)
        tok = min(self.burst, tok + max(0.0, now_s - last) * self.rate)
        self._stamp[tenant] = now_s
        if tok >= 1.0:
            self._tokens[tenant] = tok - 1.0
            return True
        self._tokens[tenant] = tok
        return False


class AdmissionController:
    """Per-tenant queues + EDF-with-fairness slot assignment.

    Overload controls (both optional, off by default so the controller
    keeps its original accept-everything contract):

      * ``max_pending`` -- bounded admission queue: a submit that would push
        the total backlog past the bound is *shed* (rejected with
        backpressure) instead of queued, so queue depth -- and therefore
        queueing delay for already-accepted requests -- stays bounded under
        open-loop overload;
      * ``rate_limiter`` -- per-tenant token bucket applied before the
        queue-depth check, so one flooding tenant is shed at its own bucket
        and cannot consume the shared queue budget.

    Bookkeeping is incremental: per-structure min-heaps (lazy deletion)
    give O(structures) ``pending_by_structure`` and an O(1)-amortized
    earliest-deadline query instead of the previous O(backlog) scans --
    under a deep backlog the per-round admission cost no longer grows with
    the number of queued requests.
    """

    def __init__(
        self,
        *,
        max_pending: int | None = None,
        rate_limiter: TenantRateLimiter | None = None,
    ):
        self._queues: dict[str, deque[TraversalRequest]] = {}
        self._served: dict[str, int] = {}
        self._seq = 0  # global arrival tiebreak
        self._push = 0  # heap-entry tiebreak (requeues reuse _seq)
        self._pending = 0
        self.max_pending = max_pending
        self.rate_limiter = rate_limiter
        # (seq, push, req) min-heaps per structure; (abs_deadline, push, req)
        # across all structures.  Entries whose request was admitted are
        # dead; they are popped lazily when they surface at a heap head.
        self._struct_heaps: dict[str, list] = {}
        self._deadline_heap: list = []
        self.shed = 0
        self.shed_rate_limited = 0
        self.shed_queue_full = 0
        self.shed_by_tenant: dict[str, int] = {}

    def _shed(self, req: TraversalRequest, *, rate_limited: bool) -> bool:
        self.shed += 1
        self.shed_rate_limited += int(rate_limited)
        self.shed_queue_full += int(not rate_limited)
        self.shed_by_tenant[req.tenant] = self.shed_by_tenant.get(req.tenant, 0) + 1
        return False

    def _push_heaps(self, req: TraversalRequest) -> None:
        self._push += 1
        heapq.heappush(
            self._struct_heaps.setdefault(req.structure, []),
            (req._seq, self._push, req),  # type: ignore[attr-defined]
        )
        if req.deadline_ms is not None:
            heapq.heappush(
                self._deadline_heap,
                (req.arrival_s + req.deadline_ms / 1e3, self._push, req),
            )

    def submit(self, req: TraversalRequest, now_s: float) -> bool:
        """Queue ``req``; returns False (and counts a shed) when the tenant
        is over its rate or the bounded queue is full."""
        if self.rate_limiter is not None and not self.rate_limiter.allow(
            req.tenant, now_s
        ):
            return self._shed(req, rate_limited=True)
        if self.max_pending is not None and self._pending >= self.max_pending:
            return self._shed(req, rate_limited=False)
        req.arrival_s = now_s
        req._seq = self._seq  # type: ignore[attr-defined]
        req._admitted = False  # type: ignore[attr-defined]
        self._seq += 1
        self._pending += 1
        self._queues.setdefault(req.tenant, deque()).append(req)
        self._served.setdefault(req.tenant, 0)
        self._push_heaps(req)
        return True

    def requeue(self, req: TraversalRequest) -> None:
        """Return a preempted continuation to the *front* of its tenant
        queue.  The request keeps its original arrival ``_seq`` (and
        deadline), so EDF ordering treats it exactly as the old request it
        is; the served credit its admission charged is refunded so
        preemption stays fairness-neutral.  Bounded-queue and rate limits do
        not apply -- the request was already accepted once."""
        req._admitted = False  # type: ignore[attr-defined]
        self._pending += 1
        self._queues.setdefault(req.tenant, deque()).appendleft(req)
        self._served[req.tenant] = max(0, self._served.get(req.tenant, 1) - 1)
        self._push_heaps(req)

    def pending(self) -> int:
        return self._pending

    def pending_by_structure(self) -> dict[str, int]:
        """Earliest queued arrival sequence per structure (presence in the
        dict == has pending work).  Drives the write barriers: the winning
        writer of a group is the one whose request has waited longest, which
        keeps the barrier consistent with FIFO admission order (a name-order
        winner could deadlock against a tenant whose queue head is the other
        writer)."""
        out: dict[str, int] = {}
        for s, h in self._struct_heaps.items():
            while h and h[0][2]._admitted:
                heapq.heappop(h)
            if h:
                out[s] = h[0][0]
        return out

    def head_pending_by_structure(self) -> dict[str, int]:
        """Like ``pending_by_structure`` but restricted to tenant-queue
        *heads* -- the only requests ``admit`` can actually reach this
        round.  This is what the write barriers must consume: a writer
        buried mid-queue cannot take the group now, and blocking the
        group's readers on it would deadlock a tenant whose queue
        interleaves reads ahead of writes (the reads can never drain, so
        the writer never reaches its head)."""
        out: dict[str, int] = {}
        for q in self._queues.values():
            if not q:
                continue
            r = q[0]
            s = getattr(r, "_seq", 0)
            cur = out.get(r.structure)
            out[r.structure] = s if cur is None else min(cur, s)
        return out

    def peek_earliest_deadline(self) -> tuple[float, TraversalRequest] | None:
        """(absolute deadline, request) of the most urgent *queued* (not yet
        admitted) request, or None.  Feeds EDF preemption: the urgent head
        may steal a slot from a strictly-less-urgent continuation."""
        h = self._deadline_heap
        while h and h[0][2]._admitted:
            heapq.heappop(h)
        return (h[0][0], h[0][2]) if h else None

    def earliest_deadline_s(self) -> float | None:
        """Earliest absolute queued deadline, or None.  Feeds SLO-aware
        quantum sizing: a deadline waiting in the queue bounds how long the
        device may stay busy on the current batch before that request must
        get a slot."""
        peek = self.peek_earliest_deadline()
        return peek[0] if peek else None

    def __len__(self) -> int:
        return self.pending()

    def admit(self, free_slots: dict[str, int]) -> list[TraversalRequest]:
        """Fill free slots from the queues; returns the admitted requests.

        Selection loop: among every tenant's head request whose structure
        group still has room, pick the earliest (deadline, served-credit,
        arrival) triple.  A head whose group is full blocks its tenant for
        this round (FIFO within tenant is preserved) -- the tenant's later
        requests for non-full groups wait their turn.
        """
        free = {k: int(v) for k, v in free_slots.items() if v > 0}
        admitted: list[TraversalRequest] = []
        while free:
            best_key = None
            best_tenant = None
            for tenant, q in self._queues.items():
                if not q:
                    continue
                head = q[0]
                if free.get(head.structure, 0) <= 0:
                    continue
                deadline = (
                    float("inf")
                    if head.deadline_ms is None
                    else head.arrival_s + head.deadline_ms / 1e3
                )
                key = (deadline, self._served[tenant], head._seq)  # type: ignore[attr-defined]
                if best_key is None or key < best_key:
                    best_key, best_tenant = key, tenant
            if best_tenant is None:
                break
            req = self._queues[best_tenant].popleft()
            req._admitted = True  # type: ignore[attr-defined]
            self._pending -= 1
            self._served[best_tenant] += 1
            free[req.structure] -= 1
            if free[req.structure] <= 0:
                del free[req.structure]
            admitted.append(req)
        return admitted
