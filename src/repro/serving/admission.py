"""Admission control for the traversal serving layer (PulseService).

The CPU node in the paper (S4.1) is where requests are born: ``init()`` runs
there, and the dispatch engine decides what gets offloaded.  At serving
scale the CPU node needs an *admission* policy too -- which of the queued
traversal requests get the accelerator's finite slot budget next.

Policy implemented here:

  * **per-tenant FIFO queues** -- arrival order is preserved within a
    tenant, so a tenant's own requests never reorder;
  * **deadline-aware (EDF) selection across tenants** -- the head request
    with the earliest absolute deadline wins a free slot;
  * **fairness credits** -- ties (including the common all-deadline-free
    case) go to the tenant that has been served least, so a flooding tenant
    cannot starve a trickle tenant;
  * **per-structure capacity** -- a SIMD slot group executes one iterator
    program, so admission respects the free-slot budget of each structure
    group and skips past requests whose group is full (they keep their
    queue position).
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class TraversalRequest:
    """One pointer-traversal request (the wire-format record's CPU-side twin).

    ``query`` is the structure-specific init argument (search key for
    find-style iterators, head pointer for aggregations).  ``deadline_ms``
    is relative to arrival; ``None`` means best-effort.
    """

    req_id: int
    structure: str
    query: int
    tenant: str = "default"
    deadline_ms: float | None = None
    arrive_round: int = 0  # logical arrival time (service rounds)

    # filled in by the service
    arrival_s: float = -1.0
    admit_s: float = -1.0
    finish_s: float = -1.0
    admit_round: int = -1
    finish_round: int = -1
    status: int = -1
    iters: int = 0
    result: np.ndarray | None = None  # final scratch pad

    @property
    def latency_ms(self) -> float:
        if self.finish_s < 0 or self.arrival_s < 0:
            return float("nan")
        return (self.finish_s - self.arrival_s) * 1e3

    @property
    def deadline_met(self) -> bool | None:
        if self.deadline_ms is None:
            return None
        return self.latency_ms <= self.deadline_ms


class AdmissionController:
    """Per-tenant queues + EDF-with-fairness slot assignment."""

    def __init__(self):
        self._queues: dict[str, deque[TraversalRequest]] = {}
        self._served: dict[str, int] = {}
        self._seq = 0  # global arrival tiebreak

    def submit(self, req: TraversalRequest, now_s: float) -> None:
        req.arrival_s = now_s
        req._seq = self._seq  # type: ignore[attr-defined]
        self._seq += 1
        self._queues.setdefault(req.tenant, deque()).append(req)
        self._served.setdefault(req.tenant, 0)

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def __len__(self) -> int:
        return self.pending()

    def admit(self, free_slots: dict[str, int]) -> list[TraversalRequest]:
        """Fill free slots from the queues; returns the admitted requests.

        Selection loop: among every tenant's head request whose structure
        group still has room, pick the earliest (deadline, served-credit,
        arrival) triple.  A head whose group is full blocks its tenant for
        this round (FIFO within tenant is preserved) -- the tenant's later
        requests for non-full groups wait their turn.
        """
        free = {k: int(v) for k, v in free_slots.items() if v > 0}
        admitted: list[TraversalRequest] = []
        while free:
            best_key = None
            best_tenant = None
            for tenant, q in self._queues.items():
                if not q:
                    continue
                head = q[0]
                if free.get(head.structure, 0) <= 0:
                    continue
                deadline = (
                    float("inf")
                    if head.deadline_ms is None
                    else head.arrival_s + head.deadline_ms / 1e3
                )
                key = (deadline, self._served[tenant], head._seq)  # type: ignore[attr-defined]
                if best_key is None or key < best_key:
                    best_key, best_tenant = key, tenant
            if best_tenant is None:
                break
            req = self._queues[best_tenant].popleft()
            self._served[best_tenant] += 1
            free[req.structure] -= 1
            if free[req.structure] <= 0:
                del free[req.structure]
            admitted.append(req)
        return admitted
