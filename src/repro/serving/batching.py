"""Continuous batching for serving: slot-based admission + retirement.

Requests arrive with prompts; the scheduler fills free decode slots, decodes
one token per step for all active slots, retires sequences on EOS/max
tokens, and immediately backfills freed slots -- the vLLM-style serving loop
on top of the model zoo's ``prefill``/``decode_step``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int
    arrived_step: int = 0
    # filled by serving
    output: list = dataclasses.field(default_factory=list)
    finished_step: int = -1


@dataclasses.dataclass
class ServeMetrics:
    steps: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0
    prefill_calls: int = 0  # jitted prefill invocations (batched admission)
    prefill_tokens: int = 0  # prompt tokens absorbed through prefill

    @property
    def tokens_per_s(self):
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ContinuousBatcher:
    """Greedy decoding over a fixed slot count with continuous admission.

    ``prefill_mode="batched"`` (default) absorbs every admission's prompt in
    one jitted full-sequence ``model.prefill`` call per distinct prompt
    length -- admitted slots' cache entries merge into the live cache, other
    slots are untouched.  ``"token"`` is the legacy slot-isolated path that
    feeds prompt tokens one by one through ``decode_step`` (one full-batch
    decode per prompt token); it remains the reference/fallback for models
    without an LM prefill (e.g. encoder-decoder).
    """

    def __init__(
        self,
        model,
        *,
        max_batch: int,
        max_len: int,
        eos_id: int = 1,
        prefill_mode: str = "batched",
    ):
        if prefill_mode not in ("batched", "token"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if getattr(model.cfg, "family", None) == "encdec":
            # encoder-decoder prefill needs acoustic frames, not a token
            # batch -- keep the slot-isolated decode_step path
            prefill_mode = "token"
        self.model = model
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_mode = prefill_mode
        self._decode = jax.jit(model.decode_step)
        # one compiled prefill per distinct prompt length; exact lengths (no
        # padding) keep recurrent-state families (SSM/hybrid) bit-correct
        self._prefill = jax.jit(
            lambda params, toks: model.prefill(params, {"tokens": toks}, max_len)
        )

    def serve(self, requests: list[Request]) -> ServeMetrics:
        t0 = time.perf_counter()
        queue = list(requests)
        B = self.max_batch
        cache = self.model.cache_init(B, self.max_len)
        slot_req: list[Request | None] = [None] * B
        pos = np.zeros(B, np.int64)
        cur_tok = np.zeros(B, np.int32)
        metrics = ServeMetrics()

        def admit_token(s: int, req: Request):
            # legacy per-slot prefill: one full-batch decode per prompt token
            nonlocal cache
            for t, tok in enumerate(req.prompt):
                logits, cache2 = self._decode(
                    self.model_params, cache,
                    jnp.asarray(np.full(B, tok, np.int32)),
                    jnp.asarray(np.full(B, t, np.int32)),
                )
                cache = _merge_slot(cache, cache2, s)
            pos[s] = len(req.prompt)
            lg = np.asarray(logits)[s]
            cur_tok[s] = int(lg.argmax())
            req.output.append(int(cur_tok[s]))

        def admit():
            nonlocal cache
            admitted: list[tuple[int, Request]] = []
            for s in range(B):
                if slot_req[s] is None and queue:
                    req = queue.pop(0)
                    slot_req[s] = req
                    admitted.append((s, req))
            if not admitted:
                return
            if self.prefill_mode == "token":
                for s, req in admitted:
                    admit_token(s, req)
                return
            # batched prefill: one jitted call per distinct prompt length in
            # this admission; non-admitted rows carry zeros and their cache
            # entries are discarded by the slot-wise merge
            by_len: dict[int, list[tuple[int, Request]]] = {}
            for s, req in admitted:
                by_len.setdefault(len(req.prompt), []).append((s, req))
            for Lp, group in sorted(by_len.items()):
                toks = np.zeros((B, Lp), np.int32)
                for s, req in group:
                    toks[s] = req.prompt
                logits, cache2 = self._prefill(self.model_params, jnp.asarray(toks))
                slots = np.array([s for s, _ in group])
                cache = _merge_slots(cache, cache2, slots)
                metrics.prefill_calls += 1
                metrics.prefill_tokens += Lp * len(group)
                lg = np.asarray(logits)[slots, Lp - 1]
                for j, (s, req) in enumerate(group):
                    pos[s] = Lp
                    cur_tok[s] = int(lg[j].argmax())
                    req.output.append(int(cur_tok[s]))

        self.model_params = getattr(self, "model_params", None)
        if self.model_params is None:
            raise RuntimeError("set .model_params before serve()")

        admit()
        while any(r is not None for r in slot_req) or queue:
            active = np.array([r is not None for r in slot_req])
            logits, cache = self._decode(
                self.model_params, cache, jnp.asarray(cur_tok),
                jnp.asarray(pos.astype(np.int32)),
            )
            metrics.steps += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in range(B):
                req = slot_req[s]
                if req is None:
                    continue
                pos[s] += 1
                tok = int(nxt[s])
                req.output.append(tok)
                metrics.tokens_out += 1
                cur_tok[s] = tok
                done = (
                    tok == self.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or pos[s] >= self.max_len - 1
                )
                if done:
                    req.finished_step = metrics.steps
                    slot_req[s] = None
                    pos[s] = 0
            admit()
        metrics.wall_s = time.perf_counter() - t0
        return metrics


def _merge_slot(cache_old, cache_new, slot: int):
    """Takes slot ``slot``'s entries from cache_new, everything else from
    cache_old (slot-isolated prefill)."""

    def merge(a, b):
        # caches have batch on axis 1 (layers first) for KV / S / conv
        idx = [slice(None)] * a.ndim
        idx[1] = slot
        return a.at[tuple(idx)].set(b[tuple(idx)])

    return jax.tree.map(merge, cache_old, cache_new)


def _merge_slots(cache_old, cache_new, slots: np.ndarray):
    """Batched ``_merge_slot``: take every slot in ``slots`` from cache_new,
    everything else from cache_old (one gather/scatter per cache leaf)."""
    idx = jnp.asarray(slots)

    def merge(a, b):
        sel = (slice(None), idx)
        return a.at[sel].set(b[sel])

    return jax.tree.map(merge, cache_old, cache_new)


# --------------------------------------------------------------------------
# Async device-runner pipeline (PulseService's background execution thread)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class QuantumWork:
    """One traversal quantum handed to the DeviceRunner.

    ``run`` executes the device work (an ``engine.execute`` call) and
    returns its result; ``apply`` consumes that result on the runner thread
    (slot-state scatter, fast retirement, emit-event push).  Both run on the
    runner thread, strictly FIFO, so the engine-call order -- and therefore
    record/commit/arena bit-identity with the synchronous loop -- is
    preserved exactly.
    """

    label: str
    run: "callable"
    apply: "callable"


class DeviceRunner:
    """Background device-runner thread with a bounded double-buffered queue.

    The main thread admits and batches the next quantum while this thread
    keeps the current one in flight on the device (XLA drops the GIL during
    execution, so admission bookkeeping genuinely overlaps device compute).
    ``depth`` bounds the handoff queue: a submit past the bound blocks the
    producer (backpressure) instead of growing an unbounded backlog.

    Lifecycle: ``start`` -> any number of ``submit`` -> ``drain`` (barrier:
    every submitted quantum ran *and* applied) -> ``close``.  An exception
    on the runner thread is captured and re-raised on the next ``submit``
    or ``drain`` so failures surface on the producer, not silently in a
    daemon thread.
    """

    def __init__(self, depth: int = 2):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        import queue
        import threading

        self._q: "queue.Queue[QuantumWork | None]" = queue.Queue(maxsize=depth)
        self._cv = threading.Condition()
        self._unfinished = 0
        self._err: BaseException | None = None
        self._thread: threading.Thread | None = None
        self.quanta_run = 0
        self.max_queue_depth = 0  # high-water mark of the handoff queue

    def start(self) -> "DeviceRunner":
        import threading

        if self._thread is not None:
            raise RuntimeError("runner already started")
        self._thread = threading.Thread(
            target=self._loop, name="pulse-device-runner", daemon=True
        )
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            work = self._q.get()
            if work is None:
                return
            try:
                if self._err is None:  # fail fast after first error
                    work.apply(work.run())
                    self.quanta_run += 1
            except BaseException as e:  # noqa: BLE001 -- must cross threads
                # tag shard failures with the failing work's label so the
                # service can identify which slot group was in flight when
                # the error resurfaces on the producer thread
                if getattr(e, "label", "") is None:
                    e.label = work.label
                with self._cv:
                    self._err = e
            finally:
                with self._cv:
                    self._unfinished -= 1
                    self._cv.notify_all()

    def _raise_pending(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def submit(self, work: QuantumWork) -> None:
        if self._thread is None:
            raise RuntimeError("runner not started")
        self._raise_pending()
        with self._cv:
            self._unfinished += 1
        self.max_queue_depth = max(
            self.max_queue_depth, min(self._q.maxsize, self._q.qsize() + 1)
        )
        self._q.put(work)  # blocks at depth: bounded handoff

    @property
    def in_flight(self) -> int:
        with self._cv:
            return self._unfinished

    def drain(self) -> None:
        """Barrier: block until every submitted quantum has run and applied."""
        with self._cv:
            self._cv.wait_for(lambda: self._unfinished == 0)
        self._raise_pending()

    def close(self) -> None:
        if self._thread is None:
            return
        self.drain()
        self._q.put(None)
        self._thread.join()
        self._thread = None
