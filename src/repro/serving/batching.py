"""Continuous batching for serving: slot-based admission + retirement.

Requests arrive with prompts; the scheduler fills free decode slots, decodes
one token per step for all active slots, retires sequences on EOS/max
tokens, and immediately backfills freed slots -- the vLLM-style serving loop
on top of the model zoo's ``prefill``/``decode_step``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int
    arrived_step: int = 0
    # filled by serving
    output: list = dataclasses.field(default_factory=list)
    finished_step: int = -1


@dataclasses.dataclass
class ServeMetrics:
    steps: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self):
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ContinuousBatcher:
    """Greedy decoding over a fixed slot count with continuous admission."""

    def __init__(self, model, *, max_batch: int, max_len: int, eos_id: int = 1):
        self.model = model
        self.cfg = model.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(model.decode_step)

    def serve(self, requests: list[Request]) -> ServeMetrics:
        t0 = time.perf_counter()
        queue = list(requests)
        B = self.max_batch
        cache = self.model.cache_init(B, self.max_len)
        slot_req: list[Request | None] = [None] * B
        pos = np.zeros(B, np.int64)
        cur_tok = np.zeros(B, np.int32)
        metrics = ServeMetrics()

        def admit():
            nonlocal cache
            for s in range(B):
                if slot_req[s] is None and queue:
                    req = queue.pop(0)
                    slot_req[s] = req
                    # per-slot prefill: feed prompt tokens one by one through
                    # decode_step (slot-isolated; batched prefill is the
                    # benchmark path)
                    for t, tok in enumerate(req.prompt):
                        logits, cache2 = self._decode(
                            self.model_params, cache,
                            jnp.asarray(np.full(B, tok, np.int32)),
                            jnp.asarray(np.full(B, t, np.int32)),
                        )
                        cache = _merge_slot(cache, cache2, s)
                    pos[s] = len(req.prompt)
                    lg = np.asarray(logits)[s]
                    cur_tok[s] = int(lg.argmax())
                    req.output.append(int(cur_tok[s]))

        self.model_params = getattr(self, "model_params", None)
        if self.model_params is None:
            raise RuntimeError("set .model_params before serve()")

        admit()
        while any(r is not None for r in slot_req) or queue:
            active = np.array([r is not None for r in slot_req])
            logits, cache = self._decode(
                self.model_params, cache, jnp.asarray(cur_tok),
                jnp.asarray(pos.astype(np.int32)),
            )
            metrics.steps += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in range(B):
                req = slot_req[s]
                if req is None:
                    continue
                pos[s] += 1
                tok = int(nxt[s])
                req.output.append(tok)
                metrics.tokens_out += 1
                cur_tok[s] = tok
                done = (
                    tok == self.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or pos[s] >= self.max_len - 1
                )
                if done:
                    req.finished_step = metrics.steps
                    slot_req[s] = None
                    pos[s] = 0
            admit()
        metrics.wall_s = time.perf_counter() - t0
        return metrics


def _merge_slot(cache_old, cache_new, slot: int):
    """Takes slot ``slot``'s entries from cache_new, everything else from
    cache_old (slot-isolated prefill)."""

    def merge(a, b):
        # caches have batch on axis 1 (layers first) for KV / S / conv
        idx = [slice(None)] * a.ndim
        idx[1] = slot
        return a.at[tuple(idx)].set(b[tuple(idx)])

    return jax.tree.map(merge, cache_old, cache_new)
