"""PulseService: continuous-batching front end for pointer traversals.

The repo's engine exposes one-shot ``PulseEngine.execute`` calls; this module
turns it into a *serving system* in the style of the vLLM-ish token loop in
``serving/batching.py``, but for the paper's workload -- heterogeneous
traversal requests (list walk, BST/B-tree lookup, skiplist search, hash-chain
probe) arriving from many tenants:

  * **slot groups** -- a SIMD batch executes one iterator program, so each
    registered structure owns a fixed group of slots; all groups share one
    admission queue.
  * **continuous batching via continuations** -- each scheduling round runs
    every occupied group for a ``quantum`` of iterations.  Requests that
    finish retire and free their slot *immediately* (backfilled in the same
    round); unfinished requests come back as STATUS_MAXED continuations --
    ``(cur_ptr, scratch_pad)`` is the complete traversal state (paper S3/S5),
    so resuming them next round is exactly the paper's "continuing stateful
    iterator execution", repurposed as a preemption mechanism.
  * **admission** -- per-tenant queues with deadline-aware (EDF) scheduling
    and fairness credits (``serving/admission.py``).
  * **accounting** -- p50/p99 latency, throughput, deadline hit rate,
    per-tenant breakdowns, plus the engine-side stats (supersteps, wire
    words, wave-scheduler savings) aggregated over the run.

The service runs identically over the engine's local XLA path, the
pulse_chase kernel path (``backend="kernel"``), and the distributed
superstep path (engine constructed with a mesh) -- admission is above the
dispatch decision, like the paper's CPU node.

**Write tenants** -- a spec whose iterator mutates (inserts/deletes/updates,
``StructureSpec.writes``) is admitted under a per-structure-group barrier
(``admission.apply_write_barriers``): a write batch owns its group
exclusively, queued writers drain readers out first, and the engine's
resident arena is swapped to the post-commit state after every mutating
quantum -- so the next round's reads (any group) traverse the updated heap.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax.numpy as jnp

from repro.core.arena import NULL
from repro.core.engine import PulseEngine
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_FAULT,
    STATUS_MAXED,
    PulseIterator,
)
from repro.serving.admission import (
    AdmissionController,
    TraversalRequest,
    apply_write_barriers,
)


@dataclasses.dataclass(frozen=True)
class StructureSpec:
    """A servable structure: the iterator program + its fixed init arguments
    (root pointer, bucket heads, ...).  ``init`` is called per admission
    batch with the admitted queries.

    ``group`` names the structure *family* the spec operates on (defaults to
    the spec's registered name): a mutating spec ("list_insert") and the
    read spec over the same heap region ("list") share a group, and the
    admission barrier gives writers the group exclusively
    (``admission.apply_write_barriers``).  Mutability is derived from the
    iterator itself."""

    iterator: PulseIterator
    init_args: tuple = ()
    group: str | None = None
    # True for specs whose init() takes (keys, values, ...) -- inserts and
    # updates consume the request's write payload (TraversalRequest.value)
    takes_value: bool = False

    @property
    def writes(self) -> bool:
        return self.iterator.mutates


@dataclasses.dataclass
class ServiceMetrics:
    rounds: int = 0
    engine_calls: int = 0
    retired: int = 0  # every request that left its slot, any status
    completed: int = 0  # retired successfully (DONE only)
    faulted: int = 0
    timed_out: int = 0  # retired at max_request_iters
    wall_s: float = 0.0
    lane_iters: int = 0  # productive iterations executed
    slot_rounds: int = 0  # occupied slot-rounds (for utilization)
    capacity_rounds: int = 0  # total slot-rounds available
    latencies_ms: list = dataclasses.field(default_factory=list)
    per_tenant: dict = dataclasses.field(default_factory=dict)
    deadlines_met: int = 0
    deadlines_missed: int = 0
    # engine-side aggregates (distributed path only)
    supersteps: int = 0
    wire_words: int = 0
    # write path: mutations committed + requests served by mutating specs
    commits: int = 0
    writes_retired: int = 0

    def _pct(self, p: float) -> float:
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_ms), p))

    @property
    def p50_ms(self) -> float:
        return self._pct(50)

    @property
    def p99_ms(self) -> float:
        return self._pct(99)

    @property
    def throughput_rps(self) -> float:
        return self.completed / self.wall_s if self.wall_s else 0.0

    @property
    def utilization(self) -> float:
        return self.slot_rounds / self.capacity_rounds if self.capacity_rounds else 0.0

    @property
    def deadline_hit_rate(self) -> float:
        n = self.deadlines_met + self.deadlines_missed
        return self.deadlines_met / n if n else float("nan")

    def summary(self) -> str:
        return (
            f"retired={self.retired} completed={self.completed} "
            f"faulted={self.faulted} "
            f"timed_out={self.timed_out} rounds={self.rounds} "
            f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
            f"throughput={self.throughput_rps:.0f} req/s "
            f"util={self.utilization:.0%}"
        )


class _SlotGroup:
    """Fixed-width slot block for one structure (one compiled batch shape)."""

    def __init__(self, name: str, spec: StructureSpec, n_slots: int):
        self.name = name
        self.spec = spec
        self.n_slots = n_slots
        S = spec.iterator.scratch_words
        self.req: list[TraversalRequest | None] = [None] * n_slots
        self.ptr = np.full(n_slots, NULL, np.int32)
        self.scratch = np.zeros((n_slots, S), np.int32)
        self.iters = np.zeros(n_slots, np.int64)

    def free_slots(self) -> int:
        return sum(r is None for r in self.req)

    def occupied(self) -> np.ndarray:
        return np.array([r is not None for r in self.req])


class PulseService:
    """Continuous-batching traversal server over a PulseEngine."""

    def __init__(
        self,
        engine: PulseEngine,
        structures: dict[str, StructureSpec],
        *,
        slots_per_structure: int = 32,
        quantum: int = 16,
        max_request_iters: int = 1 << 16,
        backend: str = "xla",
        compact: bool = True,
        fused: bool = True,
        schedule: str = "auto",
        fabric: str = "dense",
    ):
        if quantum < 1:
            raise ValueError("quantum must be >= 1")
        self.engine = engine
        self.backend = backend
        self.compact = compact
        # fused quanta share one compiled whole-traversal executable per
        # (structure, slot shape) and reuse the device-resident arena, so
        # steady-state rounds neither retrace nor re-upload the heap
        self.fused = fused
        # "auto" resolves per-iterator through the dispatch engine's overlap
        # model -- normally the wavefront-pipelined schedule, which overlaps
        # the in-flight wavefront's collective with resident local chasing
        self.schedule = schedule
        self.fabric = fabric
        self.quantum = quantum
        self.max_request_iters = max_request_iters
        self.groups = {
            name: _SlotGroup(name, spec, slots_per_structure)
            for name, spec in structures.items()
        }
        self.admission = AdmissionController()
        self.metrics = ServiceMetrics()
        self._pending_arrivals: list[TraversalRequest] = []

    # ------------------------------ intake -----------------------------------

    def submit(self, req: TraversalRequest) -> None:
        """Queue a request for admission (arrive_round gates logical time)."""
        if req.structure not in self.groups:
            raise KeyError(f"unknown structure {req.structure!r}")
        self._pending_arrivals.append(req)

    # ------------------------------ serving ----------------------------------

    def _admit(self, now_s: float, rnd: int) -> None:
        arrivals = [r for r in self._pending_arrivals if r.arrive_round <= rnd]
        self._pending_arrivals = [
            r for r in self._pending_arrivals if r.arrive_round > rnd
        ]
        for r in arrivals:
            self.admission.submit(r, now_s)
        free = {name: g.free_slots() for name, g in self.groups.items()}
        # write-path barrier: writers take their structure group exclusively
        free = apply_write_barriers(
            free,
            {n: g.spec.group or n for n, g in self.groups.items()},
            {n: g.spec.writes for n, g in self.groups.items()},
            {n: bool(g.occupied().any()) for n, g in self.groups.items()},
            self.admission.pending_by_structure(),
        )
        admitted = self.admission.admit(free)
        by_group: dict[str, list[TraversalRequest]] = {}
        for r in admitted:
            by_group.setdefault(r.structure, []).append(r)
        for name, reqs in by_group.items():
            g = self.groups[name]
            queries = jnp.asarray(
                np.array([r.query for r in reqs], np.int32)
            )
            if g.spec.takes_value:
                values = jnp.asarray(np.array([r.value for r in reqs], np.int32))
                ptr0, scr0 = g.spec.iterator.init(queries, values, *g.spec.init_args)
            else:
                ptr0, scr0 = g.spec.iterator.init(queries, *g.spec.init_args)
            ptr0 = np.asarray(ptr0, np.int32)
            scr0 = np.asarray(scr0, np.int32)
            free_idx = [i for i, r in enumerate(g.req) if r is None]
            for j, r in enumerate(reqs):
                s = free_idx[j]
                g.req[s] = r
                g.ptr[s] = ptr0[j]
                g.scratch[s] = scr0[j]
                g.iters[s] = 0
                r.admit_s = now_s
                r.admit_round = rnd

    def _retire(self, g: _SlotGroup, slot: int, status: int, now_s: float, rnd: int):
        r = g.req[slot]
        assert r is not None
        r.status = int(status)
        r.iters = int(g.iters[slot])
        r.result = g.scratch[slot].copy()
        r.finish_s = now_s
        r.finish_round = rnd
        g.req[slot] = None
        g.ptr[slot] = NULL
        m = self.metrics
        m.retired += 1
        m.writes_retired += int(g.spec.writes)
        m.completed += int(status == STATUS_DONE)
        m.faulted += int(status == STATUS_FAULT)
        m.timed_out += int(status == STATUS_MAXED)
        m.latencies_ms.append(r.latency_ms)
        t = m.per_tenant.setdefault(
            r.tenant, {"completed": 0, "latencies_ms": []}
        )
        t["completed"] += int(status == STATUS_DONE)
        t["latencies_ms"].append(r.latency_ms)
        met = r.deadline_met
        if met is not None:
            if met:
                m.deadlines_met += 1
            else:
                m.deadlines_missed += 1

    def _run_group(self, g: _SlotGroup, now_s: float, rnd: int) -> None:
        occ = g.occupied()
        if not occ.any():
            return
        # NULL pointers in padding (free) slots fault on the first iteration,
        # so a fixed-width batch costs one compiled shape per group.
        res = self.engine.execute(
            g.spec.iterator,
            g.ptr.copy(),
            g.scratch.copy(),
            max_iters=self.quantum,
            backend=self.backend,
            compact=self.compact,
            fused=self.fused,
            schedule=self.schedule,
            fabric=self.fabric,
        )
        self.metrics.engine_calls += 1
        stats = res.stats
        if stats is not None and hasattr(stats, "supersteps"):
            self.metrics.supersteps += stats.supersteps
            self.metrics.wire_words += stats.total_wire_words
            self.metrics.commits += getattr(stats, "commits", 0)
        for s in np.flatnonzero(occ):
            g.ptr[s] = res.ptr[s]
            g.scratch[s] = res.scratch[s]
            g.iters[s] += int(res.iters[s])
            self.metrics.lane_iters += int(res.iters[s])
            st = int(res.status[s])
            if st == STATUS_MAXED and g.iters[s] < self.max_request_iters:
                continue  # continuation: stays in its slot, resumes next round
            self._retire(g, int(s), st, now_s, rnd)

    def _busy(self) -> bool:
        return (
            bool(self._pending_arrivals)
            or self.admission.pending() > 0
            or any(g.occupied().any() for g in self.groups.values())
        )

    def step(self, rnd: int | None = None) -> None:
        """One scheduling round: admit -> run every occupied group -> retire."""
        m = self.metrics
        rnd = m.rounds if rnd is None else rnd
        now = time.perf_counter()
        self._admit(now, rnd)
        for g in self.groups.values():
            occupied_before = int(g.occupied().sum())  # count before retirement
            self._run_group(g, time.perf_counter(), rnd)
            m.slot_rounds += occupied_before
            m.capacity_rounds += g.n_slots
        m.rounds += 1

    def run(
        self,
        requests: list[TraversalRequest] | None = None,
        *,
        max_rounds: int = 100_000,
    ) -> ServiceMetrics:
        """Serve until every submitted request has retired."""
        t0 = time.perf_counter()
        for r in requests or []:
            self.submit(r)
        while self._busy():
            if self.metrics.rounds >= max_rounds:
                raise RuntimeError(f"service did not drain in {max_rounds} rounds")
            self.step()
        self.metrics.wall_s += time.perf_counter() - t0
        return self.metrics
