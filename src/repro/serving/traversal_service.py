"""PulseService: continuous-batching front end for pointer traversals.

The repo's engine exposes one-shot ``PulseEngine.execute`` calls; this module
turns it into a *serving system* in the style of the vLLM-ish token loop in
``serving/batching.py``, but for the paper's workload -- heterogeneous
traversal requests (list walk, BST/B-tree lookup, skiplist search, hash-chain
probe) arriving from many tenants:

  * **slot groups** -- a SIMD batch executes one iterator program, so each
    registered structure owns a fixed group of slots; all groups share one
    admission queue.
  * **continuous batching via continuations** -- each scheduling round runs
    every occupied group for a ``quantum`` of iterations.  Requests that
    finish retire and free their slot *immediately* (backfilled in the same
    round); unfinished requests come back as STATUS_MAXED continuations --
    ``(cur_ptr, scratch_pad)`` is the complete traversal state (paper S3/S5),
    so resuming them next round is exactly the paper's "continuing stateful
    iterator execution", repurposed as a preemption mechanism.
  * **admission** -- per-tenant queues with deadline-aware (EDF) scheduling
    and fairness credits (``serving/admission.py``).
  * **accounting** -- p50/p99 latency, throughput, deadline hit rate,
    per-tenant breakdowns, plus the engine-side stats (supersteps, wire
    words, wave-scheduler savings) aggregated over the run.

The service runs identically over the engine's local XLA path, the
pulse_chase kernel path (``backend="kernel"``), and the distributed
superstep path (engine constructed with a mesh) -- admission is above the
dispatch decision, like the paper's CPU node.

**Write tenants** -- a spec whose iterator mutates (inserts/deletes/updates,
``StructureSpec.writes``) is admitted under a per-structure-group barrier
(``admission.apply_write_barriers``): a write batch owns its group
exclusively, queued writers drain readers out first, and the engine's
resident arena is swapped to the post-commit state after every mutating
quantum -- so the next round's reads (any group) traverse the updated heap.
"""

from __future__ import annotations

import dataclasses
import random
import time
from collections import deque

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import routing
from repro.core.arena import NULL, remap_shards
from repro.core.engine import PulseEngine
from repro.core.faults import ShardFailure
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_FAULT,
    STATUS_MAXED,
    STATUS_RETRY,
    STATUS_SHED,
    PulseIterator,
)
from repro.serving.admission import (
    AdmissionController,
    TenantRateLimiter,
    TraversalRequest,
    apply_write_barriers,
)
from repro.serving.batching import DeviceRunner, QuantumWork

__all__ = [
    "PulseService",
    "StructureSpec",
    "ServiceMetrics",
    # status re-exports: these historically lived here; core.iterator is now
    # the single home for every STATUS_* constant
    "STATUS_SHED",
    "STATUS_RETRY",
]


@dataclasses.dataclass(frozen=True)
class StructureSpec:
    """A servable structure: the iterator program + its fixed init arguments
    (root pointer, bucket heads, ...).  ``init`` is called per admission
    batch with the admitted queries.

    ``group`` names the structure *family* the spec operates on (defaults to
    the spec's registered name): a mutating spec ("list_insert") and the
    read spec over the same heap region ("list") share a group, and the
    admission barrier gives writers the group exclusively
    (``admission.apply_write_barriers``).  Mutability is derived from the
    iterator itself."""

    iterator: PulseIterator
    init_args: tuple = ()
    group: str | None = None
    # True for specs whose init() takes (keys, values, ...) -- inserts and
    # updates consume the request's write payload (TraversalRequest.value)
    takes_value: bool = False

    @property
    def writes(self) -> bool:
        return self.iterator.mutates


@dataclasses.dataclass
class ServiceMetrics:
    rounds: int = 0
    engine_calls: int = 0
    retired: int = 0  # every request that left its slot, any status
    completed: int = 0  # retired successfully (DONE only)
    faulted: int = 0
    timed_out: int = 0  # retired at max_request_iters
    wall_s: float = 0.0
    lane_iters: int = 0  # productive iterations executed
    slot_rounds: int = 0  # occupied slot-rounds (for utilization)
    capacity_rounds: int = 0  # total slot-rounds available
    latencies_ms: list = dataclasses.field(default_factory=list)
    per_tenant: dict = dataclasses.field(default_factory=dict)
    deadlines_met: int = 0
    deadlines_missed: int = 0
    # engine-side aggregates (distributed path only)
    supersteps: int = 0
    wire_words: int = 0
    # write path: mutations committed + requests served by mutating specs
    commits: int = 0
    writes_retired: int = 0
    # overload + pipeline accounting
    shed: int = 0  # arrivals rejected (rate limit or bounded queue)
    preempted: int = 0  # continuations evicted for an urgent deadline
    queue_depth_max: int = 0  # admission-queue high-water mark
    quantum_min_used: int = 0  # smallest / largest quantum any round ran
    quantum_max_used: int = 0
    # fault tolerance (chaos runs): shard deaths recovered from, commits
    # replayed out of the durable log, requests re-queued off dead shards
    recoveries: int = 0
    replayed_commits: int = 0
    retries: int = 0
    retry_exhausted: int = 0  # requests retired STATUS_RETRY (budget spent)
    recovery_ms_total: float = 0.0
    # replication + elasticity: read quanta that fanned out to a replica
    # while a primary was dead, write quanta shipped to the hot standby,
    # watchdog probe accounting, and completed live reshards
    failover_quanta: int = 0
    replica_quanta: int = 0
    watchdog_probes: int = 0
    watchdog_suspects: int = 0
    reshards: int = 0
    reshard_drain_rounds: int = 0

    def _pct(self, p: float) -> float:
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_ms), p))

    @property
    def p50_ms(self) -> float:
        return self._pct(50)

    @property
    def p99_ms(self) -> float:
        return self._pct(99)

    @property
    def p999_ms(self) -> float:
        return self._pct(99.9)

    @property
    def throughput_rps(self) -> float:
        return self.completed / self.wall_s if self.wall_s else 0.0

    @property
    def utilization(self) -> float:
        return self.slot_rounds / self.capacity_rounds if self.capacity_rounds else 0.0

    @property
    def mean_recovery_ms(self) -> float:
        if not self.recoveries:
            return float("nan")
        return self.recovery_ms_total / self.recoveries

    @property
    def deadline_hit_rate(self) -> float:
        n = self.deadlines_met + self.deadlines_missed
        return self.deadlines_met / n if n else float("nan")

    def summary(self) -> str:
        return (
            f"retired={self.retired} completed={self.completed} "
            f"faulted={self.faulted} "
            f"timed_out={self.timed_out} rounds={self.rounds} "
            f"p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
            f"throughput={self.throughput_rps:.0f} req/s "
            f"util={self.utilization:.0%} shed={self.shed}"
        )


def _make_probe_iterator() -> PulseIterator:
    """One-touch read iterator for the shard watchdog: loads a single node
    from a chosen shard's range and finishes.  It runs through the same
    dispatched superstep path as real traffic, so a delay-faulted straggler
    stalls the probe for its full injected latency -- exactly the signal the
    watchdog escalates to suspected-dead (a straggler never raises
    ``ShardFailure`` on its own; this closes that blind spot)."""

    def end_fn(node, ptr, scr):
        return jnp.bool_(True), scr.at[0].set(node[0])

    def next_fn(node, ptr, scr):
        return jnp.int32(NULL), scr

    return PulseIterator(
        scratch_words=1, next_fn=next_fn, end_fn=end_fn, name="shard_probe"
    )


_PROBE_IT = _make_probe_iterator()


class _SlotGroup:
    """Fixed-width slot block for one structure (one compiled batch shape)."""

    def __init__(self, name: str, spec: StructureSpec, n_slots: int):
        self.name = name
        self.spec = spec
        self.n_slots = n_slots
        S = spec.iterator.scratch_words
        self.req: list[TraversalRequest | None] = [None] * n_slots
        self.ptr = np.full(n_slots, NULL, np.int32)
        self.scratch = np.zeros((n_slots, S), np.int32)
        self.iters = np.zeros(n_slots, np.int64)
        # fault tolerance: a group whose quantum hit a dead shard is parked
        # (occupants kept, admission blocked) until this round; consecutive
        # failures drive the exponential backoff
        self.backoff_until = -1
        self.fail_streak = 0

    def free_slots(self) -> int:
        return sum(r is None for r in self.req)

    def occupied(self) -> np.ndarray:
        return np.array([r is not None for r in self.req])


class PulseService:
    """Continuous-batching traversal server over a PulseEngine."""

    def __init__(
        self,
        engine: PulseEngine,
        structures: dict[str, StructureSpec],
        *,
        slots_per_structure: int = 32,
        quantum: int = 16,
        max_request_iters: int = 1 << 16,
        backend: str = "xla",
        compact: bool = True,
        fused: bool = True,
        schedule: str = "auto",
        fabric: str = "dense",
        pipeline: str = "sync",
        runner_depth: int = 2,
        min_quantum: int | None = None,
        max_quantum: int | None = None,
        slo_safety: float = 0.5,
        preempt: bool = False,
        max_pending: int | None = None,
        rate_limit_rps: float | None = None,
        rate_limit_burst: float | None = None,
        fault_tolerance=None,
    ):
        if quantum < 1:
            raise ValueError("quantum must be >= 1")
        if pipeline not in ("sync", "async"):
            raise ValueError(f"pipeline must be 'sync' or 'async', got {pipeline!r}")
        self.engine = engine
        self.backend = backend
        self.compact = compact
        # fused quanta share one compiled whole-traversal executable per
        # (structure, slot shape) and reuse the device-resident arena, so
        # steady-state rounds neither retrace nor re-upload the heap
        self.fused = fused
        # "auto" resolves per-iterator through the dispatch engine's overlap
        # model -- normally the wavefront-pipelined schedule, which overlaps
        # the in-flight wavefront's collective with resident local chasing
        self.schedule = schedule
        self.fabric = fabric
        self.quantum = quantum
        self.max_request_iters = max_request_iters
        # pipeline="async": a background DeviceRunner thread keeps the
        # current quantum in flight while this thread drains emit events and
        # books the next round's admissions.  Engine calls stay strictly
        # FIFO on the runner, so results/commits/arenas are bit-identical to
        # the synchronous loop under the same quantum policy.
        self.pipeline = pipeline
        self.runner_depth = runner_depth
        self._runner: DeviceRunner | None = None
        # SLO-aware quantum sizing: rounds run [min_quantum, max_quantum]
        # iterations, grown multiplicatively while no deadline is at risk
        # and shrunk to fit the earliest deadline's headroom (EWMA ms/iter
        # estimate).  Defaults (None) pin both bounds to ``quantum`` --
        # i.e. the legacy fixed-quantum behavior.
        self.min_quantum = min_quantum if min_quantum is not None else quantum
        self.max_quantum = max_quantum if max_quantum is not None else quantum
        if not 1 <= self.min_quantum <= self.max_quantum:
            raise ValueError("need 1 <= min_quantum <= max_quantum")
        self.slo_safety = slo_safety
        self._cur_quantum = min(max(quantum, self.min_quantum), self.max_quantum)
        self._ms_per_iter: float | None = None
        # EDF preemption: an urgent queued deadline may evict a MAXED
        # continuation (its (ptr, scratch) is complete traversal state)
        # from a full read group; the evictee requeues at its original
        # arrival order and resumes where it stopped.
        self.preempt = preempt
        # Admission-time static verification (pulse-verify): an ISA-backed
        # spec whose iterator carries no certificate is verified HERE --
        # before any slot group exists, so an unsafe tenant program is
        # rejected with instruction-level diagnostics rather than faulting
        # mid-traversal on a remote shard.  Iterators built through
        # ``isa.as_pulse_iterator`` arrive already certified (facts set) and
        # skip the re-analysis; hand-written JAX iterators have no Program
        # to analyze and stay under the conservative runtime checks.
        for name, spec in structures.items():
            self._verify_spec(name, spec)
        self.groups = {
            name: _SlotGroup(name, spec, slots_per_structure)
            for name, spec in structures.items()
        }
        limiter = (
            TenantRateLimiter(rate_limit_rps, rate_limit_burst)
            if rate_limit_rps is not None
            else None
        )
        self.admission = AdmissionController(
            max_pending=max_pending, rate_limiter=limiter
        )
        self.metrics = ServiceMetrics()
        # fault tolerance (arena_ft.FaultToleranceConfig): snapshot + commit
        # log durability for write quanta, shard-failure detection, and
        # degraded-mode serving (backoff + retry budget) while recovering
        self.ft = fault_tolerance
        self._detector = None
        self._dead_until: dict[int, int] = {}  # shard -> revive round
        self._ft_rng = None
        self._writes_since_snapshot = 0
        # hot-shard replication (arena_ft.ReplicationConfig): a log-shipped
        # standby mirrors designated shards; reads fan out to it when a
        # primary dies (or always, under policy="spread")
        self._replicas = None
        # per-quantum shard watchdog (ft.watchdog_timeout_s > 0): probes on
        # a logical round clock catch stragglers that never raise
        self._watchdog = None
        self._wd_round = -1
        if self.ft is not None:
            from repro.distributed.arena_ft import ReplicaSet
            from repro.distributed.elastic import (
                HeartbeatMonitor,
                ShardFailureDetector,
            )

            for name, spec in structures.items():
                if spec.writes:
                    self.ft.store.register_iterator(name, spec.iterator)
            # recovery always needs an anchor state to replay from
            self.ft.store.ensure_baseline(engine.arena)
            self._detector = ShardFailureDetector(engine.arena.num_shards)
            self._ft_rng = random.Random(self.ft.seed)
            rep = getattr(self.ft, "replication", None)
            if rep is not None:
                if engine.mesh is None or engine.arena.num_shards < 2:
                    raise ValueError(
                        "replication needs a distributed engine (mesh) with "
                        ">= 2 shards"
                    )
                plan = routing.make_replica_plan(
                    engine.arena.num_shards, rep.primaries, policy=rep.policy
                )
                self._replicas = ReplicaSet(plan, engine.arena)
            if getattr(self.ft, "watchdog_timeout_s", 0.0) > 0:
                if engine.mesh is None or engine.arena.num_shards < 2:
                    raise ValueError(
                        "the shard watchdog needs a distributed engine (mesh)"
                    )
                # timeout of one round on the logical clock = a shard is
                # suspected only after TWO consecutive slow probes -- one
                # transient scheduling hiccup on a loaded host never
                # degrades a healthy shard
                self._watchdog = HeartbeatMonitor(
                    engine.arena.num_shards,
                    timeout_s=1,
                    clock=lambda: self._wd_round,
                )
        # live resharding: owner-function epochs + the drain/cutover planner
        from repro.distributed.elastic import ReshardPlanner
        from repro.distributed.sharding import VersionedOwnerMap

        self._owner_map = VersionedOwnerMap(np.asarray(engine.arena.bounds))
        self._reshard = ReshardPlanner()
        self._pending_arrivals: list[TraversalRequest] = []
        # retirement events (writes?, request) pushed by whichever thread
        # retires; accounting drains them on the main thread
        self._emit: deque = deque()
        if self._watchdog is not None:
            # compile + warm the probe path so the first timed watchdog
            # round does not read XLA compile time as a stall
            for s in range(engine.arena.num_shards):
                self._probe_shard(s, warm=True)

    # ------------------------------ intake -----------------------------------

    @staticmethod
    def _verify_spec(name: str, spec: StructureSpec) -> None:
        """Reject-before-enqueue: statically verify an ISA-backed spec.

        A ``PulseIterator`` built by ``isa.as_pulse_iterator`` already went
        through pulse-verify (``facts`` is set) -- nothing to do.  One built
        around a raw ``Program`` some other way (facts absent but a
        ``__wrapped_program__`` attached to its step/mut function) is
        verified now; rejection raises the verifier's ``VerifyError`` --
        structured, instruction-pointed diagnostics under ``.diagnostics``
        -- annotated with the structure name, and the service never
        constructs a slot group for it.
        """
        it = spec.iterator
        if it.facts is not None:
            return
        prog = None
        for fn in (it.step_fn, it.mut_fn):
            prog = getattr(fn, "__wrapped_program__", None)
            if prog is not None:
                break
        if prog is None:
            return  # hand-written JAX iterator: no Program to analyze
        from repro.core.verify import VerifyError, verify_program

        try:
            verify_program(prog)
        except VerifyError as e:
            raise VerifyError(
                f"{e.name} (registered as structure {name!r})", e.diagnostics
            ) from None

    def submit(self, req: TraversalRequest) -> None:
        """Queue a request for admission (arrive_round gates logical time)."""
        if req.structure not in self.groups:
            raise KeyError(f"unknown structure {req.structure!r}")
        self._pending_arrivals.append(req)

    # ------------------------------ serving ----------------------------------

    def _intake(self, now_s: float, rnd: int) -> None:
        arrivals = [r for r in self._pending_arrivals if r.arrive_round <= rnd]
        self._pending_arrivals = [
            r for r in self._pending_arrivals if r.arrive_round > rnd
        ]
        m = self.metrics
        for r in arrivals:
            if not self.admission.submit(r, now_s):
                r.status = STATUS_SHED
                m.shed += 1
        m.queue_depth_max = max(m.queue_depth_max, self.admission.pending())

    def _maybe_preempt(self, now_s: float) -> None:
        """EDF slot stealing: if the most urgent *queued* deadline targets a
        full read group holding a strictly-less-urgent resumable
        continuation, evict that continuation (its (cur_ptr, scratch_pad)
        is complete traversal state) and requeue it at its original arrival
        order.  At most one eviction per round."""
        peek = self.admission.peek_earliest_deadline()
        if peek is None:
            return
        urgent_dl, urgent = peek
        g = self.groups.get(urgent.structure)
        if g is None or g.spec.writes or g.free_slots() > 0:
            return  # write batches own their group; free slots need no theft
        victim, victim_dl = -1, -1.0
        for s, r in enumerate(g.req):
            if r is None or g.iters[s] <= 0:
                continue  # only continuations that already ran a quantum
            dl = (
                float("inf")
                if r.deadline_ms is None
                else r.arrival_s + r.deadline_ms / 1e3
            )
            if victim < 0 or dl > victim_dl:
                victim, victim_dl = s, dl
        if victim < 0 or victim_dl <= urgent_dl:
            return  # nobody on-device is less urgent than the queued head
        v = g.req[victim]
        if v.tenant == urgent.tenant and getattr(v, "_seq", 0) < getattr(
            urgent, "_seq", 0
        ):
            # per-tenant FIFO: the requeued victim would sit ahead of the
            # urgent request in its own tenant queue, so eviction cannot
            # help -- it would only thrash the slot
            return
        r = g.req[victim]
        r.cont_ptr = int(g.ptr[victim])
        r.cont_scratch = g.scratch[victim].copy()
        r.iters = int(g.iters[victim])
        r.preemptions += 1
        g.req[victim] = None
        g.ptr[victim] = NULL
        self.admission.requeue(r)
        self.metrics.preempted += 1

    def _admit(self, now_s: float, rnd: int) -> None:
        self._intake(now_s, rnd)
        if self.preempt:
            self._maybe_preempt(now_s)
        free = {name: g.free_slots() for name, g in self.groups.items()}
        # a group parked on a dead shard admits nobody until its backoff
        # expires: the retried batch must re-run with its composition intact
        # (identical batch -> identical allocation order -> bit-identical
        # post-recovery arena)
        for name, g in self.groups.items():
            if g.backoff_until > rnd:
                free[name] = 0
        # write-path barrier: writers take their structure group exclusively
        free = apply_write_barriers(
            free,
            {n: g.spec.group or n for n, g in self.groups.items()},
            {n: g.spec.writes for n, g in self.groups.items()},
            {n: bool(g.occupied().any()) for n, g in self.groups.items()},
            # head-only pending: a writer buried behind its tenant's queued
            # reads must not block those reads (circular wait otherwise)
            self.admission.head_pending_by_structure(),
        )
        admitted = self.admission.admit(free)
        by_group: dict[str, list[TraversalRequest]] = {}
        for r in admitted:
            by_group.setdefault(r.structure, []).append(r)
        for name, reqs in by_group.items():
            g = self.groups[name]
            fresh = [r for r in reqs if r.cont_ptr is None]
            if fresh:
                queries = jnp.asarray(
                    np.array([r.query for r in fresh], np.int32)
                )
                if g.spec.takes_value:
                    values = jnp.asarray(
                        np.array([r.value for r in fresh], np.int32)
                    )
                    ptr0, scr0 = g.spec.iterator.init(
                        queries, values, *g.spec.init_args
                    )
                else:
                    ptr0, scr0 = g.spec.iterator.init(queries, *g.spec.init_args)
                ptr0 = np.asarray(ptr0, np.int32)
                scr0 = np.asarray(scr0, np.int32)
            free_idx = [i for i, r in enumerate(g.req) if r is None]
            fi = 0
            for j, r in enumerate(reqs):
                s = free_idx[j]
                g.req[s] = r
                if r.cont_ptr is None:
                    g.ptr[s] = ptr0[fi]
                    g.scratch[s] = scr0[fi]
                    g.iters[s] = 0
                    fi += 1
                else:  # preempted continuation: resume saved traversal state
                    g.ptr[s] = r.cont_ptr
                    g.scratch[s] = r.cont_scratch
                    g.iters[s] = r.iters
                    r.cont_ptr = None
                    r.cont_scratch = None
                if r.admit_s < 0:
                    r.admit_s = now_s
                    r.admit_round = rnd

    def _fast_retire(
        self, g: _SlotGroup, slot: int, status: int, now_s: float, rnd: int
    ) -> None:
        """Free the slot and capture the result (runner-thread-safe part of
        retirement); accounting happens when ``_drain_emit`` consumes the
        event on the main thread."""
        r = g.req[slot]
        assert r is not None
        r.status = int(status)
        r.iters = int(g.iters[slot])
        r.result = g.scratch[slot].copy()
        r.finish_s = now_s
        r.finish_round = rnd
        g.req[slot] = None
        g.ptr[slot] = NULL
        self._emit.append((g.spec.writes, r))

    def _drain_emit(self) -> None:
        """Consume retirement events (emit is decoupled from the step loop:
        in async mode this overlaps the device's current quantum)."""
        m = self.metrics
        while True:
            try:
                writes, r = self._emit.popleft()
            except IndexError:
                return
            m.retired += 1
            m.writes_retired += int(writes)
            m.completed += int(r.status == STATUS_DONE)
            m.faulted += int(r.status == STATUS_FAULT)
            m.timed_out += int(r.status == STATUS_MAXED)
            m.retry_exhausted += int(r.status == STATUS_RETRY)
            m.latencies_ms.append(r.latency_ms)
            t = m.per_tenant.setdefault(
                r.tenant, {"completed": 0, "latencies_ms": []}
            )
            t["completed"] += int(r.status == STATUS_DONE)
            t["latencies_ms"].append(r.latency_ms)
            met = r.deadline_met
            if met is not None:
                if met:
                    m.deadlines_met += 1
                else:
                    m.deadlines_missed += 1

    def _apply_result(self, g: _SlotGroup, occ, res, dt_s: float, rnd: int) -> None:
        now_s = time.perf_counter()
        m = self.metrics
        m.engine_calls += 1
        g.fail_streak = 0  # a quantum landed: the group is healthy again
        stats = res.stats
        if stats is not None and hasattr(stats, "supersteps"):
            m.supersteps += stats.supersteps
            m.wire_words += stats.total_wire_words
            m.commits += getattr(stats, "commits", 0)
        iters_done = 0
        for s in np.flatnonzero(occ):
            g.ptr[s] = res.ptr[s]
            g.scratch[s] = res.scratch[s]
            lane = int(res.iters[s])
            g.iters[s] += lane
            m.lane_iters += lane
            iters_done = max(iters_done, lane)
            st = int(res.status[s])
            if st == STATUS_MAXED and g.iters[s] < self.max_request_iters:
                continue  # continuation: stays in its slot, resumes next round
            self._fast_retire(g, int(s), st, now_s, rnd)
        if iters_done > 0 and dt_s > 0:
            est = dt_s * 1e3 / iters_done  # ms per iteration, EWMA-smoothed
            self._ms_per_iter = (
                est
                if self._ms_per_iter is None
                else 0.7 * self._ms_per_iter + 0.3 * est
            )

    def _make_work(self, g: _SlotGroup, rnd: int, quantum: int) -> QuantumWork:
        # NULL pointers in padding (free) slots fault on the first iteration,
        # so a fixed-width batch costs one compiled shape per group.
        occ = g.occupied()
        log_writes = self.ft is not None and g.spec.writes
        rep = self._replicas

        def run():
            t0 = time.perf_counter()
            p0 = g.ptr.copy()
            s0 = g.scratch.copy()
            rep_ctx = None if g.spec.writes else self._replica_ctx()
            res = self.engine.execute(
                g.spec.iterator,
                p0.copy(),
                s0.copy(),
                max_iters=quantum,
                backend=self.backend,
                compact=self.compact,
                fused=self.fused,
                schedule=self.schedule,
                fabric=self.fabric,
                replication=rep_ctx,
            )
            fanned_out = rep_ctx is not None and bool(
                np.asarray(rep_ctx.dead_mask).any()
            )
            shipped = False
            if log_writes:
                # durability point: the quantum is acknowledged once its
                # *inputs* are in the fsynced log (replaying them through
                # the commit oracle reproduces the post-commit arena
                # bit-for-bit); a crash before this line loses only an
                # unacknowledged quantum.  engine.execute defaults
                # k_local=4 -- recorded so replay runs the same chase depth.
                store = self.ft.store
                seq = store.log_quantum(
                    g.name, p0, s0,
                    max_iters=quantum, k_local=4, compact=self.compact,
                    commits=res.stats.commits, epochs=res.stats.epochs,
                )
                self._writes_since_snapshot += 1
                if self._writes_since_snapshot >= self.ft.snapshot_every:
                    store.snapshot(res.arena, seq)
                    self._writes_since_snapshot = 0
                if rep is not None:
                    # ship the quantum's *inputs* to the hot standby: both
                    # copies apply the same serialized commit stream, so the
                    # replica is bit-identical to the primary by construction
                    rep.apply_quantum(
                        g.spec.iterator, p0, s0,
                        max_iters=quantum, k_local=4, compact=self.compact,
                    )
                    if self.ft.replication.verify_every_quantum:
                        rep.verify(res.arena)
                    shipped = True
            return res, time.perf_counter() - t0, fanned_out, shipped

        def apply(out):
            res, dt_s, fanned_out, shipped = out
            self.metrics.failover_quanta += int(fanned_out)
            self.metrics.replica_quanta += int(shipped)
            self._apply_result(g, occ, res, dt_s, rnd)

        return QuantumWork(label=g.name, run=run, apply=apply)

    # --------------------------- fault tolerance ------------------------------

    def _verify_recovery(self, recovered) -> None:
        """The zero-acknowledged-commits-lost gate: the snapshot + replayed
        log must reproduce the engine's resident arena exactly.  The engine
        swaps its arena only after a quantum succeeds, and a successful
        write quantum is logged before it is acknowledged, so any mismatch
        means durable state lost an acked commit -- fail loudly."""
        cur = self.engine.arena
        for field in ("data", "bounds", "perms", "heap"):
            if not np.array_equal(
                np.asarray(getattr(cur, field)), np.asarray(getattr(recovered, field))
            ):
                raise RuntimeError(
                    f"recovery lost acknowledged commits: arena.{field} diverged"
                )

    def _register_retry(self, g: _SlotGroup, rnd: int) -> None:
        """Park the failed group under jittered exponential backoff and
        charge each occupant one retry; budget exhaustion retires the
        request STATUS_RETRY (the client must resubmit after recovery)."""
        ft = self.ft
        m = self.metrics
        g.fail_streak += 1
        backoff = min(ft.backoff_cap, ft.backoff_base * (1 << (g.fail_streak - 1)))
        jitter = 1.0 + ft.backoff_jitter * (2.0 * self._ft_rng.random() - 1.0)
        g.backoff_until = rnd + 1 + max(1, int(round(backoff * jitter)))
        now_s = time.perf_counter()
        for s, r in enumerate(g.req):
            if r is None:
                continue
            r.retries += 1
            m.retries += 1
            if r.retries > ft.retry_budget:
                self._fast_retire(g, s, STATUS_RETRY, now_s, rnd)

    def _on_shard_failure(self, e: ShardFailure, rnd: int) -> None:
        """Fail over: mark the shard dead, restore the latest snapshot +
        replay the commit log, verify bit-equality with the resident arena,
        and park the in-flight group for a backed-off retry.  Runs on the
        main thread; in async mode the runner is already fail-fast idle
        (its error surfaced here), so swapping the arena is race-free."""
        m = self.metrics
        self._detector.suspect(e.shard, rnd)
        self._detector.sweep()
        t0 = time.perf_counter()
        recovered, info = self.ft.store.recover()
        self._verify_recovery(recovered)
        self.engine.arena = recovered
        m.recoveries += 1
        m.replayed_commits += info.replayed_commits
        m.recovery_ms_total += (time.perf_counter() - t0) * 1e3
        self._dead_until[e.shard] = rnd + 1 + self.ft.dead_rounds
        g = self.groups.get(e.label) if e.label else None
        if g is None:
            return
        if not g.spec.writes and self._has_live_replica(e.shard):
            # hot-standby fan-out: the failed call mutated nothing, so the
            # group's slot state is intact and simply re-runs next round --
            # now redirected to the replica.  Read-only tenants ride through
            # the death with zero STATUS_RETRY and zero backoff while the
            # snapshot+log recovery above rebuilds the primary.
            return
        self._register_retry(g, rnd)

    def _has_live_replica(self, shard: int) -> bool:
        """True when ``shard``'s range can be served from a replica holder
        that is itself alive (policy "primary" never redirects)."""
        if self._replicas is None or self._replicas.plan.policy == "primary":
            return False
        rm = self._replicas.plan.replica_map
        if not 0 <= shard < len(rm):
            return False
        holder = int(rm[shard])
        return holder >= 0 and holder not in self._detector.dead_shards()

    def _replica_ctx(self) -> routing.ReplicaContext | None:
        """Read fan-out operands for this quantum.  None when replication is
        off or nothing would redirect -- failover policy with every primary
        alive keeps the fast compiled schedule; "spread" always fans out."""
        if self._replicas is None:
            return None
        P = self.engine.arena.num_shards
        rm = self._replicas.plan.replica_map
        down = {s for s in self._detector.dead_shards() if 0 <= s < P}
        dead = np.zeros(P, bool)
        for s in down:
            # only fan out ranges whose holder is itself alive: marking a
            # primary dead with a dead holder leaves its range unservable
            # and the routed records would bounce forever.  A suspected
            # (slow) shard with a slow holder keeps serving its own range.
            holder = int(rm[s]) if s < len(rm) else -1
            if holder >= 0 and holder not in down:
                dead[s] = True
        if not dead.any() and self._replicas.plan.policy != "spread":
            return None
        return routing.ReplicaContext(
            plan=self._replicas.plan,
            rep_rows=self._replicas.rep_rows(),
            dead_mask=dead,
        )

    def _probe_shard(self, shard: int, *, warm: bool = False) -> float:
        """Time one single-record read against ``shard`` through the real
        dispatched superstep path.  ``warm=True`` compiles/warms only (no
        fault injection, no failure handling), so service init does not eat
        injected delays.  Live probes share the engine's fault-injector call
        stream: ``kill_call`` indices count probe calls too."""
        bounds = np.asarray(self.engine.arena.bounds)
        if bounds[shard + 1] - bounds[shard] <= 0:
            return 0.0  # empty range: nothing to probe
        ptr0 = np.array([int(bounds[shard])], np.int32)
        scr0 = np.zeros((1, 1), np.int32)
        t0 = time.perf_counter()
        try:
            routing.distributed_execute(
                _PROBE_IT, self.engine.arena, ptr0, scr0,
                mesh=self.engine.mesh, axis_name=self.engine.axis_name,
                max_iters=2, k_local=1, compact=True, schedule="dispatched",
                fault_injector=None if warm else self.engine.fault_injector,
            )
        except ShardFailure as e:
            if e.label is None:
                e.label = "watchdog"
            self._on_shard_failure(e, max(self._wd_round, 0))
            return float("inf")
        return time.perf_counter() - t0

    def _run_watchdog(self, rnd: int) -> None:
        """Per-round shard watchdog: probe every live shard, beat the ones
        that answered within ``ft.watchdog_timeout_s``, and escalate missed
        beats to suspected-dead.  This catches *stragglers* (delay faults)
        that stall supersteps without ever raising ShardFailure: the next
        round's reads fan out to the replica instead of waiting."""
        m = self.metrics
        if self._wd_round < 0:
            # first round (or just resharded): baseline every shard as if
            # beaten last round, so the two-consecutive-misses confirmation
            # window starts counting from here
            self._wd_round = rnd - 1
            for s in self._watchdog.hosts:
                self._watchdog.beat(s)
        self._wd_round = rnd
        dead_now = set(self._detector.dead_shards())
        for s in range(self.engine.arena.num_shards):
            if s in dead_now:
                continue  # already degraded; do not stall on a dead shard
            dt = self._probe_shard(s)
            m.watchdog_probes += 1
            if dt <= self.ft.watchdog_timeout_s:
                self._watchdog.beat(s)
        for s in self._watchdog.sweep():
            if s in dead_now or s in self._detector.dead_shards():
                continue
            m.watchdog_suspects += 1
            self._detector.suspect(s, rnd)
            self._detector.sweep()
            self._dead_until[s] = rnd + 1 + self.ft.dead_rounds

    def _revive_dead_shards(self, rnd: int) -> None:
        for k in [k for k, until in self._dead_until.items() if until <= rnd]:
            self._detector.revive(k)
            if self._watchdog is not None and k in self._watchdog.hosts:
                # re-arm the watchdog beat so a still-slow revived shard is
                # re-suspected (sweep only reports *newly* missed beats)
                self._watchdog.beat(k)
            del self._dead_until[k]

    # ------------------------------ elasticity --------------------------------

    def request_reshard(self, new_num_shards: int) -> None:
        """Begin an online 2x shard-count change.  Admission pauses, every
        in-flight quantum drains through the existing write-barrier
        machinery, then the arena cuts over (``arena.remap_shards`` +
        owner-epoch forwarding + mesh rebuild) and admission resumes.  The
        result is bit-identical to a cold rebuild at the new shard count:
        the remap is deterministic and nothing routes during the swap."""
        self._reshard.request(
            int(new_num_shards),
            current=self.engine.arena.num_shards,
            rnd=self.metrics.rounds,
        )

    def _in_flight(self) -> int:
        return sum(int(g.occupied().sum()) for g in self.groups.values())

    def _cutover(self, rnd: int) -> None:
        m = self.metrics
        old_p = self.engine.arena.num_shards
        target = self._reshard.target
        new_arena = remap_shards(self.engine.arena, target)
        new_mesh = None
        if self.engine.mesh is not None:
            devs = jax.devices()
            if len(devs) < target:
                raise RuntimeError(
                    f"reshard to {target} shards needs {target} devices, "
                    f"have {len(devs)}"
                )
            new_mesh = Mesh(np.array(devs[:target]), (self.engine.axis_name,))
        ep = self._owner_map.advance(np.asarray(new_arena.bounds))
        old_epoch = ep.epoch - 1

        def fwd(s: int) -> tuple[int, ...]:
            return self._owner_map.forward_shard(
                s, from_epoch=old_epoch, to_epoch=ep.epoch
            )

        # stale per-shard serving state (minted under the old owner
        # function) forwards through the new epoch: a shard index never
        # survives a reshard raw, only via range translation
        self._dead_until = {
            d: until for s, until in self._dead_until.items() for d in fwd(s)
        }
        if self._detector is not None:
            from repro.distributed.elastic import ShardFailureDetector

            old_dead = self._detector.dead_shards()
            self._detector = ShardFailureDetector(target)
            for s in old_dead:
                for d in fwd(s):
                    self._detector.suspect(d, rnd)
            self._detector.sweep()
        self.engine.reshard(new_arena, new_mesh)
        if self._replicas is not None:
            repc = self.ft.replication
            prim = repc.primaries
            if prim is not None:
                prim = tuple(sorted({d for p in prim for d in fwd(p)}))
            plan = routing.make_replica_plan(target, prim, policy=repc.policy)
            # the standby reshards through the same deterministic remap, so
            # primary and replica stay bit-identical across the cutover
            self._replicas.reset(
                remap_shards(self._replicas.shadow, target), plan
            )
        if self._watchdog is not None:
            from repro.distributed.elastic import HeartbeatMonitor

            self._watchdog = HeartbeatMonitor(
                target, timeout_s=1, clock=lambda: self._wd_round
            )
            self._wd_round = -1  # re-arm the confirmation baseline
        if self.ft is not None:
            # a marker + snapshot land in the log so recovery replay never
            # straddles two partitions
            store = self.ft.store
            seq = store.log.append(
                {
                    "kind": "reshard",
                    "old_shards": old_p,
                    "new_shards": target,
                    "owner_epoch": ep.epoch,
                }
            )
            store.snapshot(self.engine.arena, seq)
            self._writes_since_snapshot = 0
        ev = self._reshard.complete(rnd=rnd, old_shards=old_p, owner_epoch=ep.epoch)
        m.reshards += 1
        m.reshard_drain_rounds += ev.drain_rounds

    def _quantum_for_round(self, now_s: float) -> int:
        """SLO-aware quantum sizing.  With the bounds pinned (the default)
        this returns the fixed ``quantum``.  Otherwise: no deadline in
        sight -> grow multiplicatively toward ``max_quantum`` (fewer
        rounds, fewer dispatches per request); a deadline pending or on
        device -> fit the quantum inside the earliest deadline's headroom
        using the EWMA ms/iter estimate, floored at ``min_quantum`` so
        forward progress never stalls."""
        lo, hi = self.min_quantum, self.max_quantum
        if lo == hi:
            return lo
        deadlines = []
        q_dl = self.admission.earliest_deadline_s()
        if q_dl is not None:
            deadlines.append(q_dl)
        for g in self.groups.values():
            for r in g.req:
                if r is not None and r.deadline_ms is not None:
                    deadlines.append(r.arrival_s + r.deadline_ms / 1e3)
        if not deadlines or self._ms_per_iter is None:
            self._cur_quantum = min(hi, max(lo, self._cur_quantum * 2))
        else:
            headroom_ms = max(0.0, (min(deadlines) - now_s) * 1e3)
            target = int(headroom_ms * self.slo_safety / self._ms_per_iter)
            self._cur_quantum = min(hi, max(lo, target))
        return self._cur_quantum

    def _ensure_runner(self) -> DeviceRunner | None:
        if self.pipeline != "async":
            return None
        if self._runner is None:
            self._runner = DeviceRunner(depth=self.runner_depth).start()
        return self._runner

    def _busy(self) -> bool:
        return (
            bool(self._pending_arrivals)
            or self.admission.pending() > 0
            or any(g.occupied().any() for g in self.groups.values())
            or self._reshard.phase != "idle"
        )

    def step(self, rnd: int | None = None) -> None:
        """One scheduling round: admit -> run every occupied group -> retire.

        sync: each group's quantum executes inline, retirement accounting
        drains at the end of the round.  async: group quanta are handed to
        the DeviceRunner (bounded double-buffered queue) and this thread
        books prior retirements while the device chews; the round ends on
        the runner's drain barrier, so the next round's admission sees
        settled slot state and the engine-call sequence matches sync
        exactly."""
        m = self.metrics
        rnd = m.rounds if rnd is None else rnd
        now = time.perf_counter()
        if self._detector is not None:
            self._revive_dead_shards(rnd)
        if self._reshard.phase == "draining":
            # reshard barrier: arrivals keep queueing, nothing admits, and
            # the cutover fires the round the last in-flight quantum retires
            self._intake(now, rnd)
            if self._reshard.should_cutover(self._in_flight()):
                self._cutover(rnd)
                self._admit(now, rnd)
        else:
            self._admit(now, rnd)
        quantum = self._quantum_for_round(now)
        if m.quantum_min_used == 0 or quantum < m.quantum_min_used:
            m.quantum_min_used = quantum
        m.quantum_max_used = max(m.quantum_max_used, quantum)
        runner = self._ensure_runner()
        for g in self.groups.values():
            occupied_before = int(g.occupied().sum())  # count before retirement
            m.slot_rounds += occupied_before
            m.capacity_rounds += g.n_slots
            if occupied_before == 0 or g.backoff_until > rnd:
                continue  # empty, or parked awaiting a backed-off retry
            work = self._make_work(g, rnd, quantum)
            try:
                if runner is not None:
                    # a pending runner error surfaces here *before* work
                    # enqueues: the current group simply re-runs next round
                    runner.submit(work)
                else:
                    work.apply(work.run())
            except ShardFailure as e:
                if self.ft is None:
                    raise
                if e.label is None:
                    e.label = g.name
                self._on_shard_failure(e, rnd)
        if runner is not None:
            self._drain_emit()  # overlap: account retirements mid-flight
            try:
                runner.drain()  # barrier: slot state settled for next admit
            except ShardFailure as e:
                if self.ft is None:
                    raise
                self._on_shard_failure(e, rnd)
        self._drain_emit()
        if self._watchdog is not None:
            self._run_watchdog(rnd)
        if self._detector is not None:
            self._detector.beat_all(rnd)
        m.rounds += 1

    def close(self) -> None:
        """Stop the background runner (idempotent; restarted on demand)."""
        if self._runner is not None:
            self._runner.close()
            self._runner = None

    def run(
        self,
        requests: list[TraversalRequest] | None = None,
        *,
        max_rounds: int = 100_000,
    ) -> ServiceMetrics:
        """Serve until every submitted request has retired."""
        t0 = time.perf_counter()
        for r in requests or []:
            self.submit(r)
        try:
            while self._busy():
                if self.metrics.rounds >= max_rounds:
                    raise RuntimeError(
                        f"service did not drain in {max_rounds} rounds"
                    )
                self.step()
        finally:
            self.close()
            self._drain_emit()
        self.metrics.wall_s += time.perf_counter() - t0
        return self.metrics
