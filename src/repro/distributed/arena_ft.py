"""Fault-tolerant arenas: snapshots + a durable commit log + replay recovery.

The write path's determinism contract is what makes cheap recovery possible:
every schedule x fabric combination commits staged mutations in the same
canonical (class, slot, id) order and is bit-identical to the sequential
commit oracle (``core.commit.sequential_commit_execute``).  So instead of
logging physical arena words, the commit log records write-quantum *inputs*
(iterator name, ptr0/scratch0, budget, knobs) -- replaying them through the
oracle from the latest snapshot reconstructs the exact post-commit arena,
heap registers included.

Durability protocol (the zero-acknowledged-commits-lost invariant):

  1. a write quantum executes (any schedule/fabric/backend);
  2. on success, its inputs + observed commit/epoch deltas are appended to
     the log and fsynced -- only *then* is the quantum acknowledged;
  3. every ``snapshot_every`` logged quanta, the full arena is snapshotted
     through ``CheckpointManager._atomic_save`` (manifest + shard npz +
     atomic LATEST pointer), truncating the replay prefix.

A crash between execute and log-append loses an *unacknowledged* quantum
(the client retries); a crash mid-snapshot leaves a partial dir without a
manifest, which restore ignores.  Recovery = latest snapshot + replay of
every logged quantum with ``seq > snapshot.log_seq``, verifying each
entry's commit/epoch deltas against the log record.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from repro.core import routing
from repro.core.arena import H_EPOCH, Arena
from repro.distributed.checkpoint import CheckpointManager


class RecoveryError(RuntimeError):
    """Snapshot/log state is unusable or replay diverged from the log."""


class ReplicationError(RuntimeError):
    """A replica diverged from its primary (the bit-identity invariant)."""


@dataclasses.dataclass(frozen=True)
class ArenaSnapshot:
    """A restored arena plus the log position it corresponds to."""

    arena: Arena
    log_seq: int  # last commit-log seq folded into this arena
    epoch: int  # sum of per-shard H_EPOCH registers at snapshot time


@dataclasses.dataclass
class RecoveryInfo:
    """What a ``recover()`` call did (feeds ServiceMetrics)."""

    snapshot_seq: int  # log seq the restored snapshot covered
    log_seq: int  # last log seq after replay
    replayed_quanta: int
    replayed_commits: int
    wall_s: float


class CommitLog:
    """Append-only JSONL log of acknowledged write quanta.

    One JSON object per line; ``append`` flushes and fsyncs before
    returning, so a returned seq is durable.  ``entries`` tolerates a torn
    final line (crash mid-append): the partial record was never
    acknowledged, so dropping it is correct.  A torn line *followed by*
    valid records is real corruption and raises.
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._seq = 0
        for e in self.entries():
            self._seq = max(self._seq, int(e["seq"]))
        self._f = open(self.path, "a", encoding="utf-8")

    @property
    def seq(self) -> int:
        """Last durable (acknowledged) sequence number; 0 = empty log."""
        return self._seq

    def append(self, record: dict) -> int:
        """Assign the next seq, write + fsync, return the seq (the ack)."""
        self._seq += 1
        rec = {"seq": self._seq, **record}
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        return self._seq

    def entries(self) -> list[dict]:
        if not self.path.exists():
            return []
        out = []
        lines = self.path.read_text(encoding="utf-8").splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break  # torn tail: unacknowledged, ignore
                raise RecoveryError(
                    f"corrupt commit log {self.path} at line {i + 1}"
                ) from None
        return out

    def quanta(self) -> list[dict]:
        """Entries that describe write quanta (truncation markers dropped)."""
        return [e for e in self.entries() if "kind" not in e]

    def truncate_through(self, seq: int) -> int:
        """Compact: drop every entry with seq <= ``seq`` (they are folded
        into a durable snapshot).  Returns the number of entries dropped.

        Atomic by construction: survivors (headed by a ``kind: truncated``
        marker that preserves the seq high-water mark across reopen) are
        written to a ``.tmp`` sibling, fsynced, then ``os.replace``d over
        the log and the directory entry fsynced.  A crash before the
        replace leaves the old log plus a stray ``.tmp`` (ignored -- the
        log path itself is all that is ever read); a crash after leaves
        the compacted log.  Either way the snapshot + log pair replays to
        the same arena.
        """
        keep = [e for e in self.entries() if int(e.get("seq", 0)) > seq]
        dropped = len(self.entries()) - len(keep)
        if dropped <= 0:
            return 0
        tmp = self.path.with_name(self.path.name + ".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(json.dumps({"seq": int(seq), "kind": "truncated"}) + "\n")
            for e in keep:
                f.write(json.dumps(e) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        dfd = os.open(self.path.parent, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        self._f = open(self.path, "a", encoding="utf-8")
        return dropped

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class ArenaStore:
    """Snapshot + commit-log durability for one arena.

    Owns a ``CheckpointManager`` (synchronous saves: a returned snapshot is
    durable) and a ``CommitLog`` in the same directory.  Iterators are
    referenced by name in the log, so recovery needs the same iterators
    registered that produced the log -- the service wires this up from its
    ``StructureSpec`` table.
    """

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.mgr = CheckpointManager(self.dir, keep=keep, async_save=False)
        self.log = CommitLog(self.dir / "commit_log.jsonl")
        self._iterators: dict[str, object] = {}
        self.snapshots_taken = 0

    def register_iterator(self, name: str, it) -> None:
        prev = self._iterators.get(name)
        if prev is not None and prev is not it:
            raise ValueError(f"iterator name {name!r} already registered")
        self._iterators[name] = it

    # ----------------------------- logging --------------------------------

    def log_quantum(
        self,
        it_name: str,
        ptr0,
        scratch0,
        *,
        max_iters: int,
        k_local: int,
        compact: bool,
        commits: int,
        epochs: int,
    ) -> int:
        """Record one successfully executed write quantum; the returned seq
        is the acknowledgment (durable on return)."""
        if it_name not in self._iterators:
            raise ValueError(f"unregistered iterator {it_name!r}")
        return self.log.append(
            {
                "it": it_name,
                "ptr0": np.asarray(ptr0, np.int64).tolist(),
                "scratch0": np.asarray(scratch0, np.int64).tolist(),
                "max_iters": int(max_iters),
                "k_local": int(k_local),
                "compact": bool(compact),
                "commits": int(commits),
                "epochs": int(epochs),
            }
        )

    # ---------------------------- snapshots -------------------------------

    def snapshot(
        self, arena: Arena, log_seq: int | None = None, *, compact_log: bool = True
    ) -> int:
        """Atomically persist the full arena at ``log_seq`` (default: the
        log's current durable seq).  Returns the snapshot's log_seq.

        After the LATEST pointer flips (the snapshot is durable), the
        commit log is compacted: entries with ``seq <= log_seq`` are folded
        into the snapshot and replay never needs them again.  Pass
        ``compact_log=False`` to keep the full history (debugging)."""
        seq = self.log.seq if log_seq is None else int(log_seq)
        heap = np.asarray(arena.heap)
        self.mgr._atomic_save(
            step=seq,
            arrays={
                "data": np.asarray(arena.data),
                "bounds": np.asarray(arena.bounds),
                "perms": np.asarray(arena.perms),
                "heap": heap,
            },
            manifest={
                "kind": "arena_snapshot",
                "log_seq": seq,
                "epoch": int(heap[:, H_EPOCH].sum()),
                "num_shards": arena.num_shards,
                "capacity": arena.capacity,
                "node_words": arena.node_words,
            },
        )
        self.snapshots_taken += 1
        if compact_log:
            self.log.truncate_through(seq)
        return seq

    def ensure_baseline(self, arena: Arena) -> None:
        """Snapshot the pre-serving arena if no snapshot exists yet, so
        recovery always has an anchor (replay needs a starting state)."""
        if self.mgr.latest_step() is None:
            self.snapshot(arena)

    def load_snapshot(self, step: int | None = None) -> ArenaSnapshot:
        step = self.mgr.latest_step() if step is None else step
        if step is None:
            raise RecoveryError(f"no arena snapshot under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if manifest.get("kind") != "arena_snapshot":
            raise RecoveryError(f"{d} is not an arena snapshot")
        with np.load(d / f"shard_{self.mgr.host_id}.npz") as z:
            arena = Arena(
                data=jnp.asarray(z["data"]),
                bounds=jnp.asarray(z["bounds"]),
                perms=jnp.asarray(z["perms"]),
                heap=jnp.asarray(z["heap"]),
            )
        return ArenaSnapshot(arena, int(manifest["log_seq"]), int(manifest["epoch"]))

    # ---------------------------- recovery --------------------------------

    def recover(self) -> tuple[Arena, RecoveryInfo]:
        """Latest snapshot + oracle replay of every newer logged quantum.

        Each replayed entry's commit/epoch deltas must match the log record
        (the log recorded what the acknowledged execution observed; the
        oracle is bit-identical to every schedule, so a mismatch means the
        snapshot/log pair is inconsistent, not a tolerable drift).
        """
        from repro.core.commit import sequential_commit_execute

        t0 = time.perf_counter()
        snap = self.load_snapshot()
        arena = snap.arena
        replayed = commits = 0
        last_seq = snap.log_seq
        for e in self.log.quanta():
            if int(e["seq"]) <= snap.log_seq:
                continue
            it = self._iterators.get(e["it"])
            if it is None:
                raise RecoveryError(f"log references unregistered iterator {e['it']!r}")
            B = len(e["ptr0"])
            ptr0 = np.asarray(e["ptr0"], np.int32)
            scratch0 = np.asarray(e["scratch0"], np.int32).reshape(B, -1)
            _, stats, arena = sequential_commit_execute(
                it, arena, ptr0, scratch0,
                max_iters=int(e["max_iters"]), k_local=int(e["k_local"]),
                compact=bool(e["compact"]),
            )
            if stats.commits != int(e["commits"]) or stats.epochs != int(e["epochs"]):
                raise RecoveryError(
                    f"replay diverged at seq {e['seq']}: observed "
                    f"({stats.commits} commits, {stats.epochs} epochs), log says "
                    f"({e['commits']}, {e['epochs']})"
                )
            replayed += 1
            commits += stats.commits
            last_seq = int(e["seq"])
        info = RecoveryInfo(
            snapshot_seq=snap.log_seq,
            log_seq=last_seq,
            replayed_quanta=replayed,
            replayed_commits=commits,
            wall_s=time.perf_counter() - t0,
        )
        return arena, info

    def close(self) -> None:
        self.log.close()


# ------------------------------ replication ----------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Hot-shard replication knobs (R=2, log-shipping).

    ``primaries`` names the shards to replicate (None = every shard gets a
    mirror on its antipode holder, ``routing.make_replica_plan``).
    ``policy`` is the read fan-out: "primary" (replica is a cold standby),
    "failover" (replica serves only while the primary is suspected dead),
    "spread" (odd record ids always read from the replica -- load
    balancing).  ``verify_every_quantum`` asserts replica == primary rows
    after each applied write quantum (the bit-identity invariant); cheap at
    test scale, turn off for big arenas.
    """

    policy: str = "failover"
    primaries: tuple[int, ...] | None = None
    verify_every_quantum: bool = True


class ReplicaSet:
    """Log-shipping hot standby: a shadow arena kept bit-identical to the
    primary by replaying every acknowledged write quantum through the
    sequential-commit oracle.

    The commit stream is already serialized in the canonical (class, slot,
    id) order and every schedule is bit-identical to the oracle, so replica
    = primary holds *by construction* -- there is no quorum or
    anti-entropy; ``verify`` just asserts the invariant.  ``rep_rows``
    materializes the device read-fan-out operand: holder shard r's slice
    carries its primary's rows at local offset 0 (each holder mirrors at
    most one shard, the honest R=2 memory budget).
    """

    def __init__(self, plan: routing.ReplicaPlan, arena: Arena):
        self.plan = plan
        self.shadow = arena  # frozen pytree: sharing the seed arena is safe
        self.quanta_applied = 0

    def apply_quantum(
        self, it, ptr0, scratch0, *, max_iters: int, k_local: int, compact: bool
    ) -> None:
        """Ship one acknowledged write quantum to the standby."""
        from repro.core.commit import sequential_commit_execute

        _, _, self.shadow = sequential_commit_execute(
            it, self.shadow, ptr0, scratch0,
            max_iters=max_iters, k_local=k_local, compact=compact,
        )
        self.quanta_applied += 1

    def verify(self, primary: Arena) -> None:
        """Assert replica rows == primary rows for every replicated shard."""
        b = np.asarray(primary.bounds)
        pd = np.asarray(primary.data)
        sd = np.asarray(self.shadow.data)
        for holder, p in enumerate(self.plan.primary_map):
            if p < 0:
                continue
            lo, hi = int(b[p]), int(b[p + 1])
            if not np.array_equal(pd[lo:hi], sd[lo:hi]):
                raise ReplicationError(
                    f"replica of shard {p} (held by {holder}) diverged "
                    f"from the primary after {self.quanta_applied} quanta"
                )

    def rep_rows(self) -> np.ndarray:
        """(capacity, node_words) device operand for ``ReplicaContext``:
        holder r's slice holds primary_map[r]'s rows at local offsets."""
        sd = np.asarray(self.shadow.data)
        b = np.asarray(self.shadow.bounds)
        out = np.zeros_like(sd)
        for holder, p in enumerate(self.plan.primary_map):
            if p < 0:
                continue
            n = int(b[p + 1] - b[p])
            cap = int(b[holder + 1] - b[holder])
            if n > cap:
                raise ReplicationError(
                    f"holder {holder} range ({cap} rows) cannot mirror "
                    f"shard {p} ({n} rows)"
                )
            out[int(b[holder]) : int(b[holder]) + n] = sd[int(b[p]) : int(b[p + 1])]
        return out

    def reset(self, arena: Arena, plan: routing.ReplicaPlan | None = None) -> None:
        """Re-anchor the standby (post-recovery or post-reshard)."""
        if plan is not None:
            self.plan = plan
        self.shadow = arena
        self.quanta_applied = 0


@dataclasses.dataclass
class FaultToleranceConfig:
    """Serving-layer fault-tolerance knobs (PulseService ``fault_tolerance=``).

    ``snapshot_every`` counts *logged write quanta* between snapshots.
    Backoff for requests parked on a dead shard is jittered exponential:
    ``base * 2**attempt`` rounds, capped at ``cap``, +/- ``jitter`` fraction
    (seeded: deterministic across reruns).  ``dead_rounds`` keeps a shard
    marked dead for that many scheduling rounds after recovery completes
    (0 = revive immediately), modeling the re-provisioning window.
    ``retry_budget`` bounds per-request retries; exhaustion retires the
    request with STATUS_RETRY.

    ``replication`` turns on hot-shard replicas (see ReplicationConfig):
    read quanta fan out to replicas per the policy, and a suspected-dead
    primary keeps serving reads from its replica with zero retries charged
    while recovery rebuilds it.  ``watchdog_timeout_s`` > 0 arms the
    per-round shard watchdog: the service probes every shard with a
    1-record traversal, feeds per-shard latencies to ``HeartbeatMonitor``,
    and escalates shards whose probe exceeds the timeout to suspected-dead
    -- catching delay-faulted stragglers that never raise ``ShardFailure``.
    """

    store: ArenaStore
    snapshot_every: int = 8
    retry_budget: int = 5
    backoff_base: int = 1  # rounds
    backoff_cap: int = 16  # rounds
    backoff_jitter: float = 0.5
    dead_rounds: int = 0
    seed: int = 0
    replication: ReplicationConfig | None = None
    watchdog_timeout_s: float = 0.0  # 0 disables the shard watchdog
