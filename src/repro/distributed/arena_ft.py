"""Fault-tolerant arenas: snapshots + a durable commit log + replay recovery.

The write path's determinism contract is what makes cheap recovery possible:
every schedule x fabric combination commits staged mutations in the same
canonical (class, slot, id) order and is bit-identical to the sequential
commit oracle (``core.commit.sequential_commit_execute``).  So instead of
logging physical arena words, the commit log records write-quantum *inputs*
(iterator name, ptr0/scratch0, budget, knobs) -- replaying them through the
oracle from the latest snapshot reconstructs the exact post-commit arena,
heap registers included.

Durability protocol (the zero-acknowledged-commits-lost invariant):

  1. a write quantum executes (any schedule/fabric/backend);
  2. on success, its inputs + observed commit/epoch deltas are appended to
     the log and fsynced -- only *then* is the quantum acknowledged;
  3. every ``snapshot_every`` logged quanta, the full arena is snapshotted
     through ``CheckpointManager._atomic_save`` (manifest + shard npz +
     atomic LATEST pointer), truncating the replay prefix.

A crash between execute and log-append loses an *unacknowledged* quantum
(the client retries); a crash mid-snapshot leaves a partial dir without a
manifest, which restore ignores.  Recovery = latest snapshot + replay of
every logged quantum with ``seq > snapshot.log_seq``, verifying each
entry's commit/epoch deltas against the log record.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np

import jax.numpy as jnp

from repro.core.arena import H_EPOCH, Arena
from repro.distributed.checkpoint import CheckpointManager


class RecoveryError(RuntimeError):
    """Snapshot/log state is unusable or replay diverged from the log."""


@dataclasses.dataclass(frozen=True)
class ArenaSnapshot:
    """A restored arena plus the log position it corresponds to."""

    arena: Arena
    log_seq: int  # last commit-log seq folded into this arena
    epoch: int  # sum of per-shard H_EPOCH registers at snapshot time


@dataclasses.dataclass
class RecoveryInfo:
    """What a ``recover()`` call did (feeds ServiceMetrics)."""

    snapshot_seq: int  # log seq the restored snapshot covered
    log_seq: int  # last log seq after replay
    replayed_quanta: int
    replayed_commits: int
    wall_s: float


class CommitLog:
    """Append-only JSONL log of acknowledged write quanta.

    One JSON object per line; ``append`` flushes and fsyncs before
    returning, so a returned seq is durable.  ``entries`` tolerates a torn
    final line (crash mid-append): the partial record was never
    acknowledged, so dropping it is correct.  A torn line *followed by*
    valid records is real corruption and raises.
    """

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._seq = 0
        for e in self.entries():
            self._seq = max(self._seq, int(e["seq"]))
        self._f = open(self.path, "a", encoding="utf-8")

    @property
    def seq(self) -> int:
        """Last durable (acknowledged) sequence number; 0 = empty log."""
        return self._seq

    def append(self, record: dict) -> int:
        """Assign the next seq, write + fsync, return the seq (the ack)."""
        self._seq += 1
        rec = {"seq": self._seq, **record}
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        return self._seq

    def entries(self) -> list[dict]:
        if not self.path.exists():
            return []
        out = []
        lines = self.path.read_text(encoding="utf-8").splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break  # torn tail: unacknowledged, ignore
                raise RecoveryError(
                    f"corrupt commit log {self.path} at line {i + 1}"
                ) from None
        return out

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class ArenaStore:
    """Snapshot + commit-log durability for one arena.

    Owns a ``CheckpointManager`` (synchronous saves: a returned snapshot is
    durable) and a ``CommitLog`` in the same directory.  Iterators are
    referenced by name in the log, so recovery needs the same iterators
    registered that produced the log -- the service wires this up from its
    ``StructureSpec`` table.
    """

    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.mgr = CheckpointManager(self.dir, keep=keep, async_save=False)
        self.log = CommitLog(self.dir / "commit_log.jsonl")
        self._iterators: dict[str, object] = {}
        self.snapshots_taken = 0

    def register_iterator(self, name: str, it) -> None:
        prev = self._iterators.get(name)
        if prev is not None and prev is not it:
            raise ValueError(f"iterator name {name!r} already registered")
        self._iterators[name] = it

    # ----------------------------- logging --------------------------------

    def log_quantum(
        self,
        it_name: str,
        ptr0,
        scratch0,
        *,
        max_iters: int,
        k_local: int,
        compact: bool,
        commits: int,
        epochs: int,
    ) -> int:
        """Record one successfully executed write quantum; the returned seq
        is the acknowledgment (durable on return)."""
        if it_name not in self._iterators:
            raise ValueError(f"unregistered iterator {it_name!r}")
        return self.log.append(
            {
                "it": it_name,
                "ptr0": np.asarray(ptr0, np.int64).tolist(),
                "scratch0": np.asarray(scratch0, np.int64).tolist(),
                "max_iters": int(max_iters),
                "k_local": int(k_local),
                "compact": bool(compact),
                "commits": int(commits),
                "epochs": int(epochs),
            }
        )

    # ---------------------------- snapshots -------------------------------

    def snapshot(self, arena: Arena, log_seq: int | None = None) -> int:
        """Atomically persist the full arena at ``log_seq`` (default: the
        log's current durable seq).  Returns the snapshot's log_seq."""
        seq = self.log.seq if log_seq is None else int(log_seq)
        heap = np.asarray(arena.heap)
        self.mgr._atomic_save(
            step=seq,
            arrays={
                "data": np.asarray(arena.data),
                "bounds": np.asarray(arena.bounds),
                "perms": np.asarray(arena.perms),
                "heap": heap,
            },
            manifest={
                "kind": "arena_snapshot",
                "log_seq": seq,
                "epoch": int(heap[:, H_EPOCH].sum()),
                "num_shards": arena.num_shards,
                "capacity": arena.capacity,
                "node_words": arena.node_words,
            },
        )
        self.snapshots_taken += 1
        return seq

    def ensure_baseline(self, arena: Arena) -> None:
        """Snapshot the pre-serving arena if no snapshot exists yet, so
        recovery always has an anchor (replay needs a starting state)."""
        if self.mgr.latest_step() is None:
            self.snapshot(arena)

    def load_snapshot(self, step: int | None = None) -> ArenaSnapshot:
        step = self.mgr.latest_step() if step is None else step
        if step is None:
            raise RecoveryError(f"no arena snapshot under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if manifest.get("kind") != "arena_snapshot":
            raise RecoveryError(f"{d} is not an arena snapshot")
        with np.load(d / f"shard_{self.mgr.host_id}.npz") as z:
            arena = Arena(
                data=jnp.asarray(z["data"]),
                bounds=jnp.asarray(z["bounds"]),
                perms=jnp.asarray(z["perms"]),
                heap=jnp.asarray(z["heap"]),
            )
        return ArenaSnapshot(arena, int(manifest["log_seq"]), int(manifest["epoch"]))

    # ---------------------------- recovery --------------------------------

    def recover(self) -> tuple[Arena, RecoveryInfo]:
        """Latest snapshot + oracle replay of every newer logged quantum.

        Each replayed entry's commit/epoch deltas must match the log record
        (the log recorded what the acknowledged execution observed; the
        oracle is bit-identical to every schedule, so a mismatch means the
        snapshot/log pair is inconsistent, not a tolerable drift).
        """
        from repro.core.commit import sequential_commit_execute

        t0 = time.perf_counter()
        snap = self.load_snapshot()
        arena = snap.arena
        replayed = commits = 0
        last_seq = snap.log_seq
        for e in self.log.entries():
            if int(e["seq"]) <= snap.log_seq:
                continue
            it = self._iterators.get(e["it"])
            if it is None:
                raise RecoveryError(f"log references unregistered iterator {e['it']!r}")
            B = len(e["ptr0"])
            ptr0 = np.asarray(e["ptr0"], np.int32)
            scratch0 = np.asarray(e["scratch0"], np.int32).reshape(B, -1)
            _, stats, arena = sequential_commit_execute(
                it, arena, ptr0, scratch0,
                max_iters=int(e["max_iters"]), k_local=int(e["k_local"]),
                compact=bool(e["compact"]),
            )
            if stats.commits != int(e["commits"]) or stats.epochs != int(e["epochs"]):
                raise RecoveryError(
                    f"replay diverged at seq {e['seq']}: observed "
                    f"({stats.commits} commits, {stats.epochs} epochs), log says "
                    f"({e['commits']}, {e['epochs']})"
                )
            replayed += 1
            commits += stats.commits
            last_seq = int(e["seq"])
        info = RecoveryInfo(
            snapshot_seq=snap.log_seq,
            log_seq=last_seq,
            replayed_quanta=replayed,
            replayed_commits=commits,
            wall_s=time.perf_counter() - t0,
        )
        return arena, info

    def close(self) -> None:
        self.log.close()


@dataclasses.dataclass
class FaultToleranceConfig:
    """Serving-layer fault-tolerance knobs (PulseService ``fault_tolerance=``).

    ``snapshot_every`` counts *logged write quanta* between snapshots.
    Backoff for requests parked on a dead shard is jittered exponential:
    ``base * 2**attempt`` rounds, capped at ``cap``, +/- ``jitter`` fraction
    (seeded: deterministic across reruns).  ``dead_rounds`` keeps a shard
    marked dead for that many scheduling rounds after recovery completes
    (0 = revive immediately), modeling the re-provisioning window.
    ``retry_budget`` bounds per-request retries; exhaustion retires the
    request with STATUS_RETRY.
    """

    store: ArenaStore
    snapshot_every: int = 8
    retry_budget: int = 5
    backoff_base: int = 1  # rounds
    backoff_cap: int = 16  # rounds
    backoff_jitter: float = 0.5
    dead_rounds: int = 0
    seed: int = 0
