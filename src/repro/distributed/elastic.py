"""Elastic scaling + failure handling: mesh re-planning on membership change.

The production story at 1000+ nodes:
  1. heartbeat monitor marks hosts dead after ``timeout`` missed beats;
  2. the coordinator re-plans the mesh from the surviving slice (largest
     (pod, data, model) grid that the healthy host count supports, keeping
     the model axis intact so param layouts survive);
  3. every survivor restores the latest checkpoint with the NEW mesh's
     shardings (resharding happens inside CheckpointManager.restore);
  4. the data pipeline rewinds to the checkpoint step (exactness tested).

CPU-scale tests simulate deaths by dropping host ids; the re-plan logic and
the reshard-restore path are real.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    healthy: bool = True


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, timeout_s: float = 60.0, clock=time.monotonic):
        self.clock = clock
        self.timeout = timeout_s
        now = clock()
        self.hosts = {h: HostState(h, now) for h in range(num_hosts)}

    def beat(self, host_id: int):
        self.hosts[host_id].last_beat = self.clock()
        self.hosts[host_id].healthy = True

    def sweep(self):
        """Returns the list of hosts newly marked dead."""
        now = self.clock()
        newly_dead = []
        for h in self.hosts.values():
            if h.healthy and now - h.last_beat > self.timeout:
                h.healthy = False
                newly_dead.append(h.host_id)
        return newly_dead

    def healthy_hosts(self):
        return [h.host_id for h in self.hosts.values() if h.healthy]


class ShardFailureDetector:
    """HeartbeatMonitor on a logical round clock, specialized for memory
    shards.

    The serving loop has no wall clock worth trusting in tests, so the
    detector's clock is the scheduling round: every shard that completed
    work this round beats (``beat_all``), an injected/observed death is
    reported via ``suspect``, and ``sweep`` converts missed beats into
    dead-shard declarations exactly like the host-level monitor.
    ``timeout_rounds=0`` (default) declares a suspected shard dead at the
    next sweep -- the serving layer's ShardFailure is already a positive
    signal, not a missed heartbeat, so there is nothing to wait for.
    """

    def __init__(self, num_shards: int, timeout_rounds: int = 0):
        self._round = 0
        self.monitor = HeartbeatMonitor(
            num_shards, timeout_s=timeout_rounds, clock=lambda: self._round
        )

    def beat_all(self, rnd: int):
        """All shards healthy through round ``rnd`` (normal round end)."""
        self._round = rnd
        for h in self.monitor.hosts.values():
            if h.healthy:
                self.monitor.beat(h.host_id)

    def suspect(self, shard: int, rnd: int):
        """A failure signal implicates ``shard``: freeze its beat so the
        next sweep (at any later round) declares it dead."""
        self._round = max(self._round, rnd)
        self.monitor.hosts[shard].last_beat = self._round - self.monitor.timeout - 1

    def sweep(self) -> list[int]:
        return self.monitor.sweep()

    def revive(self, shard: int):
        """Recovery finished: the shard serves again."""
        self.monitor.beat(shard)

    def dead_shards(self) -> list[int]:
        return [
            h.host_id for h in self.monitor.hosts.values() if not h.healthy
        ]


def plan_mesh_shape(
    n_devices: int,
    *,
    model_parallel: int,
    prefer_pods: int = 1,
    devices_per_host: int = 1,
):
    """Largest (pod, data, model) grid from ``n_devices`` devices, keeping the
    ``model`` axis size fixed (param layout compatibility) and dropping to
    fewer pods / smaller data axis as capacity shrinks.

    Returns (shape tuple, axis names tuple, devices_used).
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model axis {model_parallel} with {n_devices} devices"
        )
    rows = n_devices // model_parallel  # candidate data x pod extent
    pods = prefer_pods
    while pods > 1 and rows % pods:
        pods -= 1
    data = rows // pods
    # keep data a power-of-two-ish friendly size: largest divisor of rows/pods
    used = pods * data * model_parallel
    if pods > 1:
        return (pods, data, model_parallel), ("pod", "data", "model"), used
    return (data, model_parallel), ("data", "model"), used


@dataclasses.dataclass
class ElasticEvent:
    step: int
    kind: str  # shrink | grow
    old_shape: tuple
    new_shape: tuple
    lost_hosts: list


class ElasticCoordinator:
    """Glue: monitor -> replan -> (caller does) reshard-restore."""

    def __init__(self, monitor: HeartbeatMonitor, *, model_parallel: int,
                 devices_per_host: int = 1, prefer_pods: int = 1):
        self.monitor = monitor
        self.model_parallel = model_parallel
        self.devices_per_host = devices_per_host
        self.prefer_pods = prefer_pods
        self.events: list[ElasticEvent] = []

    def check(self, step: int, current_shape: tuple):
        dead = self.monitor.sweep()
        if not dead:
            return None
        n = len(self.monitor.healthy_hosts()) * self.devices_per_host
        shape, names, used = plan_mesh_shape(
            n, model_parallel=self.model_parallel, prefer_pods=self.prefer_pods,
            devices_per_host=self.devices_per_host,
        )
        ev = ElasticEvent(step, "shrink", current_shape, shape, dead)
        self.events.append(ev)
        return ev
