"""Elastic scaling + failure handling: mesh re-planning on membership change.

The production story at 1000+ nodes:
  1. heartbeat monitor marks hosts dead after ``timeout`` missed beats;
  2. the coordinator re-plans the mesh from the surviving slice (largest
     (pod, data, model) grid that the healthy host count supports, keeping
     the model axis intact so param layouts survive);
  3. every survivor restores the latest checkpoint with the NEW mesh's
     shardings (resharding happens inside CheckpointManager.restore);
  4. the data pipeline rewinds to the checkpoint step (exactness tested).

CPU-scale tests simulate deaths by dropping host ids; the re-plan logic and
the reshard-restore path are real.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    healthy: bool = True


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, timeout_s: float = 60.0, clock=time.monotonic):
        self.clock = clock
        self.timeout = timeout_s
        now = clock()
        self.hosts = {h: HostState(h, now) for h in range(num_hosts)}

    def beat(self, host_id: int):
        self.hosts[host_id].last_beat = self.clock()
        self.hosts[host_id].healthy = True

    def sweep(self):
        """Returns the list of hosts newly marked dead."""
        now = self.clock()
        newly_dead = []
        for h in self.hosts.values():
            if h.healthy and now - h.last_beat > self.timeout:
                h.healthy = False
                newly_dead.append(h.host_id)
        return newly_dead

    def healthy_hosts(self):
        return [h.host_id for h in self.hosts.values() if h.healthy]


class ShardFailureDetector:
    """HeartbeatMonitor on a logical round clock, specialized for memory
    shards.

    The serving loop has no wall clock worth trusting in tests, so the
    detector's clock is the scheduling round: every shard that completed
    work this round beats (``beat_all``), an injected/observed death is
    reported via ``suspect``, and ``sweep`` converts missed beats into
    dead-shard declarations exactly like the host-level monitor.
    ``timeout_rounds=0`` (default) declares a suspected shard dead at the
    next sweep -- the serving layer's ShardFailure is already a positive
    signal, not a missed heartbeat, so there is nothing to wait for.
    """

    def __init__(self, num_shards: int, timeout_rounds: int = 0):
        self._round = 0
        self._suspected: set[int] = set()
        self.monitor = HeartbeatMonitor(
            num_shards, timeout_s=timeout_rounds, clock=lambda: self._round
        )

    def beat_all(self, rnd: int):
        """All shards healthy through round ``rnd`` (normal round end)."""
        self._round = rnd
        for h in self.monitor.hosts.values():
            if h.healthy and h.host_id not in self._suspected:
                self.monitor.beat(h.host_id)

    def suspect(self, shard: int, rnd: int):
        """A failure signal implicates ``shard``: freeze its beat so the
        next sweep (at any later round) declares it dead.  The signal is
        *targeted* -- every other healthy, unsuspected shard is beaten at
        the (possibly advanced) clock first, so a mid-round sweep never
        takes collateral victims whose round-end ``beat_all`` simply hasn't
        happened yet, and one suspicion never erases another."""
        self._round = max(self._round, rnd)
        self._suspected.add(shard)
        for h in self.monitor.hosts.values():
            if h.healthy and h.host_id not in self._suspected:
                self.monitor.beat(h.host_id)
        for s in self._suspected:
            self.monitor.hosts[s].last_beat = self._round - self.monitor.timeout - 1

    def sweep(self) -> list[int]:
        dead = self.monitor.sweep()
        self._suspected.difference_update(dead)
        return dead

    def revive(self, shard: int):
        """Recovery finished: the shard serves again."""
        self._suspected.discard(shard)
        self.monitor.beat(shard)

    def dead_shards(self) -> list[int]:
        return [
            h.host_id for h in self.monitor.hosts.values() if not h.healthy
        ]


def plan_mesh_shape(
    n_devices: int,
    *,
    model_parallel: int,
    prefer_pods: int = 1,
    devices_per_host: int = 1,
):
    """Largest (pod, data, model) grid from ``n_devices`` devices, keeping the
    ``model`` axis size fixed (param layout compatibility) and dropping to
    fewer pods / smaller data axis as capacity shrinks.

    Returns (shape tuple, axis names tuple, devices_used).
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model axis {model_parallel} with {n_devices} devices"
        )
    rows = n_devices // model_parallel  # candidate data x pod extent
    pods = prefer_pods
    while pods > 1 and rows % pods:
        pods -= 1
    data = rows // pods
    # keep data a power-of-two-ish friendly size: largest divisor of rows/pods
    used = pods * data * model_parallel
    if pods > 1:
        return (pods, data, model_parallel), ("pod", "data", "model"), used
    return (data, model_parallel), ("data", "model"), used


@dataclasses.dataclass
class ReshardEvent:
    """One completed live reshard (feeds ServiceMetrics / benches)."""

    requested_round: int
    cutover_round: int
    old_shards: int
    new_shards: int
    owner_epoch: int  # forwarding epoch installed at cutover
    drain_rounds: int  # rounds spent waiting on the write barrier
    wall_s: float


class ReshardPlanner:
    """State machine for an online 2x shard-count change.

    The protocol (PULSE's range partition makes it pointer-rewrite-free):

      1. ``request`` pins the target shard count (exact 2x grow or shrink);
      2. **drain**: the serving loop stops launching new quanta for the
         affected structures and waits for every in-flight quantum to
         retire -- the same barrier the write path already uses, so no
         record is ever in flight across the partition change;
      3. **cutover**: the arena is re-partitioned (``arena.remap_shards``,
         bounds/allocator-register surgery only), the mesh is rebuilt at
         the new width, per-shard serving state forwards through a new
         ``VersionedOwnerMap`` epoch, and a marker + snapshot land in the
         commit log so recovery never straddles two partitions;
      4. ``complete`` resumes admission.

    The planner owns phases and accounting; ``PulseService.step`` drives it
    (``should_cutover`` per round until the barrier clears).  The result is
    bit-identical to a cold rebuild at the new shard count because the
    remap itself is deterministic and nothing routes during the swap.
    """

    def __init__(self):
        self.phase = "idle"  # idle | draining | cutover
        self.target: int | None = None
        self._requested_round = 0
        self._drain_rounds = 0
        self._t0 = 0.0
        self.events: list[ReshardEvent] = []

    def request(self, new_num_shards: int, *, current: int, rnd: int) -> None:
        if self.phase != "idle":
            raise RuntimeError(f"reshard already in progress ({self.phase})")
        new_num_shards = int(new_num_shards)
        if new_num_shards != 2 * current and current != 2 * new_num_shards:
            raise ValueError(
                f"live reshard supports exact 2x changes, {current} -> "
                f"{new_num_shards}"
            )
        self.phase = "draining"
        self.target = new_num_shards
        self._requested_round = rnd
        self._drain_rounds = 0
        self._t0 = time.perf_counter()

    def should_cutover(self, in_flight: int) -> bool:
        """Called once per scheduling round while draining; True exactly
        once, when the write barrier has cleared."""
        if self.phase != "draining":
            return False
        if in_flight > 0:
            self._drain_rounds += 1
            return False
        self.phase = "cutover"
        return True

    def complete(self, *, rnd: int, old_shards: int, owner_epoch: int) -> ReshardEvent:
        if self.phase != "cutover":
            raise RuntimeError(f"complete() in phase {self.phase}")
        ev = ReshardEvent(
            requested_round=self._requested_round,
            cutover_round=rnd,
            old_shards=old_shards,
            new_shards=self.target,
            owner_epoch=owner_epoch,
            drain_rounds=self._drain_rounds,
            wall_s=time.perf_counter() - self._t0,
        )
        self.events.append(ev)
        self.phase = "idle"
        self.target = None
        return ev


@dataclasses.dataclass
class ElasticEvent:
    step: int
    kind: str  # shrink | grow
    old_shape: tuple
    new_shape: tuple
    lost_hosts: list


class ElasticCoordinator:
    """Glue: monitor -> replan -> (caller does) reshard-restore."""

    def __init__(self, monitor: HeartbeatMonitor, *, model_parallel: int,
                 devices_per_host: int = 1, prefer_pods: int = 1):
        self.monitor = monitor
        self.model_parallel = model_parallel
        self.devices_per_host = devices_per_host
        self.prefer_pods = prefer_pods
        self.events: list[ElasticEvent] = []

    def check(self, step: int, current_shape: tuple):
        dead = self.monitor.sweep()
        if not dead:
            return None
        n = len(self.monitor.healthy_hosts()) * self.devices_per_host
        shape, names, used = plan_mesh_shape(
            n, model_parallel=self.model_parallel, prefer_pods=self.prefer_pods,
            devices_per_host=self.devices_per_host,
        )
        ev = ElasticEvent(step, "shrink", current_shape, shape, dead)
        self.events.append(ev)
        return ev
