"""Logical sharding rules: param-path pattern -> PartitionSpec, plus the
versioned shard-of-slot owner function for elastic arenas.

Conventions (Megatron TP + FSDP hybrid):
  * ``model`` axis: TP for attention heads / MLP hidden, EP for experts,
    vocab-parallel for embed/unembed.
  * ``data`` (+``pod``): FSDP shards the *other* matrix dimension, so every
    large matrix is 2-D sharded; DP handles batch.
  * Norm scales / biases / small vectors: replicated.
  * Scan-stacked params carry a leading layer axis: specs get None prepended
    automatically (detected by leaf rank vs rule rank).

The arena side (``VersionedOwnerMap``) is index translation only: arena
pointers are global row addresses, so a reshard never rewrites a pointer --
it installs a new *owner-function epoch* (a finer/coarser range partition)
and anything still carrying a shard index minted under an older epoch
(parked requests, backoff timers, dead masks) is forwarded to the shards
covering the same address range.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    """The data-parallel axes usable for FSDP sharding."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_hint(x, mesh, *axes):
    """Best-effort ``with_sharding_constraint``.

    ``axes`` entries: mesh axis name(s), ``None``, or the placeholder
    ``"dp"`` (resolves to the (pod, data) axes present).  Axes that are
    missing from the mesh or do not divide the dim are dropped; with no mesh
    this is a no-op -- so model code can sprinkle hints freely and CPU tests
    stay mesh-free.  These hints are what keep activations batch-sharded
    through gathers (XLA loses the batch sharding at the embedding lookup;
    measured 16x replicated compute without them -- EXPERIMENTS.md S Perf).
    """
    if mesh is None or x.ndim != len(axes):
        return x
    resolved = []
    for dim, ax in zip(x.shape, axes):
        if ax == "dp":
            ax = fsdp_axes(mesh) or None
        if ax is None:
            resolved.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        if not all(a in mesh.axis_names for a in names):
            resolved.append(None)
            continue
        size = 1
        for a in names:
            size *= mesh.shape[a]
        resolved.append(ax if (dim % size == 0 and dim >= size) else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))


def param_rules(mesh: Mesh):
    fsdp = fsdp_axes(mesh)
    fs = fsdp if fsdp else None
    return [
        # embeddings: vocab-parallel x fsdp
        (r"embed$", P("model", fs)),
        (r"unembed/w$", P(fs, "model")),
        (r"patch_proj/w$", P(fs, "model")),
        (r"frame_proj/w$", P(fs, "model")),
        # attention
        (r"(attn|self_attn|cross_attn)/wq/w$", P(fs, "model")),
        (r"(attn|self_attn|cross_attn)/wk/w$", P(fs, "model")),
        (r"(attn|self_attn|cross_attn)/wv/w$", P(fs, "model")),
        (r"(attn|self_attn|cross_attn)/wo/w$", P("model", fs)),
        (r"(attn|self_attn|cross_attn)/w[qkv]/b$", P("model")),
        (r"(attn|self_attn|cross_attn)/wo/b$", P()),
        # dense mlp
        (r"mlp/wi/w$", P(fs, "model")),
        (r"mlp/wg/w$", P(fs, "model")),
        (r"mlp/wo/w$", P("model", fs)),
        (r"mlp/wi/b$", P("model")),
        (r"mlp/wo/b$", P()),
        # moe: experts over model (EP), dims over fsdp
        (r"moe/wi$", P("model", fs, None)),
        (r"moe/wg$", P("model", fs, None)),
        (r"moe/wo$", P("model", None, fs)),
        (r"moe/router/w$", P(fs, None)),
        (r"moe/shared/wi/w$", P(fs, "model")),
        (r"moe/shared/wg/w$", P(fs, "model")),
        (r"moe/shared/wo/w$", P("model", fs)),
        # ssm
        (r"ssm/in_proj/w$", P(fs, "model")),
        (r"ssm/out_proj/w$", P("model", fs)),
        (r"ssm/conv_w$", P(None, "model")),
        (r"ssm/conv_b$", P("model")),
        (r"ssm/(A_log|dt_bias|D_skip)$", P()),
        (r"ssm/norm/scale$", P("model")),
        # everything else (norms, small vectors): replicated
        (r".*", P()),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for(path_str: str, leaf, rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path_str):
            # scan-stacked leaves have extra leading axes: left-pad with None
            pad = leaf.ndim - len(spec)
            if pad < 0:
                # leaf smaller than rule (e.g. non-parametric norm) -> replicate
                return P()
            flat = (None,) * pad + tuple(spec)
            # avoid sharding tiny dims: drop axes that don't divide
            return P(*flat)
    return P()


def param_specs(params, mesh: Mesh):
    """Pytree of PartitionSpec for a param pytree."""
    rules = param_rules(mesh)

    def one(path, leaf):
        spec = spec_for(_path_str(path), leaf, rules)
        # validity: every named axis must divide the dim; else drop that axis
        fixed = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            fixed.append(ax if dim % size == 0 and dim >= size else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )


def opt_state_specs(opt_state, param_spec_tree):
    """Optimizer moments mirror their param's spec; scalars replicate.

    Works for adamw {mu, nu, step} and adafactor {v: {v|vr,vc}, step}.
    """

    def like(sub):
        return jax.tree.map(lambda s: s, param_spec_tree)

    out = {}
    for k, v in opt_state.items():
        if k == "step":
            out[k] = P()
        elif k in ("mu", "nu"):
            out[k] = param_spec_tree
        elif k == "v":
            # adafactor: factored stats drop the last (vr) or second-to-last
            # (vc) axis of the param spec
            def fac(path, leaf):
                # best-effort: replicate factored stats (they are small)
                return P()

            out[k] = jax.tree_util.tree_map_with_path(fac, v)
        else:
            out[k] = jax.tree.map(lambda _: P(), v)
    return out


def batch_specs(batch, mesh: Mesh):
    """Batch dim over (pod, data); everything else replicated."""
    dp = fsdp_axes(mesh)
    dp = dp if dp else None

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch)


# ---------------------------------------------------------------------------
# Versioned shard-of-slot owner function (elastic arenas)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OwnerEpoch:
    """One version of the shard-of-slot owner function: the switch's
    translation base table (range-partition bounds) at a reshard epoch."""

    epoch: int
    bounds: tuple[int, ...]  # (num_shards + 1,) sorted row-range partition

    @property
    def num_shards(self) -> int:
        return len(self.bounds) - 1

    def owner_of(self, ptr):
        """Owning shard for global address(es); -1 when out of range."""
        b = np.asarray(self.bounds, np.int64)
        p = np.asarray(ptr, np.int64)
        shard = np.searchsorted(b, p, side="right") - 1
        valid = (p >= 0) & (p < b[-1]) & (shard >= 0) & (shard < self.num_shards)
        return np.where(valid, shard, -1).astype(np.int32)


class VersionedOwnerMap:
    """Owner-function epochs with forwarding between them.

    A reshard installs a new epoch via ``advance``.  Stale per-shard state
    minted under an older epoch is translated with ``forward_shard`` /
    ``forward_mask``: old shard -> the new shards covering the same address
    range.  Pure index translation -- pointers are global, so no record is
    ever rewritten.
    """

    def __init__(self, bounds):
        self._epochs = [OwnerEpoch(0, tuple(int(b) for b in bounds))]

    @property
    def current(self) -> OwnerEpoch:
        return self._epochs[-1]

    @property
    def epoch(self) -> int:
        return self._epochs[-1].epoch

    def at(self, epoch: int) -> OwnerEpoch:
        for e in self._epochs:
            if e.epoch == epoch:
                return e
        raise KeyError(f"unknown owner epoch {epoch}")

    def advance(self, bounds) -> OwnerEpoch:
        """Install a new owner function (the forwarding epoch boundary)."""
        new = tuple(int(b) for b in bounds)
        cur = self.current
        if new[0] != cur.bounds[0] or new[-1] != cur.bounds[-1]:
            raise ValueError(
                "an owner epoch must cover the same address space: "
                f"{cur.bounds[0]}..{cur.bounds[-1]} vs {new[0]}..{new[-1]}"
            )
        nxt = OwnerEpoch(cur.epoch + 1, new)
        self._epochs.append(nxt)
        return nxt

    def forward_shard(
        self, shard: int, *, from_epoch: int, to_epoch: int | None = None
    ) -> tuple[int, ...]:
        """New-epoch shards whose ranges overlap old ``shard``'s range."""
        src = self.at(from_epoch)
        dst = self.current if to_epoch is None else self.at(to_epoch)
        if not 0 <= shard < src.num_shards:
            raise ValueError(f"shard {shard} out of range for epoch {from_epoch}")
        lo, hi = src.bounds[shard], src.bounds[shard + 1]
        db = np.asarray(dst.bounds, np.int64)
        first = int(np.searchsorted(db, lo, side="right")) - 1
        last = int(np.searchsorted(db, hi, side="left"))
        return tuple(range(max(first, 0), min(last, dst.num_shards)))

    def forward_mask(
        self, mask, *, from_epoch: int, to_epoch: int | None = None
    ) -> np.ndarray:
        """Forward a per-shard bool mask (e.g. suspected-dead): a new shard
        is set iff any overlapping old shard was set."""
        src = self.at(from_epoch)
        dst = self.current if to_epoch is None else self.at(to_epoch)
        mask = np.asarray(mask, bool)
        if mask.shape != (src.num_shards,):
            raise ValueError(
                f"mask shape {mask.shape} != ({src.num_shards},) of epoch "
                f"{from_epoch}"
            )
        out = np.zeros(dst.num_shards, bool)
        for s in np.flatnonzero(mask):
            for d in self.forward_shard(
                int(s), from_epoch=from_epoch, to_epoch=dst.epoch
            ):
                out[d] = True
        return out
