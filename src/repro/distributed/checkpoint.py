"""Sharded, async, atomic checkpointing with exact resume.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json          tree structure, shapes, dtypes, mesh info
        shard_<host>.npz       this host's addressable array shards
    <dir>/LATEST               atomic pointer (written last)

Properties a 1000-node deployment needs, all implemented + tested:
  * per-host shard files (no single-writer bottleneck; here host 0 only,
    but the layout and the manifest carry ``num_hosts``);
  * atomic commit: data files first, then LATEST via os.replace -- a crash
    mid-save can never corrupt the restorable state;
  * async save: the device->host copy happens synchronously (cheap), the
    file write on a worker thread so the train loop keeps stepping;
  * exact resume: params, optimizer moments, data-iterator step, RNG -- the
    post-restore training trajectory is bitwise identical (tested);
  * elastic restore: a checkpoint saved on one mesh restores onto another
    (resharding happens at device_put with the new mesh's shardings).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(_k(k) for k in path) for path, _ in flat]


def _k(entry):
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str | Path, *, host_id: int = 0, num_hosts: int = 1,
                 keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    # ------------------------------ save ------------------------------------

    def _atomic_save(self, step: int, arrays: dict[str, np.ndarray], manifest: dict):
        """The atomic commit sequence, usable for any named-array payload
        (training state or arena snapshots): write everything into a temp
        dir, os.replace it into place, THEN flip the LATEST pointer.  A
        crash at any point leaves either the previous checkpoint fully
        restorable or the new one fully committed -- a partial dir has no
        manifest.json and is ignored by ``all_steps``/``latest_step``."""
        final = self.dir / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_save_"))
        try:
            np.savez(tmp / f"shard_{self.host_id}.npz", **arrays)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            # atomic LATEST pointer, written last
            ptr = self.dir / ".LATEST_tmp"
            ptr.write_text(str(step))
            os.replace(ptr, self.dir / "LATEST")
            self._gc()
        finally:
            if tmp.exists():
                shutil.rmtree(tmp, ignore_errors=True)

    def save(self, state, step: int, *, extra: dict | None = None, block: bool = False):
        """state: pytree of jax arrays.  ``extra``: small json-able dict
        (data iterator step, rng key bytes, etc.)."""
        self.wait()  # one in-flight save at a time
        leaves, treedef = _flatten(state)
        paths = _tree_paths(state)
        # device -> host copy happens NOW (state may mutate next step)
        host_leaves = [np.asarray(x) for x in leaves]
        manifest = {
            "step": step,
            "num_hosts": self.num_hosts,
            "paths": paths,
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "extra": extra or {},
        }
        arrays = {f"a{i}": x for i, x in enumerate(host_leaves)}

        def write():
            self._atomic_save(step, arrays, manifest)

        if self.async_save and not block:
            t = threading.Thread(target=write, daemon=True)
            t.start()
            self._pending = t
        else:
            write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ----------------------------- restore ----------------------------------

    def all_steps(self):
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        ]

    def latest_step(self) -> int | None:
        ptr = self.dir / "LATEST"
        if ptr.exists():
            s = int(ptr.read_text())
            if (self.dir / f"step_{s:08d}" / "manifest.json").exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, like_state, step: int | None = None, *, shardings=None):
        """Restore into the structure of ``like_state``; device_put with
        ``shardings`` (pytree of NamedSharding) reshards onto the current
        mesh -- this is the elastic-restore path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / f"shard_{self.host_id}.npz")
        leaves = [data[f"a{i}"] for i in range(len(manifest["paths"]))]
        _, treedef = _flatten(like_state)
        like_leaves = jax.tree_util.tree_leaves(like_state)
        if len(like_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}"
            )
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None
            )
            out = [
                jax.device_put(x, s) if s is not None else jax.device_put(x)
                for x, s in zip(leaves, shard_leaves)
            ]
        else:
            out = [jax.device_put(x) for x in leaves]
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"], step
