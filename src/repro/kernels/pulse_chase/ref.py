"""Pure-jnp oracle for the pulse_chase kernel: K traversal steps for a batch
of lanes over an arena, with the same masked-update semantics."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def chase_reference(arena, ptr, scratch, status, iters, logic_fn, num_steps: int):
    """``logic_fn(nodes (B,W), ptr (B,), scratch (B,S)) -> (done, new_ptr,
    new_scratch)`` vectorized over lanes.  status: 0 active, 1 done.
    ``iters`` accumulates exact per-lane iteration counts: every step an
    active lane executes counts, including the one that discovers done."""

    def body(_, st):
        ptr, scratch, status, iters = st
        active = status == 0
        safe = jnp.clip(ptr, 0, arena.shape[0] - 1)
        nodes = jnp.take(arena, jnp.where(active, safe, 0), axis=0)
        done, nptr, nscr = logic_fn(nodes, ptr, scratch)
        ptr = jnp.where(active & ~done, nptr, ptr).astype(ptr.dtype)
        scratch = jnp.where(active[:, None], nscr, scratch).astype(scratch.dtype)
        status = jnp.where(active & done, 1, status).astype(status.dtype)
        # walking off the structure (NULL) terminates too
        status = jnp.where((status == 0) & (ptr < 0), 1, status).astype(status.dtype)
        iters = jnp.where(active, iters + 1, iters).astype(iters.dtype)
        return ptr, scratch, status, iters

    return jax.lax.fori_loop(0, num_steps, body, (ptr, scratch, status, iters))
