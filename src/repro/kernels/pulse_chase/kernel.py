"""pulse_chase: the PULSE accelerator as a Pallas TPU kernel (paper S4.2).

TPU-native adaptation of the disaggregated accelerator:

  * **memory pipelines**  -> async HBM->VMEM DMAs gathering one node record
    per in-flight lane (the single aggregated <=256 B LOAD per iteration,
    S4.1).  The arena stays in HBM (``pltpu.ANY``); only fetched records
    enter VMEM, mirroring "only fetched data crosses to the accelerator".
  * **logic pipelines**   -> the vectorized iterator body (next+end fused)
    executing on the *previous* wave's records.
  * **m:n multiplexing**  -> software pipelining across WAVES of lanes:
    while wave ``g``'s records are in flight (DMA), wave ``g-1`` executes
    logic.  Property 1 (fetch->logic dependence *within* a lane) is
    respected; overlap comes only from independent lanes, exactly like the
    paper's scheduler (Fig. 4 bottom).  The wave count per buffer plays the
    role of n/m: more waves in flight == more memory pipelines.

Layout notes: node records are int32 rows of width <= 64 (256 B).  For MXU/
VREG alignment the record width is zero-padded to a 128-lane multiple by
``ops.pulse_chase`` before entering the kernel; wave size should be a
multiple of 8 (f32/i32 sublane tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPU_ANY

NBUF = 2  # double buffering: one wave in flight per buffer slot


def _chase_kernel(
    # inputs (VMEM unless noted)
    ptr_ref,  # (B,)   int32  current pointers
    scratch_ref,  # (B, S) int32  scratch pads
    status_ref,  # (B,)   int32  0 active / 1 done
    iters_ref,  # (B,)   int32  per-lane iteration counts (accumulated)
    arena_ref,  # (cap, Wp) int32 in ANY/HBM -- the disaggregated heap
    # outputs
    out_ptr_ref,  # (B,)
    out_scratch_ref,  # (B, S)
    out_status_ref,  # (B,)
    out_iters_ref,  # (B,)
    # scratch
    node_buf,  # (NBUF, G, Wp) int32 VMEM -- landed node records
    copy_sem,  # (NBUF,) DMA semaphores
    *,
    logic_fn,
    num_steps: int,
    num_waves: int,
    wave: int,
):
    """Single-program kernel; waves of G lanes software-pipeline the DMAs."""
    B = ptr_ref.shape[0]
    G = wave

    out_ptr_ref[...] = ptr_ref[...]
    out_scratch_ref[...] = scratch_ref[...]
    out_status_ref[...] = status_ref[...]
    out_iters_ref[...] = iters_ref[...]

    def issue_wave(g, step_ptr):
        """Memory pipeline: start DMAs for wave g's node records."""
        slot = jax.lax.rem(g, NBUF)

        def one_lane(i, _):
            lane = g * G + i
            p = step_ptr[lane]
            safe = jnp.clip(p, 0, arena_ref.shape[0] - 1)
            pltpu.make_async_copy(
                arena_ref.at[pl.ds(safe, 1), :],
                node_buf.at[slot, pl.ds(i, 1), :],
                copy_sem.at[slot],
            ).start()
            return 0

        jax.lax.fori_loop(0, G, one_lane, 0)

    def wait_wave(g):
        slot = jax.lax.rem(g, NBUF)

        def one_lane(i, _):
            pltpu.make_async_copy(
                arena_ref.at[pl.ds(0, 1), :],
                node_buf.at[slot, pl.ds(i, 1), :],
                copy_sem.at[slot],
            ).wait()
            return 0

        jax.lax.fori_loop(0, G, one_lane, 0)

    def logic_wave(g):
        """Logic pipeline: run the iterator body on wave g's landed records."""
        slot = jax.lax.rem(g, NBUF)
        nodes = node_buf[slot]  # (G, Wp)
        lo = g * G
        ptr = jax.lax.dynamic_slice_in_dim(out_ptr_ref[...], lo, G)
        scr = jax.lax.dynamic_slice_in_dim(out_scratch_ref[...], lo, G)
        st = jax.lax.dynamic_slice_in_dim(out_status_ref[...], lo, G)
        itc = jax.lax.dynamic_slice_in_dim(out_iters_ref[...], lo, G)
        active = st == 0
        done, nptr, nscr = logic_fn(nodes, ptr, scr)
        ptr = jnp.where(active & ~done, nptr, ptr).astype(jnp.int32)
        scr = jnp.where(active[:, None], nscr, scr).astype(jnp.int32)
        st = jnp.where(active & done, 1, st).astype(jnp.int32)
        st = jnp.where((st == 0) & (ptr < 0), 1, st).astype(jnp.int32)
        # exact per-lane accounting: every step an active lane executes
        # counts -- including the step that discovers done (the XLA
        # executor's runnable-gated increment does the same)
        itc = jnp.where(active, itc + 1, itc).astype(jnp.int32)
        out_ptr_ref[pl.ds(lo, G)] = ptr
        out_scratch_ref[pl.ds(lo, G), :] = scr
        out_status_ref[pl.ds(lo, G)] = st
        out_iters_ref[pl.ds(lo, G)] = itc

    def step(k, _):
        # snapshot pointers for this traversal step: every wave's fetch uses
        # the pointers produced by step k-1 (Property 1 per lane).  The
        # status snapshot retires whole waves: a wave whose lanes have all
        # finished issues no DMAs and runs no logic this step (the in-kernel
        # half of the variable-depth wave scheduler; ops.pulse_chase_waves
        # compacts retired lanes out *between* kernel invocations).
        step_ptr = out_ptr_ref[...]
        step_st = out_status_ref[...]

        def wave_live(g):
            st = jax.lax.dynamic_slice_in_dim(step_st, g * G, G)
            return jnp.any(st == 0)

        @pl.when(wave_live(0))
        def _():
            issue_wave(0, step_ptr)

        def pipelined(g, _):
            # overlap: start wave g+1's fetch, then execute logic on wave g.
            # issue/wait share the wave_live predicate (computed on the same
            # snapshot), so DMA semaphores stay balanced.
            @pl.when(jnp.logical_and(g + 1 < num_waves, wave_live(g + 1)))
            def _():
                issue_wave(g + 1, step_ptr)

            @pl.when(wave_live(g))
            def _():
                wait_wave(g)
                logic_wave(g)

            return 0

        jax.lax.fori_loop(0, num_waves, pipelined, 0)
        return 0

    jax.lax.fori_loop(0, num_steps, step, 0)


def pulse_chase_pallas(
    arena: jax.Array,  # (cap, Wp) int32, Wp lane-aligned
    ptr: jax.Array,  # (B,) int32
    scratch: jax.Array,  # (B, S)
    status: jax.Array,  # (B,)
    iters: jax.Array,  # (B,) int32 -- accumulated; returned exact per-lane
    *,
    logic_fn,
    num_steps: int,
    wave: int = 8,
    interpret: bool = False,
):
    B = ptr.shape[0]
    if B % wave:
        raise ValueError(f"batch {B} must be a multiple of wave size {wave}")
    num_waves = B // wave
    Wp = arena.shape[1]
    kernel = functools.partial(
        _chase_kernel,
        logic_fn=logic_fn,
        num_steps=num_steps,
        num_waves=num_waves,
        wave=wave,
    )
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=TPU_ANY),  # handled below
            pl.BlockSpec(memory_space=TPU_ANY),
            pl.BlockSpec(memory_space=TPU_ANY),
            pl.BlockSpec(memory_space=TPU_ANY),
            pl.BlockSpec(memory_space=TPU_ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=TPU_ANY),
            pl.BlockSpec(memory_space=TPU_ANY),
            pl.BlockSpec(memory_space=TPU_ANY),
            pl.BlockSpec(memory_space=TPU_ANY),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct(scratch.shape, jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((NBUF, wave, Wp), jnp.int32),
            pltpu.SemaphoreType.DMA((NBUF,)),
        ],
        interpret=interpret,
    )(ptr, scratch, status, iters, arena)
