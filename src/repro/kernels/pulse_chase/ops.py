"""jit'd wrapper for the pulse_chase kernel + PulseIterator adapter."""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.iterator import PulseIterator
from repro.core.routing import ExecutableCacheStats
from repro.kernels.pulse_chase.kernel import pulse_chase_pallas
from repro.kernels.pulse_chase.ref import chase_reference

# Executable reuse accounting for the kernel backend (same discipline as the
# routing layer's fused cache): ``traces`` only moves when a new (shape,
# statics) combination forces a recompile, so the wave scheduler's pow2 lane
# ladder is regression-tested to stay at O(log B) compiles across waves.
CACHE_STATS = ExecutableCacheStats()


def iterator_logic(it: PulseIterator):
    """Vectorized fused next+end body for a PulseIterator (the compiled
    iterator the dispatch engine ships to the accelerator)."""

    def one(node, ptr, scratch):
        if it.step_fn is not None:
            return it.step_fn(node, ptr, scratch)
        done, scr = it.end_fn(node, ptr, scratch)
        nptr, nscr = it.next_fn(node, ptr, scr)
        return done, jnp.where(done, ptr, nptr), jnp.where(done, scr, nscr)

    def logic(nodes, ptr, scratch):
        done, nptr, nscr = jax.vmap(one)(nodes, ptr, scratch)
        return done, nptr.astype(jnp.int32), nscr.astype(jnp.int32)

    return logic


@partial(
    jax.jit,
    static_argnames=("logic_fn", "num_steps", "wave", "interpret", "use_pallas"),
    donate_argnames=("ptr", "scratch", "status", "iters"),
)
def _pulse_chase_donated(
    arena_data: jax.Array,
    ptr: jax.Array,
    scratch: jax.Array,
    status: jax.Array,
    iters: jax.Array,
    *,
    logic_fn,
    num_steps: int,
    wave: int = 8,
    interpret: bool = True,
    use_pallas: bool = True,
):
    """The one compiled executable behind both entry points.

    Lane buffers (ptr/scratch/status/iters) are donated: the wave scheduler
    owns its padded buffers and rebuilds them per chunk, so XLA may alias
    them in place.  The arena is never donated -- it is the resident state
    reused across waves.  Callers that do not own their buffers go through
    ``pulse_chase``, which copies first.
    """
    CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
    ptr = jnp.asarray(ptr, jnp.int32)
    scratch = jnp.asarray(scratch, jnp.int32)
    status = jnp.asarray(status, jnp.int32)
    iters = jnp.asarray(iters, jnp.int32)
    if not use_pallas:
        return chase_reference(
            arena_data, ptr, scratch, status, iters, logic_fn, num_steps
        )
    return pulse_chase_pallas(
        jnp.asarray(arena_data, jnp.int32),
        ptr,
        scratch,
        status,
        iters,
        logic_fn=logic_fn,
        num_steps=num_steps,
        wave=wave,
        interpret=interpret,
    )


def pulse_chase(
    arena_data: jax.Array,
    ptr: jax.Array,
    scratch: jax.Array,
    status: jax.Array,
    iters: jax.Array | None = None,
    *,
    logic_fn,
    num_steps: int,
    wave: int = 8,
    interpret: bool = True,
    use_pallas: bool = True,
):
    """Run ``num_steps`` traversal iterations for a batch of lanes.

    Returns ``(ptr, scratch, status, iters)`` -- ``iters`` is the exact
    per-lane iteration count (accumulated on top of the passed-in counts,
    zeros when omitted): every step an active lane executes counts,
    including the step that discovers done, matching the XLA executor's
    runnable-gated accounting bit-for-bit.

    ``use_pallas=False`` falls back to the pure-jnp reference (the XLA path
    models use on CPU); ``interpret=True`` runs the Pallas kernel body in
    interpret mode (CPU validation of the TPU kernel).

    The caller's lane buffers are copied (``jnp.array``) before entering the
    donating executable, so they stay valid after the call.
    """
    if iters is None:
        iters = jnp.zeros(jnp.asarray(ptr).shape, jnp.int32)
    return _pulse_chase_donated(
        arena_data,
        jnp.array(ptr, jnp.int32),
        jnp.array(scratch, jnp.int32),
        jnp.array(status, jnp.int32),
        jnp.array(iters, jnp.int32),
        logic_fn=logic_fn,
        num_steps=num_steps,
        wave=wave,
        interpret=interpret,
        use_pallas=use_pallas,
    )


# ------------------------- variable-depth scheduling -------------------------


@dataclasses.dataclass
class WaveStats:
    """Accounting for the variable-depth wave scheduler.

    ``lane_steps`` is the work actually executed (surviving+padding lanes x
    steps, summed over chunks); ``dense_lane_steps`` is what the fixed-depth
    scheduler would have executed (every lane runs every step).  The ratio is
    the fraction of accelerator issue slots the early-retire scheduler saved.
    """

    chunks: int = 0
    lane_steps: int = 0
    dense_lane_steps: int = 0
    steps_per_chunk: list = dataclasses.field(default_factory=list)
    lanes_per_chunk: list = dataclasses.field(default_factory=list)
    retire_step: np.ndarray | None = None  # (B,) EXACT per-lane iteration
    # count at retirement (0 for NULL-entry lanes; the executed count for
    # lanes that never finished), accumulated by the kernel itself -- wave
    # retirement no longer rounds it up to the chunk boundary, so downstream
    # hop accounting (ServiceMetrics.lane_iters, ExecResult.iters) is exact
    faulted: np.ndarray | None = None  # (B,) lanes retired by fault_fn
    # (or by a NULL/negative pointer) rather than by finishing

    @property
    def savings(self) -> float:
        if not self.dense_lane_steps:
            return 0.0
        return 1.0 - self.lane_steps / self.dense_lane_steps


def _pad_ladder(n: int, wave: int) -> int:
    """Smallest wave multiple >= n from the power-of-two ladder {wave, 2*wave,
    4*wave, ...} -- bounds the number of distinct compiled batch shapes at
    O(log B) while keeping padding overhead under 2x."""
    m = wave
    while m < n:
        m *= 2
    return m


def pulse_chase_waves(
    arena_data: jax.Array,
    ptr: jax.Array,
    scratch: jax.Array,
    status: jax.Array,
    *,
    logic_fn,
    max_steps: int,
    depth_quantum: int = 8,
    wave: int = 8,
    interpret: bool = True,
    use_pallas: bool = True,
    fault_fn=None,
):
    """Variable-depth traversal: retire finished lanes between depth quanta.

    The fixed-depth ``pulse_chase`` runs every lane for ``num_steps``
    iterations even after it finishes -- fine when depths are uniform (B-tree
    descent), wasteful for skewed workloads (hash chains, list walks) where a
    few deep lanes pin the depth for everyone.  This scheduler runs the
    kernel in chunks of ``depth_quantum`` steps, pulls lane status between
    chunks, compacts retired lanes out of the batch (pow2 ladder padding so
    recompiles stay bounded), and keeps only survivors in flight -- the m:n
    multiplexer only ever holds live traversals, mirroring the routing
    layer's active-set compaction.

    Lanes entering with ``ptr == NULL`` retire immediately with their init
    scratch (the executor's FAULT-on-NULL semantics, minus the status code --
    the caller maps status if it needs to distinguish).

    ``fault_fn`` is the translation/protection layer's hook: a host-side
    ``(ptrs int32 array) -> bool mask`` applied to live lanes on entry and
    between chunks; ``True`` lanes retire as faults (``stats.faulted``).
    Fault detection is therefore quantum-granular -- a lane stepping into a
    bad range mid-chunk executes up to ``depth_quantum - 1`` extra (clamped,
    harmless) loads before it is retired.

    Returns ``(ptr, scratch, status, stats)`` in the original lane order;
    results are identical to running the fixed scheduler for ``max_steps``.
    """
    out_ptr = np.asarray(ptr, np.int32).copy()
    out_scr = np.asarray(scratch, np.int32).copy()
    out_st = np.asarray(status, np.int32).copy()
    B = out_ptr.shape[0]
    out_it = np.zeros(B, np.int32)  # exact per-lane counts from the kernel
    faulted = np.zeros(B, bool)
    faulted[(out_st == 0) & (out_ptr < 0)] = True  # NULL entry: fault on arrival

    stats = WaveStats(dense_lane_steps=B * max_steps)
    stats.retire_step = out_it
    stats.faulted = faulted

    def _apply_faults(idx):
        """Retire live lanes whose pointer fails the caller's check."""
        if fault_fn is None or not idx.size:
            return idx
        bad = np.asarray(fault_fn(out_ptr[idx]), bool)
        faulted[idx[bad]] = True
        out_st[idx[bad]] = 1
        return idx[~bad]

    out_st[faulted] = 1
    steps_done = 0
    live = _apply_faults(np.flatnonzero(out_st == 0))
    while live.size and steps_done < max_steps:
        q = min(depth_quantum, max_steps - steps_done)
        n = int(live.size)
        padded = _pad_ladder(n, wave)
        p_in = np.full(padded, -1, np.int32)
        s_in = np.zeros((padded, out_scr.shape[1]), np.int32)
        st_in = np.ones(padded, np.int32)  # padding lanes are born retired
        it_in = np.zeros(padded, np.int32)
        p_in[:n] = out_ptr[live]
        s_in[:n] = out_scr[live]
        st_in[:n] = 0
        it_in[:n] = out_it[live]  # kernel accumulates on top: counts stay exact
        # chunk buffers are freshly built above, so hand them straight to the
        # donating executable (no defensive copy); the pow2 lane ladder keeps
        # the executable cache at O(log B) entries across waves
        p1, s1, st1, it1 = _pulse_chase_donated(
            arena_data,
            jnp.asarray(p_in),
            jnp.asarray(s_in),
            jnp.asarray(st_in),
            jnp.asarray(it_in),
            logic_fn=logic_fn,
            num_steps=q,
            wave=wave,
            interpret=interpret,
            use_pallas=use_pallas,
        )
        out_ptr[live] = np.asarray(p1)[:n]
        out_scr[live] = np.asarray(s1)[:n]
        out_st[live] = np.asarray(st1)[:n]
        out_it[live] = np.asarray(it1)[:n]
        steps_done += q
        stats.chunks += 1
        stats.lane_steps += padded * q
        stats.steps_per_chunk.append(q)
        stats.lanes_per_chunk.append(n)
        # lanes the kernel retired on a negative pointer are faults too
        faulted[live[(np.asarray(st1)[:n] == 1) & (np.asarray(p1)[:n] < 0)]] = True
        live = _apply_faults(live[np.asarray(st1)[:n] == 0])
    return out_ptr, out_scr, out_st, stats
