"""jit'd wrapper for the pulse_chase kernel + PulseIterator adapter."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.iterator import PulseIterator
from repro.kernels.pulse_chase.kernel import pulse_chase_pallas
from repro.kernels.pulse_chase.ref import chase_reference


def iterator_logic(it: PulseIterator):
    """Vectorized fused next+end body for a PulseIterator (the compiled
    iterator the dispatch engine ships to the accelerator)."""

    def one(node, ptr, scratch):
        if it.step_fn is not None:
            return it.step_fn(node, ptr, scratch)
        done, scr = it.end_fn(node, ptr, scratch)
        nptr, nscr = it.next_fn(node, ptr, scr)
        return done, jnp.where(done, ptr, nptr), jnp.where(done, scr, nscr)

    def logic(nodes, ptr, scratch):
        done, nptr, nscr = jax.vmap(one)(nodes, ptr, scratch)
        return done, nptr.astype(jnp.int32), nscr.astype(jnp.int32)

    return logic


@partial(
    jax.jit,
    static_argnames=("logic_fn", "num_steps", "wave", "interpret", "use_pallas"),
)
def pulse_chase(
    arena_data: jax.Array,
    ptr: jax.Array,
    scratch: jax.Array,
    status: jax.Array,
    *,
    logic_fn,
    num_steps: int,
    wave: int = 8,
    interpret: bool = True,
    use_pallas: bool = True,
):
    """Run ``num_steps`` traversal iterations for a batch of lanes.

    ``use_pallas=False`` falls back to the pure-jnp reference (the XLA path
    models use on CPU); ``interpret=True`` runs the Pallas kernel body in
    interpret mode (CPU validation of the TPU kernel).
    """
    ptr = jnp.asarray(ptr, jnp.int32)
    scratch = jnp.asarray(scratch, jnp.int32)
    status = jnp.asarray(status, jnp.int32)
    if not use_pallas:
        return chase_reference(
            arena_data, ptr, scratch, status, logic_fn, num_steps
        )
    return pulse_chase_pallas(
        jnp.asarray(arena_data, jnp.int32),
        ptr,
        scratch,
        status,
        logic_fn=logic_fn,
        num_steps=num_steps,
        wave=wave,
        interpret=interpret,
    )
