"""Paged decode attention: PULSE pointer traversal fused with flash-decode.

This is ``pulse_chase`` specialized to serving: each sequence's KV cache is a
chain of fixed-size pages (page table built by walking a PULSE linked list in
the serving arena), and the per-iteration work is "fetch page -> partial
softmax".  The PULSE accelerator mapping:

  * memory pipeline -> the page DMA selected *by the scalar-prefetched page
    table* via the BlockSpec index_map (Pallas prefetches the next grid
    step's page while this one computes -- the disaggregated fetch/logic
    overlap of S4.2, done by the hardware pipeline for us);
  * logic pipeline  -> the online-softmax accumulation over the landed page;
  * scratch_pad     -> (m, l, acc) carried across pages in VMEM scratch.

Grid = (B, Hk, num_pages); the page axis iterates sequentially per core, so
the accumulator persists.  All G = H/Hk query heads of a KV head are
processed together (they share the fetched page -- one aggregated LOAD, many
consumers, the S4.1 load-aggregation argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    # scalar prefetch
    page_table_ref,  # (B, P) int32 (SMEM)
    lengths_ref,  # (B,) int32 (SMEM)
    # inputs
    q_ref,  # (1, 1, G, D)  queries of this kv head's group
    k_ref,  # (1, page, 1, D)  the page selected by index_map
    v_ref,  # (1, page, 1, D)
    # outputs
    o_ref,  # (1, 1, G, D)
    # scratch
    m_scr,  # (G, 1) f32
    l_scr,  # (G, 1) f32
    acc_scr,  # (G, D) f32
    *,
    page: int,
    num_pages: int,
    scale: float,
):
    b = pl.program_id(0)
    p = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_page = p * page < length

    @pl.when(valid_page)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, page)
        pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + pexp.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(p == num_pages - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,  # (B, H, D)
    k_pages: jax.Array,  # (N, page, Hk, D)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, P) int32
    lengths: jax.Array,  # (B,) int32
    *,
    scale: float | None = None,
    interpret: bool = False,
):
    B, H, D = q.shape
    N, page, Hk, _ = k_pages.shape
    P = page_table.shape[1]
    if H % Hk:
        raise ValueError(f"H={H} not a multiple of Hk={Hk}")
    G = H // Hk
    scale = (D ** -0.5) if scale is None else scale
    # (B, H, D) -> (B, Hk, G, D): group query heads by their kv head
    qg = q.reshape(B, Hk, G, D)

    kernel = functools.partial(
        _paged_kernel, page=page, num_pages=P, scale=scale
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt, ln: (b, h, 0, 0)),
            # the pointer traversal: the page table (already chased out of the
            # PULSE arena) selects which HBM page the pipeline DMAs next
            pl.BlockSpec((1, page, 1, D), lambda b, h, p, pt, ln: (pt[b, p], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, p, pt, ln: (pt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, D), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return out.reshape(B, H, D)
