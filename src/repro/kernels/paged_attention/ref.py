"""Pure-jnp oracle for paged decode attention.

Gathers each sequence's KV pages (page_table order), masks past ``lengths``,
and runs exact softmax attention for the single new token per sequence.
"""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_reference(
    q,  # (B, H, D) one query token per sequence
    k_pages,  # (N, page, Hk, D)
    v_pages,  # (N, page, Hk, D)
    page_table,  # (B, P) int32 page ids (padded with anything past lengths)
    lengths,  # (B,) int32 valid tokens per sequence
    *,
    scale: float | None = None,
):
    B, H, D = q.shape
    N, page, Hk, _ = k_pages.shape
    P = page_table.shape[1]
    G = H // Hk
    scale = (D ** -0.5) if scale is None else scale

    # gather pages -> (B, P*page, Hk, D)
    safe = jnp.clip(page_table, 0, N - 1)
    k = jnp.take(k_pages, safe, axis=0).reshape(B, P * page, Hk, D)
    v = jnp.take(v_pages, safe, axis=0).reshape(B, P * page, Hk, D)
    kq = jnp.repeat(k, G, axis=2)  # (B, L, H, D)
    vq = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32), kq.astype(jnp.float32))
    s = s * scale
    mask = jnp.arange(P * page)[None, :] < lengths[:, None]  # (B, L)
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhl,blhd->bhd", p, vq.astype(jnp.float32))
    return o.astype(q.dtype)
