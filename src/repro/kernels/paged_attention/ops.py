"""jit'd wrapper for paged decode attention (kernel or jnp reference)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_reference


@partial(jax.jit, static_argnames=("interpret", "use_pallas"))
def paged_attention(
    q, k_pages, v_pages, page_table, lengths, *, interpret=True, use_pallas=True
):
    if not use_pallas:
        return paged_attention_reference(q, k_pages, v_pages, page_table, lengths)
    return paged_attention_pallas(
        q, k_pages, v_pages, page_table, lengths, interpret=interpret
    )
