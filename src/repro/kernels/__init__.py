"""Pallas TPU kernels for the perf-critical compute layers.

  pulse_chase      the paper's accelerator: decoupled DMA (memory pipeline)
                   and iterator logic (logic pipeline), wave-multiplexed
  paged_attention  PULSE traversal fused with flash-decode for serving
  flash_attention  blockwise online-softmax attention (train/prefill)
  ssd_scan         Mamba2 SSD chunked scan (MXU-shaped state passing)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper
with XLA fallback), ref.py (pure-jnp oracle).  All kernels validate in
``interpret=True`` on CPU; ``use_pallas=False`` selects the XLA path that
the dry-run/roofline flow lowers.
"""
