"""Pure-jnp oracle: GQA multi-head attention (causal or full)."""

from __future__ import annotations

import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, H, Lq, D); k, v: (B, Hk, Lk, D) with H % Hk == 0."""
    B, H, Lq, D = q.shape
    Hk = k.shape[1]
    G = H // Hk
    scale = (D ** -0.5) if scale is None else scale
    kq = jnp.repeat(k, G, axis=1)
    vq = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32))
    s = s * scale
    if causal:
        Lk = k.shape[2]
        mask = jnp.tril(jnp.ones((Lq, Lk), bool), k=Lk - Lq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return o.astype(q.dtype)
