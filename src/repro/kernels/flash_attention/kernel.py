"""FlashAttention forward (GQA, causal or full) as a Pallas TPU kernel.

Classic blockwise online-softmax attention with explicit BlockSpec VMEM
tiling.  Grid = (B, H, num_q_blocks, num_k_blocks); the last axis iterates
sequentially on a TPU core, so the running max / denominator / accumulator
live in VMEM scratch across k-blocks.  Causal masking skips whole k-blocks
above the diagonal (``pl.when``), and the diagonal block applies the
per-element mask.

MXU alignment: block_q/block_k multiples of 128 recommended on real TPU;
head_dim is the lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    m_scr,  # (bq, 1) f32
    l_scr,  # (bq, 1) f32
    acc_scr,  # (bq, D) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: k-block strictly above the diagonal contributes nothing
    q_end = q_offset + (qi + 1) * block_q - 1  # last absolute q row here
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if causal:
            rows = q_offset + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        pl.when(k_start <= q_end)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, H, Lq, D)
    k: jax.Array,  # (B, Hk, Lk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    B, H, Lq, D = q.shape
    _, Hk, Lk, _ = k.shape
    if H % Hk:
        raise ValueError(f"H={H} not a multiple of Hk={Hk}")
    G = H // Hk
    block_q = min(block_q, Lq)
    block_k = min(block_k, Lk)
    if Lq % block_q or Lk % block_k:
        raise ValueError("sequence lengths must divide block sizes")
    nq, nk = Lq // block_q, Lk // block_k
    scale = (D ** -0.5) if scale is None else scale
    # decode-style queries attend at the END of the kv sequence
    q_offset = Lk - Lq if causal else 0

    kernel = functools.partial(
        _flash_fwd_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
