"""jit'd FlashAttention wrapper with reference fallback + custom VJP.

Forward = Pallas kernel (or the jnp reference on the XLA path).  Backward =
recompute-based VJP through the chunked jnp reference: numerically matches
the kernel forward (both are exact softmax attention), and keeps memory at
O(L) via chunk remat.  A dedicated flash backward kernel is a listed future
optimization; the dry-run/roofline path uses the XLA chunked implementation
in ``repro.models.attention`` either way.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_reference


@partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7),
)
def flash_attention(
    q, k, v, causal=True, block_q=128, block_k=128, interpret=True, use_pallas=True
):
    if not use_pallas:
        return mha_reference(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


def _fwd(q, k, v, causal, block_q, block_k, interpret, use_pallas):
    o = flash_attention(q, k, v, causal, block_q, block_k, interpret, use_pallas)
    return o, (q, k, v)


def _bwd(causal, block_q, block_k, interpret, use_pallas, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: mha_reference(q, k, v, causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
