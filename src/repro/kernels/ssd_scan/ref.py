"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) scan.

Two references:
  * ``ssd_sequential`` -- the exact per-token recurrence
        S_t = a_t * S_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t @ S_t
    with a_t = exp(dt_t * A) (A < 0 per head).  Ground truth.
  * ``ssd_chunked``    -- the SSD chunked algorithm (arXiv:2405.21060 S6):
    intra-chunk quadratic part + inter-chunk state passing.  This is what the
    Pallas kernel implements blockwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential(x, dt, A, B, C, *, init_state=None):
    """x: (L, dh); dt: (L,); A: scalar<0; B, C: (L, N).  Returns (y, S)."""
    L, dh = x.shape
    N = B.shape[1]
    S0 = jnp.zeros((N, dh), jnp.float32) if init_state is None else init_state

    def step(S, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * A)
        S = a * S + dtt * jnp.outer(Bt, xt)
        y = Ct @ S
        return S, y

    S, y = jax.lax.scan(step, S0, (x.astype(jnp.float32), dt.astype(jnp.float32),
                                   B.astype(jnp.float32), C.astype(jnp.float32)))
    return y, S


def ssd_chunked(x, dt, A, B, C, *, chunk: int, init_state=None, unroll: bool = False):
    """Chunked SSD, mathematically identical to ``ssd_sequential``."""
    L, dh = x.shape
    N = B.shape[1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xc = x.reshape(nc, chunk, dh).astype(jnp.float32)
    dtc = dt.reshape(nc, chunk).astype(jnp.float32)
    Bc = B.reshape(nc, chunk, N).astype(jnp.float32)
    Cc = C.reshape(nc, chunk, N).astype(jnp.float32)
    S0 = jnp.zeros((N, dh), jnp.float32) if init_state is None else init_state

    def chunk_step(S, inp):
        xq, dtq, Bq, Cq = inp  # (Q, dh), (Q,), (Q, N), (Q, N)
        la = dtq * A  # (Q,) log-decay per step
        cs = jnp.cumsum(la)  # (Q,)
        # intra-chunk: Lmat[i, j] = exp(cs_i - cs_j) for j <= i.
        # Mask BEFORE the exp: for j > i the difference is positive and can
        # overflow to inf, which would poison the VJP (0 * inf = NaN).
        diff = cs[:, None] - cs[None, :]
        tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
        Lmat = jnp.exp(jnp.where(tri, diff, -1e9))
        scores = (Cq @ Bq.T) * Lmat  # (Q, Q)
        xbar = xq * dtq[:, None]  # (Q, dh)
        y = scores @ xbar + jnp.exp(cs)[:, None] * (Cq @ S)
        # state passing
        decay_out = jnp.exp(cs[-1] - cs)  # (Q,)
        S = jnp.exp(cs[-1]) * S + Bq.T @ (decay_out[:, None] * xbar)
        return S, y

    S, y = jax.lax.scan(chunk_step, S0, (xc, dtc, Bc, Cc), unroll=nc if unroll else 1)
    return y.reshape(L, dh), S


def ssd_chunked_batched(x, dt, A, B, C, *, chunk: int, unroll: bool = False):
    """Vectorized over (batch, heads): x (Bt, L, H, dh), dt (Bt, L, H),
    A (H,), B/C (Bt, L, N) shared across heads (single group)."""

    def per_head(xh, dth, Ah, Bh, Ch):
        # xh (L, dh), dth (L,), Ah (), Bh/Ch (L, N)
        return ssd_chunked(xh, dth, Ah, Bh, Ch, chunk=chunk, unroll=unroll)

    per_batch = jax.vmap(  # over heads: x (L,H,dh) axis 1, dt (L,H) axis 1
        per_head, in_axes=(1, 1, 0, None, None), out_axes=(1, 0)
    )
    f = jax.vmap(per_batch, in_axes=(0, 0, None, 0, 0), out_axes=(0, 0))
    y, S = f(x, dt, A, B, C)  # y (B, L, H, dh) -- heads back on axis 2
    return y, S
