"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid = (B, H, num_chunks); the chunk axis runs sequentially per core so the
inter-chunk state (N, dh) lives in VMEM scratch, exactly like the flash
accumulator.  Each grid step computes the intra-chunk quadratic part on the
MXU ((Q,N)@(N,Q), (Q,Q)@(Q,dh)) and the rank-1-sum state update
((N,Q)@(Q,dh)) -- all MXU-shaped matmuls, which is the whole point of SSD's
chunked formulation on a systolic array.

Block shapes: chunk Q x state N and Q x dh tiles; Q=128 aligns the MXU; the
f32 state scratch is (N, dh).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, Q, 1, dh)
    dt_ref,  # (1, Q, 1)
    a_ref,  # (1, 1)  A for this head (SMEM-ish tiny block)
    b_ref,  # (1, Q, N)
    c_ref,  # (1, Q, N)
    y_ref,  # (1, Q, 1, dh)
    state_out_ref,  # (1, 1, N, dh) final state per (batch, head)
    s_scr,  # (N, dh) f32 inter-chunk state
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    xq = x_ref[0, :, 0].astype(jnp.float32)  # (Q, dh)
    dtq = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0, 0].astype(jnp.float32)  # ()
    Bq = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cq = c_ref[0].astype(jnp.float32)  # (Q, N)

    la = dtq * A
    cs = jnp.cumsum(la)
    diff = cs[:, None] - cs[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    Lmat = jnp.exp(jnp.where(tri, diff, -1e9))  # mask pre-exp (NaN-safe VJP)
    scores = (
        jax.lax.dot_general(
            Cq, Bq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * Lmat
    )  # (Q, Q)
    xbar = xq * dtq[:, None]
    y = jax.lax.dot_general(
        scores, xbar, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
        Cq, s_scr[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    decay_out = jnp.exp(cs[-1] - cs)
    s_scr[...] = jnp.exp(cs[-1]) * s_scr[...] + jax.lax.dot_general(
        Bq,
        decay_out[:, None] * xbar,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = s_scr[...].astype(state_out_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,  # (B, L, H, dh)
    dt: jax.Array,  # (B, L, H)
    A: jax.Array,  # (H,)
    B_in: jax.Array,  # (B, L, N)  single B/C group shared across heads
    C_in: jax.Array,  # (B, L, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    Bt, L, H, dh = x.shape
    N = B_in.shape[2]
    if L % chunk:
        raise ValueError(f"L={L} must divide chunk={chunk}")
    nc = L // chunk
    A2 = A.reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, dh), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, L, H, dh), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, N, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, dh), jnp.float32)],
        interpret=interpret,
    )(x, dt, A2, B_in, C_in)
    return y, state
