"""jit'd wrapper for the SSD scan (kernel or chunked-jnp reference)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_chunked_batched


@partial(jax.jit, static_argnames=("chunk", "interpret", "use_pallas"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=True, use_pallas=True):
    """x (B,L,H,dh), dt (B,L,H), A (H,), B/C (B,L,N) -> y (B,L,H,dh),
    final state (B,H,N,dh)."""
    if not use_pallas:
        return ssd_chunked_batched(x, dt, A, B, C, chunk=chunk)
    return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)
