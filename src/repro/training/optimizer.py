"""Optimizers: AdamW (f32 moments) and Adafactor (factored second moment,
for trillion-param fits), plus warmup-cosine schedule and global-norm clip.

Self-contained (no optax dependency): state is a pytree mirroring params,
so it shards with the same PartitionSpecs and checkpoints with the same
machinery.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 128


def schedule(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# -------------------------------- AdamW --------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step_v = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_v = step_v + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_v).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}


# ------------------------------ Adafactor ------------------------------------


def _factored(p, min_dim):
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def adafactor_init(params, cfg: OptimizerConfig | None = None):
    cfg = cfg or OptimizerConfig(name="adafactor")

    def init_leaf(p):
        if _factored(p, cfg.factored_min_dim):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(init_leaf, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)
    eps = 1e-30

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps)
            )
            pre = g / jnp.sqrt(denom + eps)
            nv = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            pre = g / jnp.sqrt(vv + eps)
            nv = {"v": vv}
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(pre * pre) + eps)
        pre = pre / jnp.maximum(1.0, rms)
        step_v = pre
        if p.ndim >= 2:
            step_v = step_v + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_v).astype(p.dtype), nv

    g_flat, treedef = jax.tree.flatten(grads)
    v_flat = treedef.flatten_up_to(state["v"])
    p_flat = jax.tree.leaves(params)
    res = [upd(g, v, p) for g, v, p in zip(g_flat, v_flat, p_flat)]
    new_params = jax.tree.unflatten(treedef, [r[0] for r in res])
    new_v = jax.tree.unflatten(treedef, [r[1] for r in res])
    return new_params, {"v": new_v, "step": step}


# ------------------------------ front door -----------------------------------


def opt_init(cfg: OptimizerConfig, params):
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    raise ValueError(cfg.name)


def opt_update(cfg: OptimizerConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if cfg.name == "adamw":
        new_p, new_s = adamw_update(cfg, grads, state, params)
    elif cfg.name == "adafactor":
        new_p, new_s = adafactor_update(cfg, grads, state, params)
    else:
        raise ValueError(cfg.name)
    return new_p, new_s, gnorm
