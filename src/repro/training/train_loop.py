"""Training loop: microbatched grad accumulation, compression hook, metrics.

``make_train_step`` builds the jittable step; ``TrainLoop`` drives it with
checkpointing, straggler deadlines, and (simulated) fault injection hooks --
the pieces a 1000-node deployment needs, exercised at CPU scale in tests.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import compression as comp_mod
from repro.training import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptimizerConfig = dataclasses.field(
        default_factory=opt_mod.OptimizerConfig
    )
    compression: comp_mod.CompressionConfig = dataclasses.field(
        default_factory=comp_mod.CompressionConfig
    )
    microbatches: int = 1  # grad accumulation steps per train step


def make_train_step(model, tcfg: TrainConfig):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    state = {params, opt, ef?}; batch leaves have leading global-batch dim;
    microbatching splits the batch with a lax.scan accumulation (keeps peak
    activation memory at 1/microbatches).
    """
    use_ef = tcfg.compression.scheme != "none"

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params = state["params"]
        n_micro = tcfg.microbatches
        if n_micro > 1:
            def micro(carry, mb):
                acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g
                )
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )
            grads, losses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        metrics = {"loss": loss}
        if use_ef:
            grads, new_ef, wire = comp_mod.compress(
                tcfg.compression, grads, state["ef"]
            )
            metrics["wire_bytes"] = jnp.asarray(wire)
        new_params, new_opt, gnorm = opt_mod.opt_update(
            tcfg.opt, grads, state["opt"], params
        )
        metrics["grad_norm"] = gnorm
        new_state = {"params": new_params, "opt": new_opt}
        if use_ef:
            new_state["ef"] = new_ef
        return new_state, metrics

    return train_step


def init_state(model, tcfg: TrainConfig, rng):
    params = model.init(rng)
    state = {"params": params, "opt": opt_mod.opt_init(tcfg.opt, params)}
    if tcfg.compression.scheme != "none":
        state["ef"] = comp_mod.ef_init(params)
    return state


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation (simulated at CPU scale).

    At 1000+ nodes the dominant failure modes are slow hosts and dead hosts.
    The loop tracks per-step wall time; a step exceeding
    ``deadline_factor x`` the rolling median triggers the mitigation hook
    (in production: re-shard the straggler's data slice / fall back to the
    backup host; here: recorded + surfaced to the caller, tested by
    injecting artificial delay)."""

    deadline_factor: float = 3.0
    window: int = 20
    history: list = dataclasses.field(default_factory=list)
    flagged_steps: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.history.append(dt)
        hist = self.history[-self.window :]
        med = sorted(hist)[len(hist) // 2]
        slow = len(hist) >= 5 and dt > self.deadline_factor * med
        if slow:
            self.flagged_steps.append(step)
        return slow


class TrainLoop:
    """Drives train_step with checkpoint/restart + straggler accounting."""

    def __init__(self, model, tcfg: TrainConfig, data_iter, *, ckpt_manager=None,
                 ckpt_every: int = 0, straggler: StragglerPolicy | None = None):
        self.model = model
        self.tcfg = tcfg
        self.data_iter = data_iter
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.straggler = straggler or StragglerPolicy()
        self.step_fn = jax.jit(make_train_step(model, tcfg))

    def run(self, state, start_step: int, num_steps: int, *, fault_hook=None):
        metrics_log = []
        for step in range(start_step, start_step + num_steps):
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            if fault_hook is not None:
                fault_hook(step)  # may raise to simulate a node loss
            state, metrics = self.step_fn(state, batch)
            metrics = jax.block_until_ready(metrics)  # honest step timing
            dt = time.perf_counter() - t0
            self.straggler.observe(step, dt)
            metrics_log.append(
                {k: float(v) for k, v in metrics.items()} | {"step": step, "dt": dt}
            )
            if self.ckpt is not None and self.ckpt_every and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(state, step + 1)
        return state, metrics_log
