"""Gradient compression for the data-parallel all-reduce (distributed-opt).

Two schemes with error feedback (EF — the residual of what compression threw
away is added back into the next step, preserving convergence):

  * ``topk``  — keep the k largest-|g| entries per leaf (sparsify before the
    DP reduce; on the wire this is ~k/(n) of the bytes).
  * ``int8``  — per-leaf symmetric linear quantization to int8.

The compressed representation round-trips through ``compress`` /
``decompress`` so the train loop can reduce in compressed space (sum of int8
dequantized, or sparse accumulation).  Convergence is covered by
``tests/test_training.py::test_compressed_training_converges``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"  # none | topk | int8
    topk_frac: float = 0.05


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_leaf(g, frac):
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    kept = jnp.zeros_like(flat).at[idx].set(vals)
    return kept.reshape(g.shape), (idx, vals)


def _int8_leaf(g):
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, (q, scale)


def compress(cfg: CompressionConfig, grads, ef):
    """Returns (decompressed_grads, new_ef, wire_bytes_est).

    The returned grads are the values the DP all-reduce actually sees
    (compression error moved into the EF residual).
    """
    if cfg.scheme == "none":
        bytes_est = sum(g.size * 4 for g in jax.tree.leaves(grads))
        return grads, ef, bytes_est

    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        if cfg.scheme == "topk":
            kept, (idx, vals) = _topk_leaf(gf, cfg.topk_frac)
            wire = idx.size * 8  # int32 idx + f32 val
        elif cfg.scheme == "int8":
            kept, (q, scale) = _int8_leaf(gf)
            wire = q.size * 1 + 4
        else:
            raise ValueError(cfg.scheme)
        return kept.astype(g.dtype), gf - kept, wire

    out = jax.tree.map(leaf, grads, ef)
    is_tup = lambda x: isinstance(x, tuple)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=is_tup)
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=is_tup)
    wire = sum(t[2] for t in jax.tree.leaves(out, is_leaf=is_tup))
    return new_g, new_ef, wire
