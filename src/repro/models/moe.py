"""Mixture-of-Experts layer with PULSE-style switch routing (DESIGN.md S3).

Token -> expert dispatch reuses the paper's in-network routing shape: the
router ("switch") computes each token-copy's owner from a range partition of
expert ids; records route to the owning shard; results combine back with the
identical record format.  On the TPU mesh:

  * experts are range-partitioned over the mesh ``model`` axis (EP), exactly
    like arena addresses over memory nodes;
  * activations are replicated over ``model`` (TP convention), so dispatch
    needs NO collective: each expert shard masks + compacts the token copies
    it owns (the "switch" is a local owner_of computation, S5), computes its
    experts, and the weighted combine is the block's existing TP psum;
  * capacity overflow drops copies (standard MoE), mirroring the paper's
    bounded per-link capacity with retry -- here the residual connection
    stands in for the retry.

Implemented with ``jax.shard_map`` over the full mesh; with a (1,1,1) mesh it
degrades to the single-device reference semantics (used by smoke tests).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_apply, dense_init


def moe_init(key, cfg, *, stack=None):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)

    def ew(k, a, b):
        shape = (E, a, b) if stack is None else (stack, E, a, b)
        std = 1.0 / math.sqrt(a)
        return (jax.random.normal(k, shape) * std).astype(cfg.param_dtype)

    p = {
        "router": dense_init(ks[0], D, E, cfg.param_dtype, stack=stack),
        "wi": ew(ks[1], D, F),
        "wg": ew(ks[2], D, F),
        "wo": ew(ks[3], F, D),
    }
    if cfg.n_shared_experts:
        from repro.models.common import swiglu_init

        p["shared"] = swiglu_init(
            ks[4], D, F * cfg.n_shared_experts, cfg.param_dtype, stack=stack
        )
    return p


def _expert_ffn(wi, wg, wo, xb, compute_dtype):
    """Grouped SwiGLU: xb (E_loc, C, D) @ per-expert weights."""
    xb = xb.astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wg.astype(compute_dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xb, wi.astype(compute_dtype))
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(compute_dtype))


def _moe_local(p, cfg, x_flat, my_rank, ep, compute_dtype):
    """Per-shard MoE body: route, compact, grouped FFN, weighted combine.

    x_flat: (T, D) local tokens (replicated over the EP axis).
    Returns this shard's partial output (T, D) -- psum over EP outside.
    """
    T, D = x_flat.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    E_loc = E // ep
    C = max(8, int(math.ceil(T * K / E * cfg.moe_capacity_factor)))

    logits = dense_apply(p["router"], x_flat, jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (T, K)
    if cfg.moe_renormalize:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- the switch: owner = range partition of expert ids (S5) ---
    copies_e = top_e.reshape(-1)  # (T*K,) expert id per copy
    copies_t = jnp.repeat(jnp.arange(T), K)  # token of each copy
    copies_w = top_p.reshape(-1)
    owner = copies_e // E_loc
    local_e = copies_e % E_loc
    mine = owner == my_rank

    # rank of each copy within its expert (deterministic, replicated compute)
    order = jnp.argsort(copies_e, stable=True)
    inv = jnp.argsort(order, stable=True)
    sorted_e = copies_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    rank_in_e = jnp.arange(T * K) - start[sorted_e]
    rank = rank_in_e[inv]  # back to copy order

    fits = mine & (rank < C)
    slot = jnp.where(fits, local_e * C + rank, E_loc * C)  # trash slot at end
    # gather tokens into the expert buffer (E_loc, C, D)
    buf_tok = jnp.full((E_loc * C + 1,), T, jnp.int32)  # T -> zero row sentinel
    buf_tok = buf_tok.at[slot].set(
        jnp.where(fits, copies_t, T).astype(jnp.int32)
    )[: E_loc * C]
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, D), x_flat.dtype)], axis=0)
    xb = x_pad[buf_tok].reshape(E_loc, C, D)

    yb = _expert_ffn(p["wi"], p["wg"], p["wo"], xb, compute_dtype)
    yb = yb.reshape(E_loc * C, D)

    # combine: scatter-add weighted expert outputs back to tokens
    y_copy_slot = jnp.where(fits, slot, E_loc * C)
    yb_pad = jnp.concatenate([yb, jnp.zeros((1, D), yb.dtype)], axis=0)
    y_copies = yb_pad[y_copy_slot] * jnp.where(fits, copies_w, 0.0)[:, None].astype(
        yb.dtype
    )
    return jnp.zeros((T, D), yb.dtype).at[copies_t].add(y_copies)


def moe_apply(p, cfg, x, *, mesh=None, compute_dtype=None):
    """x: (B, L, D) -> (B, L, D).  EP over the mesh 'model' axis when a mesh
    is provided; single-shard reference semantics otherwise."""
    compute_dtype = compute_dtype or cfg.compute_dtype
    B, L, D = x.shape

    if mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1:
        def body(x_flat):
            y = _moe_local(p, cfg, x_flat, 0, 1, compute_dtype)
            if "shared" in p:
                from repro.models.common import swiglu_apply

                y = y + swiglu_apply(p["shared"], x_flat, compute_dtype)
            return y

        return body(x.reshape(B * L, D)).reshape(B, L, D)

    ep = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def gather(w, axis):
        """Explicit FSDP unshard over the dp axes (ZeRO-3 weight gather)."""
        return jax.lax.all_gather(w, dp, axis=axis, tiled=True) if dp else w

    def body(p_loc, x_loc):
        # FULL-manual region (all mesh axes): tokens stay dp-SHARDED, so the
        # routing sort/scatter is rank-local.  (A manual-'model'-only region
        # left dp auto: GSPMD could not shard the sort and ALL-GATHERED the
        # whole f32 token batch to every device -- measured 30 GB/dev/layer
        # on kimi-k2; see EXPERIMENTS.md hillclimb H2.)
        xf = x_loc.reshape(-1, D)
        my = jax.lax.axis_index("model")
        p_full = {
            "router": {"w": gather(p_loc["router"]["w"], 0)},
            "wi": gather(p_loc["wi"], 1),
            "wg": gather(p_loc["wg"], 1),
            "wo": gather(p_loc["wo"], 2),
        }
        y = _moe_local(p_full, cfg, xf, my, ep, compute_dtype)
        if "shared" in p_loc:
            # shared expert is TP-sharded on F: each rank's F-slice partial
            # sums into the same psum as the routed experts.
            from repro.models.common import swiglu_apply

            shared = {
                "wi": {"w": gather(p_loc["shared"]["wi"]["w"], 0)},
                "wg": {"w": gather(p_loc["shared"]["wg"]["w"], 0)},
                "wo": {"w": gather(p_loc["shared"]["wo"]["w"], 1)},
            }
            y = y + swiglu_apply(shared, xf, compute_dtype)
        # psum in f32: bf16 all-reduce trips XLA:CPU's AllReducePromotion
        # (fatal "Invalid binary instruction opcode copy"); f32 is also the
        # right accumulation dtype for the expert combine.
        y = jax.lax.psum(y.astype(jnp.float32), "model")
        return y.reshape(x_loc.shape)

    fs = dp if dp else None
    pspec = {
        "router": {"w": P(fs, None)},
        "wi": P("model", fs, None),
        "wg": P("model", fs, None),
        "wo": P("model", None, fs),
    }
    if "shared" in p:
        pspec["shared"] = {
            "wi": {"w": P(fs, "model")},
            "wg": {"w": P(fs, "model")},
            "wo": {"w": P("model", fs)},
        }
    xspec = P(fs, None, None)
    # f32 x at the boundary: x is replicated over 'model' in the manual
    # region, so its cotangent is psum'ed -- f32 sidesteps the XLA:CPU
    # bf16-all-reduce abort.  Tokens are dp-sharded, so this costs a local
    # convert, not a gather.
    p_in = dict(p, router={"w": p["router"]["w"].astype(jnp.float32)})
    out = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, xspec),
        out_specs=xspec,
        check_vma=False,
    )(p_in, x.astype(jnp.float32))
    return out.astype(x.dtype)
