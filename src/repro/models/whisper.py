"""Whisper-large-v3 backbone: encoder-decoder transformer.

Backbone only (assignment): the mel-spectrogram conv frontend is a stub --
``input_specs()`` feeds precomputed frame embeddings (B, 1500, d_model).
LayerNorm + GELU MLP + sinusoidal positions + QKV bias; decoder = causal
self-attention + cross-attention to the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention
from repro.models.common import (
    chunked_softmax_xent,
    cross_entropy_loss,
    stack_scan,
    dense_apply,
    dense_init,
    gelu_mlp_init,
    gelu_mlp_apply,
    layernorm_apply,
    layernorm_init,
    sinusoidal_positions,
    uniform_scale_init,
)


def whisper_init(key, cfg):
    keys = jax.random.split(key, 10)
    D, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.n_enc_layers, cfg.n_dec_layers
    return {
        "frame_proj": dense_init(keys[0], D, D, cfg.param_dtype, bias=True),
        "embed": uniform_scale_init(keys[1], (V, D), 1.0, cfg.param_dtype),
        "enc": {
            "attn_norm": layernorm_init(D, cfg.param_dtype, stack=Le),
            "attn": attention.attention_init(keys[2], cfg, stack=Le),
            "mlp_norm": layernorm_init(D, cfg.param_dtype, stack=Le),
            "mlp": gelu_mlp_init(keys[3], D, cfg.d_ff, cfg.param_dtype, stack=Le),
        },
        "enc_norm": layernorm_init(D, cfg.param_dtype),
        "dec": {
            "self_norm": layernorm_init(D, cfg.param_dtype, stack=Ld),
            "self_attn": attention.attention_init(keys[4], cfg, stack=Ld),
            "cross_norm": layernorm_init(D, cfg.param_dtype, stack=Ld),
            "cross_attn": attention.attention_init(keys[5], cfg, stack=Ld),
            "mlp_norm": layernorm_init(D, cfg.param_dtype, stack=Ld),
            "mlp": gelu_mlp_init(keys[6], D, cfg.d_ff, cfg.param_dtype, stack=Ld),
        },
        "dec_norm": layernorm_init(D, cfg.param_dtype),
    }


def encode(params, cfg, frames, *, mesh=None):
    """frames (B, T, D) precomputed (stub frontend) -> encoder states."""
    B, T, D = frames.shape
    x = dense_apply(params["frame_proj"], frames.astype(cfg.compute_dtype), cfg.compute_dtype)
    x = x + sinusoidal_positions(T, D)[None].astype(cfg.compute_dtype)

    def body(h, lp):
        hn = layernorm_apply(lp["attn_norm"], h)
        a, _ = attention.attention_apply(
            lp["attn"], cfg, hn, causal=False, rope=False,
            backend=cfg.attn_backend, mesh=mesh,
        )
        h = h + a
        hn = layernorm_apply(lp["mlp_norm"], h)
        h = h + gelu_mlp_apply(lp["mlp"], hn, cfg.compute_dtype)
        return h, None

    x, _ = stack_scan(body, x, params["enc"], cfg.scan_layers)
    return layernorm_apply(params["enc_norm"], x)


def decode_train(params, cfg, tokens, enc_out, *, collect_kv=False, mesh=None):
    """Teacher-forced decoder pass -> (h, aux)."""
    B, L = tokens.shape
    D = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = x + sinusoidal_positions(L, D)[None].astype(cfg.compute_dtype)

    def body(h, lp):
        hn = layernorm_apply(lp["self_norm"], h)
        a, kv = attention.attention_apply(
            lp["self_attn"], cfg, hn, causal=True, rope=False,
            backend=cfg.attn_backend, mesh=mesh,
        )
        h = h + a
        hn = layernorm_apply(lp["cross_norm"], h)
        a, xkv = attention.attention_apply(
            lp["cross_attn"], cfg, hn, kv_x=enc_out, causal=False, rope=False,
            backend=cfg.attn_backend, mesh=mesh,
        )
        h = h + a
        hn = layernorm_apply(lp["mlp_norm"], h)
        h = h + gelu_mlp_apply(lp["mlp"], hn, cfg.compute_dtype)
        return h, (kv, xkv) if collect_kv else None

    x, aux = stack_scan(body, x, params["dec"], cfg.scan_layers)
    return layernorm_apply(params["dec_norm"], x), aux


def whisper_loss(params, cfg, batch, *, mesh=None):
    """batch: {frames (B,T,D), tokens (B,L), labels (B,L)}."""
    enc_out = encode(params, cfg, batch["frames"], mesh=mesh)
    h, _ = decode_train(params, cfg, batch["tokens"], enc_out, mesh=mesh)
    # tied unembedding, fused chunked CE
    return chunked_softmax_xent(
        h, params["embed"].T, batch["labels"],
        chunk=cfg.ce_chunk, z_loss=1e-4, mask=batch.get("mask"),
    )


# ------------------------------ serving -------------------------------------


def whisper_cache_init(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    Ld = cfg.n_dec_layers
    Hk, hd = cfg.n_kv_heads, cfg.hd
    T = cfg.n_audio_frames
    return {
        "k": jnp.zeros((Ld, batch, max_len, Hk, hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, Hk, hd), dtype),
        "xk": jnp.zeros((Ld, batch, T, Hk, hd), dtype),
        "xv": jnp.zeros((Ld, batch, T, Hk, hd), dtype),
    }


def whisper_prefill(params, cfg, tokens, frames, max_len: int, *, mesh=None):
    enc_out = encode(params, cfg, frames, mesh=mesh)
    h, aux = decode_train(params, cfg, tokens, enc_out, collect_kv=True, mesh=mesh)
    (k, v), (xk, xv) = aux
    logits = dense_apply({"w": params["embed"].T}, h, cfg.compute_dtype)
    pad = max_len - tokens.shape[1]
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "xk": xk,
        "xv": xv,
    }
    return logits, cache


def whisper_decode_step(params, cfg, cache, tokens, pos, *, mesh=None):
    """One decode token vs self-KV ring cache + fixed cross KV."""
    B = tokens.shape[0]
    D = cfg.d_model
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cfg.compute_dtype)
    # per-sequence position embedding lookup
    posemb = sinusoidal_positions(cache["k"].shape[2], D)[pos][:, None]
    x = x + posemb.astype(cfg.compute_dtype)

    def body(h, lpc):
        lp, ck, cv, xk, xv = lpc
        hn = layernorm_apply(lp["self_norm"], h)
        a, ck, cv = attention.decode_attention_apply(
            lp["self_attn"], cfg, hn, ck, cv, pos, rope=False
        )
        h = h + a
        # cross attention: fixed KV, full (unmasked) softmax
        hn = layernorm_apply(lp["cross_norm"], h)
        q = dense_apply(lp["cross_attn"]["wq"], hn, cfg.compute_dtype).reshape(B, 1, H, hd)
        G = H // Hk
        qg = q.astype(jnp.float32).reshape(B, Hk, G, hd) * (hd ** -0.5)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, xk.astype(jnp.float32))
        p_att = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", p_att, xv.astype(jnp.float32))
        o = o.reshape(B, 1, H * hd).astype(cfg.compute_dtype)
        h = h + dense_apply(lp["cross_attn"]["wo"], o, cfg.compute_dtype)
        hn = layernorm_apply(lp["mlp_norm"], h)
        h = h + gelu_mlp_apply(lp["mlp"], hn, cfg.compute_dtype)
        return h, (ck, cv)

    x, (nk, nv) = stack_scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        cfg.scan_layers,
    )
    h = layernorm_apply(params["dec_norm"], x)
    logits = dense_apply({"w": params["embed"].T}, h, cfg.compute_dtype)[:, 0]
    return logits, {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
