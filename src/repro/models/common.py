"""Shared model building blocks (functional, dict-param style).

Conventions:
  * params are nested dicts of jnp arrays; layer stacks carry a leading L axis
    and are applied with ``jax.lax.scan`` (keeps HLO size O(1) in depth --
    essential for 512-device dry-run compiles).
  * compute happens in ``cfg.compute_dtype`` (bf16 on TPU), master params in
    ``cfg.param_dtype``; norms/softmax/rope always f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stack_scan(body, carry, xs, use_scan: bool = True):
    """``jax.lax.scan`` or a Python-unrolled equivalent (``use_scan=False``).

    The unrolled form exists for the dry-run cost probes: XLA's
    ``cost_analysis`` counts a while-loop body ONCE regardless of trip count
    (measured; see EXPERIMENTS.md), so per-layer marginal costs are measured
    on small unrolled stacks and scaled analytically.
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def uniform_scale_init(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / (fan_in ** 0.5)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def dense_init(key, in_dim, out_dim, dtype, *, bias=False, scale=1.0, stack=None):
    shape = (in_dim, out_dim) if stack is None else (stack, in_dim, out_dim)
    p = {"w": uniform_scale_init(key, shape, scale, dtype)}
    if bias:
        bshape = (out_dim,) if stack is None else (stack, out_dim)
        p["b"] = jnp.zeros(bshape, dtype)
    return p


def dense_apply(p, x, compute_dtype):
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rmsnorm_init(dim, dtype, *, parametric=True, stack=None):
    if not parametric:  # OLMo-style non-parametric norm: no learned scale
        return {}
    shape = (dim,) if stack is None else (stack, dim)
    return {"scale": jnp.ones(shape, dtype)}


def rmsnorm_apply(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if "scale" in p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(dim, dtype, stack=None):
    shape = (dim,) if stack is None else (stack, dim)
    return {"scale": jnp.ones(shape, dtype), "bias": jnp.zeros(shape, dtype)}


def layernorm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., L, H, D); positions: broadcastable to (..., L)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    return pe.at[:, 1::2].set(jnp.cos(pos * div))


def swiglu_init(key, d_model, d_ff, dtype, stack=None):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype, stack=stack),
        "wg": dense_init(k2, d_model, d_ff, dtype, stack=stack),
        "wo": dense_init(k3, d_ff, d_model, dtype, stack=stack),
    }


def swiglu_apply(p, x, compute_dtype):
    h = jax.nn.silu(dense_apply(p["wg"], x, compute_dtype)) * dense_apply(
        p["wi"], x, compute_dtype
    )
    return dense_apply(p["wo"], h, compute_dtype)


def gelu_mlp_init(key, d_model, d_ff, dtype, stack=None):
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype, bias=True, stack=stack),
        "wo": dense_init(k2, d_ff, d_model, dtype, bias=True, stack=stack),
    }


def gelu_mlp_apply(p, x, compute_dtype):
    return dense_apply(p["wo"], jax.nn.gelu(dense_apply(p["wi"], x, compute_dtype)), compute_dtype)


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0, mask=None):
    """logits (..., V) f32-cast inside; labels int32.  Returns mean nll."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def chunked_softmax_xent(
    h, unembed_w, labels, *, chunk: int = 512, z_loss: float = 0.0, mask=None,
    mesh=None,
):
    """Fused unembed-projection + cross entropy, chunked over the sequence.

    Never materializes the full (B, L, V) logits: each chunk computes
    (B, chunk, V), reduces to per-token nll, and is rematerialized in the
    backward pass (jax.checkpoint on the chunk body).  This is the memory
    fix that keeps the 151k-vocab train cells inside HBM (see EXPERIMENTS.md
    dry-run S Perf-0).
    """
    B, L, D = h.shape
    chunk = min(chunk, L)
    if L % chunk:
        pad = chunk - L % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else jnp.pad(
            jnp.ones((B, L), jnp.float32), ((0, 0), (0, pad))
        )
    elif mask is None:
        mask = jnp.ones((B, L), jnp.float32)
    nc = h.shape[1] // chunk
    hc = h.reshape(B, nc, chunk, D).swapaxes(0, 1)  # (nc, B, chunk, D)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        hq, lq, mq = inp
        logits = (
            hq.astype(unembed_w.dtype) @ unembed_w
        ).astype(jnp.float32)  # (B, chunk, V)
        from repro.distributed.sharding import shard_hint

        logits = shard_hint(logits, mesh, "dp", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lq[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if z_loss:
            nll = nll + z_loss * lse**2
        return (tot + (nll * mq).sum(), cnt + mq.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)
