"""Decoder-LM covering the dense / moe / ssm / hybrid / vlm families.

All layer stacks apply via ``jax.lax.scan`` over stacked params so the HLO is
O(1) in depth -- 61-layer Kimi-K2 compiles at 512 devices in one layer's
worth of IR.  Remat policy wraps the scanned body.

Hybrid (Zamba2): ONE weight-shared attention+MLP block applied after every
``hybrid_attn_every`` mamba layers.  The stack is scanned in *groups* of
``every`` mamba layers + the shared block, with a tail scan for the
remainder (81 = 13x6 + 3), so prefill can collect per-application KV caches
without materializing per-mamba-layer dummies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import attention, moe as moe_mod, ssm as ssm_mod
from repro.models.common import (
    chunked_softmax_xent,
    cross_entropy_loss,
    stack_scan,
    dense_apply,
    dense_init,
    rmsnorm_apply,
    rmsnorm_init,
    swiglu_apply,
    swiglu_init,
    uniform_scale_init,
)


# ------------------------------- init ---------------------------------------


def lm_init(key, cfg):
    keys = jax.random.split(key, 12)
    L = cfg.n_layers
    D, V = cfg.d_model, cfg.vocab
    parametric = not cfg.nonparametric_norm
    p = {
        "embed": uniform_scale_init(keys[0], (V, D), 1.0, cfg.param_dtype),
        "final_norm": rmsnorm_init(D, cfg.param_dtype, parametric=parametric),
        "unembed": dense_init(keys[1], D, V, cfg.param_dtype),
    }
    if cfg.family in ("dense", "vlm", "moe"):
        layer = {
            "attn_norm": rmsnorm_init(D, cfg.param_dtype, parametric=parametric, stack=L),
            "attn": attention.attention_init(keys[2], cfg, stack=L),
            "mlp_norm": rmsnorm_init(D, cfg.param_dtype, parametric=parametric, stack=L),
        }
        if cfg.family == "moe":
            layer["moe"] = moe_mod.moe_init(keys[3], cfg, stack=L)
        else:
            layer["mlp"] = swiglu_init(keys[3], D, cfg.d_ff, cfg.param_dtype, stack=L)
        p["layers"] = layer
    elif cfg.family in ("ssm", "hybrid"):
        p["layers"] = {
            "norm": rmsnorm_init(D, cfg.param_dtype, stack=L),
            "ssm": ssm_mod.ssm_init(keys[2], cfg, stack=L),
        }
        if cfg.family == "hybrid":
            p["shared_attn"] = {
                "attn_norm": rmsnorm_init(D, cfg.param_dtype),
                "attn": attention.attention_init(keys[4], cfg),
                "mlp_norm": rmsnorm_init(D, cfg.param_dtype),
                "mlp": swiglu_init(keys[5], D, cfg.d_ff, cfg.param_dtype),
            }
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        # stub frontend adapter: precomputed patch embeddings -> d_model
        p["patch_proj"] = dense_init(keys[6], D, D, cfg.param_dtype)
    return p


def hybrid_split(cfg):
    """(n_groups, tail): 81 layers, every=6 -> 13 groups + 3 tail layers."""
    every = cfg.hybrid_attn_every
    return cfg.n_layers // every, cfg.n_layers % every


def _tree_reshape_groups(tree, n_groups, every):
    """(n_groups*every, ...) leaves -> (n_groups, every, ...)."""
    return jax.tree.map(
        lambda a: a[: n_groups * every].reshape((n_groups, every) + a.shape[1:]), tree
    )


def _tree_tail(tree, n_groups, every):
    return jax.tree.map(lambda a: a[n_groups * every :], tree)


# ----------------------------- blocks ---------------------------------------


def _dense_block(lp, cfg, x, positions, mesh, is_moe, collect_kv=False):
    x = shard_hint(x, mesh, "dp", None, None)
    h = rmsnorm_apply(lp["attn_norm"], x)
    a, kv = attention.attention_apply(
        lp["attn"], cfg, h, positions=positions, causal=True,
        backend=cfg.attn_backend, mesh=mesh,
    )
    x = x + a
    h = rmsnorm_apply(lp["mlp_norm"], x)
    if is_moe:
        x = x + moe_mod.moe_apply(lp["moe"], cfg, h, mesh=mesh)
    else:
        x = x + swiglu_apply(lp["mlp"], h, cfg.compute_dtype)
    return (x, kv) if collect_kv else (x, None)


def _shared_attn_block(sp, cfg, x, positions, collect_kv=False, mesh=None):
    x = shard_hint(x, mesh, "dp", None, None)
    h = rmsnorm_apply(sp["attn_norm"], x)
    a, kv = attention.attention_apply(
        sp["attn"], cfg, h, positions=positions, causal=True,
        backend=cfg.attn_backend, mesh=mesh,
    )
    x = x + a
    h = rmsnorm_apply(sp["mlp_norm"], x)
    x = x + swiglu_apply(sp["mlp"], h, cfg.compute_dtype)
    return (x, kv) if collect_kv else (x, None)


def _ssm_block(lp, cfg, x, collect_state=False, mesh=None):
    x = shard_hint(x, mesh, "dp", None, None)
    h = rmsnorm_apply(lp["norm"], x)
    if collect_state:
        out, st = ssm_mod.ssm_apply(lp["ssm"], cfg, h, backend=cfg.ssm_backend, return_state=True)
        return x + out, st
    return x + ssm_mod.ssm_apply(lp["ssm"], cfg, h, backend=cfg.ssm_backend), None


def _remat(f, policy: str):
    if policy == "none":
        return f
    if policy == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(f)


# ----------------------------- forward --------------------------------------


def backbone_apply(params, cfg, x, *, positions=None, mesh=None, collect=False):
    """Layer stack on embeddings x (B, T, D) -> (h, cache_parts | None).

    ``collect=True`` additionally returns the serving cache ingredients
    (per-layer KV / SSM states), used by prefill.
    """
    B, T, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(h, lp):
            return _dense_block(lp, cfg, h, positions, mesh, is_moe, collect)

        body = _remat(body, cfg.remat)
        x, kvs = stack_scan(body, x, params["layers"], cfg.scan_layers)
        aux = {"k": kvs[0], "v": kvs[1]} if collect else None

    elif cfg.family == "ssm":

        def body(h, lp):
            return _ssm_block(lp, cfg, h, collect, mesh=mesh)

        body = _remat(body, cfg.remat)
        x, states = stack_scan(body, x, params["layers"], cfg.scan_layers)
        aux = states if collect else None

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, tail = hybrid_split(cfg)
        sp = params["shared_attn"]
        grouped = _tree_reshape_groups(params["layers"], n_groups, every)
        tail_p = _tree_tail(params["layers"], n_groups, every)

        def mamba_body(h, lp):
            return _ssm_block(lp, cfg, h, collect, mesh=mesh)

        mamba_body = _remat(mamba_body, cfg.remat)

        def group_body(h, glp):
            h, states = stack_scan(mamba_body, h, glp, cfg.scan_layers)
            h, kv = _shared_attn_block(sp, cfg, h, positions, collect, mesh=mesh)
            return h, (states, kv)

        x, gouts = stack_scan(group_body, x, grouped, cfg.scan_layers)
        g_states, g_kv = gouts if gouts is not None else (None, None)
        if tail:
            x, t_states = stack_scan(mamba_body, x, tail_p, cfg.scan_layers)
        aux = None
        if collect:
            # flatten (n_groups, every, ...) states + tail back to (L, ...)
            flat = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), g_states
            )
            if tail:
                flat = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=0), flat, t_states
                )
            aux = {"S": flat["S"], "conv": flat["conv"], "k": g_kv[0], "v": g_kv[1]}
    else:
        raise ValueError(cfg.family)
    return rmsnorm_apply(params["final_norm"], x), aux


def embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)


def lm_logits(params, cfg, h):
    return dense_apply(params["unembed"], h, cfg.compute_dtype)


def lm_loss(params, cfg, batch, *, mesh=None):
    """batch: {tokens (B,L), labels (B,L), [patches|frames ...]}."""
    x = embed_tokens(params, cfg, batch["tokens"])
    n_prefix = 0
    if cfg.family == "vlm" and "patches" in batch:
        pe = dense_apply(
            params["patch_proj"], batch["patches"].astype(cfg.compute_dtype),
            cfg.compute_dtype,
        )
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    x = shard_hint(x, mesh, "dp", None, None)
    h, _ = backbone_apply(params, cfg, x, mesh=mesh)
    h = h[:, n_prefix:]
    # fused chunked unembed+CE: never materializes (B, L, V) logits
    return chunked_softmax_xent(
        h, params["unembed"]["w"], batch["labels"],
        chunk=cfg.ce_chunk, z_loss=1e-4, mask=batch.get("mask"), mesh=mesh,
    )


# ------------------------------ serving -------------------------------------


def decode_cache_init(cfg, batch: int, max_len: int, dtype=None):
    """Ring-buffer KV cache (attention) / recurrent state (ssm/hybrid)."""
    dtype = dtype or cfg.compute_dtype
    L = cfg.n_layers
    if cfg.family in ("dense", "vlm", "moe"):
        Hk, hd = cfg.n_kv_heads, cfg.hd
        return {
            "k": jnp.zeros((L, batch, max_len, Hk, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, Hk, hd), dtype),
        }
    st = ssm_mod.ssm_decode_init(cfg, batch, dtype)
    cache = {
        "S": jnp.zeros((L,) + st["S"].shape, st["S"].dtype),
        "conv": jnp.zeros((L,) + st["conv"].shape, st["conv"].dtype),
    }
    if cfg.family == "hybrid":
        n_groups, _ = hybrid_split(cfg)
        Hk, hd = cfg.n_kv_heads, cfg.hd
        cache["k"] = jnp.zeros((n_groups, batch, max_len, Hk, hd), dtype)
        cache["v"] = jnp.zeros((n_groups, batch, max_len, Hk, hd), dtype)
    return cache


def decode_step(params, cfg, cache, tokens, pos, *, mesh=None):
    """One decode step.  tokens (B,), pos (B,).  -> (logits (B,V), cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens[:, None])  # (B, 1, D)
    x = shard_hint(x, mesh, "dp", None, None)

    if cfg.family in ("dense", "vlm", "moe"):
        is_moe = cfg.family == "moe"

        def body(h, lpc):
            lp, ck, cv = lpc
            hn = rmsnorm_apply(lp["attn_norm"], h)
            a, ck, cv = attention.decode_attention_apply(lp["attn"], cfg, hn, ck, cv, pos)
            h = h + a
            hn = rmsnorm_apply(lp["mlp_norm"], h)
            if is_moe:
                h = h + moe_mod.moe_apply(lp["moe"], cfg, hn, mesh=mesh)
            else:
                h = h + swiglu_apply(lp["mlp"], hn, cfg.compute_dtype)
            return h, (ck, cv)

        x, (nk, nv) = stack_scan(body, x, (params["layers"], cache["k"], cache["v"]), cfg.scan_layers)
        cache = {"k": nk, "v": nv}

    elif cfg.family == "ssm":

        def body(h, lps):
            lp, S, conv = lps
            hn = rmsnorm_apply(lp["norm"], h)
            out, st = ssm_mod.ssm_decode_apply(lp["ssm"], cfg, hn, {"S": S, "conv": conv})
            return h + out, (st["S"], st["conv"])

        x, (nS, nconv) = stack_scan(body, x, (params["layers"], cache["S"], cache["conv"]), cfg.scan_layers)
        cache = {"S": nS, "conv": nconv}

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, tail = hybrid_split(cfg)
        sp = params["shared_attn"]
        grouped = _tree_reshape_groups(params["layers"], n_groups, every)
        tail_p = _tree_tail(params["layers"], n_groups, every)
        gS = cache["S"][: n_groups * every].reshape((n_groups, every) + cache["S"].shape[1:])
        gC = cache["conv"][: n_groups * every].reshape((n_groups, every) + cache["conv"].shape[1:])

        def mamba_body(h, lps):
            lp, S, conv = lps
            hn = rmsnorm_apply(lp["norm"], h)
            out, st = ssm_mod.ssm_decode_apply(lp["ssm"], cfg, hn, {"S": S, "conv": conv})
            return h + out, (st["S"], st["conv"])

        def group_body(h, gin):
            glp, S, conv, ck, cv = gin
            h, (nS, nconv) = stack_scan(mamba_body, h, (glp, S, conv), cfg.scan_layers)
            hn = rmsnorm_apply(sp["attn_norm"], h)
            a, ck, cv = attention.decode_attention_apply(sp["attn"], cfg, hn, ck, cv, pos)
            h = h + a
            hn = rmsnorm_apply(sp["mlp_norm"], h)
            h = h + swiglu_apply(sp["mlp"], hn, cfg.compute_dtype)
            return h, (nS, nconv, ck, cv)

        x, (nS, nconv, nk, nv) = stack_scan(
            group_body, x, (grouped, gS, gC, cache["k"], cache["v"]), cfg.scan_layers
        )
        nS = nS.reshape((-1,) + nS.shape[2:])
        nconv = nconv.reshape((-1,) + nconv.shape[2:])
        if tail:
            tS = cache["S"][n_groups * every :]
            tC = cache["conv"][n_groups * every :]
            x, (tS, tC) = stack_scan(mamba_body, x, (tail_p, tS, tC), cfg.scan_layers)
            nS = jnp.concatenate([nS, tS], axis=0)
            nconv = jnp.concatenate([nconv, tC], axis=0)
        cache = {"S": nS, "conv": nconv, "k": nk, "v": nv}
    else:
        raise ValueError(cfg.family)

    h = rmsnorm_apply(params["final_norm"], x)
    logits = lm_logits(params, cfg, h)[:, 0]
    return logits, cache


def prefill(params, cfg, tokens, max_len: int, *, mesh=None, patches=None):
    """Full-sequence prefill: returns (logits, cache)."""
    B, L = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm" and patches is not None:
        pe = dense_apply(
            params["patch_proj"], patches.astype(cfg.compute_dtype), cfg.compute_dtype
        )
        x = jnp.concatenate([pe, x], axis=1)
    T = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    h, aux = backbone_apply(params, cfg, x, positions=positions, mesh=mesh, collect=True)
    logits = lm_logits(params, cfg, h)

    max_len = max(max_len, T)  # vlm: patches extend the cached prefix
    cache = decode_cache_init(cfg, B, max_len)
    if "k" in cache and aux is not None and "k" in aux:
        pad = max_len - T
        cache["k"] = jnp.pad(aux["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(aux["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if "S" in cache and aux is not None and "S" in aux:
        cache["S"] = aux["S"]
        cache["conv"] = aux["conv"].astype(cache["conv"].dtype)
    return logits, cache
