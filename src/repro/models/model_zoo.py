"""Unified model factory: ``build_model(cfg)`` -> Model bundle.

One interface for every assigned architecture:
  init(rng)                      -> params
  loss(params, batch)            -> scalar (train objective)
  prefill(params, batch, max_len)-> (logits, cache)
  decode_step(params, cache, tokens, pos) -> (logits, cache)
  cache_init(batch, max_len)     -> cache
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer, whisper


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_init: Callable


def build_model(cfg, mesh=None) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda rng: whisper.whisper_init(rng, cfg),
            loss=lambda p, batch: whisper.whisper_loss(p, cfg, batch, mesh=mesh),
            prefill=lambda p, batch, max_len: whisper.whisper_prefill(
                p, cfg, batch["tokens"], batch["frames"], max_len, mesh=mesh
            ),
            decode_step=lambda p, cache, tokens, pos: whisper.whisper_decode_step(
                p, cfg, cache, tokens, pos, mesh=mesh
            ),
            cache_init=lambda batch, max_len: whisper.whisper_cache_init(
                cfg, batch, max_len
            ),
        )

    return Model(
        cfg=cfg,
        init=lambda rng: transformer.lm_init(rng, cfg),
        loss=lambda p, batch: transformer.lm_loss(p, cfg, batch, mesh=mesh),
        prefill=lambda p, batch, max_len: transformer.prefill(
            p, cfg, batch["tokens"], max_len, mesh=mesh,
            patches=batch.get("patches"),
        ),
        decode_step=lambda p, cache, tokens, pos: transformer.decode_step(
            p, cfg, cache, tokens, pos, mesh=mesh
        ),
        cache_init=lambda batch, max_len: transformer.decode_cache_init(
            cfg, batch, max_len
        ),
    )


def make_batch(cfg, shape_kind: str, seq_len: int, batch: int, rng=None):
    """Concrete (CPU smoke) batch for a shape kind."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 4)
    toks = jax.random.randint(ks[0], (batch, seq_len), 0, cfg.vocab)
    batch_d = {
        "tokens": toks,
        "labels": jnp.roll(toks, -1, axis=1),
    }
    if cfg.family == "vlm":
        batch_d["patches"] = jax.random.normal(
            ks[1], (batch, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.family == "encdec":
        batch_d["frames"] = jax.random.normal(
            ks[2], (batch, cfg.n_audio_frames, cfg.d_model), jnp.float32
        )
    return batch_d
