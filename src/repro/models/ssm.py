"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Block structure (simplified faithfully from the reference implementation):
  in_proj -> [z (gate), x, B, C, dt] ; causal depthwise conv on (x, B, C) ;
  SSD scan over chunks ; gated RMSNorm ; out_proj.

Train/prefill use the chunked SSD (``repro.kernels.ssd_scan`` ref or Pallas
kernel); decode carries the O(1) recurrent state (B, H, N, dh) -- this is why
SSM archs run ``long_500k`` natively (DESIGN.md S6).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_apply, dense_init, rmsnorm_apply, rmsnorm_init

CONV_K = 4  # causal depthwise conv width


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def ssm_init(key, cfg, *, stack=None):
    D = cfg.d_model
    N = cfg.ssm_state
    d_inner, H = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * N  # x, B, C all pass the conv

    def shp(*s):
        return s if stack is None else (stack, *s)

    # dt bias drawn log-uniform in [1e-3, 1e-1] (mamba2 reference init)
    dt_bias = jax.random.uniform(
        ks[3], shp(H), minval=math.log(1e-3), maxval=math.log(1e-1)
    )
    return {
        # in_proj emits [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], D, 2 * d_inner + 2 * N + H, cfg.param_dtype, stack=stack),
        "conv_w": (jax.random.normal(ks[1], shp(CONV_K, conv_dim)) * 0.1).astype(cfg.param_dtype),
        "conv_b": jnp.zeros(shp(conv_dim), cfg.param_dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], shp(H), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D_skip": jnp.ones(shp(H), cfg.param_dtype),
        "norm": rmsnorm_init(d_inner, cfg.param_dtype, stack=stack),
        "out_proj": dense_init(ks[4], d_inner, D, cfg.param_dtype, stack=stack),
    }


def _split_proj(cfg, proj):
    d_inner, H = ssm_dims(cfg)
    N = cfg.ssm_state
    z, x, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, x, Bm, Cm, dt


def _causal_conv(w, b, u, conv_state=None):
    """Depthwise causal conv, width CONV_K.  u: (B, L, C).  Returns (y, new
    state (B, CONV_K-1, C)) for decode continuation."""
    Bt, L, Cdim = u.shape
    if conv_state is None:
        pad = jnp.zeros((Bt, CONV_K - 1, Cdim), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)  # (B, L+K-1, C)
    y = sum(
        ext[:, i : i + L] * w[i][None, None, :].astype(u.dtype) for i in range(CONV_K)
    )
    y = y + b[None, None, :].astype(u.dtype)
    return jax.nn.silu(y), ext[:, L:]  # last K-1 raw inputs = decode state


def ssm_apply(p, cfg, xin, *, backend="xla", return_state=False):
    """Train/prefill: xin (B, L, D) -> (B, L, D) [, decode state]."""
    Bt, L, D = xin.shape
    d_inner, H = ssm_dims(cfg)
    N = cfg.ssm_state
    dh = cfg.ssm_head_dim
    proj = dense_apply(p["in_proj"], xin, cfg.compute_dtype)
    z, x, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc, conv_state = _causal_conv(p["conv_w"], p["conv_b"], xbc_raw)
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])  # (H,) negative
    xh = x.reshape(Bt, L, H, dh)

    chunk = min(cfg.ssm_chunk, L)
    if L % chunk:  # pad to a chunk multiple (zero dt => identity dynamics)
        padlen = chunk - L % chunk
        xh = jnp.pad(xh, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, padlen), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, padlen), (0, 0)))

    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels.ssd_scan.ops import ssd_scan

        y, S = ssd_scan(
            xh.astype(jnp.float32), dt, A, Bm.astype(jnp.float32),
            Cm.astype(jnp.float32), chunk=chunk,
            interpret=backend == "pallas_interpret", use_pallas=True,
        )
    else:
        from repro.kernels.ssd_scan.ref import ssd_chunked_batched

        y, S = ssd_chunked_batched(
            xh.astype(jnp.float32), dt, A, Bm.astype(jnp.float32),
            Cm.astype(jnp.float32), chunk=chunk, unroll=cfg.ssm_unroll,
        )
    y = y[:, :L]
    xh = xh[:, :L]
    dt = dt[:, :L]
    y = y + xh.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bt, L, d_inner).astype(cfg.compute_dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y, cfg.compute_dtype)
    if return_state:
        # conv state = last CONV_K-1 *raw* conv inputs (from _causal_conv)
        return out, {"S": S, "conv": conv_state}
    return out


def ssm_decode_init(cfg, batch, dtype=jnp.float32):
    d_inner, H = ssm_dims(cfg)
    N = cfg.ssm_state
    dh = cfg.ssm_head_dim
    return {
        "S": jnp.zeros((batch, H, N, dh), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_inner + 2 * N), dtype),
    }


def ssm_decode_apply(p, cfg, xin, state):
    """One-token decode: xin (B, 1, D), O(1) state update."""
    Bt = xin.shape[0]
    d_inner, H = ssm_dims(cfg)
    N = cfg.ssm_state
    dh = cfg.ssm_head_dim
    proj = dense_apply(p["in_proj"], xin, cfg.compute_dtype)
    z, x, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)  # (B, 1, conv_dim)
    conv_in = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    y = sum(
        conv_in[:, i : i + 1] * p["conv_w"][i][None, None, :].astype(xbc.dtype)
        for i in range(CONV_K)
    ) + p["conv_b"][None, None, :].astype(xbc.dtype)
    xbc_out = jax.nn.silu(y)
    new_conv = conv_in[:, 1:]
    x, Bm, Cm = jnp.split(xbc_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])[:, 0]
    A = -jnp.exp(p["A_log"])  # (H,)
    a = jnp.exp(dt * A[None, :])  # (B, H)
    xh = x.reshape(Bt, H, dh).astype(jnp.float32)
    Bf = Bm[:, 0].astype(jnp.float32)  # (B, N)
    Cf = Cm[:, 0].astype(jnp.float32)
    # S <- a S + dt * B x^T ; y = C S
    S = state["S"] * a[:, :, None, None] + (
        dt[:, :, None, None] * jnp.einsum("bn,bhd->bhnd", Bf, xh)
    )
    yh = jnp.einsum("bn,bhnd->bhd", Cf, S)
    yh = yh + xh * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = yh.reshape(Bt, 1, d_inner).astype(cfg.compute_dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y, cfg.compute_dtype)
    return out, {"S": S, "conv": new_conv}
