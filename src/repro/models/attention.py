"""GQA attention: init + train/prefill apply + decode-with-cache apply.

Covers the assigned archs' variants: GQA kv grouping, qk-norm (Qwen3), QKV
bias (Qwen1.5), bidirectional (Whisper encoder), cross-attention (Whisper
decoder).  The train/prefill path is blockwise ("chunked") online-softmax
attention in pure JAX -- the XLA twin of the flash kernel, O(L) memory, safe
to lower at 32k on 512 devices.  ``backend='pallas'`` switches to the Pallas
kernel (TPU; interpret=True for CPU validation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models.common import apply_rope, dense_apply, dense_init, rmsnorm_apply, rmsnorm_init

NEG_INF = -1e30


def attention_init(key, cfg, *, stack=None, cross=False):
    D, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd, cfg.param_dtype, bias=cfg.qkv_bias, stack=stack),
        "wk": dense_init(ks[1], D, Hk * hd, cfg.param_dtype, bias=cfg.qkv_bias, stack=stack),
        "wv": dense_init(ks[2], D, Hk * hd, cfg.param_dtype, bias=cfg.qkv_bias, stack=stack),
        "wo": dense_init(ks[3], H * hd, D, cfg.param_dtype, stack=stack),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, cfg.param_dtype, stack=stack)
        p["k_norm"] = rmsnorm_init(hd, cfg.param_dtype, stack=stack)
    return p


def _project_q(p, cfg, x, positions, *, rope=True):
    B, L, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = dense_apply(p["wq"], x, cfg.compute_dtype).reshape(B, L, H, hd)
    if "q_norm" in p:
        q = rmsnorm_apply(p["q_norm"], q)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(p, cfg, x, positions, *, rope=True):
    B, L, _ = x.shape
    Hk, hd = cfg.n_kv_heads, cfg.head_dim
    k = dense_apply(p["wk"], x, cfg.compute_dtype).reshape(B, L, Hk, hd)
    v = dense_apply(p["wv"], x, cfg.compute_dtype).reshape(B, L, Hk, hd)
    if "k_norm" in p:
        k = rmsnorm_apply(p["k_norm"], k)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024, q_offset: int = 0):
    """Blockwise online-softmax attention (XLA path).

    q: (B, L, H, hd); k, v: (B, Lk, Hk, hd).  O(L*chunk) live memory via a
    scan over kv chunks; mathematically exact softmax attention.

    Sharding note: KV heads are expanded to the full H query heads BEFORE the
    score einsum (Megatron's GQA-under-TP convention).  With Hk < TP, a
    grouped (Hk, G) layout cannot shard query heads over the mesh 'model'
    axis and XLA silently replicates the whole quadratic computation
    (measured: ~256x per-device FLOPs on the 16x16 mesh); the H-flat layout
    lets the head dim shard cleanly.
    """
    B, Lq, H, hd = q.shape
    Lk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    chunk = min(chunk, Lk)
    nchunk = -(-Lk // chunk)
    pad = nchunk * chunk - Lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if G > 1:  # expand kv heads -> H (shardable over TP)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    kc = k.reshape(B, nchunk, chunk, H, hd)
    vc = v.reshape(B, nchunk, chunk, H, hd)
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    rows = q_offset + jnp.arange(Lq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp  # (B, chunk, H, hd) x2, scalar chunk idx
        s = jnp.einsum(
            "blhd,bchd->blhc", qf, kb.astype(jnp.float32)
        )  # (B, Lq, H, chunk)
        cols = ci * chunk + jnp.arange(chunk)
        valid = cols < Lk
        if causal:
            valid = valid[None, :] & (rows[:, None] >= cols[None, :])
            s = jnp.where(valid[None, :, None, :], s, NEG_INF)
        else:
            s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + pexp.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "blhc,bchd->blhd", pexp, vb.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Lq, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Lq, H), jnp.float32)
    a0 = jnp.zeros((B, Lq, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nchunk)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention_apply(
    p,
    cfg,
    x,
    *,
    positions=None,
    causal=True,
    rope=True,
    kv_x=None,
    backend="xla",
    mesh=None,
):
    """Train/prefill attention.  ``kv_x`` switches to cross-attention."""
    B, L, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    q = _project_q(p, cfg, x, positions, rope=rope)
    src = x if kv_x is None else kv_x
    kv_pos = positions if kv_x is None else jnp.broadcast_to(
        jnp.arange(src.shape[1]), (B, src.shape[1])
    )
    k, v = _project_kv(p, cfg, src, kv_pos, rope=rope)
    # keep heads on the TP axis and batch on DP through the quadratic part
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if cfg.n_heads % max(tp, 1) == 0:
        q = shard_hint(q, mesh, "dp", None, "model", None)
        k = shard_hint(k, mesh, "dp", None, "model", None)
        v = shard_hint(v, mesh, "dp", None, "model", None)
    else:
        # SP fallback (e.g. whisper: 20 heads, TP=16): shard the QUERY rows
        # over the model axis instead; KV replicates (the standard
        # sequence-parallel attention trade -- KV all-gather instead of
        # replicated quadratic compute).  EXPERIMENTS.md hillclimb H1.
        q = shard_hint(q, mesh, "dp", "model", None, None)
        k = shard_hint(k, mesh, "dp", None, None, None)
        v = shard_hint(v, mesh, "dp", None, None, None)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention.ops import flash_attention

        o = flash_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal, 128, 128, backend == "pallas_interpret", True,
        ).swapaxes(1, 2)
    else:
        o = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    return dense_apply(p["wo"], o.reshape(B, L, -1), cfg.compute_dtype), (k, v)


def decode_attention_apply(p, cfg, x, cache_k, cache_v, pos, *, rope=True):
    """One-token decode vs a (B, S, Hk, hd) cache.

    Writes the new token's K/V at position ``pos`` (per-sequence), attends
    over positions <= pos, and returns (out, cache_k, cache_v).  Exact
    softmax with a length mask; with the cache's S axis sharded over the mesh
    'model' axis, XLA lowers this to the flash-decode partial-softmax +
    combine pattern (see DESIGN.md).
    """
    B = x.shape[0]
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    S = cache_k.shape[1]
    pos = pos if pos.ndim == 1 else pos[:, 0]
    positions = pos[:, None]  # (B, 1)
    q = _project_q(p, cfg, x, positions, rope=rope)  # (B, 1, H, hd)
    k_new, v_new = _project_kv(p, cfg, x, positions, rope=rope)  # (B, 1, Hk, hd)
    cache_k = cache_k.at[jnp.arange(B), pos].set(k_new[:, 0])
    cache_v = cache_v.at[jnp.arange(B), pos].set(v_new[:, 0])

    G = H // Hk
    qg = q.reshape(B, Hk, G, hd)
    if cfg.decode_kv_f32:
        # baseline: f32 copies of the whole cache (2x HBM traffic)
        s = jnp.einsum(
            "bkgd,bskd->bkgs", qg.astype(jnp.float32) * (hd ** -0.5),
            cache_k.astype(jnp.float32),
        )
    else:
        # H3: read the cache in its storage dtype; MXU accumulates f32
        s = jnp.einsum(
            "bkgd,bskd->bkgs", qg, cache_k, preferred_element_type=jnp.float32
        ) * (hd ** -0.5)
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    pexp = jnp.exp(s - m)
    if cfg.decode_kv_f32:
        o = jnp.einsum("bkgs,bskd->bkgd", pexp, cache_v.astype(jnp.float32))
    else:
        o = jnp.einsum(
            "bkgs,bskd->bkgd", pexp.astype(cache_v.dtype), cache_v,
            preferred_element_type=jnp.float32,
        )
    o = o / pexp.sum(axis=-1)[..., None]
    o = o.reshape(B, 1, H * hd).astype(cfg.compute_dtype)
    return dense_apply(p["wo"], o, cfg.compute_dtype), cache_k, cache_v
