"""Deterministic synthetic data pipeline with per-host sharding + packing.

Real deployments stream tokenized shards; at 1000 nodes what matters is that
(a) every host reads a disjoint, deterministic slice keyed by (step, host),
(b) restart resumes exactly (no data repeated/skipped after checkpoint
restore), and (c) sequence packing keeps padding waste near zero.  All three
are implemented and tested here; the token source is a counter-hash PRNG (a
stand-in corpus with a vocab-shaped unigram skew so losses are non-trivial).
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    doc_len_mean: int = 512  # for packing


def _hash_u32(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B)
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B)
    x = x ^ (x >> 16)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def tokens_for(cfg: DataConfig, step: int) -> np.ndarray:
    """Deterministic (step, host)-keyed batch slice: (local_batch, seq_len)."""
    if cfg.global_batch % cfg.num_hosts:
        raise ValueError("global_batch must divide num_hosts")
    local = cfg.global_batch // cfg.num_hosts
    rows = np.arange(local) + cfg.host_id * local
    pos = np.arange(cfg.seq_len)
    key = (
        np.uint64(cfg.seed) * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(2_654_435_761)
    )
    grid = key + (rows[:, None].astype(np.uint64) << np.uint64(20)) + pos[None, :].astype(np.uint64)
    h = _hash_u32(grid)
    # unigram skew: square the uniform draw -> Zipf-ish head
    u = h.astype(np.float64) / 2**32
    return (u * u * (cfg.vocab - 2)).astype(np.int32) + 1


def pack_documents(doc_lengths: np.ndarray, seq_len: int):
    """First-fit packing of documents into fixed windows.

    Returns (assignments, waste_fraction): assignments[i] = window of doc i.
    """
    windows: list[int] = []  # remaining space per window
    assign = np.empty(len(doc_lengths), np.int64)
    for i, dl in enumerate(doc_lengths):
        dl = int(min(dl, seq_len))
        for w, rem in enumerate(windows):
            if rem >= dl:
                windows[w] -= dl
                assign[i] = w
                break
        else:
            windows.append(seq_len - dl)
            assign[i] = len(windows) - 1
    waste = sum(windows) / max(len(windows) * seq_len, 1)
    return assign, waste


class DataIterator:
    """Stateful iterator with exact checkpoint/resume semantics."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, extras=None):
        self.cfg = cfg
        self.step = start_step
        self.extras = extras or {}

    def __iter__(self):
        return self

    def __next__(self):
        toks = tokens_for(self.cfg, self.step)
        self.step += 1
        batch = {
            "tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
        }
        for k, fn in self.extras.items():
            batch[k] = fn(self.step - 1, toks.shape[0])
        return batch

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, d):
        self.step = int(d["step"])
