"""Step builders for the dry-run / production launchers.

``build_step(cfg, shape, mesh)`` returns (step_fn, example_args,
in_shardings) ready for ``jax.jit(...).lower(...)``:
  * train   -> train_step(state, batch)  (loss + grads + optimizer update)
  * prefill -> prefill_step(params, batch)
  * decode  -> serve_step(params, cache, tokens, pos)
"""

from __future__ import annotations

from functools import partial

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec
from repro.distributed.sharding import param_specs
from repro.launch import specs as S
from repro.models.model_zoo import build_model
from repro.training import optimizer as opt_mod
from repro.training.train_loop import TrainConfig, make_train_step


def _param_shardings(params_struct, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_struct, mesh)
    )


def _opt_shardings(opt_struct, pspecs, mesh):
    """Optimizer state shardings mirror the param shardings.

    Adafactor's factored stats drop one axis of the param: vr = mean over the
    last axis (param spec minus its last entry), vc = mean over the
    second-to-last.  Replicating them instead forces XLA to materialize
    REPLICATED gradients -- measured 107 GB/dev/layer of all-reduce on
    kimi-k2 train_4k (EXPERIMENTS.md hillclimb H2)."""

    def like_params(sub):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    out = {}
    for k, v in opt_struct.items():
        if k == "step":
            out[k] = NamedSharding(mesh, P())
        elif k in ("mu", "nu"):
            out[k] = like_params(v)
        elif k == "v":  # adafactor
            flat_p, treedef = jax.tree_util.tree_flatten(pspecs)
            stats = treedef.flatten_up_to(v)

            def stat_shard(spec, stat):
                if isinstance(stat, dict) and "vr" in stat:
                    full = tuple(spec)
                    return {
                        "vr": NamedSharding(mesh, P(*full[:-1])),
                        "vc": NamedSharding(mesh, P(*(full[:-2] + full[-1:]))),
                    }
                return {"v": NamedSharding(mesh, spec)}

            out[k] = jax.tree_util.tree_unflatten(
                treedef, [stat_shard(s, st) for s, st in zip(flat_p, stats)]
            )
        else:
            out[k] = jax.tree.map(lambda _: NamedSharding(mesh, P()), v)
    return out


def build_step(cfg: ArchConfig, shape: ShapeSpec, mesh):
    model = build_model(cfg, mesh=mesh)

    if shape.kind == "train":
        state_struct, ocfg = S.train_state_struct(cfg, model)
        tcfg = TrainConfig(opt=ocfg)
        step = make_train_step(model, tcfg)
        batch = S.batch_struct(cfg, shape)
        pspecs = param_specs(state_struct["params"], mesh)
        in_sh = (
            {
                "params": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                "opt": _opt_shardings(state_struct["opt"], pspecs, mesh),
            },
            S.batch_sharding(cfg, batch, mesh),
        )
        return step, (state_struct, batch), in_sh

    if shape.kind == "prefill":
        params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        batch = S.batch_struct(cfg, shape)

        def prefill_step(params, batch):
            logits, cache = model.prefill(params, batch, shape.seq_len)
            return logits

        in_sh = (
            _param_shardings(params_struct, mesh),
            S.batch_sharding(cfg, batch, mesh),
        )
        return prefill_step, (params_struct, batch), in_sh

    if shape.kind == "decode":
        params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        cache = S.cache_struct(cfg, shape)
        (tok, pos), (tok_sh, pos_sh) = S.decode_inputs(cfg, shape, mesh)

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        in_sh = (
            _param_shardings(params_struct, mesh),
            S.cache_sharding(cfg, cache, mesh),
            tok_sh,
            pos_sh,
        )
        return serve_step, (params_struct, cache, tok, pos), in_sh

    raise ValueError(shape.kind)
