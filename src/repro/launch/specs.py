"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

Weak-type-correct, shardable, no device allocation: the dry-run lowers
``train_step`` / ``prefill_step`` / ``serve_step`` against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ArchConfig, ShapeSpec
from repro.distributed.sharding import fsdp_axes
from repro.models import transformer, whisper
from repro.models.model_zoo import build_model
from repro.training import optimizer as opt_mod
from repro.training.train_loop import TrainConfig


def _valid(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes that do not divide the dim (tiny dims replicate)."""
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        fixed.append(ax if dim % size == 0 and dim >= size else None)
    return P(*fixed)


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for a train/prefill batch."""
    B, L = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, L)), "labels": sds((B, L))}
    if cfg.family == "vlm":
        batch["patches"] = sds((B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = sds((B, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    return batch


def batch_sharding(cfg, batch, mesh: Mesh):
    dp = fsdp_axes(mesh)
    dp = dp if dp else (None,)

    def one(leaf):
        spec = P(dp) if leaf.ndim == 1 else P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, _valid(spec, leaf.shape, mesh))

    return jax.tree.map(one, batch)


def cache_struct(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the serve_step decode cache at shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        init = lambda: whisper.whisper_cache_init(cfg, B, S)
    else:
        init = lambda: transformer.decode_cache_init(cfg, B, S)
    return jax.eval_shape(init)


def cache_sharding(cfg, cache, mesh: Mesh):
    """KV: (L, B, S, Hk, hd) -> batch over dp, S over model (flash-decode
    style sequence sharding).  SSM state: batch over dp, heads over model
    when divisible."""
    dp = fsdp_axes(mesh)
    dp = dp if dp else (None,)

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "xk", "xv"):  # (L, B, S, Hk, hd)
            spec = P(None, dp, "model", None, None)
            if leaf.shape[1] == 1:  # batch 1 (long_500k): shard S harder
                spec = P(None, None, dp + ("model",), None, None)
        elif name == "S":  # (L, B, H, N, dh)
            spec = P(None, dp, "model", None, None)
        elif name == "conv":  # (L, B, K-1, C)
            spec = P(None, dp, None, "model")
        else:
            spec = P()
        return NamedSharding(mesh, _valid(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


def decode_inputs(cfg, shape: ShapeSpec, mesh: Mesh):
    """(tokens, pos) structs + shardings for serve_step."""
    B = shape.global_batch
    dp = fsdp_axes(mesh)
    dp = dp if dp else (None,)
    tok = sds((B,))
    pos = sds((B,))
    sh = NamedSharding(mesh, _valid(P(dp), (B,), mesh))
    return (tok, pos), (sh, sh)


def train_state_struct(cfg: ArchConfig, model=None):
    """abstract {params, opt} via eval_shape (no allocation)."""
    model = model or build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    ocfg = opt_mod.OptimizerConfig(name=cfg.optimizer)
    opt = jax.eval_shape(lambda: opt_mod.opt_init(ocfg, params))
    return {"params": params, "opt": opt}, ocfg
