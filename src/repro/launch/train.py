"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

On a real TPU pod this binary runs per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); on CPU it drives the same loop at
reduced scale -- the quickstart/examples use it.  Features exercised:
sharded state, microbatching, gradient compression, async checkpointing,
exact resume, straggler accounting, elastic replan hooks.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed.checkpoint import CheckpointManager
from repro.launch.mesh import make_test_mesh
from repro.models.model_zoo import build_model
from repro.training import optimizer as opt_mod
from repro.training.compression import CompressionConfig
from repro.training.train_loop import StragglerPolicy, TrainConfig, TrainLoop, init_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none", choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host entry

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        opt=opt_mod.OptimizerConfig(
            name=cfg.optimizer, lr=args.lr, warmup_steps=max(args.steps // 20, 5),
            total_steps=args.steps,
        ),
        compression=CompressionConfig(scheme=args.compression),
        microbatches=args.microbatches,
    )
    data = DataIterator(
        DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            num_hosts=jax.process_count(), host_id=jax.process_index(),
        )
    )
    if cfg.family == "vlm":
        data.extras["patches"] = lambda step, b: np.zeros(
            (b, cfg.n_patches, cfg.d_model), np.float32
        )
    if cfg.family == "encdec":
        data.extras["frames"] = lambda step, b: np.zeros(
            (b, cfg.n_audio_frames, cfg.d_model), np.float32
        )

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state = init_state(model, tcfg, jax.random.PRNGKey(0))
    start_step = 0
    if args.resume and ckpt is not None and ckpt.latest_step() is not None:
        state, extra, start_step = ckpt.restore(state)
        data.load_state_dict(extra)
        print(f"resumed from step {start_step}")

    loop = TrainLoop(
        model, tcfg, data, ckpt_manager=ckpt, ckpt_every=args.ckpt_every,
        straggler=StragglerPolicy(),
    )
    t0 = time.time()
    state, log = loop.run(state, start_step, args.steps - start_step)
    for row in log:
        if row["step"] % args.log_every == 0 or row["step"] == args.steps - 1:
            print(
                f"step {row['step']:5d} loss {row['loss']:.4f} "
                f"gnorm {row['grad_norm']:.3f} dt {row['dt']*1e3:.0f}ms"
            )
    if ckpt is not None:
        ckpt.save(state, args.steps, extra=data.state_dict(), block=True)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"final loss {log[-1]['loss']:.4f}, stragglers {loop.straggler.flagged_steps}")
    return log


if __name__ == "__main__":
    main()
