"""Serving launcher: continuous batching over the model zoo.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --reduced \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse

import numpy as np
import jax

from repro.configs import get_config, get_reduced_config
from repro.models.model_zoo import build_model
from repro.serving.batching import ContinuousBatcher, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            req_id=i,
            prompt=rng.integers(2, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    b = ContinuousBatcher(model, max_batch=args.max_batch, max_len=args.max_len)
    b.model_params = params
    m = b.serve(reqs)
    done = sum(1 for r in reqs if r.finished_step >= 0)
    print(
        f"served {done}/{len(reqs)} requests in {m.steps} steps, "
        f"{m.tokens_out} tokens, {m.tokens_per_s:.1f} tok/s (CPU)"
    )
    for r in reqs[:3]:
        print(f"  req {r.req_id}: out[{len(r.output)}] = {r.output[:8]}...")
    return m


if __name__ == "__main__":
    main()
