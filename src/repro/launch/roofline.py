"""Roofline analysis from the compiled dry-run artifact (assignment g).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = ring wire bytes / ICI link bw    (per chip)

``cost_analysis()`` provides per-device FLOPs / bytes-accessed; collective
bytes come from parsing ``compiled.as_text()`` and summing the ring-model
wire traffic of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (group size from replica_groups, both explicit and iota
forms).  Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str) -> dict:
    """Per-chip ring-model wire bytes by collective kind."""
    out = {
        "all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # count the -start (or plain) form once
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        # group size n
        n = 0
        ge = _GROUPS_EXPL_RE.search(line)
        if ge:
            n = len([x for x in ge.group(1).split(",") if x.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))  # [groups, group_size]
        n = max(n, 2)
        ring = (n - 1) / n
        if kind == "all-gather":
            wire = nbytes * ring  # result bytes cross the ring once
        elif kind == "all-reduce":
            wire = 2 * nbytes * ring  # reduce-scatter + all-gather phases
        elif kind == "reduce-scatter":
            wire = nbytes * (n - 1)  # result is 1/n of the input
        elif kind == "all-to-all":
            wire = nbytes * ring
        else:  # collective-permute
            wire = nbytes
        out[kind] += wire
        counts[kind] += 1
    out["counts"] = counts
    out["total"] = sum(v for k, v in out.items() if k not in ("counts", "total"))
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per chip
    hlo_bytes: float  # per chip
    collective_bytes: float  # per chip (ring wire)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6*N*D (global, per step)
    useful_ratio: float  # model_flops / (hlo_flops * chips)
    bytes_per_device: int
    collective_detail: dict
    note: str = ""

    def to_json(self):
        return dataclasses.asdict(self)


def analyze(
    *, arch: str, shape_name: str, mesh_name: str, chips: int,
    cost: dict, hlo_text: str, memory_stats, model_flops: float, note: str = "",
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_wire_bytes(hlo_text)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = coll["total"] / ICI_BW
    dom = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", coll_s)],
        key=lambda kv: kv[1],
    )[0]
    per_dev = 0
    if memory_stats is not None:
        per_dev = int(getattr(memory_stats, "temp_size_in_bytes", 0)) + int(
            getattr(memory_stats, "argument_size_in_bytes", 0)
        ) + int(getattr(memory_stats, "output_size_in_bytes", 0)) + int(
            getattr(memory_stats, "generated_code_size_in_bytes", 0)
        )
    useful = model_flops / (flops * chips) if flops else 0.0
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=coll["total"],
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dom, model_flops=model_flops, useful_ratio=useful,
        bytes_per_device=per_dev,
        collective_detail={k: v for k, v in coll.items() if k != "counts"}
        | {"counts": coll["counts"]},
        note=note,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch
    tokens per step; train adds nothing extra (the 6 covers fwd+bwd)."""
    n = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
