"""Renders the EXPERIMENTS.md roofline table from results/dryrun.json.

    PYTHONPATH=src python -m repro.launch.report [--json results/dryrun.json]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def render(path: str, mesh_filter: str | None = None) -> str:
    data = json.loads(Path(path).read_text())
    rows = []
    for key, v in sorted(data.items()):
        if not v.get("ok") or "skipped" in v:
            continue
        arch, shape, mesh = key.split("|")
        if mesh_filter and mesh != mesh_filter:
            continue
        flag = " (probeless)" if v.get("probeless") else ""
        rows.append(
            f"| {arch} | {shape} | {mesh}{flag} | {fmt_s(v['compute_s'])} "
            f"| {fmt_s(v['memory_s'])} | {fmt_s(v['collective_s'])} "
            f"| {v['dominant']} | {v['useful_ratio']:.3f} "
            f"| {fmt_b(v['bytes_per_device'])} |"
        )
    hdr = (
        "| arch | shape | mesh | compute | memory | collective | dominant "
        "| 6ND/HLO | bytes/dev |\n|---|---|---|---|---|---|---|---|---|"
    )
    skips = [
        f"| {k.split('|')[0]} | {k.split('|')[1]} | SKIPPED: {v['skipped']} |"
        for k, v in data.items()
        if v.get("skipped")
    ]
    failures = [k for k, v in data.items() if not v.get("ok")]
    out = [hdr] + rows
    if skips:
        out += ["", "Skipped cells:"] + skips
    if failures:
        out += ["", f"FAILED cells: {failures}"]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    print(render(args.json, args.mesh))


if __name__ == "__main__":
    main()
