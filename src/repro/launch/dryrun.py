import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment e): lower + compile every
(architecture x input shape) cell on the production meshes, print
memory_analysis / cost_analysis, and emit roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod
    PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.json

The XLA_FLAGS line above MUST run before any jax import: jax pins the host
device count at first init.  This module is the only place that forces 512
placeholder devices.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, SKIPPED_CELLS, all_cells, get_config  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402


def _dryrun_cfg(arch: str):
    """Production numerics for the dry-run: bf16 compute everywhere.

    PULSE_DECODE_KV_BF16=1 flips the H3 hillclimb flag (bf16 cache reads
    with f32 MXU accumulation) so before/after runs share one entry point.
    """
    cfg = get_config(arch).replace(compute_dtype=jnp.bfloat16)
    if os.environ.get("PULSE_DECODE_KV_BF16"):
        cfg = cfg.replace(decode_kv_f32=False)
    return cfg


def _probe_cfg(cfg, k: int):
    """k repeating units with EVERY scan unrolled (layers, attention
    kv-chunks, CE chunks, SSD chunks) so cost_analysis counts all work --
    XLA counts while-loop bodies once, so scanned stacks undercount."""
    cfg = cfg.replace(
        scan_layers=False,
        attn_chunk=1 << 20,  # single kv chunk -> length-1 scan
        ce_chunk=1 << 20,
        ssm_unroll=True,
    )
    if cfg.family == "encdec":
        return cfg.replace(n_enc_layers=k, n_dec_layers=k, n_layers=k)
    if cfg.family == "hybrid":
        return cfg.replace(n_layers=cfg.hybrid_attn_every * k)
    return cfg.replace(n_layers=k)


def _units(cfg) -> float:
    if cfg.family == "encdec":
        return float(cfg.n_enc_layers)
    if cfg.family == "hybrid":
        return cfg.n_layers / cfg.hybrid_attn_every
    return float(cfg.n_layers)


def _compile(cfg, shape, mesh):
    step, args, in_sh = build_step(cfg, shape, mesh)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled):
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    cost = dict(cost) if cost else {}
    coll = rl.collective_wire_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
        "coll_detail": coll,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, verbose: bool = True):
    cfg = _dryrun_cfg(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()

    # 1) the REAL program (scanned stack): proves lower+compile+sharding and
    #    gives the per-device memory analysis
    compiled = _compile(cfg, shape, mesh)
    mem = compiled.memory_analysis()
    reported = _cost_of(compiled)

    # 2) cost probes: unrolled 1-unit and 2-unit stacks -> exact marginal
    #    per-layer cost; scale to full depth (XLA counts scan bodies once)
    c1 = _cost_of(_compile(_probe_cfg(cfg, 1), shape, mesh))
    c2 = _cost_of(_compile(_probe_cfg(cfg, 2), shape, mesh))
    units = _units(cfg)
    corrected = {
        k: c1[k] + (units - 1.0) * (c2[k] - c1[k])
        for k in ("flops", "bytes", "coll")
    }
    dt = time.time() - t0

    report = rl.analyze(
        arch=arch, shape_name=shape_name, mesh_name=mesh_name, chips=chips,
        cost={"flops": corrected["flops"], "bytes accessed": corrected["bytes"]},
        hlo_text="", memory_stats=mem,
        model_flops=rl.model_flops_for(cfg, shape),
    )
    # overwrite collective numbers with the probe-corrected wire bytes
    report.collective_bytes = corrected["coll"]
    report.collective_s = corrected["coll"] / rl.ICI_BW
    report.collective_detail = {
        "probe1": {k: v for k, v in c1["coll_detail"].items() if k != "counts"},
        "probe_counts": c2["coll_detail"]["counts"],
        "reported_scanned": reported,
    }
    report.dominant = max(
        [("compute", report.compute_s), ("memory", report.memory_s),
         ("collective", report.collective_s)],
        key=lambda kv: kv[1],
    )[0]
    if verbose:
        print(f"\n=== {arch} x {shape_name} @ {mesh_name} ({dt:.1f}s total) ===")
        print(f"memory_analysis: {mem}")
        print(
            f"cost(corrected): flops/dev={report.hlo_flops:.3e} "
            f"bytes/dev={report.hlo_bytes:.3e} coll_wire/dev={report.collective_bytes:.3e}"
        )
        print(
            f"roofline: compute={report.compute_s*1e3:.3f}ms "
            f"memory={report.memory_s*1e3:.3f}ms "
            f"collective={report.collective_s*1e3:.3f}ms "
            f"dominant={report.dominant} useful={report.useful_ratio:.3f}"
        )
        print(f"collectives(probe2): {c2['coll_detail']['counts']}")
    return report, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi_pod", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch.replace("-", "_")]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi_pod": [True], "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            key = f"{arch}|{shape_name}|{'2x16x16' if mp else '16x16'}"
            if key in results and results[key].get("ok"):
                print(f"[skip cached] {key}")
                continue
            try:
                report, dt = run_cell(arch, shape_name, multi_pod=mp)
                results[key] = {"ok": True, "compile_s": dt, **report.to_json()}
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append(key)
                results[key] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            out_path.write_text(json.dumps(results, indent=1))
    for a_s, why in SKIPPED_CELLS.items():
        results[f"{a_s[0]}|{a_s[1]}|skipped"] = {"ok": True, "skipped": why}
    out_path.write_text(json.dumps(results, indent=1))

    n_ok = sum(1 for v in results.values() if v.get("ok"))
    print(f"\n==== dry-run complete: {n_ok}/{len(results)} ok; failures: {failures} ====")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
