"""Production meshes (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  Single-pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) -- the pod axis
is the slower DCN/ICI-superpod dimension; DP/FSDP spans (pod, data).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU tests (1 device)."""
    return jax.make_mesh(shape, axes)
