"""Mamba2-780M [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2_780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,  # attention-free; unused
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    source="arXiv:2405.21060; unverified",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab=512, ssm_state=16, ssm_head_dim=16,
    )
