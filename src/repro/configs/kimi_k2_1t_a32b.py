"""Kimi-K2 [moe]: trillion-param MoE, 384 experts top-8 + 1 shared.
[arXiv:2501.kimi2; unverified (paper-table)]

Trillion-scale execution notes: bf16 params + Adafactor (factored second
moment) + full remat; FSDP over (pod, data) x TP/EP over model is required to
fit v5e HBM (see EXPERIMENTS.md dry-run memory analysis)."""

import jax.numpy as jnp

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="kimi_k2_1t_a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    n_experts=384,
    moe_top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
    optimizer="adafactor",
    remat="full",
    source="arXiv:2501.kimi2; unverified",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
        head_dim=16, n_experts=8, moe_top_k=2, moe_d_ff=64, n_shared_experts=1,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
        optimizer="adamw",
    )
