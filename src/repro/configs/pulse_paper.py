"""The paper's own evaluation workloads (S6, Table 3) as configs.

Not an LM arch: these parameterize the PULSE engine benchmarks (WebService
hash table, WiredTiger B+tree range queries, BTrDB time-series aggregation)
with the paper's dataset shapes and the prototype's hardware constants.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PulseWorkload:
    name: str
    structure: str  # hash | btree_find | btree_range
    n_keys: int
    n_buckets: int = 0
    value_bytes: int = 8
    expected_tc_td: float = 0.0  # paper Table 3
    expected_iters: tuple = ()  # paper Table 3
    zipf_s: float = 0.99  # YCSB zipfian skew


WEBSERVICE = PulseWorkload(
    name="webservice",
    structure="hash",
    n_keys=200_000,
    n_buckets=4096,  # long chains: ~48 iterations/request (Table 3)
    expected_tc_td=0.06,
    expected_iters=(48,),
)

WIREDTIGER = PulseWorkload(
    name="wiredtiger",
    structure="btree_find",
    n_keys=500_000,
    expected_tc_td=0.63,
    expected_iters=(25,),
)

BTRDB = PulseWorkload(
    name="btrdb",
    structure="btree_range",
    n_keys=500_000,
    expected_tc_td=0.71,
    expected_iters=(38, 227),  # 1 s .. 8 s windows
)

WORKLOADS = {w.name: w for w in (WEBSERVICE, WIREDTIGER, BTRDB)}

# prototype constants (S6 setup)
MEM_BW_GBPS = 25.0
MEM_NODES = 4
ETA = 0.75  # m=3 logic : n=4 memory pipelines
CONFIG = None  # not an LM arch; see WORKLOADS
