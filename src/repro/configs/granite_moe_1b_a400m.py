"""Granite-3.0-1B-A400M [moe]: 32 experts, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="granite_moe_1b_a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    n_experts=32,
    moe_top_k=8,
    moe_d_ff=512,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
        head_dim=16, n_experts=8, moe_top_k=2, moe_d_ff=96,
    )
