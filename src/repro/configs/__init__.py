"""Architecture configs: the 10 assigned archs + the paper's own workloads.

Each ``<arch>.py`` exports ``CONFIG`` (exact published dims) and the registry
maps ``--arch <id>`` to it.  ``reduced()`` gives the CPU-smoke-test variant
(same family, tiny dims).  Input shape sets are defined here too
(train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention variants
    qk_norm: bool = False
    qkv_bias: bool = False
    nonparametric_norm: bool = False  # OLMo: LN without learned params
    rope_theta: float = 10000.0
    attn_chunk: int = 1024  # kv-chunk for the XLA blockwise attention
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_renormalize: bool = True
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0  # zamba: shared attn block every k mamba blocks
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    n_audio_frames: int = 1500
    # vlm
    n_patches: int = 0
    # numerics / execution
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    remat: str = "none"  # none | dots | full
    optimizer: str = "adamw"  # adamw | adafactor
    attn_backend: str = "xla"  # xla | pallas | pallas_interpret
    ssm_backend: str = "xla"
    scan_layers: bool = True  # False: Python-unrolled stack (cost probes)
    ce_chunk: int = 512  # sequence chunk for the fused cross-entropy
    ssm_unroll: bool = False  # unroll the SSD chunk scan (cost probes)
    decode_kv_f32: bool = True  # False: bf16 cache reads w/ f32 MXU accum (H3)
    # citation per the assignment table
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * D
        if self.family in ("ssm",):
            d_in = self.ssm_expand * D
            per = D * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * D
            return self.n_layers * per + 2 * V * D
        if self.family == "hybrid":
            d_in = self.ssm_expand * D
            per = D * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * D
            n_attn = self.n_layers // max(self.hybrid_attn_every, 1)
            return self.n_layers * per + n_attn * 0 + attn + 3 * D * F + 2 * V * D
        mlp = 3 * D * F
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * self.moe_d_ff + D * self.n_experts
            mlp += self.n_shared_experts * 3 * D * self.moe_d_ff
        layers = self.n_layers
        if self.family == "encdec":
            # enc: self-attn; dec: self + cross; 2-matrix GELU MLP; tied embed
            mlp = 2 * D * F
            return (
                self.n_enc_layers * (attn + mlp)
                + self.n_dec_layers * (2 * attn + mlp)
                + V * D
            )
        return layers * (attn + mlp) + 2 * V * D

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        attn = D * self.hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * self.hd * D
        mlp = (self.moe_top_k + self.n_shared_experts) * 3 * D * self.moe_d_ff
        mlp += D * self.n_experts  # router
        return self.n_layers * (attn + mlp) + 2 * self.vocab * D


# ---------------------------- input shapes ----------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "internvl2_2b",
    "granite_moe_1b_a400m",
    "kimi_k2_1t_a32b",
    "whisper_large_v3",
    "zamba2_7b",
    "qwen3_0_6b",
    "qwen1_5_4b",
    "qwen3_4b",
    "olmo_1b",
    "mamba2_780m",
    "pulse_paper",  # the paper's own traversal workloads (non-LM)
]

# cells skipped with justification (DESIGN.md S6)
SKIPPED_CELLS = {("whisper_large_v3", "long_500k"): "enc-dec decoder: 30s audio source; no meaningful 500k self-attn KV"}


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.reduced()


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) dry-run cell, skips filtered per DESIGN.md."""
    cells = []
    for a in ARCH_IDS:
        if a == "pulse_paper":
            continue
        for s in SHAPES:
            if not include_skipped and (a, s) in SKIPPED_CELLS:
                continue
            cells.append((a, s))
    return cells
