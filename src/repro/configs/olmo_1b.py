"""OLMo-1B [dense]: non-parametric LN.  [arXiv:2402.00838; hf]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="olmo_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    head_dim=128,
    nonparametric_norm=True,
    source="arXiv:2402.00838; hf",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        head_dim=16,
    )
