"""Qwen3-4B [dense]: qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3_4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B; hf",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192, vocab=512,
        head_dim=16,
    )
