"""Whisper-large-v3 [audio]: enc-dec, conv frontend (stub).
[arXiv:2212.04356; unverified]

Backbone only: ``input_specs()`` provides precomputed mel/conv frame
embeddings (B, 1500, d_model); the conv frontend is a stub.  32 encoder + 32
decoder layers, LayerNorm + GELU MLP + sinusoidal positions (no RoPE)."""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper_large_v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    n_dec_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    qkv_bias=True,
    n_audio_frames=1500,
    source="arXiv:2212.04356; unverified",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=512, head_dim=16, n_audio_frames=16,
    )
