"""Qwen3-0.6B [dense]: qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3_0_6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B; hf",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        head_dim=16,
    )
