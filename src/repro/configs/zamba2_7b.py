"""Zamba2-7B [hybrid]: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

81 Mamba2 layers with ONE shared (weight-tied) attention+MLP block applied
every 6 mamba blocks (simplified from Zamba2's two alternating shared blocks;
noted in DESIGN.md)."""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,
    source="arXiv:2411.15242; unverified",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        head_dim=16, ssm_state=16, ssm_head_dim=16, hybrid_attn_every=2,
    )
