"""Qwen1.5-4B [dense]: QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1_5_4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
        head_dim=16,
    )
