"""InternVL2-2B [vlm]: InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; hf]

The transformer backbone only, per the assignment: ``input_specs()`` provides
precomputed patch embeddings; the ViT frontend is a stub."""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2_2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    n_patches=256,
    source="arXiv:2404.16821; hf",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        head_dim=16, n_patches=8,
    )
