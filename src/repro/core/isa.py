"""PULSE ISA (paper S4.1, Table 2): a stripped RISC subset + VM.

The paper compiles iterator bodies (via an LLVM Sparc-backend port) into a
restricted ISA executed by the accelerator's logic pipeline.  We keep the
exact instruction classes of Table 2 and the eBPF-style *forward-jump-only*
rule, with a tiny assembler DSL standing in for the LLVM backend (the
production path in this repo is traced JAX -- XLA is our compiler toolchain;
the VM exists to (a) validate the bounded-computation contract, (b) give the
dispatch engine an exact instruction count for its t_c model, and (c) run the
paper-faithful microbenchmarks).

Register model (one iterator workspace, S4.2):
  r0..r15         general registers
  NODE[0..W-1]    the aggregated 256 B LOAD result (read via LOADN)
  SP[0..S-1]      scratch_pad words (LOADS/STORES)
  CUR_PTR         read via GETPTR; written only by NEXT_ITER(reg)

An iteration runs from pc=0 until NEXT_ITER (yield new cur_ptr; memory
pipeline takes over) or RETURN (traversal done; scratch_pad is the result).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.iterator import PulseIterator

# opcodes (Table 2, extended with the store class -- S4.1 footnote 4's
# modification iterators).  The dead SELECT stub (op 23, "not in paper") is
# gone; the write-path opcodes take over the tail of the encoding space.
HALT = 0  # implicit safety stop
LOADN = 1  # rd <- NODE[imm]          (Memory: the per-iteration LOAD's words)
LOADS = 2  # rd <- SP[imm]
STORES = 3  # SP[imm] <- rs1
ADD, SUB, MUL, DIV, AND, OR, NOT = 4, 5, 6, 7, 8, 9, 10  # ALU
MOVE = 11  # rd <- rs1                (Register)
MOVI = 12  # rd <- imm
JEQ, JNE, JLT, JLE, JGT, JGE = 13, 14, 15, 16, 17, 18  # COMPARE+JUMP (fwd)
JMP = 19  # unconditional forward jump
NEXT_ITER = 20  # cur_ptr <- rs1; end iteration (Terminal)
RETURN = 21  # traversal done          (Terminal)
GETPTR = 22  # rd <- CUR_PTR
# store class: each stages one mutation per iteration into the request
# record's payload; the owning shard's commit phase applies it (core.commit)
STOREN = 23  # stage NODE[imm] <- rs1 write-back of the current node
ALLOC = 24  # stage a free-list claim; the staged STOREN image becomes the
#             new node, and the commit deposits its address in SP[imm]
SETPTR = 25  # stage link swing (CAS): NODE[imm] <- rs1 iff NODE[imm] == rs2
FREE = 26  # stage free of the node addressed by rs1

NUM_REGS = 16
_JUMPS = (JEQ, JNE, JLT, JLE, JGT, JGE, JMP)
_TERMINALS = (NEXT_ITER, RETURN)
_MUTATORS = (STOREN, ALLOC, SETPTR, FREE)

OP_NAMES = {
    HALT: "HALT", LOADN: "LOADN", LOADS: "LOADS", STORES: "STORES",
    ADD: "ADD", SUB: "SUB", MUL: "MUL", DIV: "DIV", AND: "AND", OR: "OR",
    NOT: "NOT", MOVE: "MOVE", MOVI: "MOVI", JEQ: "JEQ", JNE: "JNE",
    JLT: "JLT", JLE: "JLE", JGT: "JGT", JGE: "JGE", JMP: "JMP",
    NEXT_ITER: "NEXT_ITER", RETURN: "RETURN", GETPTR: "GETPTR",
    STOREN: "STOREN", ALLOC: "ALLOC", SETPTR: "SETPTR", FREE: "FREE",
}
ALL_OPS = tuple(range(FREE + 1))  # dense opcode space; OP_NAMES is exhaustive
assert set(OP_NAMES) == set(ALL_OPS)


@dataclasses.dataclass(frozen=True)
class Program:
    """Encoded PULSE program: (T, 4) int32 rows of [op, a, b, imm]."""

    code: np.ndarray
    scratch_words: int
    node_words: int
    name: str = "isa_program"

    def __post_init__(self):
        # structural validation only (shape/dtype/nonempty): semantic checks
        # are the verifier's job (core.verify), and tests deliberately build
        # semantically-corrupt Programs to exercise its rejections
        code = np.asarray(self.code)
        if code.ndim != 2 or code.shape[1] != 4:
            raise ValueError(
                f"program code must be (T, 4) [op, a, b, imm] rows, "
                f"got shape {code.shape}"
            )
        if code.shape[0] == 0:
            raise ValueError("empty program")
        if not np.issubdtype(code.dtype, np.integer):
            raise ValueError(f"program code must be integer, got {code.dtype}")
        if self.scratch_words < 0 or self.node_words < 1:
            raise ValueError(
                f"need scratch_words >= 0 and node_words >= 1, got "
                f"{self.scratch_words}/{self.node_words}"
            )
        object.__setattr__(self, "code", code.astype(np.int32, copy=False))

    def __len__(self) -> int:
        return self.code.shape[0]

    @property
    def mutates(self) -> bool:
        """True iff the program CONTAINS any store-class opcode.

        Whole-array opcode scan: the conservative fallback for unverified
        programs.  A store-class op in dead code still returns True here;
        ``verify.ProgramFacts.mutates`` is the reachability-based answer
        (what ``as_pulse_iterator`` uses), so only programs that can
        actually stage a mutation pay for the write path's record lanes.
        """
        return bool(np.isin(self.code[:, 0], _MUTATORS).any())

    def disasm(self) -> str:
        rows = []
        for i, (op, a, b, imm) in enumerate(self.code):
            rows.append(f"{i:3d}: {OP_NAMES.get(int(op), '?'):9s} a={a} b={b} imm={imm}")
        return "\n".join(rows)


class Asm:
    """Tiny assembler for PULSE programs (the LLVM-backend stand-in)."""

    def __init__(self, scratch_words: int, node_words: int, name="isa_program"):
        self.rows: list[list[int]] = []
        self.scratch_words = scratch_words
        self.node_words = node_words
        self.name = name
        self._labels: dict[str, int] = {}
        self._fixups: list[tuple[int, str]] = []

    def _emit(self, op, a=0, b=0, imm=0):
        self.rows.append([op, a, b, imm])
        return len(self.rows) - 1

    # memory / register ops
    def loadn(self, rd, idx):
        return self._emit(LOADN, rd, 0, idx)

    def loads(self, rd, idx):
        return self._emit(LOADS, rd, 0, idx)

    def stores(self, idx, rs):
        return self._emit(STORES, rs, 0, idx)

    def add(self, rd, rs1, rs2):
        return self._emit(ADD, rd, rs1, rs2)

    def sub(self, rd, rs1, rs2):
        return self._emit(SUB, rd, rs1, rs2)

    def mul(self, rd, rs1, rs2):
        return self._emit(MUL, rd, rs1, rs2)

    def div(self, rd, rs1, rs2):
        return self._emit(DIV, rd, rs1, rs2)

    def and_(self, rd, rs1, rs2):
        return self._emit(AND, rd, rs1, rs2)

    def or_(self, rd, rs1, rs2):
        return self._emit(OR, rd, rs1, rs2)

    def not_(self, rd, rs1):
        return self._emit(NOT, rd, rs1)

    def move(self, rd, rs1):
        return self._emit(MOVE, rd, rs1)

    def movi(self, rd, imm):
        return self._emit(MOVI, rd, 0, imm)

    def getptr(self, rd):
        return self._emit(GETPTR, rd)

    # store class (write path; each stages into the record's mutation payload)
    def storen(self, idx, rs):
        return self._emit(STOREN, rs, 0, idx)

    def alloc(self, scratch_idx):
        return self._emit(ALLOC, 0, 0, scratch_idx)

    def setptr(self, idx, rs_val, rs_expect):
        return self._emit(SETPTR, rs_val, rs_expect, idx)

    def free(self, rs):
        return self._emit(FREE, rs)

    # control flow -- forward only, via labels resolved at finish()
    def label(self, name: str):
        if name in self._labels:
            raise ValueError(
                f"duplicate label {name!r} (first defined at pc "
                f"{self._labels[name]}): a silent redefinition would "
                f"retarget every earlier jump"
            )
        self._labels[name] = len(self.rows)

    def _jump(self, op, a, b, target: str):
        idx = self._emit(op, a, b, 0)
        self._fixups.append((idx, target))
        return idx

    def jeq(self, rs1, rs2, target):
        return self._jump(JEQ, rs1, rs2, target)

    def jne(self, rs1, rs2, target):
        return self._jump(JNE, rs1, rs2, target)

    def jlt(self, rs1, rs2, target):
        return self._jump(JLT, rs1, rs2, target)

    def jle(self, rs1, rs2, target):
        return self._jump(JLE, rs1, rs2, target)

    def jgt(self, rs1, rs2, target):
        return self._jump(JGT, rs1, rs2, target)

    def jge(self, rs1, rs2, target):
        return self._jump(JGE, rs1, rs2, target)

    def jmp(self, target):
        return self._jump(JMP, 0, 0, target)

    def next_iter(self, rs_newptr):
        return self._emit(NEXT_ITER, rs_newptr)

    def ret(self):
        return self._emit(RETURN)

    def finish(self) -> Program:
        code = np.asarray(self.rows, np.int32).reshape(-1, 4)
        for idx, target in self._fixups:
            if target not in self._labels:
                raise ValueError(f"undefined label {target!r}")
            code[idx, 3] = self._labels[target]
        validate(code, self.scratch_words, self.node_words)
        return Program(code, self.scratch_words, self.node_words, self.name)


def validate(code: np.ndarray, scratch_words: int, node_words: int) -> None:
    """Static verifier (the paper's eBPF-style checks, S4.1):
    forward-only jumps, register/scratch/node bounds, terminal reachability,
    and bounded execution (trivially true given forward-only control flow)."""
    T = code.shape[0]
    if T == 0:
        raise ValueError("empty program")
    for i, (op, a, b, imm) in enumerate(code):
        op = int(op)
        if op in _JUMPS:
            if int(imm) <= i:
                raise ValueError(
                    f"backward/self jump at pc={i} -> {int(imm)}: PULSE allows "
                    f"forward jumps only (S4.1); backward edges exist solely "
                    f"via NEXT_ITER"
                )
            if int(imm) > T:
                raise ValueError(f"jump target out of range at pc={i}")
        if op in (LOADN, STOREN, SETPTR) and not (0 <= int(imm) < node_words):
            raise ValueError(f"node index {int(imm)} out of range at pc={i}")
        if op in (LOADS, STORES, ALLOC) and not (0 <= int(imm) < scratch_words):
            raise ValueError(f"scratch index {int(imm)} out of range at pc={i}")
        for r in (int(a), int(b)):
            if op != HALT and not (0 <= r < NUM_REGS):
                raise ValueError(f"register {r} out of range at pc={i}")
        # three-register ALU forms read rs2 from the imm column: it is a
        # register index and must be bounds-checked like a/b (the VM clips
        # at runtime, which would silently read the wrong register)
        if op in (ADD, SUB, MUL, DIV, AND, OR) and not (0 <= int(imm) < NUM_REGS):
            raise ValueError(f"register {int(imm)} out of range at pc={i}")
    # every straight-line path must hit a terminal: cheap sufficient check --
    # the last instruction must be a terminal or an unconditional jump target
    # chain ending in one.  (Forward-only control flow makes this decidable;
    # we enforce the simple form.)
    if int(code[-1, 0]) not in _TERMINALS:
        raise ValueError("program must end in NEXT_ITER or RETURN")


def max_instructions_per_iteration(prog: Program) -> int:
    """Upper bound N on instructions per iteration (forward-only control flow
    => bounded by program length).  Used by the dispatch engine's t_c = t_i*N
    (S4.1)."""
    return len(prog)


def _run_vm(prog_code: jnp.ndarray, node, ptr, scratch):
    """Execute ONE iteration of an encoded program on the logic pipeline.

    Returns ``(done, new_ptr, new_scratch, (m_op, m_tgt, m_mask, m_expect,
    m_data))`` -- the trailing tuple is the staged mutation (all zeros /
    M_NONE for read-only programs).  Pure JAX: lax.while_loop over the pc
    with a lax.switch per opcode, so it jit-compiles and vmaps over a batch
    of workspaces.

    Store-class staging semantics (one mutation per iteration, applied by
    the owning shard's commit phase -- core.commit):
      * STOREN accumulates a masked write-back image of the current node;
      * ALLOC retargets the accumulated image at a fresh free-list slot
        (commit deposits the claimed address into SP[imm]);
      * SETPTR stages the image as a CAS on NODE[imm] (expect rs2);
      * FREE stages the release of the node addressed by rs1.
    """
    from repro.core.arena import M_ALLOC, M_CAS, M_FREE, M_NONE, M_STORE

    T = prog_code.shape[0]
    W = node.shape[0]
    regs0 = jnp.zeros((NUM_REGS,), jnp.int32)

    def cond(st):
        return (~st[5]) & (st[0] < T)

    def body(st):
        pc, regs, scr, out_ptr, done, halted, mop, mtgt, mmask, mexp, mdata = st
        row = jax.lax.dynamic_index_in_dim(prog_code, pc, 0, keepdims=False)
        op, a, b, imm = row[0], row[1], row[2], row[3]
        ra = regs[jnp.clip(a, 0, NUM_REGS - 1)]
        rb = regs[jnp.clip(b, 0, NUM_REGS - 1)]
        rimm = regs[jnp.clip(imm, 0, NUM_REGS - 1)]

        def wr(r, v):
            return regs.at[jnp.clip(r, 0, NUM_REGS - 1)].set(v)

        node_imm = node[jnp.clip(imm, 0, W - 1)]
        scr_imm = scr[jnp.clip(imm, 0, scr.shape[0] - 1)]
        mut = (mop, mtgt, mmask, mexp, mdata)

        def keep(pc2, regs2=None, scr2=None, optr2=None, done2=None, halt2=None,
                 mut2=None):
            return (
                pc2,
                regs if regs2 is None else regs2,
                scr if scr2 is None else scr2,
                out_ptr if optr2 is None else optr2,
                done if done2 is None else done2,
                halted if halt2 is None else halt2,
                *(mut if mut2 is None else mut2),
            )

        # STOREN: accumulate the write-back image; an already-staged ALLOC
        # keeps its op/target (the image IS the new node being built)
        storen_op = jnp.where(mop == M_ALLOC, mop, jnp.int32(M_STORE))
        storen_tgt = jnp.where(mop == M_ALLOC, mtgt, jnp.asarray(ptr, jnp.int32))
        storen_mut = (
            storen_op, storen_tgt,
            mmask | jnp.left_shift(jnp.int32(1), jnp.clip(imm, 0, W - 1)),
            mexp,
            mdata.at[jnp.clip(imm, 0, W - 1)].set(ra),
        )
        alloc_mut = (jnp.int32(M_ALLOC), jnp.asarray(imm, jnp.int32), mmask, mexp, mdata)
        setptr_mut = (
            jnp.int32(M_CAS), jnp.asarray(ptr, jnp.int32),
            jnp.left_shift(jnp.int32(1), jnp.clip(imm, 0, W - 1)),
            rb,
            mdata.at[jnp.clip(imm, 0, W - 1)].set(ra),
        )
        free_mut = (jnp.int32(M_FREE), ra, jnp.int32(0), jnp.int32(0), mdata)

        branches = [
            lambda: keep(pc + 1, halt2=jnp.bool_(True)),  # HALT
            lambda: keep(pc + 1, wr(a, node_imm)),  # LOADN
            lambda: keep(pc + 1, wr(a, scr_imm)),  # LOADS
            lambda: keep(  # STORES
                pc + 1, scr2=scr.at[jnp.clip(imm, 0, scr.shape[0] - 1)].set(ra)
            ),
            lambda: keep(pc + 1, wr(a, rb + rimm)),  # ADD rd=rb+rimm
            lambda: keep(pc + 1, wr(a, rb - rimm)),  # SUB
            lambda: keep(pc + 1, wr(a, rb * rimm)),  # MUL
            lambda: keep(  # DIV (guarded)
                pc + 1,
                wr(a, jnp.where(rimm == 0, 0, rb // jnp.where(rimm == 0, 1, rimm))),
            ),
            lambda: keep(pc + 1, wr(a, rb & rimm)),  # AND
            lambda: keep(pc + 1, wr(a, rb | rimm)),  # OR
            lambda: keep(pc + 1, wr(a, ~rb)),  # NOT
            lambda: keep(pc + 1, wr(a, rb)),  # MOVE
            lambda: keep(pc + 1, wr(a, imm)),  # MOVI
            lambda: keep(jnp.where(ra == rb, imm, pc + 1)),  # JEQ
            lambda: keep(jnp.where(ra != rb, imm, pc + 1)),  # JNE
            lambda: keep(jnp.where(ra < rb, imm, pc + 1)),  # JLT
            lambda: keep(jnp.where(ra <= rb, imm, pc + 1)),  # JLE
            lambda: keep(jnp.where(ra > rb, imm, pc + 1)),  # JGT
            lambda: keep(jnp.where(ra >= rb, imm, pc + 1)),  # JGE
            lambda: keep(imm),  # JMP
            lambda: keep(pc + 1, optr2=ra, halt2=jnp.bool_(True)),  # NEXT_ITER
            lambda: keep(  # RETURN
                pc + 1, done2=jnp.bool_(True), halt2=jnp.bool_(True)
            ),
            lambda: keep(pc + 1, wr(a, ptr)),  # GETPTR
            lambda: keep(pc + 1, mut2=storen_mut),  # STOREN
            lambda: keep(pc + 1, mut2=alloc_mut),  # ALLOC
            lambda: keep(pc + 1, mut2=setptr_mut),  # SETPTR
            lambda: keep(pc + 1, mut2=free_mut),  # FREE
        ]
        sel = jnp.clip(op, 0, len(branches) - 1)
        return jax.lax.switch(sel, branches)

    st0 = (
        jnp.int32(0),
        regs0,
        jnp.asarray(scratch, jnp.int32),
        jnp.asarray(ptr, jnp.int32),
        jnp.bool_(False),
        jnp.bool_(False),
        jnp.int32(0),  # m_op (M_NONE)
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
        jnp.zeros((W,), jnp.int32),
    )
    pc, regs, scr, out_ptr, done, halted, mop, mtgt, mmask, mexp, mdata = (
        jax.lax.while_loop(cond, body, st0)
    )
    return done, out_ptr, scr, (mop, mtgt, mmask, mexp, mdata)


def run_iteration(prog_code: jnp.ndarray, node, ptr, scratch):
    """Read-path VM entry point: (done, new_ptr, new_scratch)."""
    done, out_ptr, scr, _ = _run_vm(prog_code, node, ptr, scratch)
    return done, out_ptr, scr


def run_iteration_mut(prog_code: jnp.ndarray, node, ptr, scratch):
    """Write-path VM entry point: also returns the staged mutation tuple."""
    return _run_vm(prog_code, node, ptr, scratch)


# NOTE on ALU encoding: rows are [op, rd, rs1, rs2-as-imm-field]; the
# three-register ALU forms read rs2 from the imm column (register index).
# The assembler emits them accordingly (see Asm.add/sub/...), and validate()
# bounds-checks the imm column for ALU ops like any other register index.


def as_pulse_iterator(
    prog: Program,
    *,
    verify: bool = True,
    node_ptr_slots=None,
    scratch_ptr_slots=None,
) -> PulseIterator:
    """Wrap an encoded program as a PulseIterator (the accelerator path).

    With ``verify=True`` (the default) the program is admitted through
    pulse-verify (``core.verify``): unsafe programs raise ``VerifyError``
    with instruction-level diagnostics, and accepted ones carry their
    ``ProgramFacts`` certificate on the returned iterator -- the
    reachability-based ``facts.mutates`` decides the read-vs-write path, so
    dead store-class code no longer forces a program onto the mutating
    record format.  ``verify=False`` skips admission and falls back to the
    conservative opcode scan (``Program.mutates``).

    Read-only programs supply the fused ``step_fn`` -- one VM pass yields
    (done, new_ptr, scratch), matching the hardware where a single
    logic-pipeline activation ends in either NEXT_ITER or RETURN.  Programs
    that can reach the store class supply ``mut_fn`` instead, so the
    executors route them through the commit machinery (a mutating program
    on the read path would silently drop its stores).
    """
    facts = None
    if verify:
        from repro.core import verify as verify_mod  # isa<->verify cycle

        facts = verify_mod.verify_program(
            prog,
            node_ptr_slots=node_ptr_slots,
            scratch_ptr_slots=scratch_ptr_slots,
        )
    mutates = facts.mutates if facts is not None else prog.mutates
    code = jnp.asarray(prog.code)

    def next_fn(node, ptr, scratch):
        done, new_ptr, scr = run_iteration(code, node, ptr, scratch)
        return new_ptr, scr

    def end_fn(node, ptr, scratch):
        done, new_ptr, scr = run_iteration(code, node, ptr, scratch)
        return done, scr

    if mutates:
        def mut_fn(node, ptr, scratch):
            return run_iteration_mut(code, node, ptr, scratch)

        mut_fn.__wrapped_program__ = prog  # exact N for the dispatch model
        return PulseIterator(
            scratch_words=prog.scratch_words,
            next_fn=next_fn,
            end_fn=end_fn,
            mut_fn=mut_fn,
            name=prog.name,
            facts=facts,
        )

    def step_fn(node, ptr, scratch):
        done, new_ptr, scr = run_iteration(code, node, ptr, scratch)
        return done, new_ptr, scr

    step_fn.__wrapped_program__ = prog  # exact N for the dispatch cost model

    return PulseIterator(
        scratch_words=prog.scratch_words,
        next_fn=next_fn,
        end_fn=end_fn,
        step_fn=step_fn,
        name=prog.name,
        facts=facts,
    )
