"""PULSE ISA (paper S4.1, Table 2): a stripped RISC subset + VM.

The paper compiles iterator bodies (via an LLVM Sparc-backend port) into a
restricted ISA executed by the accelerator's logic pipeline.  We keep the
exact instruction classes of Table 2 and the eBPF-style *forward-jump-only*
rule, with a tiny assembler DSL standing in for the LLVM backend (the
production path in this repo is traced JAX -- XLA is our compiler toolchain;
the VM exists to (a) validate the bounded-computation contract, (b) give the
dispatch engine an exact instruction count for its t_c model, and (c) run the
paper-faithful microbenchmarks).

Register model (one iterator workspace, S4.2):
  r0..r15         general registers
  NODE[0..W-1]    the aggregated 256 B LOAD result (read via LOADN)
  SP[0..S-1]      scratch_pad words (LOADS/STORES)
  CUR_PTR         read via GETPTR; written only by NEXT_ITER(reg)

An iteration runs from pc=0 until NEXT_ITER (yield new cur_ptr; memory
pipeline takes over) or RETURN (traversal done; scratch_pad is the result).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.iterator import PulseIterator

# opcodes (Table 2)
HALT = 0  # implicit safety stop
LOADN = 1  # rd <- NODE[imm]          (Memory: the per-iteration LOAD's words)
LOADS = 2  # rd <- SP[imm]
STORES = 3  # SP[imm] <- rs1
ADD, SUB, MUL, DIV, AND, OR, NOT = 4, 5, 6, 7, 8, 9, 10  # ALU
MOVE = 11  # rd <- rs1                (Register)
MOVI = 12  # rd <- imm
JEQ, JNE, JLT, JLE, JGT, JGE = 13, 14, 15, 16, 17, 18  # COMPARE+JUMP (fwd)
JMP = 19  # unconditional forward jump
NEXT_ITER = 20  # cur_ptr <- rs1; end iteration (Terminal)
RETURN = 21  # traversal done          (Terminal)
GETPTR = 22  # rd <- CUR_PTR
SELECT = 23  # rd <- rs1 if flag(imm-less cmp result reg) ... not in paper; omit

NUM_REGS = 16
_JUMPS = (JEQ, JNE, JLT, JLE, JGT, JGE, JMP)
_TERMINALS = (NEXT_ITER, RETURN)

OP_NAMES = {
    HALT: "HALT", LOADN: "LOADN", LOADS: "LOADS", STORES: "STORES",
    ADD: "ADD", SUB: "SUB", MUL: "MUL", DIV: "DIV", AND: "AND", OR: "OR",
    NOT: "NOT", MOVE: "MOVE", MOVI: "MOVI", JEQ: "JEQ", JNE: "JNE",
    JLT: "JLT", JLE: "JLE", JGT: "JGT", JGE: "JGE", JMP: "JMP",
    NEXT_ITER: "NEXT_ITER", RETURN: "RETURN", GETPTR: "GETPTR",
}


@dataclasses.dataclass(frozen=True)
class Program:
    """Encoded PULSE program: (T, 4) int32 rows of [op, a, b, imm]."""

    code: np.ndarray
    scratch_words: int
    node_words: int
    name: str = "isa_program"

    def __len__(self) -> int:
        return self.code.shape[0]

    def disasm(self) -> str:
        rows = []
        for i, (op, a, b, imm) in enumerate(self.code):
            rows.append(f"{i:3d}: {OP_NAMES.get(int(op), '?'):9s} a={a} b={b} imm={imm}")
        return "\n".join(rows)


class Asm:
    """Tiny assembler for PULSE programs (the LLVM-backend stand-in)."""

    def __init__(self, scratch_words: int, node_words: int, name="isa_program"):
        self.rows: list[list[int]] = []
        self.scratch_words = scratch_words
        self.node_words = node_words
        self.name = name
        self._labels: dict[str, int] = {}
        self._fixups: list[tuple[int, str]] = []

    def _emit(self, op, a=0, b=0, imm=0):
        self.rows.append([op, a, b, imm])
        return len(self.rows) - 1

    # memory / register ops
    def loadn(self, rd, idx):
        return self._emit(LOADN, rd, 0, idx)

    def loads(self, rd, idx):
        return self._emit(LOADS, rd, 0, idx)

    def stores(self, idx, rs):
        return self._emit(STORES, rs, 0, idx)

    def add(self, rd, rs1, rs2):
        return self._emit(ADD, rd, rs1, rs2)

    def sub(self, rd, rs1, rs2):
        return self._emit(SUB, rd, rs1, rs2)

    def mul(self, rd, rs1, rs2):
        return self._emit(MUL, rd, rs1, rs2)

    def div(self, rd, rs1, rs2):
        return self._emit(DIV, rd, rs1, rs2)

    def and_(self, rd, rs1, rs2):
        return self._emit(AND, rd, rs1, rs2)

    def or_(self, rd, rs1, rs2):
        return self._emit(OR, rd, rs1, rs2)

    def not_(self, rd, rs1):
        return self._emit(NOT, rd, rs1)

    def move(self, rd, rs1):
        return self._emit(MOVE, rd, rs1)

    def movi(self, rd, imm):
        return self._emit(MOVI, rd, 0, imm)

    def getptr(self, rd):
        return self._emit(GETPTR, rd)

    # control flow -- forward only, via labels resolved at finish()
    def label(self, name: str):
        self._labels[name] = len(self.rows)

    def _jump(self, op, a, b, target: str):
        idx = self._emit(op, a, b, 0)
        self._fixups.append((idx, target))
        return idx

    def jeq(self, rs1, rs2, target):
        return self._jump(JEQ, rs1, rs2, target)

    def jne(self, rs1, rs2, target):
        return self._jump(JNE, rs1, rs2, target)

    def jlt(self, rs1, rs2, target):
        return self._jump(JLT, rs1, rs2, target)

    def jle(self, rs1, rs2, target):
        return self._jump(JLE, rs1, rs2, target)

    def jgt(self, rs1, rs2, target):
        return self._jump(JGT, rs1, rs2, target)

    def jge(self, rs1, rs2, target):
        return self._jump(JGE, rs1, rs2, target)

    def jmp(self, target):
        return self._jump(JMP, 0, 0, target)

    def next_iter(self, rs_newptr):
        return self._emit(NEXT_ITER, rs_newptr)

    def ret(self):
        return self._emit(RETURN)

    def finish(self) -> Program:
        code = np.asarray(self.rows, np.int32).reshape(-1, 4)
        for idx, target in self._fixups:
            if target not in self._labels:
                raise ValueError(f"undefined label {target!r}")
            code[idx, 3] = self._labels[target]
        validate(code, self.scratch_words, self.node_words)
        return Program(code, self.scratch_words, self.node_words, self.name)


def validate(code: np.ndarray, scratch_words: int, node_words: int) -> None:
    """Static verifier (the paper's eBPF-style checks, S4.1):
    forward-only jumps, register/scratch/node bounds, terminal reachability,
    and bounded execution (trivially true given forward-only control flow)."""
    T = code.shape[0]
    if T == 0:
        raise ValueError("empty program")
    for i, (op, a, b, imm) in enumerate(code):
        op = int(op)
        if op in _JUMPS:
            if int(imm) <= i:
                raise ValueError(
                    f"backward/self jump at pc={i} -> {int(imm)}: PULSE allows "
                    f"forward jumps only (S4.1); backward edges exist solely "
                    f"via NEXT_ITER"
                )
            if int(imm) > T:
                raise ValueError(f"jump target out of range at pc={i}")
        if op == LOADN and not (0 <= int(imm) < node_words):
            raise ValueError(f"LOADN node index {int(imm)} out of range at pc={i}")
        if op in (LOADS, STORES) and not (0 <= int(imm) < scratch_words):
            raise ValueError(f"scratch index {int(imm)} out of range at pc={i}")
        for r in (int(a), int(b)):
            if op != HALT and not (0 <= r < NUM_REGS):
                raise ValueError(f"register {r} out of range at pc={i}")
    # every straight-line path must hit a terminal: cheap sufficient check --
    # the last instruction must be a terminal or an unconditional jump target
    # chain ending in one.  (Forward-only control flow makes this decidable;
    # we enforce the simple form.)
    if int(code[-1, 0]) not in _TERMINALS:
        raise ValueError("program must end in NEXT_ITER or RETURN")


def max_instructions_per_iteration(prog: Program) -> int:
    """Upper bound N on instructions per iteration (forward-only control flow
    => bounded by program length).  Used by the dispatch engine's t_c = t_i*N
    (S4.1)."""
    return len(prog)


def run_iteration(prog_code: jnp.ndarray, node, ptr, scratch):
    """Execute ONE iteration of an encoded program on the logic pipeline.

    Returns (done, new_ptr, new_scratch).  Pure JAX: lax.while_loop over the
    pc with a lax.switch per opcode, so it jit-compiles and vmaps over a
    batch of workspaces.
    """
    T = prog_code.shape[0]
    regs0 = jnp.zeros((NUM_REGS,), jnp.int32)

    def cond(st):
        pc, regs, scr, out_ptr, done, halted = st
        return (~halted) & (pc < T)

    def body(st):
        pc, regs, scr, out_ptr, done, halted = st
        row = jax.lax.dynamic_index_in_dim(prog_code, pc, 0, keepdims=False)
        op, a, b, imm = row[0], row[1], row[2], row[3]
        ra = regs[jnp.clip(a, 0, NUM_REGS - 1)]
        rb = regs[jnp.clip(b, 0, NUM_REGS - 1)]

        def wr(r, v):
            return regs.at[jnp.clip(r, 0, NUM_REGS - 1)].set(v)

        node_imm = node[jnp.clip(imm, 0, node.shape[0] - 1)]
        scr_imm = scr[jnp.clip(imm, 0, scr.shape[0] - 1)]

        branches = [
            lambda: (pc + 1, regs, scr, out_ptr, done, jnp.bool_(True)),  # HALT
            lambda: (pc + 1, wr(a, node_imm), scr, out_ptr, done, halted),  # LOADN
            lambda: (pc + 1, wr(a, scr_imm), scr, out_ptr, done, halted),  # LOADS
            lambda: (  # STORES
                pc + 1,
                regs,
                scr.at[jnp.clip(imm, 0, scr.shape[0] - 1)].set(ra),
                out_ptr,
                done,
                halted,
            ),
            lambda: (pc + 1, wr(a, regs[jnp.clip(b, 0, NUM_REGS - 1)] + regs[jnp.clip(imm, 0, NUM_REGS - 1)]), scr, out_ptr, done, halted),  # ADD rd=rb+rimm
            lambda: (pc + 1, wr(a, regs[jnp.clip(b, 0, NUM_REGS - 1)] - regs[jnp.clip(imm, 0, NUM_REGS - 1)]), scr, out_ptr, done, halted),  # SUB
            lambda: (pc + 1, wr(a, regs[jnp.clip(b, 0, NUM_REGS - 1)] * regs[jnp.clip(imm, 0, NUM_REGS - 1)]), scr, out_ptr, done, halted),  # MUL
            lambda: (  # DIV (guarded)
                pc + 1,
                wr(
                    a,
                    jnp.where(
                        regs[jnp.clip(imm, 0, NUM_REGS - 1)] == 0,
                        0,
                        regs[jnp.clip(b, 0, NUM_REGS - 1)]
                        // jnp.where(regs[jnp.clip(imm, 0, NUM_REGS - 1)] == 0, 1, regs[jnp.clip(imm, 0, NUM_REGS - 1)]),
                    ),
                ),
                scr,
                out_ptr,
                done,
                halted,
            ),
            lambda: (pc + 1, wr(a, regs[jnp.clip(b, 0, NUM_REGS - 1)] & regs[jnp.clip(imm, 0, NUM_REGS - 1)]), scr, out_ptr, done, halted),  # AND
            lambda: (pc + 1, wr(a, regs[jnp.clip(b, 0, NUM_REGS - 1)] | regs[jnp.clip(imm, 0, NUM_REGS - 1)]), scr, out_ptr, done, halted),  # OR
            lambda: (pc + 1, wr(a, ~rb), scr, out_ptr, done, halted),  # NOT
            lambda: (pc + 1, wr(a, rb), scr, out_ptr, done, halted),  # MOVE
            lambda: (pc + 1, wr(a, imm), scr, out_ptr, done, halted),  # MOVI
            lambda: (jnp.where(ra == rb, imm, pc + 1), regs, scr, out_ptr, done, halted),  # JEQ
            lambda: (jnp.where(ra != rb, imm, pc + 1), regs, scr, out_ptr, done, halted),  # JNE
            lambda: (jnp.where(ra < rb, imm, pc + 1), regs, scr, out_ptr, done, halted),  # JLT
            lambda: (jnp.where(ra <= rb, imm, pc + 1), regs, scr, out_ptr, done, halted),  # JLE
            lambda: (jnp.where(ra > rb, imm, pc + 1), regs, scr, out_ptr, done, halted),  # JGT
            lambda: (jnp.where(ra >= rb, imm, pc + 1), regs, scr, out_ptr, done, halted),  # JGE
            lambda: (imm, regs, scr, out_ptr, done, halted),  # JMP
            lambda: (pc + 1, regs, scr, ra, done, jnp.bool_(True)),  # NEXT_ITER
            lambda: (pc + 1, regs, scr, out_ptr, jnp.bool_(True), jnp.bool_(True)),  # RETURN
            lambda: (pc + 1, wr(a, ptr), scr, out_ptr, done, halted),  # GETPTR
        ]
        sel = jnp.clip(op, 0, len(branches) - 1)
        return jax.lax.switch(sel, branches)

    st0 = (
        jnp.int32(0),
        regs0,
        jnp.asarray(scratch, jnp.int32),
        jnp.asarray(ptr, jnp.int32),
        jnp.bool_(False),
        jnp.bool_(False),
    )
    pc, regs, scr, out_ptr, done, halted = jax.lax.while_loop(cond, body, st0)
    return done, out_ptr, scr


# NOTE on ALU encoding: rows are [op, rd, rs1, rs2-as-imm-field]; the
# three-register ALU forms read rs2 from the imm column (register index).
# The assembler emits them accordingly (see Asm.add/sub/...), and validate()
# bounds-checks the imm column for ALU ops via the register check on a/b and
# the LOADN/LOADS checks; ALU imm indexes are clipped at runtime.


def as_pulse_iterator(prog: Program) -> PulseIterator:
    """Wrap an encoded program as a PulseIterator (the accelerator path).

    Supplies the fused ``step_fn`` -- one VM pass yields (done, new_ptr,
    scratch), matching the hardware where a single logic-pipeline activation
    ends in either NEXT_ITER or RETURN.
    """
    code = jnp.asarray(prog.code)

    def step_fn(node, ptr, scratch):
        done, new_ptr, scr = run_iteration(code, node, ptr, scratch)
        return done, new_ptr, scr

    step_fn.__wrapped_program__ = prog  # exact N for the dispatch cost model

    def next_fn(node, ptr, scratch):
        done, new_ptr, scr = run_iteration(code, node, ptr, scratch)
        return new_ptr, scr

    def end_fn(node, ptr, scratch):
        done, new_ptr, scr = run_iteration(code, node, ptr, scratch)
        return done, scr

    return PulseIterator(
        scratch_words=prog.scratch_words,
        next_fn=next_fn,
        end_fn=end_fn,
        step_fn=step_fn,
        name=prog.name,
    )
