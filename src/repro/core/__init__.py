"""PULSE core: the paper's contribution as a composable JAX library.

Layers (paper section in parens):
  arena        flat disaggregated heap + allocation policies (S2, App. Fig 5)
  translation  hierarchical address translation / protection (S5, Fig. 6)
  iterator     init/next/end + scratch_pad programming model (S3)
  isa          restricted RISC ISA + VM + verifier (S4.1, Table 2)
  dispatch     offload cost model t_c <= eta * t_d (S4.1)
  scheduler    disaggregated m:n pipeline model, Alg. 1 (S4.2)
  routing      in-network switch routing via all_to_all supersteps (S5)
  engine       PulseEngine front door + compared-system baselines (S6)
  structures   ported data structures (S3, Table 5, Appendix B)
"""

from repro.core.arena import (  # noqa: F401
    NULL,
    Arena,
    ArenaBuilder,
    f2i,
    i2f,
    load_node,
    make_arena,
)
from repro.core.dispatch import AcceleratorSpec, offload_decision  # noqa: F401
from repro.core.engine import PulseEngine, cpu_node_execute  # noqa: F401
from repro.core.iterator import (  # noqa: F401
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_FAULT,
    STATUS_MAXED,
    PulseIterator,
    execute_batched,
)
from repro.core.routing import distributed_execute  # noqa: F401
