"""Fault injection for the distributed traversal engine.

Rack-scale disaggregated memory treats memory-node failure as a normal
operating condition, not an exception.  This module is the *test-only* hook
that lets every schedule x fabric combination be exercised under injected
failures:

  * **kill** -- shard ``kill_shard`` dies before superstep ``kill_superstep``
    of engine call ``kill_call``: the executor raises ``ShardFailure``
    *without* publishing any partial state (the engine's arena swap only
    happens on success, so the heap observed after a kill is exactly the
    pre-quantum heap -- the recovery anchor).
  * **drop** -- each record crossing the fabric is independently "lost" with
    probability ``drop_prob``.  Loss is modeled at the link level as
    park-and-retransmit: a dropped record stays on its source shard and is
    retransmitted next superstep, so no traversal state is ever lost -- only
    superstep counts grow.  The seeded mask is a pure function of
    (drop_seed, shard, superstep), so drop runs replay bit-identically.
  * **delay** -- shard ``delay_shard`` sleeps ``delay_s`` before each
    superstep of the dispatched (host-loop) schedule **in which it serves
    work** (an ACTIVE record points into its range), modeling a straggler
    memory node.  Attribution matters: a per-shard watchdog probe to the
    straggler is slow while probes elsewhere are not, so the serving
    layer's heartbeat can name the suspect; and once reads fan out to the
    shard's replica the straggler stops costing anyone anything.

The injector is threaded through ``routing.distributed_execute``,
``commit.sequential_commit_execute`` and ``PulseEngine`` as an optional
argument; production paths pay nothing when it is absent.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative failure schedule for one engine lifetime.

    ``kill_call`` counts engine executions (0-based): a service run makes
    many engine calls, and the plan targets one of them.  ``kill_superstep``
    is 1-based: the failure fires *before* that superstep runs, so exactly
    ``kill_superstep - 1`` supersteps of the targeted call complete.
    """

    kill_shard: int | None = None  # shard that dies (None: no kill)
    kill_call: int = 0  # which engine call the kill targets
    kill_superstep: int = 1  # die before this (1-based) superstep
    drop_prob: float = 0.0  # per-record fabric loss probability
    drop_seed: int = 0  # PRNG seed for the loss mask
    delay_shard: int | None = None  # straggler shard (dispatched path only)
    delay_s: float = 0.0  # straggler delay per superstep it serves work in


class ShardFailure(RuntimeError):
    """An injected (or detected) memory-shard death.

    ``label`` is attached by whoever owns the failing unit of work (the
    DeviceRunner tags it with the work label so the service can tell which
    slot group was in flight).
    """

    def __init__(self, shard: int, superstep: int):
        super().__init__(
            f"shard {shard} died before superstep {superstep}"
        )
        self.shard = shard
        self.superstep = superstep
        self.label: str | None = None


class FaultInjector:
    """Mutable per-run state for a FaultPlan: counts engine calls, fires the
    kill exactly once.  One injector serves a whole service run."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.calls = 0  # engine calls begun
        self.fired = False  # the kill already happened

    def begin_call(self) -> int:
        """Register one engine execution; returns its 0-based index."""
        idx = self.calls
        self.calls += 1
        return idx

    def kill_step(self, call_idx: int) -> int | None:
        """The 1-based superstep before which this call must die, or None
        if this call is not targeted (wrong call, no kill, already fired)."""
        p = self.plan
        if self.fired or p.kill_shard is None or call_idx != p.kill_call:
            return None
        return p.kill_superstep

    def fire(self, superstep: int):
        """Raise the shard death (once)."""
        self.fired = True
        raise ShardFailure(self.plan.kill_shard, superstep)
