"""Disaggregated-accelerator pipeline model (paper S4.2, Appendix Alg. 1).

The paper's accelerator decouples ``m`` logic pipelines from ``n`` memory
pipelines and multiplexes up to ``m + n`` concurrent iterator executions
across them.  There is no FPGA here, so Table 4 / Fig. 10 / Fig. 11 are
reproduced with a discrete-event simulator of the two pipeline classes,
parameterized by the prototype's measured component latencies (Fig. 10).
The TPU-native analogue of this multiplexing -- double-buffered DMA vs
compute waves -- lives in ``repro.kernels.pulse_chase``; this module is the
architecture-level model used for the paper's design-space tables.

Also includes the FPGA area and power fits used by the Table 4 / Fig. 8 /
Fig. 11 benchmarks (documented least-squares fits to the paper's numbers;
clearly model outputs, not measurements).
"""

from __future__ import annotations

import dataclasses
import heapq


@dataclasses.dataclass(frozen=True)
class PipelineParams:
    t_c_ns: float  # logic time per iteration
    t_d_ns: float  # memory fetch time per iteration
    network_ns: float = 426.3  # Fig. 10 request/response path
    scheduler_ns: float = 5.1


@dataclasses.dataclass
class SimResult:
    makespan_ns: float
    throughput_mops: float  # completed traversals / s (in Mops)
    avg_latency_ns: float
    logic_util: float
    mem_util: float


def staggered_start_times(m: int, n: int, t_d_ns: float) -> list[float]:
    """Appendix Algorithm 1: start request i at (i-1) * t_d / n."""
    return [i * t_d_ns / n for i in range(m + n)]


def simulate(
    m: int,
    n: int,
    t_c_ns: float,
    t_d_ns: float,
    *,
    iters_per_request: int,
    num_requests: int,
    concurrency: int | None = None,
    network_ns: float = 426.3,
    scheduler_ns: float = 5.1,
    coupled: bool = False,
) -> SimResult:
    """Event-driven simulation of iterator executions on the accelerator.

    ``coupled=True`` models the traditional multi-core layout (Table 4 top):
    logic+memory pairs are fused into cores, and a request stays on its core,
    so each core serializes fetch and compute with no cross-request overlap
    within the core (the Fig. 4 (top) behaviour).
    """
    if coupled:
        assert m == n, "a coupled core has one logic + one memory pipeline"
        cores = m
        per_req = network_ns + iters_per_request * (t_d_ns + scheduler_ns + t_c_ns)
        # round-robin static assignment
        counts = [num_requests // cores + (1 if i < num_requests % cores else 0)
                  for i in range(cores)]
        makespan = max(c * per_req for c in counts) if num_requests else 0.0
        busy_mem = num_requests * iters_per_request * t_d_ns
        busy_logic = num_requests * iters_per_request * t_c_ns
        lat = per_req  # queueing-free latency (paper reports loaded latency;
        # the benchmark adds queueing from makespan/throughput)
        return SimResult(
            makespan_ns=makespan,
            throughput_mops=num_requests / makespan * 1e3 if makespan else 0.0,
            avg_latency_ns=lat,
            logic_util=busy_logic / (cores * makespan) if makespan else 0.0,
            mem_util=busy_mem / (cores * makespan) if makespan else 0.0,
        )

    # Disaggregated: memory pipes and logic pipes are independent pools.
    # Each request alternates fetch (memory pipe) -> logic (logic pipe),
    # `iters_per_request` times.  The scheduler admits up to m+n in flight
    # (one workspace each, S4.2).
    slots = concurrency or (m + n)
    mem_free = [0.0] * n
    logic_free = [0.0] * m
    heapq.heapify(mem_free)
    heapq.heapify(logic_free)
    finish = []
    start = []
    busy_mem = busy_logic = 0.0
    admit = staggered_start_times(m, n, t_d_ns)
    next_slot_free = [0.0] * slots
    for r in range(num_requests):
        s = r % slots
        t = max(next_slot_free[s], admit[r % len(admit)] if r < slots else 0.0)
        t += network_ns / 2  # request-side network stack
        start.append(t)
        for _ in range(iters_per_request):
            t += scheduler_ns
            mf = heapq.heappop(mem_free)
            t_fetch_start = max(t, mf)
            t = t_fetch_start + t_d_ns
            heapq.heappush(mem_free, t)
            busy_mem += t_d_ns
            lf = heapq.heappop(logic_free)
            t_logic_start = max(t, lf)
            t = t_logic_start + t_c_ns
            heapq.heappush(logic_free, t)
            busy_logic += t_c_ns
        t += network_ns / 2  # response-side network stack
        finish.append(t)
        next_slot_free[s] = t
    makespan = max(finish) if finish else 0.0
    lat = sum(f - s for f, s in zip(finish, start)) / len(finish) if finish else 0.0
    return SimResult(
        makespan_ns=makespan,
        throughput_mops=num_requests / makespan * 1e3 if makespan else 0.0,
        avg_latency_ns=lat,
        logic_util=busy_logic / (m * makespan) if makespan else 0.0,
        mem_util=busy_mem / (n * makespan) if makespan else 0.0,
    )


# --------------------------- area & power fits ------------------------------

# Least-squares-style fits to Table 4 (FPGA resource %, Alveo U250).  The
# coupled design folds pipeline pairs into cores; PULSE pays a small
# scheduler/interconnect overhead that grows with m*n.
def area_coupled(cores: int) -> tuple[float, float]:
    lut = 3.55 + 3.76 * cores
    bram = 4.70 + 3.22 * cores
    return lut, bram


def area_pulse(m: int, n: int) -> tuple[float, float]:
    lut = 1.60 + 2.95 * m + 1.15 * n + 0.28 * m * n
    bram = 5.90 + 1.25 * m + 1.05 * n
    return lut, bram


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Component power (W).  FPGA values sized so the Fig. 8 ratios
    (PULSE ~4.5-5x less energy/op than CPU RPC; ASIC another ~6.3-7x on the
    accelerator share; wimpy ARM worse than CPU at equal work) reproduce.
    Clearly a model -- no RAPL/XRT in this container."""

    static_w: float = 14.0  # board + shell + network IPs
    logic_pipe_w: float = 1.8
    mem_pipe_w: float = 2.6
    dram_w: float = 9.0
    cpu_pkg_w: float = 150.0  # Xeon Gold 6240 under load (RPC baseline)
    cpu_idle_frac: float = 0.35
    arm_pkg_w: float = 22.0  # BlueField-2 8xA72
    asic_scale: float = 6.6  # Kuon-Rose FPGA->ASIC dynamic-power scaling

    def pulse_power_w(self, m: int, n: int, logic_util: float, mem_util: float) -> float:
        return (
            self.static_w
            + self.dram_w
            + self.logic_pipe_w * m * (0.35 + 0.65 * logic_util)
            + self.mem_pipe_w * n * (0.35 + 0.65 * mem_util)
        )

    def pulse_asic_power_w(self, m, n, logic_util, mem_util) -> float:
        accel = (
            self.logic_pipe_w * m * (0.35 + 0.65 * logic_util)
            + self.mem_pipe_w * n * (0.35 + 0.65 * mem_util)
            + self.static_w * 0.5  # accelerator share of static
        )
        other = self.static_w * 0.5 + self.dram_w
        return accel / self.asic_scale + other

    def cpu_power_w(self, cores_used: int, total_cores: int = 18) -> float:
        frac = cores_used / total_cores
        return self.cpu_pkg_w * (self.cpu_idle_frac + (1 - self.cpu_idle_frac) * frac)

    def arm_power_w(self, cores_used: int, total_cores: int = 8) -> float:
        frac = cores_used / total_cores
        return self.arm_pkg_w * (0.5 + 0.5 * frac)
