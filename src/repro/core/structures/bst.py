"""STL ``map::find`` via ``_M_lower_bound`` (paper Listings 10-11).

The identical traversal shape covers Boost AVL / splay / scapegoat trees
(``lower_bound_loop``, Listings 12-13) -- only the balancing differs, which
is invisible to the read path.  Node layout (W=4): [key, value, left, right].
The lower-bound candidate ``y`` lives in the scratch pad (a pointer carried
as traversal state -- the paper's continuation argument).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.arena import M_NONE, M_STORE, NULL, ArenaBuilder
from repro.core.iterator import PulseIterator

NODE_WORDS = 4
KEY, VALUE, LEFT, RIGHT = 0, 1, 2, 3
KEY_NOT_FOUND = -(2**31) + 1

# scratch: [search_key, y_ptr, y_key, y_value]
S_KEY, S_Y, S_YKEY, S_YVAL = 0, 1, 2, 3
SCRATCH_WORDS = 4


def build_into(b: ArenaBuilder, keys: np.ndarray, values: np.ndarray):
    """Builds a balanced BST into a (possibly shared) heap; returns
    (root_ptr, height)."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    order = np.argsort(keys, kind="stable")
    keys, values = keys[order], values[order]
    n = len(keys)
    ptrs = b.alloc(n)
    rec = np.zeros((n, NODE_WORDS), np.int32)

    # level-order balanced build so 'sequential' allocation keeps top levels
    # together (partitioned-allocation experiments rely on this)
    slot = [0]
    height = [0]

    def place(lo, hi, depth):  # returns ptr of subtree root over keys[lo:hi)
        if lo >= hi:
            return NULL
        height[0] = max(height[0], depth + 1)
        mid = (lo + hi) // 2
        my = slot[0]
        slot[0] += 1
        rec[my, KEY] = keys[mid]
        rec[my, VALUE] = values[mid]
        rec[my, LEFT] = place(lo, mid, depth + 1)
        rec[my, RIGHT] = place(mid + 1, hi, depth + 1)
        return int(ptrs[my])

    import sys

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old, 4 * (n.bit_length() + 2) * 64 + 10_000))
    root = place(0, n, 0)
    sys.setrecursionlimit(old)
    b.write(ptrs, rec)
    return root, height[0]


def build(
    keys: np.ndarray,
    values: np.ndarray,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Builds a balanced BST (median split). Returns (arena, root_ptr, height)."""
    n = len(keys)
    cap = capacity or max(num_shards, ((n + num_shards - 1) // num_shards) * num_shards)
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    root, height = build_into(b, keys, values)
    return b.finish(), root, height


def find_iterator() -> PulseIterator:
    """``map::find`` as lower-bound descent (Listing 11): walk to NULL while
    tracking the smallest node with key >= search key, then compare."""

    def init(search_keys, root_ptr):
        sk = jnp.asarray(search_keys, jnp.int32)
        B = sk.shape[0]
        scratch = jnp.zeros((B, SCRATCH_WORDS), jnp.int32)
        scratch = scratch.at[:, S_KEY].set(sk)
        scratch = scratch.at[:, S_Y].set(NULL)
        scratch = scratch.at[:, S_YVAL].set(KEY_NOT_FOUND)
        return jnp.full((B,), root_ptr, jnp.int32), scratch

    def next_fn(node, ptr, scratch):
        # Listing 11: if key <= node.key -> remember y, go left; else right.
        goes_left = scratch[S_KEY] <= node[KEY]
        scratch = scratch.at[S_Y].set(jnp.where(goes_left, ptr, scratch[S_Y]))
        scratch = scratch.at[S_YKEY].set(
            jnp.where(goes_left, node[KEY], scratch[S_YKEY])
        )
        scratch = scratch.at[S_YVAL].set(
            jnp.where(goes_left, node[VALUE], scratch[S_YVAL])
        )
        nxt = jnp.where(goes_left, node[LEFT], node[RIGHT])
        return nxt, scratch

    def end_fn(node, ptr, scratch):
        # Terminate when the *next* hop would be NULL.  (The executor treats a
        # NULL cur_ptr as a fault, so we stop one step early, mirroring
        # ``while (x != 0)``.)
        goes_left = scratch[S_KEY] <= node[KEY]
        nxt = jnp.where(goes_left, node[LEFT], node[RIGHT])
        upd = scratch
        upd = upd.at[S_Y].set(jnp.where(goes_left, ptr, scratch[S_Y]))
        upd = upd.at[S_YKEY].set(jnp.where(goes_left, node[KEY], scratch[S_YKEY]))
        upd = upd.at[S_YVAL].set(jnp.where(goes_left, node[VALUE], scratch[S_YVAL]))
        done = nxt == NULL
        return done, jnp.where(done, upd, scratch)

    return PulseIterator(SCRATCH_WORDS, next_fn, end_fn, init, name="bst_find")


# ------------------------------ write path ---------------------------------

# update scratch: [key, new_value, state, found]
U_KEY, U_VAL, U_ST, U_FOUND = range(4)
U_WORDS = 4


def update_iterator() -> PulseIterator:
    """``map::operator[]``-style update-in-place: classic BST search descent;
    on the matching node, stage a masked STORE of the VALUE word, then
    validate on the post-commit iteration (a racing writer to the same node
    serializes through the commit phase's (slot, id) order -- the loser
    observes the foreign value and restages, so the last committed write
    wins deterministically).  ``init(keys, values, root)``; scratch[U_FOUND]
    reports whether the key existed."""

    def init(keys, values, root_ptr):
        keys = jnp.asarray(keys, jnp.int32)
        B = keys.shape[0]
        scratch = jnp.zeros((B, U_WORDS), jnp.int32)
        scratch = scratch.at[:, U_KEY].set(keys)
        scratch = scratch.at[:, U_VAL].set(jnp.asarray(values, jnp.int32))
        return jnp.full((B,), root_ptr, jnp.int32), scratch

    def mut_fn(node, ptr, scratch):
        W = node.shape[0]
        key = scratch[U_KEY]
        val = scratch[U_VAL]
        st = scratch[U_ST]
        zeros = jnp.zeros((W,), jnp.int32)
        hit = node[KEY] == key
        nxt = jnp.where(key < node[KEY], node[LEFT], node[RIGHT])
        s0, s1 = st == 0, st == 1
        stage = (s0 & hit) | (s1 & (node[VALUE] != val))  # write or re-stage
        updated = s1 & (node[VALUE] == val)
        miss = s0 & ~hit & (nxt == NULL)
        done = miss | updated
        advance = s0 & ~hit & ~miss
        new_ptr = jnp.where(advance, nxt, ptr).astype(jnp.int32)
        new_scratch = scratch.at[U_ST].set(jnp.where(stage & s0, 1, st))
        new_scratch = new_scratch.at[U_FOUND].set(
            jnp.where(updated, 1, jnp.where(miss, 0, scratch[U_FOUND]))
        )
        m_op = jnp.where(stage, M_STORE, M_NONE).astype(jnp.int32)
        m_tgt = jnp.where(stage, ptr, 0).astype(jnp.int32)
        m_mask = jnp.where(stage, jnp.int32(1 << VALUE), 0)
        m_data = jnp.where(stage[..., None], zeros.at[VALUE].set(val), zeros)
        return done, new_ptr, new_scratch, (
            m_op, m_tgt, m_mask, jnp.int32(0), m_data.astype(jnp.int32)
        )

    return PulseIterator(
        scratch_words=U_WORDS,
        next_fn=lambda node, ptr, scratch: (
            jnp.where(scratch[U_KEY] < node[KEY], node[LEFT], node[RIGHT]), scratch
        ),
        end_fn=lambda node, ptr, scratch: (node[KEY] == scratch[U_KEY], scratch),
        init_fn=init,
        mut_fn=mut_fn,
        name="bst_update",
    )


def result(scratch: jnp.ndarray):
    """CPU-node finalize: found iff lower-bound key equals the search key."""
    found = (scratch[..., S_Y] != NULL) & (scratch[..., S_YKEY] == scratch[..., S_KEY])
    value = jnp.where(found, scratch[..., S_YVAL], KEY_NOT_FOUND)
    return value, found


# ------------------------------- references --------------------------------


def ref_find(keys, values, search_keys):
    d = {int(k): int(v) for k, v in zip(keys, values)}
    return [(d.get(int(k), KEY_NOT_FOUND), int(int(k) in d)) for k in search_keys]
