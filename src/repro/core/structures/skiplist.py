"""Skip list with PULSE-friendly fat pointers (beyond-paper structure).

A classic skip-list search compares the *successor's* key before advancing,
which would need two loads per hop.  PULSE's single-aggregated-LOAD rule
(S4.1) motivates a near-memory-friendly layout that caches each successor's
key next to its pointer ("fat pointers"), the same co-design trick as the
disaggregated-native structures the paper cites (Sherman/ROLEX, S2.2):

  node (W=12): [key, value, (next_ptr[l], next_key[l]) for l in 0..3, pad, pad]

One load per hop then suffices: pick the highest level whose cached successor
key does not overshoot the target.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.arena import (
    M_ALLOC,
    M_CAS,
    M_FREE,
    M_NONE,
    M_STORE,
    NULL,
    ArenaBuilder,
)
from repro.core.iterator import PulseIterator

LEVELS = 4
NODE_WORDS = 12
KEY, VALUE = 0, 1
NPTR0 = 2  # next ptrs at words 2,4,6,8 ; next keys at 3,5,7,9
KEY_NOT_FOUND = -(2**31) + 1
INT_MAX = 2**31 - 1
SCRATCH_WORDS = 3  # [target, value, found]


def _level_of(i: int) -> int:
    """Deterministic geometric(1/4) level from a hashed index."""
    h = (i * 2654435761) & 0xFFFFFFFF
    lvl = 0
    while lvl < LEVELS - 1 and (h & 3) == 3:
        lvl += 1
        h >>= 2
    return lvl


def build_into(b: ArenaBuilder, keys: np.ndarray, values: np.ndarray) -> int:
    """Builds the skip list into a (possibly shared) heap; returns head_ptr."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    order = np.argsort(keys, kind="stable")
    keys, values = keys[order], values[order]
    n = len(keys)
    total = n + 1  # + head
    ptrs = b.alloc(total)  # ptrs[0] = head, ptrs[1+i] = i-th key
    levels = np.array([LEVELS - 1] + [_level_of(i) for i in range(n)])
    rec = np.zeros((total, NODE_WORDS), np.int32)
    rec[0, KEY] = -(2**31)
    rec[1:, KEY] = keys
    rec[1:, VALUE] = values
    # default: no successor
    for l in range(LEVELS):
        rec[:, NPTR0 + 2 * l] = NULL
        rec[:, NPTR0 + 2 * l + 1] = INT_MAX
    # link each level
    for l in range(LEVELS):
        chain = [0] + [i + 1 for i in range(n) if levels[i + 1] >= l]
        for a, bnode in zip(chain[:-1], chain[1:]):
            rec[a, NPTR0 + 2 * l] = ptrs[bnode]
            rec[a, NPTR0 + 2 * l + 1] = rec[bnode, KEY]
    b.write(ptrs, rec)
    return int(ptrs[0])


def build(
    keys: np.ndarray,
    values: np.ndarray,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Builds from sorted keys; returns (arena, head_ptr)."""
    total = len(keys) + 1  # + head
    cap = capacity or max(
        num_shards, ((total + num_shards - 1) // num_shards) * num_shards
    )
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    head = build_into(b, keys, values)
    return b.finish(), head


def find_iterator() -> PulseIterator:
    def init(search_keys, head_ptr):
        sk = jnp.asarray(search_keys, jnp.int32)
        B = sk.shape[0]
        scratch = jnp.zeros((B, SCRATCH_WORDS), jnp.int32)
        scratch = scratch.at[:, 0].set(sk)
        scratch = scratch.at[:, 1].set(KEY_NOT_FOUND)
        return jnp.full((B,), head_ptr, jnp.int32), scratch

    def _advance(node, target):
        nkeys = jnp.stack([node[NPTR0 + 2 * l + 1] for l in range(LEVELS)])
        nptrs = jnp.stack([node[NPTR0 + 2 * l] for l in range(LEVELS)])
        ok = nkeys <= target  # safe to jump at these levels
        # highest safe level = longest jump
        lvl = (LEVELS - 1) - jnp.argmax(ok[::-1]).astype(jnp.int32)
        can = ok.any()
        return can, jnp.where(can, nptrs[lvl], NULL)

    def next_fn(node, ptr, scratch):
        _, nxt = _advance(node, scratch[0])
        return nxt, scratch

    def end_fn(node, ptr, scratch):
        target = scratch[0]
        hit = node[KEY] == target
        can, _ = _advance(node, target)
        done = hit | ~can  # found, or stuck (no successor <= target)
        scratch = scratch.at[1].set(
            jnp.where(hit, node[VALUE], jnp.int32(KEY_NOT_FOUND))
        )
        scratch = scratch.at[2].set(hit.astype(jnp.int32))
        return done, scratch

    return PulseIterator(SCRATCH_WORDS, next_fn, end_fn, init, name="skiplist_find")


def ref_find(keys, values, search_keys):
    d = {int(k): int(v) for k, v in zip(keys, values)}
    return [(d.get(int(k), KEY_NOT_FOUND), int(int(k) in d)) for k in search_keys]


# ------------------------------ write path ---------------------------------
#
# Runtime inserts link at level 0 only: the new node is a full tower record
# (upper levels empty), reachable through every search path because level 0
# is the ground truth list; upper levels merely shortcut.  Runtime deletes
# are therefore valid for level-0 nodes (everything inserted at runtime);
# deleting a build-time node with a taller tower would leave stale tower
# links -- per-node lock/tower-repair is future work (see README).

# insert scratch: [key, value, state, new_ptr, succ_ptr]
SI_KEY, SI_VAL, SI_ST, SI_RES, SI_SUCC = range(5)
SI_WORDS = 5
# delete scratch: [key, state, prev, victim, victim_next0, result]
SD_KEY, SD_ST, SD_PREV, SD_VICTIM, SD_VNEXT, SD_RES = range(6)
SD_WORDS = 6

_LINK_MASK = (1 << NPTR0) | (1 << (NPTR0 + 1))  # (next_ptr0, next_key0)


def _advance_strict(node, key):
    """Pred walk: longest jump to a node with key strictly below ``key``."""
    nkeys = jnp.stack([node[NPTR0 + 2 * l + 1] for l in range(LEVELS)])
    nptrs = jnp.stack([node[NPTR0 + 2 * l] for l in range(LEVELS)])
    ok = nkeys < key
    lvl = (LEVELS - 1) - jnp.argmax(ok[::-1]).astype(jnp.int32)
    return ok.any(), jnp.where(ok.any(), nptrs[lvl], NULL)


def insert_iterator() -> PulseIterator:
    """Optimistic level-0 insert with fat-pointer maintenance: descend to the
    strict predecessor, ALLOC the new tower (level-0 links copied from the
    pred's cached fat pointer), then CAS the pred's (next_ptr0, next_key0)
    pair; a lost race is observed at the pred and repaired by re-fixing the
    new node's own links (blind STORE -- it is unreachable until linked) and
    re-CASing.  Duplicate keys free the allocated node and report found=0."""

    def init(keys, values, head_ptr):
        keys = jnp.asarray(keys, jnp.int32)
        B = keys.shape[0]
        scratch = jnp.zeros((B, SI_WORDS), jnp.int32)
        scratch = scratch.at[:, SI_KEY].set(keys)
        scratch = scratch.at[:, SI_VAL].set(jnp.asarray(values, jnp.int32))
        return jnp.full((B,), head_ptr, jnp.int32), scratch

    def mut_fn(node, ptr, scratch):
        W = node.shape[0]
        key = scratch[SI_KEY]
        val = scratch[SI_VAL]
        st = scratch[SI_ST]
        zeros = jnp.zeros((W,), jnp.int32)
        can_adv, nxt = _advance_strict(node, key)
        next0, nkey0 = node[NPTR0], node[NPTR0 + 1]
        at_pred = ~can_adv
        dup = at_pred & (nkey0 == key)
        s0, s1, s3 = st == 0, st == 1, st == 3

        # state 0: descend; at the pred, ALLOC the tower (or bail on dup)
        stage_alloc = s0 & at_pred & ~dup
        tower = zeros.at[KEY].set(key).at[VALUE].set(val)
        tower = tower.at[NPTR0].set(next0).at[NPTR0 + 1].set(nkey0)
        for l in range(1, LEVELS):
            tower = tower.at[NPTR0 + 2 * l].set(NULL)
            tower = tower.at[NPTR0 + 2 * l + 1].set(INT_MAX)
        tower_mask = (1 << (2 + 2 * LEVELS)) - 1  # words 0 .. 1+2*LEVELS

        # state 1: at the pred with an allocated node
        linked = s1 & (next0 == scratch[SI_RES])
        dup_won = s1 & at_pred & ~linked & dup  # someone linked our key
        succ_stale = s1 & at_pred & ~linked & ~dup & (next0 != scratch[SI_SUCC])
        stage_fix = succ_stale  # blind STORE: our node is still unreachable
        fix_data = zeros.at[NPTR0].set(next0).at[NPTR0 + 1].set(nkey0)
        stage_cas = s1 & at_pred & ~linked & ~dup & (next0 == scratch[SI_SUCC])
        cas_data = zeros.at[NPTR0].set(scratch[SI_RES]).at[NPTR0 + 1].set(key)
        stage_free = dup_won  # give the unused slot back
        done = (s0 & dup) | linked | s3

        advance = (s0 | s1) & can_adv & ~done
        new_ptr = jnp.where(advance, nxt, ptr).astype(jnp.int32)
        new_scratch = scratch
        new_scratch = new_scratch.at[SI_ST].set(
            jnp.where(stage_alloc, 1, jnp.where(stage_free, 3, st))
        )
        new_scratch = new_scratch.at[SI_SUCC].set(
            jnp.where(stage_alloc | stage_fix, next0, scratch[SI_SUCC])
        )

        m_op = jnp.where(
            stage_alloc, M_ALLOC,
            jnp.where(stage_cas, M_CAS,
                      jnp.where(stage_fix, M_STORE,
                                jnp.where(stage_free, M_FREE, M_NONE))),
        ).astype(jnp.int32)
        m_tgt = jnp.where(
            stage_alloc, jnp.int32(SI_RES),
            jnp.where(stage_cas, ptr,
                      jnp.where(stage_fix | stage_free, scratch[SI_RES], 0)),
        ).astype(jnp.int32)
        m_mask = jnp.where(
            stage_alloc, jnp.int32(tower_mask),
            jnp.where(stage_cas | stage_fix, jnp.int32(_LINK_MASK), 0),
        )
        m_expect = jnp.where(stage_cas, scratch[SI_SUCC], jnp.int32(0))
        m_data = jnp.where(
            stage_alloc[..., None], tower,
            jnp.where(stage_cas[..., None], cas_data,
                      jnp.where(stage_fix[..., None], fix_data, zeros)),
        ).astype(jnp.int32)
        return done, new_ptr, new_scratch, (m_op, m_tgt, m_mask, m_expect, m_data)

    return PulseIterator(
        scratch_words=SI_WORDS,
        next_fn=lambda node, ptr, scratch: (
            _advance_strict(node, scratch[SI_KEY])[1], scratch
        ),
        end_fn=lambda node, ptr, scratch: (
            ~_advance_strict(node, scratch[SI_KEY])[0], scratch
        ),
        init_fn=init,
        mut_fn=mut_fn,
        name="skiplist_insert",
    )


def delete_iterator() -> PulseIterator:
    """Unlink a level-0 node: descend to the strict pred, hop to the victim
    to read its level-0 links, CAS the pred's fat pointer past it, validate,
    then FREE the slot.  ``init(keys, head_ptr)``; scratch[SD_RES] reports
    success (absent keys report 0)."""

    def init(keys, head_ptr):
        keys = jnp.asarray(keys, jnp.int32)
        B = keys.shape[0]
        scratch = jnp.zeros((B, SD_WORDS), jnp.int32)
        scratch = scratch.at[:, SD_KEY].set(keys)
        return jnp.full((B,), head_ptr, jnp.int32), scratch

    def mut_fn(node, ptr, scratch):
        W = node.shape[0]
        key = scratch[SD_KEY]
        st = scratch[SD_ST]
        zeros = jnp.zeros((W,), jnp.int32)
        can_adv, nxt = _advance_strict(node, key)
        next0, nkey0 = node[NPTR0], node[NPTR0 + 1]
        at_pred = ~can_adv
        s0, s1, s2, s3 = st == 0, st == 1, st == 2, st == 3

        # state 0: descend to pred; hop to the victim (or miss)
        found = s0 & at_pred & (nkey0 == key)
        miss = s0 & at_pred & (nkey0 != key)
        # state 1: at the victim -- read its links, CAS the pred past it
        stage_cas = s1
        cas_data = zeros.at[NPTR0].set(next0).at[NPTR0 + 1].set(nkey0)
        # state 2: back at the pred -- validate the swing
        swung = s2 & (next0 == scratch[SD_VNEXT])
        refind = s2 & ~swung  # lost the race: walk again from the pred
        stage_free = swung
        done = miss | s3

        advance = s0 & can_adv
        new_ptr = jnp.where(
            advance, nxt,
            jnp.where(found, next0,  # hop to the victim
                      jnp.where(stage_cas, scratch[SD_PREV], ptr)),
        ).astype(jnp.int32)
        new_scratch = scratch
        new_scratch = new_scratch.at[SD_PREV].set(
            jnp.where(found, ptr, scratch[SD_PREV])
        )
        new_scratch = new_scratch.at[SD_VICTIM].set(
            jnp.where(found, next0, scratch[SD_VICTIM])
        )
        new_scratch = new_scratch.at[SD_VNEXT].set(
            jnp.where(stage_cas, next0, scratch[SD_VNEXT])
        )
        new_scratch = new_scratch.at[SD_ST].set(
            jnp.where(found, 1,
                      jnp.where(stage_cas, 2,
                                jnp.where(swung, 3, jnp.where(refind, 0, st))))
        )
        new_scratch = new_scratch.at[SD_RES].set(
            jnp.where(s3, 1, scratch[SD_RES])
        )

        m_op = jnp.where(
            stage_cas, M_CAS, jnp.where(stage_free, M_FREE, M_NONE)
        ).astype(jnp.int32)
        m_tgt = jnp.where(
            stage_cas, scratch[SD_PREV],
            jnp.where(stage_free, scratch[SD_VICTIM], 0),
        ).astype(jnp.int32)
        m_mask = jnp.where(stage_cas, jnp.int32(_LINK_MASK), 0)
        m_expect = jnp.where(stage_cas, scratch[SD_VICTIM], jnp.int32(0))
        m_data = jnp.where(stage_cas[..., None], cas_data, zeros).astype(jnp.int32)
        return done, new_ptr, new_scratch, (m_op, m_tgt, m_mask, m_expect, m_data)

    return PulseIterator(
        scratch_words=SD_WORDS,
        next_fn=lambda node, ptr, scratch: (
            _advance_strict(node, scratch[SD_KEY])[1], scratch
        ),
        end_fn=lambda node, ptr, scratch: (
            ~_advance_strict(node, scratch[SD_KEY])[0], scratch
        ),
        init_fn=init,
        mut_fn=mut_fn,
        name="skiplist_delete",
    )
