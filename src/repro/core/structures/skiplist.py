"""Skip list with PULSE-friendly fat pointers (beyond-paper structure).

A classic skip-list search compares the *successor's* key before advancing,
which would need two loads per hop.  PULSE's single-aggregated-LOAD rule
(S4.1) motivates a near-memory-friendly layout that caches each successor's
key next to its pointer ("fat pointers"), the same co-design trick as the
disaggregated-native structures the paper cites (Sherman/ROLEX, S2.2):

  node (W=12): [key, value, (next_ptr[l], next_key[l]) for l in 0..3, pad, pad]

One load per hop then suffices: pick the highest level whose cached successor
key does not overshoot the target.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.arena import NULL, ArenaBuilder
from repro.core.iterator import PulseIterator

LEVELS = 4
NODE_WORDS = 12
KEY, VALUE = 0, 1
NPTR0 = 2  # next ptrs at words 2,4,6,8 ; next keys at 3,5,7,9
KEY_NOT_FOUND = -(2**31) + 1
INT_MAX = 2**31 - 1
SCRATCH_WORDS = 3  # [target, value, found]


def _level_of(i: int) -> int:
    """Deterministic geometric(1/4) level from a hashed index."""
    h = (i * 2654435761) & 0xFFFFFFFF
    lvl = 0
    while lvl < LEVELS - 1 and (h & 3) == 3:
        lvl += 1
        h >>= 2
    return lvl


def build_into(b: ArenaBuilder, keys: np.ndarray, values: np.ndarray) -> int:
    """Builds the skip list into a (possibly shared) heap; returns head_ptr."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    order = np.argsort(keys, kind="stable")
    keys, values = keys[order], values[order]
    n = len(keys)
    total = n + 1  # + head
    ptrs = b.alloc(total)  # ptrs[0] = head, ptrs[1+i] = i-th key
    levels = np.array([LEVELS - 1] + [_level_of(i) for i in range(n)])
    rec = np.zeros((total, NODE_WORDS), np.int32)
    rec[0, KEY] = -(2**31)
    rec[1:, KEY] = keys
    rec[1:, VALUE] = values
    # default: no successor
    for l in range(LEVELS):
        rec[:, NPTR0 + 2 * l] = NULL
        rec[:, NPTR0 + 2 * l + 1] = INT_MAX
    # link each level
    for l in range(LEVELS):
        chain = [0] + [i + 1 for i in range(n) if levels[i + 1] >= l]
        for a, bnode in zip(chain[:-1], chain[1:]):
            rec[a, NPTR0 + 2 * l] = ptrs[bnode]
            rec[a, NPTR0 + 2 * l + 1] = rec[bnode, KEY]
    b.write(ptrs, rec)
    return int(ptrs[0])


def build(
    keys: np.ndarray,
    values: np.ndarray,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Builds from sorted keys; returns (arena, head_ptr)."""
    total = len(keys) + 1  # + head
    cap = capacity or max(
        num_shards, ((total + num_shards - 1) // num_shards) * num_shards
    )
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    head = build_into(b, keys, values)
    return b.finish(), head


def find_iterator() -> PulseIterator:
    def init(search_keys, head_ptr):
        sk = jnp.asarray(search_keys, jnp.int32)
        B = sk.shape[0]
        scratch = jnp.zeros((B, SCRATCH_WORDS), jnp.int32)
        scratch = scratch.at[:, 0].set(sk)
        scratch = scratch.at[:, 1].set(KEY_NOT_FOUND)
        return jnp.full((B,), head_ptr, jnp.int32), scratch

    def _advance(node, target):
        nkeys = jnp.stack([node[NPTR0 + 2 * l + 1] for l in range(LEVELS)])
        nptrs = jnp.stack([node[NPTR0 + 2 * l] for l in range(LEVELS)])
        ok = nkeys <= target  # safe to jump at these levels
        # highest safe level = longest jump
        lvl = (LEVELS - 1) - jnp.argmax(ok[::-1]).astype(jnp.int32)
        can = ok.any()
        return can, jnp.where(can, nptrs[lvl], NULL)

    def next_fn(node, ptr, scratch):
        _, nxt = _advance(node, scratch[0])
        return nxt, scratch

    def end_fn(node, ptr, scratch):
        target = scratch[0]
        hit = node[KEY] == target
        can, _ = _advance(node, target)
        done = hit | ~can  # found, or stuck (no successor <= target)
        scratch = scratch.at[1].set(
            jnp.where(hit, node[VALUE], jnp.int32(KEY_NOT_FOUND))
        )
        scratch = scratch.at[2].set(hit.astype(jnp.int32))
        return done, scratch

    return PulseIterator(SCRATCH_WORDS, next_fn, end_fn, init, name="skiplist_find")


def ref_find(keys, values, search_keys):
    d = {int(k): int(v) for k, v in zip(keys, values)}
    return [(d.get(int(k), KEY_NOT_FOUND), int(int(k) in d)) for k in search_keys]
