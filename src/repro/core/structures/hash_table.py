"""Bucket-chained hash table: ``unordered_map::find`` (paper Listings 2-3).

``init()`` runs on the CPU node: it hashes the key and resolves the bucket
head pointer (the paper computes ``bucket_ptr(hash(key))`` in ``init``).  The
chain walk is the offloaded traversal.  Node layout (W=4):
``[key, value, next, pad]``.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.arena import NULL, ArenaBuilder
from repro.core.iterator import PulseIterator
from repro.core.structures import linked_list

NODE_WORDS = 4
KEY, VALUE, NEXT = 0, 1, 2
SCRATCH_WORDS = 3  # [search_key, result_value, found]
KEY_NOT_FOUND = -(2**31) + 1

_MULT = np.int64(2654435761)  # Knuth multiplicative hash


def hash_fn(key, n_buckets: int):
    """32-bit multiplicative hash; identical in numpy and jnp."""
    if isinstance(key, (int, np.integer)) or isinstance(key, np.ndarray):
        h = (np.int64(key) * _MULT) & np.int64(0x7FFFFFFF)
        return (h % n_buckets).astype(np.int32) if isinstance(h, np.ndarray) else np.int32(h % n_buckets)
    h = (jnp.asarray(key, jnp.uint32) * jnp.uint32(2654435761)) & jnp.uint32(0x7FFFFFFF)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def _np_hash(keys: np.ndarray, n_buckets: int) -> np.ndarray:
    h = (keys.astype(np.uint32) * np.uint32(2654435761)) & np.uint32(0x7FFFFFFF)
    return (h % np.uint32(n_buckets)).astype(np.int32)


def build_into(
    b: ArenaBuilder, keys: np.ndarray, values: np.ndarray, n_buckets: int
) -> np.ndarray:
    """Builds the bucket chains into a (possibly shared) heap; returns the
    bucket-head pointer array (n_buckets,) int32."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    n = len(keys)
    ptrs = b.alloc(n)
    heads = np.full(n_buckets, NULL, np.int32)
    rec = np.zeros((n, NODE_WORDS), np.int32)
    rec[:, KEY] = keys
    rec[:, VALUE] = values
    buckets = _np_hash(keys, n_buckets)
    # push-front insertion per bucket
    for i in range(n):
        rec[i, NEXT] = heads[buckets[i]]
        heads[buckets[i]] = ptrs[i]
    b.write(ptrs, rec)
    return heads


def build(
    keys: np.ndarray,
    values: np.ndarray,
    n_buckets: int,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Returns (arena, bucket_heads (n_buckets,) int32 np array)."""
    n = len(keys)
    cap = capacity or max(num_shards, ((n + num_shards - 1) // num_shards) * num_shards)
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    heads = build_into(b, keys, values, n_buckets)
    return b.finish(), heads


def find_iterator(n_buckets: int) -> PulseIterator:
    """``unordered_map::find`` (Listing 3)."""

    def init(search_keys, bucket_heads):
        sk = jnp.asarray(search_keys, jnp.int32)
        buckets = hash_fn(sk, n_buckets)
        ptr0 = jnp.take(jnp.asarray(bucket_heads, jnp.int32), buckets, axis=0)
        B = sk.shape[0]
        scratch0 = jnp.zeros((B, SCRATCH_WORDS), jnp.int32)
        scratch0 = scratch0.at[:, 0].set(sk)
        # Empty bucket: ptr0 == NULL -> the executor faults it immediately;
        # mark result up-front so the CPU node can interpret the fault.
        scratch0 = scratch0.at[:, 1].set(KEY_NOT_FOUND)
        return ptr0, scratch0

    def next_fn(node, ptr, scratch):
        return node[NEXT], scratch

    def end_fn(node, ptr, scratch):
        key = scratch[0]
        hit = node[KEY] == key
        tail = node[NEXT] == NULL
        scratch = scratch.at[1].set(
            jnp.where(hit, node[VALUE], jnp.int32(KEY_NOT_FOUND))
        )
        scratch = scratch.at[2].set(hit.astype(jnp.int32))
        return hit | tail, scratch

    return PulseIterator(
        scratch_words=SCRATCH_WORDS,
        next_fn=next_fn,
        end_fn=end_fn,
        init_fn=init,
        name="hash_find",
    )


# ------------------------------ write path ---------------------------------

# sentinel bucket-head key: never matches a real key (real keys are >= 0 in
# the write-path workloads); the sentinel gives every chain a stable first
# node, so inserts into empty buckets and deletes of the first real node
# both have a predecessor to CAS.
SENTINEL_KEY = -(2**31)


def build_writable(
    b: ArenaBuilder, keys: np.ndarray, values: np.ndarray, n_buckets: int
) -> np.ndarray:
    """Writable-table build: every bucket head is an arena-resident sentinel
    node (key = SENTINEL_KEY) whose NEXT starts the chain.  Returns the
    sentinel addresses (n_buckets,) -- these never move, so the host-side
    bucket table stays valid across inserts and deletes."""
    sent = b.alloc(n_buckets)
    rec = np.zeros((n_buckets, NODE_WORDS), np.int32)
    rec[:, KEY] = SENTINEL_KEY
    rec[:, NEXT] = NULL
    b.write(sent, rec)
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    n = len(keys)
    if n:
        ptrs = b.alloc(n)
        recs = np.zeros((n, NODE_WORDS), np.int32)
        recs[:, KEY] = keys
        recs[:, VALUE] = values
        buckets = _np_hash(keys, n_buckets)
        heads = np.asarray(b.data[sent, NEXT])
        for i in range(n):
            recs[i, NEXT] = heads[buckets[i]]
            heads[buckets[i]] = ptrs[i]
        b.write(ptrs, recs)
        b.data[sent, NEXT] = heads
    return sent.astype(np.int32)


def _bucket_init(n_buckets, ops, keys, values, sentinels):
    keys = jnp.asarray(keys, jnp.int32)
    ptr0 = jnp.take(
        jnp.asarray(sentinels, jnp.int32), hash_fn(keys, n_buckets), axis=0
    )
    _, scratch = linked_list._rw_init(ops, keys, values, 0)
    return ptr0, scratch


def rw_iterator(n_buckets: int) -> PulseIterator:
    """Mixed find/insert/delete over the writable (sentinel-headed) table:
    one batch, one iterator program, per-record op in scratch[RW_OP].
    ``init(ops, keys, values, sentinels)``."""
    def init(ops, keys, values, sentinels):
        return _bucket_init(n_buckets, ops, keys, values, sentinels)

    return dataclasses.replace(
        linked_list.rw_iterator(), init_fn=init, name="hash_rw"
    )


def insert_iterator(n_buckets: int) -> PulseIterator:
    """``unordered_map::insert`` as chain tail-append under the bucket's
    sentinel.  ``init(keys, values, sentinels)``."""
    def init(keys, values, sentinels):
        ops = jnp.full(jnp.asarray(keys).shape, linked_list.OP_INSERT, jnp.int32)
        return _bucket_init(n_buckets, ops, keys, values, sentinels)

    return dataclasses.replace(
        linked_list.rw_iterator(), init_fn=init, name="hash_insert"
    )


def delete_iterator(n_buckets: int) -> PulseIterator:
    """``unordered_map::erase``: unlink under the sentinel + FREE the slot.
    ``init(keys, sentinels)``."""
    def init(keys, sentinels):
        keys = jnp.asarray(keys, jnp.int32)
        ops = jnp.full(keys.shape, linked_list.OP_DELETE, jnp.int32)
        return _bucket_init(n_buckets, ops, keys, jnp.zeros_like(keys), sentinels)

    return dataclasses.replace(
        linked_list.rw_iterator(), init_fn=init, name="hash_delete"
    )


# ------------------------------- references --------------------------------


def ref_find(keys, values, n_buckets, search_keys):
    """Oracle: (value, found, hops) per query, matching chain order."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    buckets = _np_hash(keys, n_buckets)
    chains: dict[int, list[int]] = {}
    for i in range(len(keys)):
        chains.setdefault(int(buckets[i]), []).insert(0, i)  # push-front
    out = []
    for sk in np.asarray(search_keys, np.int32):
        b = int(_np_hash(np.asarray([sk], np.int32), n_buckets)[0])
        chain = chains.get(b, [])
        val, found, hops = KEY_NOT_FOUND, 0, 0
        for idx in chain:
            hops += 1
            if int(keys[idx]) == int(sk):
                val, found = int(values[idx]), 1
                break
        else:
            hops = len(chain)
        out.append((val, found, hops))
    return out
