"""Google-BTree descent (paper Listings 8-9) + B+tree leaf-chain range
aggregation (the WiredTiger / BTrDB workload shape, paper S6).

Node layout (W=20, one 80 B record -> single aggregated LOAD):
  word 0      is_leaf
  word 1      num_keys (<= FANOUT)
  words 2..9  keys[FANOUT]
  internal:   words 10..18 children[FANOUT+1]
  leaf:       words 10..17 values[FANOUT], word 18 next_leaf
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.arena import M_NONE, M_STORE, NULL, ArenaBuilder
from repro.core.iterator import PulseIterator

FANOUT = 8  # kNodeValues in Listing 8
NODE_WORDS = 20
IS_LEAF, NUM_KEYS, KEYS0, CHILD0, VAL0, NEXT_LEAF = 0, 1, 2, 10, 10, 18
KEY_NOT_FOUND = -(2**31) + 1
INT_MIN = -(2**31)
INT_MAX = 2**31 - 1


def node_estimate(n: int) -> int:
    """Upper bound on node count: leaves + internals (geometric series)."""
    n_leaves = max(1, (n + FANOUT - 1) // FANOUT)
    total, level = n_leaves, n_leaves
    while level > 1:
        level = (level + FANOUT) // (FANOUT + 1)
        total += level
    return total


def build_into(b: ArenaBuilder, keys: np.ndarray, values: np.ndarray):
    """Bulk-loads a B+tree into a (possibly shared) heap; returns
    (root_ptr, height)."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    order = np.argsort(keys, kind="stable")
    keys, values = keys[order], values[order]
    n = len(keys)
    n_leaves = max(1, (n + FANOUT - 1) // FANOUT)

    # --- leaves ---
    leaf_ptrs = b.alloc(n_leaves)
    recs = np.zeros((n_leaves, NODE_WORDS), np.int32)
    maxkeys = np.empty(n_leaves, np.int32)
    for i in range(n_leaves):
        lo, hi = i * FANOUT, min(n, (i + 1) * FANOUT)
        k = hi - lo
        recs[i, IS_LEAF] = 1
        recs[i, NUM_KEYS] = k
        recs[i, KEYS0 : KEYS0 + k] = keys[lo:hi]
        recs[i, KEYS0 + k : KEYS0 + FANOUT] = INT_MAX  # pad keys high
        recs[i, VAL0 : VAL0 + k] = values[lo:hi]
        recs[i, NEXT_LEAF] = leaf_ptrs[i + 1] if i + 1 < n_leaves else NULL
        maxkeys[i] = keys[hi - 1] if k else INT_MAX
    b.write(leaf_ptrs, recs)

    # --- internal levels ---
    height = 1
    child_ptrs, child_max = leaf_ptrs, maxkeys
    while len(child_ptrs) > 1:
        height += 1
        n_nodes = (len(child_ptrs) + FANOUT) // (FANOUT + 1)
        ptrs = b.alloc(n_nodes)
        recs = np.zeros((n_nodes, NODE_WORDS), np.int32)
        new_max = np.empty(n_nodes, np.int32)
        for i in range(n_nodes):
            lo = i * (FANOUT + 1)
            hi = min(len(child_ptrs), lo + FANOUT + 1)
            c = hi - lo
            recs[i, IS_LEAF] = 0
            recs[i, NUM_KEYS] = c - 1
            # separator keys = max key of each child subtree except the last
            recs[i, KEYS0 : KEYS0 + c - 1] = child_max[lo : hi - 1]
            recs[i, KEYS0 + c - 1 : KEYS0 + FANOUT] = INT_MAX
            recs[i, CHILD0 : CHILD0 + c] = child_ptrs[lo:hi]
            new_max[i] = child_max[hi - 1]
        b.write(ptrs, recs)
        child_ptrs, child_max = ptrs, new_max
    root = int(child_ptrs[0])
    return root, height


def build(
    keys: np.ndarray,
    values: np.ndarray,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Bulk-loads a B+tree from sorted keys. Returns (arena, root_ptr, height)."""
    total = node_estimate(len(keys))
    cap = capacity or max(
        num_shards, ((total + num_shards - 1) // num_shards) * num_shards
    )
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    root, height = build_into(b, keys, values)
    return b.finish(), root, height


def _descend_index(node, key):
    """First i with key <= keys[i] (Listing 8's inner loop), else num_keys."""
    nk = node[NUM_KEYS]
    keys = jnp.asarray(node[KEYS0 : KEYS0 + FANOUT])
    idx = jnp.arange(FANOUT, dtype=jnp.int32)
    ok = (idx < nk) & (key <= keys)
    return jnp.where(ok.any(), jnp.argmax(ok).astype(jnp.int32), nk)


def find_iterator() -> PulseIterator:
    """``btree::internal_locate_plain_compare`` (Listing 9) + leaf probe."""
    S = 3  # [search_key, result_value, found]

    def init(search_keys, root_ptr):
        sk = jnp.asarray(search_keys, jnp.int32)
        B = sk.shape[0]
        scratch = jnp.zeros((B, S), jnp.int32).at[:, 0].set(sk)
        return jnp.full((B,), root_ptr, jnp.int32), scratch

    def next_fn(node, ptr, scratch):
        i = _descend_index(node, scratch[0])
        child = jnp.asarray(node[CHILD0 : CHILD0 + FANOUT + 1])[i]
        return child, scratch

    def end_fn(node, ptr, scratch):
        key = scratch[0]
        leaf = node[IS_LEAF] == 1
        keys = jnp.asarray(node[KEYS0 : KEYS0 + FANOUT])
        vals = jnp.asarray(node[VAL0 : VAL0 + FANOUT])
        nk = node[NUM_KEYS]
        idx = jnp.arange(FANOUT, dtype=jnp.int32)
        hitvec = (idx < nk) & (keys == key)
        hit = hitvec.any() & leaf
        val = jnp.where(hit, vals[jnp.argmax(hitvec)], jnp.int32(KEY_NOT_FOUND))
        scratch = scratch.at[1].set(jnp.where(leaf, val, scratch[1]))
        scratch = scratch.at[2].set(jnp.where(leaf, hit.astype(jnp.int32), scratch[2]))
        return leaf, scratch

    return PulseIterator(S, next_fn, end_fn, init, name="btree_find")


# ------------------------------ write path ---------------------------------

# update scratch: [key, new_value, state, found]
U_KEY, U_VAL, U_ST, U_FOUND = range(4)
U_WORDS = 4


def update_iterator() -> PulseIterator:
    """Leaf-slot update-in-place: ``internal_locate`` descent to the leaf,
    masked STORE of the matching slot's value word, post-commit validation
    (racing writers to one slot serialize through the commit phase; the last
    committed write wins and losers restage).  ``init(keys, values, root)``."""

    def init(keys, values, root_ptr):
        keys = jnp.asarray(keys, jnp.int32)
        B = keys.shape[0]
        scratch = jnp.zeros((B, U_WORDS), jnp.int32)
        scratch = scratch.at[:, U_KEY].set(keys)
        scratch = scratch.at[:, U_VAL].set(jnp.asarray(values, jnp.int32))
        return jnp.full((B,), root_ptr, jnp.int32), scratch

    def mut_fn(node, ptr, scratch):
        W = node.shape[0]
        key = scratch[U_KEY]
        val = scratch[U_VAL]
        st = scratch[U_ST]
        zeros = jnp.zeros((W,), jnp.int32)
        leaf = node[IS_LEAF] == 1
        i = _descend_index(node, key)
        child = jnp.asarray(node[CHILD0 : CHILD0 + FANOUT + 1])[i]
        keys = jnp.asarray(node[KEYS0 : KEYS0 + FANOUT])
        vals = jnp.asarray(node[VAL0 : VAL0 + FANOUT])
        nk = node[NUM_KEYS]
        idx = jnp.arange(FANOUT, dtype=jnp.int32)
        hitvec = (idx < nk) & (keys == key)
        hit = hitvec.any()
        slot = jnp.argmax(hitvec).astype(jnp.int32)
        s0, s1 = st == 0, st == 1
        at_leaf_hit = leaf & hit
        stage = (s0 & at_leaf_hit) | (s1 & (vals[slot] != val))
        updated = s1 & (vals[slot] == val)
        miss = s0 & leaf & ~hit
        done = miss | updated
        advance = s0 & ~leaf
        new_ptr = jnp.where(advance, child, ptr).astype(jnp.int32)
        new_scratch = scratch.at[U_ST].set(jnp.where(stage & s0, 1, st))
        new_scratch = new_scratch.at[U_FOUND].set(
            jnp.where(updated, 1, jnp.where(miss, 0, scratch[U_FOUND]))
        )
        m_op = jnp.where(stage, M_STORE, M_NONE).astype(jnp.int32)
        m_tgt = jnp.where(stage, ptr, 0).astype(jnp.int32)
        word = VAL0 + slot
        m_mask = jnp.where(stage, jnp.left_shift(jnp.int32(1), word), 0)
        m_data = jnp.where(
            stage[..., None], zeros.at[word].set(val), zeros
        )
        return done, new_ptr, new_scratch, (
            m_op, m_tgt, m_mask, jnp.int32(0), m_data.astype(jnp.int32)
        )

    def next_fn(node, ptr, scratch):
        i = _descend_index(node, scratch[U_KEY])
        return jnp.asarray(node[CHILD0 : CHILD0 + FANOUT + 1])[i], scratch

    return PulseIterator(
        scratch_words=U_WORDS,
        next_fn=next_fn,
        end_fn=lambda node, ptr, scratch: (node[IS_LEAF] == 1, scratch),
        init_fn=init,
        mut_fn=mut_fn,
        name="btree_update",
    )


# scratch layout for range aggregation (the BTrDB workload: stateful
# sum/min/max/count over a key window, paper S6 "stateful aggregations").
RA_LO, RA_HI, RA_SUM, RA_MIN, RA_MAX, RA_COUNT = 0, 1, 2, 3, 4, 5
RA_WORDS = 6


def range_aggregate_iterator() -> PulseIterator:
    """Descend to the first leaf >= lo, then walk the leaf chain accumulating
    sum/min/max/count of values with key in [lo, hi]."""

    def init(lo, hi, root_ptr):
        lo = jnp.asarray(lo, jnp.int32)
        hi = jnp.asarray(hi, jnp.int32)
        B = lo.shape[0]
        scratch = jnp.zeros((B, RA_WORDS), jnp.int32)
        scratch = scratch.at[:, RA_LO].set(lo)
        scratch = scratch.at[:, RA_HI].set(hi)
        scratch = scratch.at[:, RA_MIN].set(INT_MAX)
        scratch = scratch.at[:, RA_MAX].set(INT_MIN)
        return jnp.full((B,), root_ptr, jnp.int32), scratch

    def next_fn(node, ptr, scratch):
        leaf = node[IS_LEAF] == 1
        i = _descend_index(node, scratch[RA_LO])
        child = jnp.asarray(node[CHILD0 : CHILD0 + FANOUT + 1])[i]
        nxt = jnp.where(leaf, node[NEXT_LEAF], child)
        return nxt, scratch

    def end_fn(node, ptr, scratch):
        leaf = node[IS_LEAF] == 1
        nk = node[NUM_KEYS]
        keys = jnp.asarray(node[KEYS0 : KEYS0 + FANOUT])
        vals = jnp.asarray(node[VAL0 : VAL0 + FANOUT])
        idx = jnp.arange(FANOUT, dtype=jnp.int32)
        in_rng = (idx < nk) & (keys >= scratch[RA_LO]) & (keys <= scratch[RA_HI]) & leaf
        s = jnp.where(in_rng, vals, 0).sum()
        mn = jnp.where(in_rng, vals, INT_MAX).min()
        mx = jnp.where(in_rng, vals, INT_MIN).max()
        c = in_rng.sum().astype(jnp.int32)
        scratch = scratch.at[RA_SUM].add(s)
        scratch = scratch.at[RA_MIN].min(mn)
        scratch = scratch.at[RA_MAX].max(mx)
        scratch = scratch.at[RA_COUNT].add(c)
        # done: last key in this leaf already past hi, or end of chain
        lastkey = jnp.where(nk > 0, keys[jnp.maximum(nk - 1, 0)], INT_MAX)
        done = leaf & ((lastkey > scratch[RA_HI]) | (node[NEXT_LEAF] == NULL))
        return done, scratch

    return PulseIterator(RA_WORDS, next_fn, end_fn, init, name="btree_range_agg")


# ------------------------------- references --------------------------------


def ref_find(keys, values, search_keys):
    d = {int(k): int(v) for k, v in zip(keys, values)}
    return [(d.get(int(k), KEY_NOT_FOUND), int(int(k) in d)) for k in search_keys]


def ref_range_aggregate(keys, values, los, his):
    keys = np.asarray(keys, np.int64)
    values = np.asarray(values, np.int64)
    order = np.argsort(keys)
    keys, values = keys[order], values[order]
    out = []
    for lo, hi in zip(los, his):
        m = (keys >= lo) & (keys <= hi)
        v = values[m]
        out.append(
            (
                int(v.sum() % (2**32) if len(v) else 0),
                int(v.min()) if len(v) else INT_MAX,
                int(v.max()) if len(v) else INT_MIN,
                int(len(v)),
            )
        )
    return out
