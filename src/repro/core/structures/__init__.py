"""Linked data structures ported to the PULSE iterator interface (paper S3,
Table 5 / Appendix B).

Families covered (matching the paper's categories):
  * list:  ``linked_list`` (STL list/forward_list ``std::find``),
           ``hash_table`` (Boost bimap/unordered_{map,set} bucket chains)
  * tree:  ``btree``      (Google BTree ``internal_locate_plain_compare``
                           + B+tree leaf-chain range aggregation, the BTrDB
                           workload),
           ``bst``        (STL map/set ``_M_lower_bound``; the same traversal
                           shape covers Boost AVL/splay/scapegoat
                           ``lower_bound_loop`` per Appendix B.5)
  * probabilistic: ``skiplist`` (beyond-paper extra family)

Each module provides a host-side numpy builder, PULSE iterators (traced
next/end), and pure-Python references used as test oracles.
"""

from repro.core.structures import bst, btree, hash_table, linked_list, skiplist  # noqa: F401
