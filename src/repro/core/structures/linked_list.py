"""STL ``std::find`` over list/forward_list (paper Listings 4-5).

Node layout (W=4): ``[key, value, next, pad]``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.arena import NULL, ArenaBuilder
from repro.core.iterator import PulseIterator

NODE_WORDS = 4
KEY, VALUE, NEXT = 0, 1, 2

# scratch layout for find: [search_key, result_value, found_flag]
SCRATCH_WORDS = 3
KEY_NOT_FOUND = -(2**31) + 1


def build_into(b: ArenaBuilder, keys: np.ndarray, values: np.ndarray) -> int:
    """Builds a singly linked list into a (possibly shared) heap; returns the
    head pointer.  Several structures can live in one pooled arena -- exactly
    the paper's memory nodes, which host many applications' structures."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    n = len(keys)
    ptrs = b.alloc(n)
    rec = np.zeros((n, NODE_WORDS), np.int32)
    rec[:, KEY] = keys
    rec[:, VALUE] = values
    rec[:-1, NEXT] = ptrs[1:]
    rec[-1, NEXT] = NULL
    b.write(ptrs, rec)
    return int(ptrs[0])


def build(
    keys: np.ndarray,
    values: np.ndarray,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Builds a singly linked list in list order; returns (arena, head_ptr)."""
    n = len(keys)
    cap = capacity or max(num_shards, ((n + num_shards - 1) // num_shards) * num_shards)
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    head = build_into(b, keys, values)
    return b.finish(), head


def find_iterator() -> PulseIterator:
    """``std::find(first, last, value)`` -> PULSE (Listing 5)."""

    def init(search_keys, head_ptr):
        B = search_keys.shape[0]
        ptr0 = jnp.full((B,), head_ptr, jnp.int32)
        scratch0 = jnp.zeros((B, SCRATCH_WORDS), jnp.int32)
        scratch0 = scratch0.at[:, 0].set(jnp.asarray(search_keys, jnp.int32))
        return ptr0, scratch0

    def next_fn(node, ptr, scratch):
        return node[NEXT], scratch

    def end_fn(node, ptr, scratch):
        key = scratch[0]
        hit = node[KEY] == key
        tail = node[NEXT] == NULL
        done = hit | tail
        scratch = scratch.at[1].set(
            jnp.where(hit, node[VALUE], jnp.int32(KEY_NOT_FOUND))
        )
        scratch = scratch.at[2].set(hit.astype(jnp.int32))
        return done, scratch

    return PulseIterator(
        scratch_words=SCRATCH_WORDS,
        next_fn=next_fn,
        end_fn=end_fn,
        init_fn=init,
        name="list_find",
    )


def sum_iterator() -> PulseIterator:
    """Stateful aggregation: sum all values along the chain (scratch carries
    the running sum -- the paper's 'continuation' use of the scratch pad)."""
    S = 2  # [running_sum, count]

    def init(head_ptrs):
        B = head_ptrs.shape[0]
        return jnp.asarray(head_ptrs, jnp.int32), jnp.zeros((B, S), jnp.int32)

    def next_fn(node, ptr, scratch):
        return node[NEXT], scratch

    def end_fn(node, ptr, scratch):
        scratch = scratch.at[0].add(node[VALUE])
        scratch = scratch.at[1].add(1)
        return node[NEXT] == NULL, scratch

    return PulseIterator(S, next_fn, end_fn, init, name="list_sum")


# ------------------------------- references --------------------------------


def ref_find(keys, values, search_keys):
    """Pure-python oracle for find_iterator results (value, found, hops)."""
    keys = list(map(int, keys))
    out = []
    for sk in map(int, search_keys):
        hops = 0
        val, found = KEY_NOT_FOUND, 0
        for i, k in enumerate(keys):
            hops += 1
            if k == sk:
                val, found = int(values[i]), 1
                break
        else:
            hops = len(keys)
        out.append((val, found, hops))
    return out
