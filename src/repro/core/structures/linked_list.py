"""STL ``std::find`` over list/forward_list (paper Listings 4-5).

Node layout (W=4): ``[key, value, next, pad]``.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.arena import M_ALLOC, M_CAS, M_FREE, M_NONE, NULL, ArenaBuilder
from repro.core.iterator import PulseIterator

NODE_WORDS = 4
KEY, VALUE, NEXT = 0, 1, 2

# scratch layout for find: [search_key, result_value, found_flag]
SCRATCH_WORDS = 3
KEY_NOT_FOUND = -(2**31) + 1

# ---------------------------------------------------------------------------
# Write path (chain structures): optimistic tail-insert and unlink-delete.
#
# One scratch layout serves find/insert/delete so a single mutating iterator
# program (``rw_iterator``) can serve a *mixed* read/write batch -- finds race
# inserts and deletes inside the same supersteps, and the per-shard commit
# phase serializes the writers:
#   [op, key, value, state, result, aux_prev, aux_victim, aux_vnext]
# op: 0 find / 1 insert / 2 delete.
RW_OP, RW_KEY, RW_VAL, RW_STATE, RW_RES, RW_A, RW_B, RW_C = range(8)
RW_WORDS = 8
OP_FIND, OP_INSERT, OP_DELETE = 0, 1, 2


def build_into(b: ArenaBuilder, keys: np.ndarray, values: np.ndarray) -> int:
    """Builds a singly linked list into a (possibly shared) heap; returns the
    head pointer.  Several structures can live in one pooled arena -- exactly
    the paper's memory nodes, which host many applications' structures."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values, np.int32)
    n = len(keys)
    ptrs = b.alloc(n)
    rec = np.zeros((n, NODE_WORDS), np.int32)
    rec[:, KEY] = keys
    rec[:, VALUE] = values
    rec[:-1, NEXT] = ptrs[1:]
    rec[-1, NEXT] = NULL
    b.write(ptrs, rec)
    return int(ptrs[0])


def build(
    keys: np.ndarray,
    values: np.ndarray,
    num_shards: int = 1,
    policy: str = "sequential",
    capacity: int | None = None,
):
    """Builds a singly linked list in list order; returns (arena, head_ptr)."""
    n = len(keys)
    cap = capacity or max(num_shards, ((n + num_shards - 1) // num_shards) * num_shards)
    b = ArenaBuilder(cap, NODE_WORDS, num_shards=num_shards, policy=policy)
    head = build_into(b, keys, values)
    return b.finish(), head


def find_iterator() -> PulseIterator:
    """``std::find(first, last, value)`` -> PULSE (Listing 5)."""

    def init(search_keys, head_ptr):
        B = search_keys.shape[0]
        ptr0 = jnp.full((B,), head_ptr, jnp.int32)
        scratch0 = jnp.zeros((B, SCRATCH_WORDS), jnp.int32)
        scratch0 = scratch0.at[:, 0].set(jnp.asarray(search_keys, jnp.int32))
        return ptr0, scratch0

    def next_fn(node, ptr, scratch):
        return node[NEXT], scratch

    def end_fn(node, ptr, scratch):
        key = scratch[0]
        hit = node[KEY] == key
        tail = node[NEXT] == NULL
        done = hit | tail
        scratch = scratch.at[1].set(
            jnp.where(hit, node[VALUE], jnp.int32(KEY_NOT_FOUND))
        )
        scratch = scratch.at[2].set(hit.astype(jnp.int32))
        return done, scratch

    return PulseIterator(
        scratch_words=SCRATCH_WORDS,
        next_fn=next_fn,
        end_fn=end_fn,
        init_fn=init,
        name="list_find",
    )


def sum_iterator() -> PulseIterator:
    """Stateful aggregation: sum all values along the chain (scratch carries
    the running sum -- the paper's 'continuation' use of the scratch pad)."""
    S = 2  # [running_sum, count]

    def init(head_ptrs):
        B = head_ptrs.shape[0]
        return jnp.asarray(head_ptrs, jnp.int32), jnp.zeros((B, S), jnp.int32)

    def next_fn(node, ptr, scratch):
        return node[NEXT], scratch

    def end_fn(node, ptr, scratch):
        scratch = scratch.at[0].add(node[VALUE])
        scratch = scratch.at[1].add(1)
        return node[NEXT] == NULL, scratch

    return PulseIterator(S, next_fn, end_fn, init, name="list_sum")


# ------------------------------ write path ---------------------------------


def chain_rw_step(node, ptr, scratch):
    """One iteration of the chain read/write state machine (shared by
    linked_list and hash_table -- the node layout is identical).

    Insert appends at the tail: walk to NEXT == NULL, stage ALLOC of the new
    node (commit deposits its address into scratch[RW_RES]), then CAS the
    tail's NEXT from NULL to the new address; a lost CAS is observed on the
    next iteration (NEXT neither NULL nor ours) and the walk resumes toward
    the new tail.  Delete walks with a carried prev pointer, CASes
    prev.NEXT from victim to victim.NEXT, validates at prev, then FREEs the
    victim's slot.  The first node of a chain acts as a sentinel: it is
    never deleted (hash_table's writable build allocates explicit sentinel
    bucket heads; list workloads reserve the head key).

    Known limitation (documented, per-node locks are future work): a
    concurrent delete of the same victim or an ABA on a freed-and-reused
    slot is not detected -- workloads must not race two deletes of one key.
    """
    W = node.shape[0]
    op = scratch[RW_OP]
    key = scratch[RW_KEY]
    val = scratch[RW_VAL]
    st = scratch[RW_STATE]
    nkey, nval, nnext = node[KEY], node[VALUE], node[NEXT]
    zeros = jnp.zeros((W,), jnp.int32)

    is_find = op == OP_FIND
    is_ins = op == OP_INSERT
    is_del = op == OP_DELETE

    # ---- find -------------------------------------------------------------
    f_hit = nkey == key
    f_done = f_hit | (nnext == NULL)
    f_scratch = scratch.at[RW_VAL].set(
        jnp.where(f_hit, nval, jnp.int32(KEY_NOT_FOUND))
    ).at[RW_RES].set(f_hit.astype(jnp.int32))

    # ---- insert -----------------------------------------------------------
    at_tail = nnext == NULL
    linked = nnext == scratch[RW_RES]
    i0, i1 = st == 0, st == 1
    ins_done = i1 & linked
    ins_stage_alloc = i0 & at_tail
    ins_stage_cas = i1 & at_tail
    ins_advance = ~at_tail & ~ins_done
    i_scratch = scratch.at[RW_STATE].set(jnp.where(ins_stage_alloc, 1, st))
    alloc_data = zeros.at[KEY].set(key).at[VALUE].set(val).at[NEXT].set(NULL)
    alloc_mask = (1 << KEY) | (1 << VALUE) | (1 << NEXT)
    ins_cas_data = zeros.at[NEXT].set(scratch[RW_RES])

    # ---- delete -----------------------------------------------------------
    prev, victim, vnext = scratch[RW_A], scratch[RW_B], scratch[RW_C]
    d0, d1, d2 = st == 0, st == 1, st == 2
    d_hit = nkey == key
    d_hasprev = prev != NULL
    del_stage_cas = d0 & d_hit & d_hasprev
    del_miss = d0 & ((d_hit & ~d_hasprev) | (~d_hit & (nnext == NULL)))
    del_ok = d1 & (nnext == vnext)  # swing took; free the victim
    del_refind = d1 & ~del_ok  # lost the CAS: re-walk from prev
    del_done = d2  # free committed
    d_advance = d0 & ~d_hit & (nnext != NULL)
    d_scratch = scratch
    d_scratch = d_scratch.at[RW_A].set(jnp.where(d_advance, ptr, prev))
    d_scratch = d_scratch.at[RW_B].set(jnp.where(del_stage_cas, ptr, victim))
    d_scratch = d_scratch.at[RW_C].set(jnp.where(del_stage_cas, nnext, vnext))
    d_scratch = d_scratch.at[RW_STATE].set(
        jnp.where(del_stage_cas, 1, jnp.where(del_ok, 2, jnp.where(del_refind, 0, st)))
    )
    d_scratch = d_scratch.at[RW_RES].set(jnp.where(del_done, 1, scratch[RW_RES]))
    # the CAS is staged on the same iteration that discovers the victim, so
    # its payload uses the live values (ptr/nnext), not the scratch copies
    # being written this step
    del_cas_data = zeros.at[NEXT].set(nnext)

    # ---- combine ----------------------------------------------------------
    done = (
        (is_find & f_done)
        | (is_ins & ins_done)
        | (is_del & (del_miss | del_done))
    )
    new_ptr = jnp.where(
        is_find,
        nnext,
        jnp.where(
            is_ins,
            jnp.where(ins_advance, nnext, ptr),
            jnp.where(d_advance, nnext, jnp.where(del_stage_cas, prev, ptr)),
        ),
    ).astype(jnp.int32)
    new_scratch = jnp.where(
        is_find, f_scratch, jnp.where(is_ins, i_scratch, d_scratch)
    ).astype(jnp.int32)

    m_op = jnp.where(
        is_ins & ins_stage_alloc,
        M_ALLOC,
        jnp.where(
            (is_ins & ins_stage_cas) | (is_del & del_stage_cas),
            M_CAS,
            jnp.where(is_del & del_ok, M_FREE, M_NONE),
        ),
    ).astype(jnp.int32)
    m_tgt = jnp.where(
        is_ins & ins_stage_alloc,
        jnp.int32(RW_RES),
        jnp.where(
            is_ins & ins_stage_cas,
            ptr,
            jnp.where(is_del & del_stage_cas, prev, victim),
        ),
    ).astype(jnp.int32)
    m_mask = jnp.where(
        is_ins & ins_stage_alloc,
        jnp.int32(alloc_mask),
        jnp.where(
            (is_ins & ins_stage_cas) | (is_del & del_stage_cas),
            jnp.int32(1 << NEXT),
            jnp.int32(0),
        ),
    )
    m_expect = jnp.where(
        is_ins & ins_stage_cas, jnp.int32(NULL),
        jnp.where(is_del & del_stage_cas, ptr, jnp.int32(0)),
    )
    m_data = jnp.where(
        (is_ins & ins_stage_alloc)[..., None],
        alloc_data,
        jnp.where(
            (is_ins & ins_stage_cas)[..., None],
            ins_cas_data,
            jnp.where((is_del & del_stage_cas)[..., None], del_cas_data, zeros),
        ),
    ).astype(jnp.int32)
    return done, new_ptr, new_scratch, (m_op, m_tgt, m_mask, m_expect, m_data)


def _rw_init(ops, keys, values, head_ptr):
    ops = jnp.asarray(ops, jnp.int32)
    B = ops.shape[0]
    scratch = jnp.zeros((B, RW_WORDS), jnp.int32)
    scratch = scratch.at[:, RW_OP].set(ops)
    scratch = scratch.at[:, RW_KEY].set(jnp.asarray(keys, jnp.int32))
    scratch = scratch.at[:, RW_VAL].set(jnp.asarray(values, jnp.int32))
    scratch = scratch.at[:, RW_A].set(NULL)  # delete's prev pointer
    ptr0 = jnp.broadcast_to(jnp.asarray(head_ptr, jnp.int32), (B,))
    return ptr0, scratch


def rw_iterator() -> PulseIterator:
    """Mixed read/write chain iterator: each record's scratch[RW_OP] selects
    find, tail-insert, or delete -- all racing in the same batch, serialized
    only by the per-shard commit phases.  ``init(ops, keys, values, head)``."""
    return PulseIterator(
        scratch_words=RW_WORDS,
        next_fn=lambda node, ptr, scratch: (node[NEXT], scratch),
        end_fn=lambda node, ptr, scratch: (node[NEXT] == NULL, scratch),
        init_fn=_rw_init,
        mut_fn=chain_rw_step,
        name="list_rw",
    )


def insert_iterator() -> PulseIterator:
    """Tail-insert: ``init(keys, values, head)``; the committed node's global
    address lands in scratch[RW_RES]."""

    def init(keys, values, head_ptr):
        keys = jnp.asarray(keys, jnp.int32)
        return _rw_init(jnp.full(keys.shape, OP_INSERT, jnp.int32), keys, values, head_ptr)

    return dataclasses.replace(rw_iterator(), init_fn=init, name="list_insert")


def delete_iterator() -> PulseIterator:
    """Unlink + free by key: ``init(keys, head)``; scratch[RW_RES] reports
    success.  The chain's first node is a sentinel and is never deleted."""

    def init(keys, head_ptr):
        keys = jnp.asarray(keys, jnp.int32)
        return _rw_init(
            jnp.full(keys.shape, OP_DELETE, jnp.int32), keys,
            jnp.zeros_like(keys), head_ptr,
        )

    return dataclasses.replace(rw_iterator(), init_fn=init, name="list_delete")


# ------------------------------- references --------------------------------


def ref_find(keys, values, search_keys):
    """Pure-python oracle for find_iterator results (value, found, hops)."""
    keys = list(map(int, keys))
    out = []
    for sk in map(int, search_keys):
        hops = 0
        val, found = KEY_NOT_FOUND, 0
        for i, k in enumerate(keys):
            hops += 1
            if k == sk:
                val, found = int(values[i]), 1
                break
        else:
            hops = len(keys)
        out.append((val, found, hops))
    return out
