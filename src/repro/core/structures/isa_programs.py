"""Hand-assembled PULSE ISA programs for the ported structures (S4.1).

These are what the paper's LLVM backend would emit; they execute on the ISA
VM (``core.isa.run_iteration``) and are cross-validated against the traced
iterators in tests.  Bounded inner loops (e.g. the BTree key scan, Listing 8)
are unrolled to FANOUT compares, exactly as the dispatch engine requires
("loops that cannot be unrolled to a fixed number of instructions" are
rejected, S3).
"""

from __future__ import annotations

from repro.core import isa
from repro.core.structures import bst, btree, hash_table, linked_list

KEY_NOT_FOUND = linked_list.KEY_NOT_FOUND
NULL_IMM = -1


def list_find_program() -> isa.Program:
    """Listing 5 compiled by hand.  scratch: [key, value, found]."""
    a = isa.Asm(
        scratch_words=linked_list.SCRATCH_WORDS,
        node_words=linked_list.NODE_WORDS,
        name="list_find_isa",
    )
    # r0=search key, r1=node key, r2=node value, r3=node next, r4=NULL, r5=1
    a.loads(0, 0)
    a.loadn(1, linked_list.KEY)
    a.loadn(2, linked_list.VALUE)
    a.loadn(3, linked_list.NEXT)
    a.movi(4, NULL_IMM)
    a.jne(0, 1, "miss")
    # hit: scratch[1]=value, scratch[2]=1, return
    a.stores(1, 2)
    a.movi(5, 1)
    a.stores(2, 5)
    a.ret()
    a.label("miss")
    a.movi(5, KEY_NOT_FOUND)
    a.stores(1, 5)
    a.movi(5, 0)
    a.stores(2, 5)
    a.jne(3, 4, "cont")
    a.ret()  # next == NULL -> not found
    a.label("cont")
    a.next_iter(3)
    return a.finish()


def hash_find_program() -> isa.Program:
    """Listing 3 compiled by hand (identical body to list find -- the chain
    walk is the same; the bucket resolution happened in init() on the CPU
    node).  scratch: [key, value, found]."""
    p = list_find_program()
    return isa.Program(p.code, p.scratch_words, hash_table.NODE_WORDS, "hash_find_isa")


def bst_find_program() -> isa.Program:
    """Listing 11 compiled by hand.  scratch: [key, y_ptr, y_key, y_value]."""
    a = isa.Asm(
        scratch_words=bst.SCRATCH_WORDS, node_words=bst.NODE_WORDS, name="bst_find_isa"
    )
    # r0=key r1=node.key r2=node.value r3=left r4=right r5=NULL r6=cur r7=next
    a.loads(0, bst.S_KEY)
    a.loadn(1, bst.KEY)
    a.loadn(2, bst.VALUE)
    a.loadn(3, bst.LEFT)
    a.loadn(4, bst.RIGHT)
    a.movi(5, NULL_IMM)
    a.getptr(6)
    a.jle(0, 1, "go_left")
    a.move(7, 4)  # next = right
    a.jmp("advance")
    a.label("go_left")
    # y <- cur: remember lower-bound candidate
    a.stores(bst.S_Y, 6)
    a.stores(bst.S_YKEY, 1)
    a.stores(bst.S_YVAL, 2)
    a.move(7, 3)  # next = left
    a.label("advance")
    a.jne(7, 5, "cont")
    a.ret()  # next == NULL -> done, y is the answer
    a.label("cont")
    a.next_iter(7)
    return a.finish()


def btree_find_program() -> isa.Program:
    """Listing 9 compiled by hand, inner key loop unrolled to FANOUT
    (bounded-loop rule, S3).  scratch: [key, value, found]."""
    a = isa.Asm(scratch_words=3, node_words=btree.NODE_WORDS, name="btree_find_isa")
    F = btree.FANOUT
    # r0=key r1=is_leaf r2=num_keys r3=tmp key_i r4=i r5=const r6=child/val r7=1
    a.loads(0, 0)
    a.loadn(1, btree.IS_LEAF)
    a.loadn(2, btree.NUM_KEYS)
    a.movi(7, 1)
    # unrolled: find first i with (i < num_keys) and key <= keys[i]
    for i in range(F):
        a.movi(4, i)
        a.jge(4, 2, "after_scan")  # i >= num_keys -> i = num_keys
        a.loadn(3, btree.KEYS0 + i)
        a.jle(0, 3, f"found_{i}")
    a.label("after_scan")
    a.move(4, 2)  # i = num_keys
    a.jmp("descend")
    for i in range(F):
        a.label(f"found_{i}")
        a.movi(4, i)
        if i != F - 1:
            a.jmp("descend")
    a.label("descend")
    a.movi(5, 0)
    a.jne(1, 5, "leaf")  # is_leaf != 0 -> leaf handling
    # internal: child = children[i]; unrolled select
    for i in range(F + 1):
        a.movi(5, i)
        a.jne(4, 5, f"notc_{i}")
        a.loadn(6, btree.CHILD0 + i)
        a.next_iter(6)
        a.label(f"notc_{i}")
    a.ret()  # unreachable (i <= num_keys <= F)
    a.label("leaf")
    # leaf: exact-match probe at slot i (keys sorted; key <= keys[i])
    a.movi(5, KEY_NOT_FOUND)
    a.stores(1, 5)
    a.movi(5, 0)
    a.stores(2, 5)
    a.jge(4, 2, "done")  # i == num_keys -> miss
    for i in range(F):
        a.movi(5, i)
        a.jne(4, 5, f"notl_{i}")
        a.loadn(3, btree.KEYS0 + i)
        a.jne(0, 3, "done")
        a.loadn(6, btree.VAL0 + i)
        a.stores(1, 6)
        a.stores(2, 7)
        a.jmp("done")
        a.label(f"notl_{i}")
    a.label("done")
    a.ret()
    return a.finish()


def bst_update_program() -> isa.Program:
    """Write path: BST update-in-place via the store class (STOREN).

    Same state machine as ``bst.update_iterator``: descend (state 0), stage
    a STOREN of the VALUE word on the matching node, stall for the commit,
    then validate on the post-commit iteration (state 1) -- a foreign value
    means a racing writer won the (slot, id) order, so the program restages.
    scratch: [key, new_value, state, found].
    """
    a = isa.Asm(
        scratch_words=bst.U_WORDS, node_words=bst.NODE_WORDS, name="bst_update_isa"
    )
    # r0=key r1=node.key r2=node.value r3=left r4=right r5=NULL r6=new_value
    # r7=1 r8=state r9=cur r10=next r11=0
    a.loads(0, bst.U_KEY)
    a.loads(6, bst.U_VAL)
    a.loads(8, bst.U_ST)
    a.loadn(1, bst.KEY)
    a.loadn(2, bst.VALUE)
    a.loadn(3, bst.LEFT)
    a.loadn(4, bst.RIGHT)
    a.movi(5, NULL_IMM)
    a.movi(7, 1)
    a.getptr(9)
    a.jeq(8, 7, "validate")
    # state 0: descend or stage
    a.jne(0, 1, "descend")
    a.storen(bst.VALUE, 6)  # stage the write-back; commit applies it
    a.stores(bst.U_ST, 7)
    a.next_iter(9)  # stall at the node until the commit lands
    a.label("descend")
    a.jlt(0, 1, "left")
    a.move(10, 4)
    a.jmp("step")
    a.label("left")
    a.move(10, 3)
    a.label("step")
    a.jne(10, 5, "cont")
    a.movi(11, 0)
    a.stores(bst.U_FOUND, 11)
    a.ret()  # miss: next hop is NULL
    a.label("cont")
    a.next_iter(10)
    a.label("validate")
    a.jeq(2, 6, "ok")
    a.storen(bst.VALUE, 6)  # lost the commit race: restage
    a.next_iter(9)
    a.label("ok")
    a.stores(bst.U_FOUND, 7)
    a.ret()
    return a.finish()


def all_programs() -> dict[str, isa.Program]:
    return {
        "list_find": list_find_program(),
        "hash_find": hash_find_program(),
        "bst_find": bst_find_program(),
        "btree_find": btree_find_program(),
        "bst_update": bst_update_program(),
    }
