"""Sequential-commit oracle for the write path (the determinism contract).

``sequential_commit_execute`` re-implements the distributed superstep
schedule -- placement, local chase, commit, capacity ladder, parking,
exchange, merge -- as a *sequential* host program: shards are visited one at
a time and every staged mutation is applied strictly one-at-a-time in the
canonical (class, slot, id) order with plain numpy stores.  No mesh, no
collectives, no vectorized scatter.

This is the bar every device schedule must clear: dispatched, fused, and
wavefront-pipelined supersteps, on the dense all_to_all or the ppermute
ring, must match this executor **bit for bit** -- records (ptr / scratch /
status / iters / hops), superstep counts, wire accounting, and the final
arena contents including the per-shard heap registers.  The iterator *body*
is shared (it defines the traversal semantics); the schedule, routing, and
commit logic here are written independently of ``core.routing``'s traced
implementations, so agreement actually checks the device-side serialization.

It doubles as the single-memory-node write executor: ``PulseEngine.execute``
runs mutating iterators through it when no mesh is configured (num_shards
== 1 degenerates to chase-k / commit-in-id-order rounds).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import routing
from repro.core.arena import (
    H_BUMP,
    H_COMMITS,
    H_EPOCH,
    H_FREE,
    M_ALLOC,
    M_CAS,
    M_FREE,
    M_NONE,
    M_STORE,
    NULL,
    PERM_READ,
    PERM_WRITE,
    Arena,
    mut_width,
)
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_EMPTY,
    STATUS_FAULT,
    PulseIterator,
    mut_step_batch,
    step_batch,
)

F_ID = routing.F_ID
F_HOME = routing.F_HOME
F_PTR = routing.F_PTR
F_STATUS = routing.F_STATUS
F_ITERS = routing.F_ITERS
F_HOPS = routing.F_HOPS
F_SCRATCH = routing.F_SCRATCH

# jitted per-(iterator, max_iters) chase step: the iterator body is the one
# piece deliberately shared with the device path (it IS the semantics)
_CHASE_JIT: dict = {}


def _chase_step(it: PulseIterator, max_iters: int, *, rep: bool = False):
    key = (it, max_iters, it.mutates, rep)
    fn = _CHASE_JIT.get(key)
    if fn is None:
        if it.mutates:
            def fn(rows, ptr, scr, st, iters, mut, lo, hi, perm):
                return mut_step_batch(
                    it, rows, ptr, scr, st, iters, mut, max_iters=max_iters,
                    local_lo=lo, local_hi=hi, perm_ok=perm,
                )
        elif rep:
            # replica-serving twin: the oracle shard also chases records in
            # its mirrored primary's range (hot-shard replication) -- same
            # dual-range step_batch the device path runs, so k_local budgets
            # interleave across the two ranges identically
            def fn(rows, ptr, scr, st, iters, lo, hi, perm,
                   rep_rows, rep_lo, rep_hi, rep_on, rep_perm):
                return step_batch(
                    it, rows, ptr, scr, st, iters, max_iters=max_iters,
                    local_lo=lo, local_hi=hi, perm_ok=perm,
                    rep_data=rep_rows, rep_lo=rep_lo, rep_hi=rep_hi,
                    rep_base=jnp.int32(0), rep_on=rep_on, rep_perm_ok=rep_perm,
                )
        else:
            def fn(rows, ptr, scr, st, iters, lo, hi, perm):
                return step_batch(
                    it, rows, ptr, scr, st, iters, max_iters=max_iters,
                    local_lo=lo, local_hi=hi, perm_ok=perm,
                )
        fn = _CHASE_JIT[key] = jax.jit(fn)
    return fn


def _owner_of(bounds: np.ndarray, ptr: np.ndarray) -> np.ndarray:
    shard = np.searchsorted(bounds, ptr, side="right").astype(np.int64) - 1
    P = len(bounds) - 1
    valid = (ptr >= 0) & (ptr < bounds[-1]) & (shard >= 0) & (shard < P)
    return np.where(valid, shard, NULL).astype(np.int32)


def _serve_np(owner: np.ndarray, rec_id: np.ndarray, rep) -> np.ndarray:
    """Numpy port of ``routing._serve_shard``: map the owning shard to the
    shard that *serves* the read under the replication policy."""
    if rep is None:
        return owner
    replica_map, dead_mask, policy = rep
    P = len(replica_map)
    safe = np.clip(owner, 0, P - 1)
    alt = replica_map[safe]
    has_alt = (alt >= 0) & (owner >= 0) & ~dead_mask[np.clip(alt, 0, P - 1)]
    dead = dead_mask[safe]
    if policy == "spread":
        redirect = has_alt & (dead | (rec_id % 2 == 1))
    elif policy == "failover":
        redirect = has_alt & dead
    else:  # "primary"
        redirect = np.zeros_like(has_alt)
    return np.where(redirect, alt, owner).astype(np.int32)


def _commit_shard(pool, data, heap, s, lo, hi, perm_w, *, S, W, MB):
    """Apply shard ``s``'s eligible commits one at a time, in the canonical
    (class, slot, id) order.  Mutates pool/data/heap in place; returns the
    number of commit slots consumed (CAS misses included)."""
    m_op = pool[:, MB]
    m_tgt = pool[:, MB + 1]
    status = pool[:, F_STATUS]
    pend = (m_op != M_NONE) & (status != STATUS_EMPTY)
    is_alloc = m_op == M_ALLOC
    eligible = pend & np.where(
        is_alloc, pool[:, F_HOME] == s, (m_tgt >= lo) & (m_tgt < hi)
    )
    if not eligible.any():
        return 0
    if not perm_w:
        pool[eligible, F_STATUS] = STATUS_FAULT
        pool[eligible, MB] = M_NONE
        return 0
    klass = np.where(is_alloc, 2, np.where(m_op == M_FREE, 1, 0))
    slot_key = np.where(is_alloc, 0, m_tgt)
    order = np.lexsort(
        (pool[:, F_ID], slot_key, klass, (~eligible).astype(np.int32))
    )
    applied = 0
    for r in order:
        if not eligible[r]:
            break  # eligible records sort first
        op = int(pool[r, MB])
        tgt = int(pool[r, MB + 1])
        mask = int(pool[r, MB + 2])
        expect = int(pool[r, MB + 3])
        mdata = pool[r, MB + 4 : MB + 4 + W]
        maskb = ((mask >> np.arange(W)) & 1).astype(bool)
        if op in (M_STORE, M_CAS):
            old = data[tgt]
            if op == M_STORE or int(old[int(np.argmax(maskb))]) == expect:
                data[tgt] = np.where(maskb, mdata, old)
        elif op == M_FREE:
            row = np.zeros(W, np.int32)
            row[0] = heap[s, H_FREE]
            data[tgt] = row
            heap[s, H_FREE] = tgt
        elif op == M_ALLOC:
            if heap[s, H_FREE] != NULL:
                slot = int(heap[s, H_FREE])
                heap[s, H_FREE] = data[slot, 0]
            elif heap[s, H_BUMP] < hi:
                slot = int(heap[s, H_BUMP])
                heap[s, H_BUMP] += 1
            else:
                pool[r, F_STATUS] = STATUS_FAULT
                pool[r, MB] = M_NONE
                applied += 1
                continue
            data[slot] = np.where(maskb, mdata, 0)
            pool[r, F_SCRATCH + min(max(tgt, 0), S - 1)] = slot
        pool[r, MB] = M_NONE
        applied += 1
    heap[s, H_EPOCH] += int(applied > 0)
    heap[s, H_COMMITS] += applied
    return applied


def _decide_and_send(pool, bounds, s, P, *, capacity, drain_done, MB, rep=None):
    """Numpy port of the switch decision (``_route_decide``): fault-mark,
    compute destinations (staged mutations route to their commit shard),
    park overflow, extract leavers.  Returns the per-destination send lists
    and blanks leavers in place."""
    status = pool[:, F_STATUS]
    valid = status != STATUS_EMPTY
    active = status == STATUS_ACTIVE

    if MB is not None:
        m_op = pool[:, MB]
        pendm = m_op != M_NONE
        is_alloc = m_op == M_ALLOC
        towner = _owner_of(bounds, pool[:, MB + 1])
    else:
        pendm = np.zeros(len(pool), bool)

    owner = _owner_of(bounds, pool[:, F_PTR])
    bad = active & (owner == NULL) & ~pendm
    if MB is not None:
        bad_mut = active & pendm & ~is_alloc & (towner == NULL)
        bad = bad | bad_mut
        pool[bad_mut, MB] = M_NONE
        pendm = pendm & ~bad_mut
    pool[bad, F_STATUS] = STATUS_FAULT
    status = pool[:, F_STATUS]
    active = status == STATUS_ACTIVE

    serve = _serve_np(owner, pool[:, F_ID], rep)
    if drain_done:
        dest = np.where(active, serve, s)
    else:
        dest = np.where(active, serve, pool[:, F_HOME])
    if MB is not None:
        cdest = np.where(is_alloc, pool[:, F_HOME], towner)
        dest = np.where(active & pendm, cdest, dest)
    dest = np.where(valid, dest, s).astype(np.int32)

    moves = valid & (dest != s)
    send = [[] for _ in range(P)]
    n_routed = 0
    fill = np.zeros(P, np.int64)
    for r in range(len(pool)):
        if not moves[r]:
            continue
        d = int(dest[r])
        if fill[d] < capacity:  # fits under the link budget
            pool[r, F_HOPS] += 1
            send[d].append(pool[r].copy())
            pool[r, F_STATUS] = STATUS_EMPTY
            fill[d] += 1
            n_routed += 1
        # overflow parks in place for the next superstep
    return send, n_routed


def _merge(kept, arrivals, L):
    both = np.concatenate([kept, arrivals], axis=0) if len(arrivals) else kept
    is_empty = both[:, F_STATUS] == STATUS_EMPTY
    order = np.argsort(is_empty, kind="stable")
    merged = both[order][:L]
    dropped = int((~is_empty).sum()) - int(
        (merged[:, F_STATUS] != STATUS_EMPTY).sum()
    )
    return merged, dropped


def _remote_count(pool, bounds, s, MB, rep=None):
    active = pool[:, F_STATUS] == STATUS_ACTIVE
    owner = _owner_of(bounds, pool[:, F_PTR])
    if MB is not None:
        m_op = pool[:, MB]
        pendm = m_op != M_NONE
        towner = np.where(
            m_op == M_ALLOC, pool[:, F_HOME], _owner_of(bounds, pool[:, MB + 1])
        )
        owner = np.where(pendm, towner, owner)
    else:
        owner = _serve_np(owner, pool[:, F_ID], rep)
    return int((active & (owner != s)).sum())


def sequential_commit_execute(
    it: PulseIterator,
    arena: Arena,
    ptr0,
    scratch0,
    *,
    max_iters: int = 1 << 30,
    k_local: int = 4,
    max_supersteps: int = 1 << 16,
    compact: bool = True,
    min_link_capacity: int = 8,
    fault_injector=None,
    replication=None,
):
    """Run a batch to completion under the sequential-commit schedule.

    Returns ``(records (B, R) ordered by id, RoutingStats, new Arena)`` for
    mutating iterators, or ``(records, RoutingStats)`` for read-only ones --
    mirroring ``routing.distributed_execute``'s contract so tests can
    compare the two outputs directly.  The input arena is never modified.

    ``fault_injector`` (test-only, ``core.faults.FaultInjector``): a
    targeted shard kill raises ``ShardFailure`` before the named superstep
    runs -- the single-node write executor dies exactly like the mesh paths,
    with the input arena untouched.  Fabric loss/delay do not apply (this
    schedule has no fabric).

    ``replication`` (``routing.ReplicaContext``, read-only iterators): the
    oracle twin of the device read fan-out.  Replica rows are served from
    the oracle's own copy of the primary's range -- legitimate because
    replicas are bit-identical by construction -- so a device failover run
    must match this executor bit for bit *including* hops and supersteps.
    """
    kill_at = None
    if fault_injector is not None:
        kill_at = fault_injector.kill_step(fault_injector.begin_call())
    if replication is not None and it.mutates:
        raise ValueError(
            "replication serves the READ path only; the write path commits "
            "through the primary and ships the log to the replica"
        )
    P = arena.num_shards
    bounds = np.asarray(arena.bounds)
    perms = np.asarray(arena.perms)
    data = np.array(arena.data)  # private copy: the mutated heap
    heap = np.array(arena.heap)
    commits0 = int(heap[:, H_COMMITS].sum())
    epochs0 = int(heap[:, H_EPOCH].sum())
    mutate = it.mutates
    S = it.scratch_words
    W = data.shape[1]
    MW = mut_width(W) if mutate else 0
    MB = F_SCRATCH + S if mutate else None
    R = routing.record_width(S, MW)

    ptr0 = np.asarray(ptr0, np.int32)
    scratch0 = np.asarray(scratch0, np.int32).reshape(len(ptr0), S)
    B = len(ptr0)
    Bp = ((B + P - 1) // P) * P
    L = Bp
    rec = np.zeros((Bp, R), np.int32)
    rec[:, F_STATUS] = STATUS_EMPTY
    rec[:B, F_ID] = np.arange(B)
    rec[:B, F_PTR] = ptr0
    rec[:B, F_STATUS] = STATUS_ACTIVE
    rec[:B, F_SCRATCH : F_SCRATCH + S] = scratch0
    home = np.arange(Bp, dtype=np.int32) % P
    rec[:, F_HOME] = home
    order = np.argsort(home, kind="stable")
    rec_sorted = rec[order]
    counts = np.bincount(home, minlength=P)
    pools = np.zeros((P, L, R), np.int32)
    pools[:, :, F_STATUS] = STATUS_EMPTY
    off = 0
    for s in range(P):
        c = int(counts[s])
        pools[s, :c] = rec_sorted[off : off + c]
        off += c

    base_capacity = L // P
    chase = _chase_step(it, max_iters, rep=replication is not None)
    readable = (perms & PERM_READ) == PERM_READ
    writable = (perms & PERM_WRITE) == PERM_WRITE

    rep_np = None
    primary_map = None
    dead_np = None
    if replication is not None:
        plan = replication.plan
        primary_map = np.asarray(plan.primary_map, np.int32)
        dead_np = np.asarray(replication.dead_mask, bool)
        rep_np = (np.asarray(plan.replica_map, np.int32), dead_np, plan.policy)

    routed_per_step, active_per_step = [], []
    wire_words_per_step, capacity_per_step = [], []
    local_only_steps = 0
    steps = 0
    n_active, n_remote = B, B
    for _ in range(max_supersteps):
        # injected shard death: fires before the targeted (1-based)
        # superstep, so the mutated ``data``/``heap`` copies are discarded
        # with exactly kill_at - 1 supersteps applied -- never published
        if kill_at is not None and steps + 1 >= kill_at:
            fault_injector.fire(steps + 1)
        # ---- local phase: chase then commit, shard by shard ---------------
        for s in range(P):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            pool = pools[s]
            args = [
                jnp.asarray(data[lo:hi]),
                jnp.asarray(pool[:, F_PTR]),
                jnp.asarray(pool[:, F_SCRATCH : F_SCRATCH + S]),
                jnp.asarray(pool[:, F_STATUS]),
                jnp.asarray(pool[:, F_ITERS]),
            ]
            if mutate:
                args.append(jnp.asarray(pool[:, MB:]))
            hi_eff = lo if (dead_np is not None and dead_np[s]) else hi
            args += [
                jnp.int32(lo), jnp.int32(hi_eff), jnp.asarray(bool(readable[s]))
            ]
            if replication is not None:
                # shard s doubles as the replica holder for primary_map[s]:
                # it serves reads over the primary's range when the policy
                # spreads or the primary is dead (never while itself dead)
                p = int(primary_map[s])
                ps = max(p, 0)
                plo, phi = int(bounds[ps]), int(bounds[ps + 1])
                rep_on = (
                    p >= 0 and not dead_np[s]
                    and (rep_np[2] == "spread" or dead_np[p])
                )
                args += [
                    jnp.asarray(data[plo:phi]),
                    jnp.int32(plo), jnp.int32(phi),
                    jnp.asarray(bool(rep_on)),
                    jnp.asarray(bool(readable[ps])),
                ]
            for _k in range(k_local):
                out = chase(*args[:1], *args[1:])
                args[1 : 1 + len(out)] = [*out]
            pool[:, F_PTR] = np.asarray(args[1])
            pool[:, F_SCRATCH : F_SCRATCH + S] = np.asarray(args[2])
            pool[:, F_STATUS] = np.asarray(args[3])
            pool[:, F_ITERS] = np.asarray(args[4])
            if mutate:
                pool[:, MB:] = np.asarray(args[5])
                _commit_shard(
                    pool, data, heap, s, lo, hi, bool(writable[s]),
                    S=S, W=W, MB=MB,
                )

        # ---- switch phase: the same ladder, sequentially ------------------
        if compact:
            demand = (n_active + P - 1) // P
            capacity = min(
                base_capacity,
                max(min_link_capacity, routing._pow2_at_least(demand)),
            )
            do_route = n_remote > 0
        else:
            capacity, do_route = base_capacity, True
        if do_route:
            sends = []
            n_routed = 0
            for s in range(P):
                send, routed = _decide_and_send(
                    pools[s], bounds, s, P,
                    capacity=capacity, drain_done=compact, MB=MB, rep=rep_np,
                )
                sends.append(send)
                n_routed += routed
            for d in range(P):
                arrivals = [row for s in range(P) for row in sends[s][d]]
                arrivals = (
                    np.asarray(arrivals, np.int32).reshape(-1, R)
                    if arrivals else np.zeros((0, R), np.int32)
                )
                pools[d], dropped = _merge(pools[d], arrivals, L)
                if dropped:
                    raise RuntimeError(f"oracle pool overflow: {dropped}")
        else:
            n_routed = 0

        steps += 1
        n_active = int((pools[:, :, F_STATUS] == STATUS_ACTIVE).sum())
        n_remote = sum(
            _remote_count(pools[s], bounds, s, MB, rep_np) for s in range(P)
        )
        routed_per_step.append(n_routed)
        active_per_step.append(n_active)
        capacity_per_step.append(capacity if do_route else 0)
        wire_words_per_step.append(P * (P - 1) * capacity * R if do_route else 0)
        local_only_steps += int(not do_route)
        if n_active == 0:
            break
    else:
        raise RuntimeError(
            f"sequential_commit_execute: {n_active} records still ACTIVE "
            f"after max_supersteps={max_supersteps}"
        )

    all_rec = pools.reshape(-1, R)
    all_rec = all_rec[all_rec[:, F_STATUS] != STATUS_EMPTY]
    all_rec = all_rec[all_rec[:, F_ID] < B]
    all_rec = all_rec[np.argsort(all_rec[:, F_ID], kind="stable")]
    stats = routing.RoutingStats(
        supersteps=steps,
        crossings=all_rec[:, F_HOPS].copy(),
        routed_per_step=routed_per_step,
        active_per_step=active_per_step,
        wire_words_per_step=wire_words_per_step,
        capacity_per_step=capacity_per_step,
        local_only_steps=local_only_steps,
        schedule="sequential-oracle",
        commits=int(heap[:, H_COMMITS].sum()) - commits0,
        epochs=int(heap[:, H_EPOCH].sum()) - epochs0,
        _num_shards=P,
    )
    if not mutate:
        return all_rec, stats
    new_arena = Arena(
        data=jnp.asarray(data),
        bounds=arena.bounds,
        perms=arena.perms,
        heap=jnp.asarray(heap),
    )
    return all_rec, stats, new_arena
