"""Hierarchical address translation (PULSE S5).

Two levels, exactly as in the paper (Fig. 6):

  1. **Switch level** -- the programmable switch stores only the
     *base-address -> memory-node* map.  Here that is the sorted ``bounds``
     array replicated on every shard; ``owner_of`` is the TCAM lookup,
     realized as a branch-free ``searchsorted``.
  2. **Node level** -- each memory node translates a global address to a
     local offset (``local_offset``) and enforces protection
     (``check_access``).  A translation/protection failure terminates the
     traversal with a FAULT status that is routed back to the CPU node
     (S4.2 scheduler step 4).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.arena import NULL, PERM_READ


def owner_of(bounds: jnp.ndarray, ptr: jnp.ndarray) -> jnp.ndarray:
    """Switch-level lookup: which memory node owns global address ``ptr``.

    Returns -1 for NULL / out-of-range addresses (invalid pointer -> the
    switch notifies the CPU node, Fig. 6 step 6).
    """
    ptr = jnp.asarray(ptr, jnp.int32)
    shard = jnp.searchsorted(bounds, ptr, side="right").astype(jnp.int32) - 1
    num_shards = bounds.shape[0] - 1
    valid = (ptr >= 0) & (ptr < bounds[-1]) & (shard >= 0) & (shard < num_shards)
    return jnp.where(valid, shard, jnp.int32(NULL))


def local_offset(bounds: jnp.ndarray, shard: jnp.ndarray, ptr: jnp.ndarray) -> jnp.ndarray:
    """Node-level translation: global address -> row offset in the shard."""
    base = jnp.take(bounds, jnp.clip(shard, 0, bounds.shape[0] - 2), axis=0)
    return jnp.asarray(ptr, jnp.int32) - base


def is_local(bounds: jnp.ndarray, shard_id, ptr) -> jnp.ndarray:
    """True iff ``ptr`` translates locally on ``shard_id`` (no re-route)."""
    lo = jnp.take(bounds, jnp.asarray(shard_id, jnp.int32), axis=0)
    hi = jnp.take(bounds, jnp.asarray(shard_id, jnp.int32) + 1, axis=0)
    ptr = jnp.asarray(ptr, jnp.int32)
    return (ptr >= lo) & (ptr < hi)


def access_table(perms: jnp.ndarray, want: int = PERM_READ) -> jnp.ndarray:
    """Per-shard grant table for ``want`` access: ``(num_shards,)`` bool.

    The table depends only on the (loop-invariant) permission registers, so
    traversal loops hoist it once and index it per iteration instead of
    re-deriving the bitmask comparison every step.
    """
    return (perms & want) == want


def check_access_table(table: jnp.ndarray, shard: jnp.ndarray) -> jnp.ndarray:
    """Protection check against a hoisted ``access_table`` result."""
    num_shards = table.shape[0]
    safe = jnp.clip(shard, 0, num_shards - 1)
    return jnp.take(table, safe, axis=0) & (shard >= 0) & (shard < num_shards)


def check_access(perms: jnp.ndarray, shard: jnp.ndarray, want: int = PERM_READ) -> jnp.ndarray:
    """Node-level protection check: does the range grant ``want`` access."""
    return check_access_table(access_table(perms, want), shard)
