"""PulseEngine: the user-facing traversal engine (dispatch + execute).

Execution paths (the compared systems of S6):
  * ``local``        -- single memory node, PULSE accelerator semantics
                        (``iterator.execute_batched``).
  * ``distributed``  -- multi-node with in-network switch routing (S5).
  * ``distributed`` + ``return_to_cpu=True`` -- the PULSE-ACC ablation
                        (Fig. 9): crossings bounce through the home node.
  * ``cpu_node``     -- the Cache-based baseline: the traversal runs at the
                        CPU node; every node fetch is a remote access unless
                        it hits the CPU-side cache (LRU, S2.1).  Functionally
                        identical results + an access trace for the latency /
                        energy models.

The dispatch engine's offload decision (t_c <= eta * t_d, S4.1) lives in
``core.dispatch``; ``PulseEngine.execute`` consults it and falls back to the
``cpu_node`` path for non-offloadable iterators, exactly like the paper.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as dispatch_mod
from repro.core import routing
from repro.core.arena import NULL, Arena
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    PulseIterator,
    execute_batched,
)


@dataclasses.dataclass
class CpuNodeTrace:
    """Access trace from the cpu_node path (feeds Fig. 7 latency models)."""

    total_fetches: int
    cache_hits: int
    per_request_iters: np.ndarray

    @property
    def misses(self) -> int:
        return self.total_fetches - self.cache_hits


def cpu_node_execute(
    it: PulseIterator,
    arena: Arena,
    ptr0,
    scratch0,
    *,
    max_iters: int = 1 << 20,
    cache_nodes: int = 0,
):
    """Cache-based baseline: traverse at the CPU node over remote memory.

    Functionally equivalent to the accelerator path; additionally simulates a
    CPU-side LRU cache of ``cache_nodes`` node records and reports the trace.
    Runs hop-by-hop on host (numpy) -- it *is* the slow path being modeled.
    """
    data = np.asarray(arena.data)
    ptr = np.asarray(ptr0, np.int64).copy()
    B = ptr.shape[0]
    scratch = np.asarray(scratch0, np.int32).reshape(B, it.scratch_words).copy()
    done = np.zeros(B, bool)
    iters = np.zeros(B, np.int64)
    lru: OrderedDict[int, None] = OrderedDict()
    hits = fetches = 0

    step = jax.jit(jax.vmap(lambda n, p, s: _fused_step(it, n, p, s)))
    while not done.all() and (iters[~done].min(initial=0) < max_iters):
        live = ~done & (ptr != NULL)
        if not live.any():
            break
        # CPU-node cache simulation, per node fetch
        for a in ptr[live]:
            fetches += 1
            a = int(a)
            if a in lru:
                hits += 1
                lru.move_to_end(a)
            elif cache_nodes > 0:
                lru[a] = None
                if len(lru) > cache_nodes:
                    lru.popitem(last=False)
        node = data[np.clip(ptr, 0, data.shape[0] - 1)]
        d, np_, ns = step(jnp.asarray(node), jnp.asarray(ptr, jnp.int32), jnp.asarray(scratch))
        d, np_, ns = np.asarray(d), np.asarray(np_), np.asarray(ns)
        scratch[live] = ns[live]
        iters[live] += 1
        newly_done = live & (d | (np_ == NULL) | (iters >= max_iters))
        ptr[live & ~newly_done] = np_[live & ~newly_done]
        done |= newly_done
    trace = CpuNodeTrace(fetches, hits, iters.copy())
    return ptr.astype(np.int32), scratch, iters, trace


def _fused_step(it: PulseIterator, node, ptr, scratch):
    if it.step_fn is not None:
        return it.step_fn(node, ptr, scratch)
    done, scr = it.end_fn(node, ptr, scratch)
    nptr, nscr = it.next_fn(node, ptr, scr)
    return done, jnp.where(done, ptr, nptr), jnp.where(done, scr, nscr)


@dataclasses.dataclass
class ExecResult:
    ptr: np.ndarray
    scratch: np.ndarray
    status: np.ndarray
    iters: np.ndarray
    stats: object | None = None
    offloaded: bool = True


class PulseEngine:
    """Front door: dispatch decision + the right execution path."""

    def __init__(
        self,
        arena: Arena,
        *,
        mesh=None,
        axis_name: str = "mem",
        accel: dispatch_mod.AcceleratorSpec | None = None,
        eta: float | None = None,
    ):
        self.arena = arena
        self.mesh = mesh
        self.axis_name = axis_name
        self.accel = accel or dispatch_mod.AcceleratorSpec()
        self.eta = self.accel.eta if eta is None else eta

    def dispatch(self, it: PulseIterator) -> dispatch_mod.OffloadDecision:
        return dispatch_mod.offload_decision(
            it, self.arena.node_words, self.accel, eta=self.eta
        )

    def execute(
        self,
        it: PulseIterator,
        ptr0,
        scratch0,
        *,
        max_iters: int = 1 << 20,
        force_offload: bool | None = None,
        return_to_cpu: bool = False,
        k_local: int = 4,
        cache_nodes: int = 0,
    ) -> ExecResult:
        decision = self.dispatch(it)
        offload = decision.offload if force_offload is None else force_offload
        if not offload:
            ptr, scratch, iters, trace = cpu_node_execute(
                it, self.arena, ptr0, scratch0,
                max_iters=max_iters, cache_nodes=cache_nodes,
            )
            status = np.where(iters >= max_iters, 2, STATUS_DONE).astype(np.int32)
            return ExecResult(ptr, scratch, status, np.asarray(iters), trace, False)

        if self.mesh is not None and self.arena.num_shards > 1:
            rec, stats = routing.distributed_execute(
                it, self.arena, ptr0, scratch0,
                mesh=self.mesh, axis_name=self.axis_name,
                max_iters=max_iters, k_local=k_local,
                return_to_cpu=return_to_cpu,
            )
            return ExecResult(
                ptr=rec[:, routing.F_PTR],
                scratch=rec[:, routing.F_SCRATCH:],
                status=rec[:, routing.F_STATUS],
                iters=rec[:, routing.F_ITERS],
                stats=stats,
            )

        ptr, scratch, status, iters = execute_batched(
            it, self.arena, jnp.asarray(ptr0), jnp.asarray(scratch0),
            max_iters=max_iters,
        )
        return ExecResult(
            np.asarray(ptr), np.asarray(scratch), np.asarray(status),
            np.asarray(iters),
        )
