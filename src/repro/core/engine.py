"""PulseEngine: the user-facing traversal engine (dispatch + execute).

Execution paths (the compared systems of S6):
  * ``local``        -- single memory node, PULSE accelerator semantics
                        (``iterator.execute_batched``).
  * ``distributed``  -- multi-node with in-network switch routing (S5).
  * ``distributed`` + ``return_to_cpu=True`` -- the PULSE-ACC ablation
                        (Fig. 9): crossings bounce through the home node.
  * ``cpu_node``     -- the Cache-based baseline: the traversal runs at the
                        CPU node; every node fetch is a remote access unless
                        it hits the CPU-side cache (LRU, S2.1).  Functionally
                        identical results + an access trace for the latency /
                        energy models.

The dispatch engine's offload decision (t_c <= eta * t_d, S4.1) lives in
``core.dispatch``; ``PulseEngine.execute`` consults it and falls back to the
``cpu_node`` path for non-offloadable iterators, exactly like the paper.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as dispatch_mod
from repro.core import routing
from repro.core.arena import NULL, Arena
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_FAULT,
    STATUS_MAXED,
    PulseIterator,
    execute_batched,
)

# Re-exported: the specialization predicate lives with the distributed
# executor but is part of the engine's public surface (callers asking "will
# this run probe-free?" shouldn't need to know which layer owns the proof).
can_elide_access_check = routing.can_elide_access_check


@dataclasses.dataclass
class CpuNodeTrace:
    """Access trace from the cpu_node path (feeds Fig. 7 latency models)."""

    total_fetches: int
    cache_hits: int
    per_request_iters: np.ndarray

    @property
    def misses(self) -> int:
        return self.total_fetches - self.cache_hits


def cpu_node_execute(
    it: PulseIterator,
    arena: Arena,
    ptr0,
    scratch0,
    *,
    max_iters: int = 1 << 20,
    cache_nodes: int = 0,
):
    """Cache-based baseline: traverse at the CPU node over remote memory.

    Functionally equivalent to the accelerator path; additionally simulates a
    CPU-side LRU cache of ``cache_nodes`` node records and reports the trace.
    Runs hop-by-hop on host (numpy) -- it *is* the slow path being modeled.
    """
    data = np.asarray(arena.data)
    ptr = np.asarray(ptr0, np.int64).copy()
    B = ptr.shape[0]
    scratch = np.asarray(scratch0, np.int32).reshape(B, it.scratch_words).copy()
    done = np.zeros(B, bool)
    iters = np.zeros(B, np.int64)
    lru: OrderedDict[int, None] = OrderedDict()
    hits = fetches = 0

    step = jax.jit(jax.vmap(lambda n, p, s: _fused_step(it, n, p, s)))
    while not done.all() and (iters[~done].min(initial=0) < max_iters):
        live = ~done & (ptr != NULL)
        if not live.any():
            break
        # CPU-node cache simulation, per node fetch
        for a in ptr[live]:
            fetches += 1
            a = int(a)
            if a in lru:
                hits += 1
                lru.move_to_end(a)
            elif cache_nodes > 0:
                lru[a] = None
                if len(lru) > cache_nodes:
                    lru.popitem(last=False)
        node = data[np.clip(ptr, 0, data.shape[0] - 1)]
        d, np_, ns = step(jnp.asarray(node), jnp.asarray(ptr, jnp.int32), jnp.asarray(scratch))
        d, np_, ns = np.asarray(d), np.asarray(np_), np.asarray(ns)
        scratch[live] = ns[live]
        iters[live] += 1
        newly_done = live & (d | (np_ == NULL) | (iters >= max_iters))
        ptr[live & ~newly_done] = np_[live & ~newly_done]
        done |= newly_done
    trace = CpuNodeTrace(fetches, hits, iters.copy())
    return ptr.astype(np.int32), scratch, iters, trace


def _fused_step(it: PulseIterator, node, ptr, scratch):
    if it.step_fn is not None:
        return it.step_fn(node, ptr, scratch)
    done, scr = it.end_fn(node, ptr, scratch)
    nptr, nscr = it.next_fn(node, ptr, scr)
    return done, jnp.where(done, ptr, nptr), jnp.where(done, scr, nscr)


@dataclasses.dataclass
class ExecResult:
    ptr: np.ndarray
    scratch: np.ndarray
    status: np.ndarray
    iters: np.ndarray
    stats: object | None = None
    offloaded: bool = True
    # write path: the post-commit arena (mutating iterators only).  The
    # engine already swapped its own resident arena to this value; callers
    # holding the pre-call Arena object keep an intact snapshot.
    arena: Arena | None = None


class PulseEngine:
    """Front door: dispatch decision + the right execution path."""

    def __init__(
        self,
        arena: Arena,
        *,
        mesh=None,
        axis_name: str = "mem",
        accel: dispatch_mod.AcceleratorSpec | None = None,
        eta: float | None = None,
        fault_injector=None,
    ):
        self.arena = arena
        self.mesh = mesh
        self.axis_name = axis_name
        self.accel = accel or dispatch_mod.AcceleratorSpec()
        self.eta = self.accel.eta if eta is None else eta
        # test-only fault hook (core.faults.FaultInjector); every execute()
        # counts as one call toward the plan's kill_call regardless of path
        self.fault_injector = fault_injector
        # serving calls execute() every scheduling round with a fixed batch
        # shape; cache the compiled local executor per (iterator, B, budget).
        # The kernel path's logic closure is cached per iterator in
        # routing._kernel_logic (pulse_chase jits on logic_fn identity, so a
        # fresh closure per call would retrace) -- one cache shared by the
        # single-node kernel path and the distributed local_backend="kernel".
        self._local_jit: dict = {}
        # schedule_decision re-traces the iterator's jaxpr for the overlap
        # model; serving calls execute() per quantum, so cache per iterator
        self._schedule_cache: dict = {}

    def _local_fault_check(self):
        """Fault accounting for execution paths that never enter the
        distributed/commit executors (local jit, kernel, cpu_node): register
        the engine call and fire the kill before any work runs.  The leaf
        executors own their begin_call, so this must NOT run for paths that
        delegate to them (double-counting would skew kill_call targeting)."""
        inj = self.fault_injector
        if inj is not None:
            k = inj.kill_step(inj.begin_call())
            if k is not None:
                inj.fire(k)

    def dispatch(self, it: PulseIterator) -> dispatch_mod.OffloadDecision:
        return dispatch_mod.offload_decision(
            it, self.arena.node_words, self.accel, eta=self.eta
        )

    def reshard(self, arena: Arena, mesh=None) -> None:
        """Install a re-partitioned arena (and optionally a new mesh width).

        Shard-count-dependent decision caches are dropped; compiled
        executables key on shapes/static args and stay valid for whatever
        still matches."""
        self.arena = arena
        if mesh is not None:
            self.mesh = mesh
        self._schedule_cache.clear()

    def execute(
        self,
        it: PulseIterator,
        ptr0,
        scratch0,
        *,
        max_iters: int = 1 << 20,
        force_offload: bool | None = None,
        return_to_cpu: bool = False,
        k_local: int = 4,
        cache_nodes: int = 0,
        compact: bool = True,
        fused: bool = True,
        backend: str = "xla",
        schedule: str = "auto",
        fabric: str = "dense",
        replication: routing.ReplicaContext | None = None,
    ) -> ExecResult:
        """Dispatch + execute a batch of traversals.

        ``backend`` selects the single-node executor: ``"xla"`` is the pure
        JAX while_loop oracle; ``"kernel"`` runs the pulse_chase Pallas
        kernel under the variable-depth wave scheduler (compiled on TPU, the
        Pallas interpreter elsewhere), retiring finished lanes between depth
        quanta.  On a mesh, ``backend="kernel"`` threads the distributed
        local chase through the kernel's vectorized iterator body
        (``local_backend="kernel"``), so the overlapped local step shares
        the accelerator's compiled logic end-to-end.

        ``schedule`` picks the distributed superstep engine: ``"auto"``
        consults the dispatch engine's overlap model
        (``dispatch.schedule_decision``) and normally selects the
        wavefront-pipelined loop, which overlaps the in-flight wavefront's
        fabric time with the resident wavefront's local chase; ``"fused"``
        and ``"dispatched"`` force the serialized schedules.  ``fabric``
        selects the collective carrying the records (dense all_to_all or a
        ppermute ring).  All combinations are bit-identical in results and
        wire accounting.  ``compact`` enables active-set compaction of
        distributed supersteps (ignored for the ``return_to_cpu`` ablation);
        ``fused`` is the pre-pipelined boolean knob, still honored when
        ``schedule="auto"`` resolves away from it only by the overlap model.
        """
        if it.mutates:
            # write iterators always run near-memory: the commit machinery
            # (per-shard serialization, free-list allocator) lives with the
            # data, so there is no CPU-node fallback to dispatch them to --
            # and the knobs that would bypass it are errors, not no-ops
            if return_to_cpu:
                raise ValueError(
                    "mutating iterators cannot run the return_to_cpu ablation"
                )
            if backend == "kernel":
                raise ValueError(
                    "mutating iterators are not supported on the pulse_chase "
                    "kernel backend yet; use backend='xla'"
                )
            if force_offload is False:
                raise ValueError(
                    "mutating iterators cannot run at the CPU node "
                    "(force_offload=False): commits live with the data"
                )
            return self._execute_mut(
                it, ptr0, scratch0, max_iters=max_iters, k_local=k_local,
                compact=compact, fused=fused, schedule=schedule, fabric=fabric,
            )
        decision = self.dispatch(it)
        offload = decision.offload if force_offload is None else force_offload
        if not offload:
            self._local_fault_check()
            ptr, scratch, iters, trace = cpu_node_execute(
                it, self.arena, ptr0, scratch0,
                max_iters=max_iters, cache_nodes=cache_nodes,
            )
            status = np.where(iters >= max_iters, 2, STATUS_DONE).astype(np.int32)
            return ExecResult(ptr, scratch, status, np.asarray(iters), trace, False)

        if self.mesh is not None and self.arena.num_shards > 1:
            if replication is not None:
                # replica fan-out runs on the dispatched schedule; results
                # are schedule-invariant, so degraded/spread rounds just use
                # the host loop instead of the overlap model's pick
                schedule = "dispatched"
            else:
                schedule = self._resolve_schedule(it, schedule, fused, k_local)
            rec, stats = routing.distributed_execute(
                it, self.arena, ptr0, scratch0,
                mesh=self.mesh, axis_name=self.axis_name,
                max_iters=max_iters, k_local=k_local,
                return_to_cpu=return_to_cpu, compact=compact, fused=fused,
                schedule=schedule, fabric=fabric,
                local_backend="kernel" if backend == "kernel" else "xla",
                fault_injector=self.fault_injector,
                replication=replication,
            )
            return ExecResult(
                ptr=rec[:, routing.F_PTR],
                scratch=rec[:, routing.F_SCRATCH:],
                status=rec[:, routing.F_STATUS],
                iters=rec[:, routing.F_ITERS],
                stats=stats,
            )

        if backend == "kernel":
            self._local_fault_check()
            return self._execute_kernel(it, ptr0, scratch0, max_iters=max_iters)

        self._local_fault_check()
        # jnp.array copies (unlike asarray), so donating the copies keeps the
        # caller's buffers alive while letting the while_loop alias in place.
        # The iteration budget is a traced operand (not part of the key), so
        # SLO-aware quantum sizing in the serving layer re-enters the same
        # compiled executable with a different budget every round.
        # Re-derive the access-check elision per call (perms can change
        # between calls) and key the cache on it: a revocation flips the key
        # back to the unspecialized executable instead of silently running
        # the probe-free one.
        elide = routing.can_elide_access_check(it, self.arena)
        ptr0 = jnp.array(ptr0, jnp.int32)
        key = (it, int(ptr0.shape[0]), elide)
        fn = self._local_jit.get(key)
        if fn is None:
            fn = jax.jit(
                lambda arena, p, s, budget: execute_batched(
                    it, arena, p, s, max_iters=budget, elide_access_check=elide
                ),
                donate_argnums=(1, 2),
            )
            self._local_jit[key] = fn
        ptr, scratch, status, iters = fn(
            self.arena, ptr0, jnp.array(scratch0, jnp.int32),
            jnp.int32(min(max_iters, (1 << 31) - 1)),
        )
        return ExecResult(
            np.asarray(ptr), np.asarray(scratch), np.asarray(status),
            np.asarray(iters),
        )

    def _resolve_schedule(
        self, it: PulseIterator, schedule: str, fused: bool, k_local: int
    ) -> str:
        """``schedule="auto"`` -> the dispatch engine's overlap-model pick
        (cached per iterator); ``fused=False`` is the explicit opt-out of
        device-resident loops.  Shared by the read and write paths."""
        if schedule != "auto":
            return schedule
        if not fused:
            return "dispatched"
        sk = (it, k_local)
        sd = self._schedule_cache.get(sk)
        if sd is None:
            sd = self._schedule_cache[sk] = dispatch_mod.schedule_decision(
                it, self.arena.node_words, self.arena.num_shards,
                self.accel, k_local=k_local,
            )
        return sd.schedule if sd.schedule != "local" else "fused"

    def _execute_mut(
        self,
        it: PulseIterator,
        ptr0,
        scratch0,
        *,
        max_iters: int,
        k_local: int,
        compact: bool,
        fused: bool,
        schedule: str,
        fabric: str,
    ) -> ExecResult:
        """Write path: run a mutating iterator and swap the engine's arena to
        the post-commit state.

        The distributed path threads the arena + heap registers through the
        superstep loops as carried state; single-node (no mesh / one shard)
        runs the sequential-commit executor (``core.commit``) -- the same
        semantics the distributed schedules are verified against bit-for-bit.
        The *input* arena object is never modified, so callers can replay a
        snapshot through several schedules.
        """
        S = it.scratch_words
        if self.mesh is not None and self.arena.num_shards > 1:
            schedule = self._resolve_schedule(it, schedule, fused, k_local)
            rec, stats, new_arena = routing.distributed_execute(
                it, self.arena, ptr0, scratch0,
                mesh=self.mesh, axis_name=self.axis_name,
                max_iters=max_iters, k_local=k_local,
                compact=compact, schedule=schedule, fabric=fabric,
                fault_injector=self.fault_injector,
            )
        else:
            from repro.core import commit as commit_mod

            rec, stats, new_arena = commit_mod.sequential_commit_execute(
                it, self.arena, ptr0, scratch0,
                max_iters=max_iters, k_local=k_local, compact=compact,
                fault_injector=self.fault_injector,
            )
        self.arena = new_arena
        return ExecResult(
            ptr=rec[:, routing.F_PTR],
            scratch=rec[:, routing.F_SCRATCH : routing.F_SCRATCH + S],
            status=rec[:, routing.F_STATUS],
            iters=rec[:, routing.F_ITERS],
            stats=stats,
            arena=new_arena,
        )

    def _execute_kernel(
        self, it: PulseIterator, ptr0, scratch0, *, max_iters: int
    ) -> ExecResult:
        """Single-node path on the pulse_chase kernel (variable-depth waves).

        Translation/protection faults (NULL or out-of-range pointers,
        perm-revoked ranges) are enforced by a host-side ``fault_fn`` between
        depth quanta, so detection is quantum-granular rather than
        per-iteration like the XLA executor -- a faulting lane may execute a
        few extra clamped (harmless) loads first.  Lanes still active after
        ``max_iters`` report MAXED (resumable).  Iteration counts are exact
        per lane (the kernel accumulates them; wave retirement no longer
        rounds up to the depth quantum), except for fault_fn-retired lanes,
        whose counts include the clamped loads executed before the
        quantum-granular check caught them.  Runs the compiled kernel on TPU
        and the Pallas interpreter elsewhere.
        """
        from repro.core.arena import PERM_READ
        from repro.kernels.pulse_chase import ops as chase_ops

        ptr0 = np.asarray(ptr0, np.int32)
        B = ptr0.shape[0]
        scratch0 = np.asarray(scratch0, np.int32).reshape(B, it.scratch_words)
        logic = routing._kernel_logic(it)
        max_steps = int(min(max_iters, 1 << 20))

        bounds = np.asarray(self.arena.bounds)
        perms = np.asarray(self.arena.perms)
        cap = self.arena.capacity

        def fault_fn(p):
            shard = np.searchsorted(bounds, p, side="right") - 1
            ok = perms[np.clip(shard, 0, perms.shape[0] - 1)] & PERM_READ
            return (p < 0) | (p >= cap) | (ok != PERM_READ)

        ptr, scratch, st, wstats = chase_ops.pulse_chase_waves(
            self.arena.data, ptr0, scratch0, np.zeros(B, np.int32),
            logic_fn=logic, max_steps=max_steps, fault_fn=fault_fn,
            interpret=jax.default_backend() != "tpu",
        )
        status = np.where(st == 1, STATUS_DONE, STATUS_MAXED).astype(np.int32)
        status = np.where(wstats.faulted, STATUS_FAULT, status)
        return ExecResult(
            ptr, scratch, status, wstats.retire_step.astype(np.int32), wstats
        )
