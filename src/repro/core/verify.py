"""pulse-verify: an eBPF-style static verifier for PULSE ISA programs.

The paper's safety story (S4.1) is that offloaded traversal functions are
admitted *without trusting the tenant* because the ISA is restricted enough
to verify.  ``isa.validate`` enforces the cheap syntactic subset (forward
jumps, index bounds, terminal-last); this module is the full admission
check: it builds a control-flow graph over the encoded instructions and
runs an abstract interpretation that either

  (a) **rejects** the program with instruction-level diagnostics --
      undefined opcodes, out-of-range jump targets / register / node-word /
      scratch indices, use of scratch registers before definition, more
      than one store-class mutation staged on a single iteration path,
      SETPTR / FREE / NEXT_ITER operands with no pointer provenance,
      CFG-unreachable code, reachable HALTs, paths that fall off the
      program end, and backward jumps that can loop without reaching
      NEXT_ITER / RETURN (per-iteration termination); or

  (b) **certifies** it with a :class:`ProgramFacts` record -- the
      reachability-based ``mutates`` / ``allocs`` / ``frees`` flags, the
      scratch words actually touched, the permission mask the program can
      ever need, and the longest instruction path per iteration.  The
      certificate threads through ``core.iterator`` / ``core.engine`` /
      ``core.routing`` / ``serving.traversal_service`` so verified
      read-only programs skip the mutation-payload record lanes and elide
      the per-hop access-table check (see ``engine.can_elide_access``).

Verification is per *iteration*: one activation of the logic pipeline runs
from pc 0 to NEXT_ITER / RETURN, so the CFG never includes the implicit
back edge through the memory pipeline.  Termination therefore reduces to
the reachable CFG being acyclic -- a refinement of the assembler's blanket
forward-jump-only rule (a backward jump that cannot close a cycle is
harmless; one that can is rejected with the jump's pc).

Pointer provenance is a four-point lattice per register / scratch slot:
UNINIT < {NUM, PTR} < ANY.  GETPTR yields PTR; MOVI and the ALU yield NUM;
LOADN / LOADS yield the declared slot class (``node_ptr_slots`` /
``scratch_ptr_slots``) or ANY when the caller declares nothing -- so
undeclared programs are only rejected for *forged* pointers (MOVI / ALU
values flowing into SETPTR, FREE, or NEXT_ITER), never for honest loads.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import isa
from repro.core.arena import PERM_READ, PERM_WRITE

__all__ = [
    "Diagnostic",
    "VerifyError",
    "ProgramFacts",
    "analyze_program",
    "verify_program",
    "annotate_disasm",
]

# --------------------------------------------------------------------------
# diagnostic codes -- stable, machine-readable (the mutant corpus and the
# serving admission tests key on these strings; never rename casually)
E_EMPTY = "empty-program"
E_BAD_OPCODE = "bad-opcode"
E_JUMP_RANGE = "jump-out-of-range"
E_REG_RANGE = "register-out-of-range"
E_NODE_RANGE = "node-index-out-of-range"
E_SCRATCH_RANGE = "scratch-index-out-of-range"
E_FALLTHROUGH = "falls-off-end"
E_HALT = "halt-reachable"
E_LOOP = "unbounded-loop"
E_UNREACHABLE = "unreachable-code"
E_UNDEF_READ = "use-before-def"
E_DOUBLE_STAGE = "conflicting-stage"
E_PROVENANCE = "pointer-provenance"

ALL_CODES = (
    E_EMPTY, E_BAD_OPCODE, E_JUMP_RANGE, E_REG_RANGE, E_NODE_RANGE,
    E_SCRATCH_RANGE, E_FALLTHROUGH, E_HALT, E_LOOP, E_UNREACHABLE,
    E_UNDEF_READ, E_DOUBLE_STAGE, E_PROVENANCE,
)


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding, pointed at the offending instruction (pc = -1 for
    whole-program findings such as an empty code array)."""

    code: str
    pc: int
    message: str

    def __str__(self) -> str:
        where = f"pc={self.pc}" if self.pc >= 0 else "program"
        return f"[{self.code}] {where}: {self.message}"


class VerifyError(ValueError):
    """Structured rejection raised at registration / admission time.

    ``diagnostics`` carries every finding; ``codes`` is the tuple of their
    machine-readable code strings (what tests assert on).
    """

    def __init__(self, name: str, diagnostics):
        self.name = name
        self.diagnostics = tuple(diagnostics)
        lines = "\n  ".join(str(d) for d in self.diagnostics)
        super().__init__(
            f"pulse-verify rejected {name!r}: "
            f"{len(self.diagnostics)} finding(s)\n  {lines}"
        )

    @property
    def codes(self) -> tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)


@dataclasses.dataclass(frozen=True)
class ProgramFacts:
    """The verification certificate (hashable: rides executable cache keys).

    Attributes:
      name: the verified program's name.
      reachable_ops: opcodes at CFG-reachable pcs.
      mutates/allocs/frees: reachability-based store-class flags -- unlike
        ``Program.mutates`` (a whole-array opcode scan), dead store-class
        code cannot force a program onto the mutating path.
      scratch_words_used: 1 + highest scratch index a reachable
        LOADS/STORES/ALLOC touches (0 for scratch-free programs).
      perm_mask: the access the program can ever require (PERM_READ, plus
        PERM_WRITE iff it mutates) -- what admission must grant, and what
        the read-only specialization is allowed to assume.
      max_path_len: longest instruction path through one iteration (the
        dispatch engine's exact N for its t_c = t_i * N model).
    """

    name: str
    reachable_ops: frozenset[int]
    mutates: bool
    allocs: bool
    frees: bool
    scratch_words_used: int
    perm_mask: int
    max_path_len: int

    @property
    def read_only(self) -> bool:
        return not self.mutates

    def summary(self) -> str:
        kind = "mutating" if self.mutates else "read-only"
        perm = {PERM_READ: "R", PERM_READ | PERM_WRITE: "RW"}[self.perm_mask]
        extra = "".join(
            f" {flag}" for flag, on in (("allocs", self.allocs), ("frees", self.frees))
            if on
        )
        return (
            f"{kind}{extra}; perm={perm}; "
            f"scratch_used={self.scratch_words_used}; "
            f"max_path={self.max_path_len}"
        )


# --------------------------------------------------------------------------
# provenance lattice: join is bitwise-or, UNINIT is bottom, ANY is top
TAG_UNINIT = 0
TAG_NUM = 1
TAG_PTR = 2
TAG_ANY = TAG_NUM | TAG_PTR

# staged-mutation possibility set (bitmask over what _run_vm may have staged
# when control reaches a pc); transitions mirror the VM's staging semantics
# exactly -- an op is rejected iff the VM would silently clobber a prior
# stage on some path (SETPTR resets the mask, FREE/ALLOC retarget, ...).
SG_NONE = 1
SG_STORE = 2
SG_ALLOC = 4
SG_CAS = 8
SG_FREE = 16
_SG_NAMES = {
    SG_NONE: "none", SG_STORE: "STOREN", SG_ALLOC: "ALLOC",
    SG_CAS: "SETPTR", SG_FREE: "FREE",
}

_ALU_3REG = (isa.ADD, isa.SUB, isa.MUL, isa.DIV, isa.AND, isa.OR)
_COND_JUMPS = (isa.JEQ, isa.JNE, isa.JLT, isa.JLE, isa.JGT, isa.JGE)


def _stage_names(mask: int) -> str:
    return "/".join(name for bit, name in _SG_NAMES.items() if mask & bit)


def _reg_reads(op: int, a: int, b: int, imm: int):
    """Register indices an instruction reads (VM semantics, incl. the ALU's
    rs2-in-imm-field encoding)."""
    if op in _ALU_3REG:
        return (b, imm)
    if op in (isa.NOT, isa.MOVE):
        return (b,)
    if op in (isa.STORES, isa.STOREN, isa.FREE, isa.NEXT_ITER):
        return (a,)
    if op == isa.SETPTR:
        return (a, b)
    if op in _COND_JUMPS:
        return (a, b)
    return ()


def _reg_write(op: int, a: int):
    """The register an instruction defines, or None."""
    if op in (isa.LOADN, isa.LOADS, isa.MOVE, isa.MOVI, isa.GETPTR) or op in _ALU_3REG or op == isa.NOT:
        return a
    return None


def _successors(op: int, pc: int, imm: int):
    """CFG successor pcs.  Terminals end the iteration (no successors);
    HALT is handled separately (reachable HALTs are rejected)."""
    if op in (isa.NEXT_ITER, isa.RETURN, isa.HALT):
        return ()
    if op == isa.JMP:
        return (imm,)
    if op in _COND_JUMPS:
        return (imm, pc + 1)
    return (pc + 1,)


def _scan_syntax(code: np.ndarray, scratch_words: int, node_words: int):
    """Phase A: per-instruction syntactic checks over EVERY pc (reachable or
    not -- corrupted dead code is still corrupt).  Returns diagnostics;
    bad opcodes / jump targets make the CFG unbuildable, so callers stop
    there."""
    diags = []
    T = code.shape[0]
    for pc in range(T):
        op, a, b, imm = (int(x) for x in code[pc])
        if op not in isa.OP_NAMES:
            diags.append(Diagnostic(
                E_BAD_OPCODE, pc, f"undefined opcode {op}"
            ))
            continue
        name = isa.OP_NAMES[op]
        if op in isa._JUMPS and not (0 <= imm <= T):
            diags.append(Diagnostic(
                E_JUMP_RANGE, pc,
                f"{name} target {imm} outside [0, {T}]",
            ))
        regs = {
            "a": (a,) if op not in (isa.HALT, isa.JMP, isa.ALLOC) else (),
            "b": (b,) if op in _ALU_3REG + (isa.NOT, isa.MOVE, isa.SETPTR)
            + _COND_JUMPS else (),
            "imm(rs2)": (imm,) if op in _ALU_3REG else (),
        }
        for field, idxs in regs.items():
            for r in idxs:
                if not 0 <= r < isa.NUM_REGS:
                    diags.append(Diagnostic(
                        E_REG_RANGE, pc,
                        f"{name} {field}: register {r} outside "
                        f"[0, {isa.NUM_REGS})",
                    ))
        if op in (isa.LOADN, isa.STOREN, isa.SETPTR) and not (
            0 <= imm < node_words
        ):
            diags.append(Diagnostic(
                E_NODE_RANGE, pc,
                f"{name} node word {imm} outside [0, {node_words})",
            ))
        if op in (isa.LOADS, isa.STORES, isa.ALLOC) and not (
            0 <= imm < scratch_words
        ):
            diags.append(Diagnostic(
                E_SCRATCH_RANGE, pc,
                f"{name} scratch word {imm} outside [0, {scratch_words})",
            ))
    return diags


def _build_cfg(code: np.ndarray):
    """Phase B: reachability + termination over the per-iteration CFG.

    Returns ``(reachable: set[int], diags)``.  Diagnostics: paths that fall
    off the end (pc T is a virtual non-terminated exit), reachable HALTs,
    unreachable instructions, and back edges that close a cycle (the
    iteration could run forever without reaching NEXT_ITER / RETURN).
    """
    T = code.shape[0]
    diags = []
    succ = {}
    for pc in range(T):
        op, _, _, imm = (int(x) for x in code[pc])
        succ[pc] = _successors(op, pc, imm)

    # reachability from pc 0
    reachable: set[int] = set()
    stack = [0]
    while stack:
        pc = stack.pop()
        if pc in reachable or pc >= T:
            continue
        reachable.add(pc)
        stack.extend(succ[pc])

    for pc in sorted(reachable):
        op = int(code[pc, 0])
        if op == isa.HALT:
            diags.append(Diagnostic(
                E_HALT, pc,
                "HALT is reachable: the iteration would end without "
                "NEXT_ITER/RETURN and the record would spin in place",
            ))
        for s in succ[pc]:
            if s == T:
                diags.append(Diagnostic(
                    E_FALLTHROUGH, pc,
                    "execution can run past the last instruction without "
                    "reaching NEXT_ITER/RETURN",
                ))
    for pc in range(T):
        if pc not in reachable:
            diags.append(Diagnostic(
                E_UNREACHABLE, pc,
                f"{isa.OP_NAMES[int(code[pc, 0])]} is unreachable from pc 0",
            ))

    # cycle detection on the reachable subgraph (iterative DFS, colors):
    # a back edge means some iteration path never terminates
    WHITE, GRAY, BLACK = 0, 1, 2
    color = dict.fromkeys(reachable, WHITE)
    for root in sorted(reachable):
        if color[root] != WHITE:
            continue
        stack = [(root, iter([s for s in succ[root] if s < T]))]
        color[root] = GRAY
        while stack:
            pc, it_succ = stack[-1]
            advanced = False
            for s in it_succ:
                if color.get(s, BLACK) == GRAY:
                    diags.append(Diagnostic(
                        E_LOOP, pc,
                        f"jump to pc {s} closes a loop with no intervening "
                        f"NEXT_ITER/RETURN (unbounded iteration)",
                    ))
                elif color.get(s) == WHITE:
                    color[s] = GRAY
                    stack.append((s, iter([t for t in succ[s] if t < T])))
                    advanced = True
                    break
            if not advanced:
                color[pc] = BLACK
                stack.pop()
    return reachable, diags


def _topo_order(reachable, succ):
    """Kahn topological order of the (acyclic) reachable subgraph."""
    indeg = dict.fromkeys(reachable, 0)
    for pc in reachable:
        for s in succ[pc]:
            if s in indeg:
                indeg[s] += 1
    frontier = sorted(pc for pc, d in indeg.items() if d == 0)
    order = []
    while frontier:
        pc = frontier.pop(0)
        order.append(pc)
        for s in succ[pc]:
            if s in indeg:
                indeg[s] -= 1
                if indeg[s] == 0:
                    frontier.append(s)
        frontier.sort()
    return order


def _dataflow(code, reachable, *, scratch_words, node_ptr_slots,
              scratch_ptr_slots):
    """Phase C: abstract interpretation in topological order.

    Per-pc in-state = meet over predecessors of
      (defined-register bitmask [meet = intersection],
       register provenance tags [meet = lattice join],
       scratch provenance tags  [meet = lattice join],
       staged-mutation possibility set [meet = union]).

    One pass is exact because the reachable CFG is a DAG by the time this
    runs (cycles were rejected in phase B).
    """
    T = code.shape[0]
    succ = {}
    for pc in range(T):
        op, _, _, imm = (int(x) for x in code[pc])
        succ[pc] = tuple(s for s in _successors(op, pc, imm) if s < T)

    if node_ptr_slots is None:
        node_tag = None  # undeclared: every node word is ANY
    else:
        node_tag = {int(w): TAG_PTR for w in node_ptr_slots}
    if scratch_ptr_slots is None:
        scratch0 = [TAG_ANY] * scratch_words
    else:
        declared = {int(w) for w in scratch_ptr_slots}
        scratch0 = [
            TAG_PTR if w in declared else TAG_NUM for w in range(scratch_words)
        ]

    entry = (0, (TAG_UNINIT,) * isa.NUM_REGS, tuple(scratch0), SG_NONE)
    state: dict[int, tuple] = {0: entry}
    diags = []

    for pc in _topo_order(reachable, succ):
        st = state.get(pc)
        if st is None:  # pred had no out-state (shouldn't happen on a DAG)
            continue
        defined, rtags, stags, staged = st
        op, a, b, imm = (int(x) for x in code[pc])
        name = isa.OP_NAMES[op]

        # use-before-def on every register read
        ok_reads = True
        for r in _reg_reads(op, a, b, imm):
            if not defined & (1 << r):
                ok_reads = False
                diags.append(Diagnostic(
                    E_UNDEF_READ, pc,
                    f"{name} reads r{r} before any definition on some path",
                ))

        # pointer provenance: values flowing into the memory pipeline
        # (link swings, frees, the next hop) must be able to be pointers
        if ok_reads and op in (isa.SETPTR, isa.FREE, isa.NEXT_ITER):
            val = rtags[a]
            role = {
                isa.SETPTR: "staged link value",
                isa.FREE: "freed address",
                isa.NEXT_ITER: "next cur_ptr",
            }[op]
            if not val & TAG_PTR:
                diags.append(Diagnostic(
                    E_PROVENANCE, pc,
                    f"{name}: {role} r{a} has no pointer provenance "
                    f"(GETPTR/ALLOC/pointer-slot load), only "
                    f"{'numeric' if val else 'uninitialized'} values",
                ))

        # staging discipline: reject any op the VM would let silently
        # clobber (or be clobbered by) a previously staged mutation
        new_staged = staged
        if op == isa.STOREN:
            allowed = SG_NONE | SG_STORE | SG_ALLOC
            new_staged = (
                (SG_STORE if staged & (SG_NONE | SG_STORE) else 0)
                | (staged & SG_ALLOC)
            )
        elif op == isa.ALLOC:
            allowed = SG_NONE | SG_STORE
            new_staged = SG_ALLOC
        elif op == isa.SETPTR:
            allowed = SG_NONE
            new_staged = SG_CAS
        elif op == isa.FREE:
            allowed = SG_NONE
            new_staged = SG_FREE
        else:
            allowed = None
        if allowed is not None and staged & ~allowed:
            diags.append(Diagnostic(
                E_DOUBLE_STAGE, pc,
                f"{name} would clobber a mutation already staged on some "
                f"path ({_stage_names(staged & ~allowed)}): one staged "
                f"mutation per iteration",
            ))

        # transfer: register / scratch writes
        rtags = list(rtags)
        stags = list(stags)
        rd = _reg_write(op, a)
        if rd is not None and 0 <= rd < isa.NUM_REGS:
            defined |= 1 << rd
            if op == isa.GETPTR:
                rtags[rd] = TAG_PTR
            elif op in (isa.MOVI, isa.NOT) or op in _ALU_3REG:
                rtags[rd] = TAG_NUM
            elif op == isa.MOVE:
                rtags[rd] = rtags[b] if 0 <= b < isa.NUM_REGS else TAG_ANY
            elif op == isa.LOADN:
                if node_tag is None:
                    rtags[rd] = TAG_ANY
                else:
                    rtags[rd] = node_tag.get(imm, TAG_NUM)
            elif op == isa.LOADS:
                rtags[rd] = (
                    stags[imm] if 0 <= imm < scratch_words else TAG_ANY
                )
        if op == isa.STORES and 0 <= imm < scratch_words:
            stags[imm] = rtags[a] if 0 <= a < isa.NUM_REGS else TAG_ANY

        out = (defined, tuple(rtags), tuple(stags), new_staged)
        for s in succ[pc]:
            prev = state.get(s)
            if prev is None:
                state[s] = out
            else:
                state[s] = (
                    prev[0] & out[0],
                    tuple(x | y for x, y in zip(prev[1], out[1])),
                    tuple(x | y for x, y in zip(prev[2], out[2])),
                    prev[3] | out[3],
                )
    return diags


def _longest_path(code, reachable):
    """Longest instruction path through one iteration (exact on the DAG)."""
    T = code.shape[0]
    succ = {}
    for pc in range(T):
        op, _, _, imm = (int(x) for x in code[pc])
        succ[pc] = tuple(s for s in _successors(op, pc, imm) if s < T)
    depth = dict.fromkeys(reachable, 1)
    for pc in _topo_order(reachable, succ):
        for s in succ[pc]:
            if s in depth:
                depth[s] = max(depth[s], depth[pc] + 1)
    return max(depth.values(), default=0)


def analyze_program(
    prog,
    *,
    node_ptr_slots=None,
    scratch_ptr_slots=None,
):
    """Run the full verification pipeline without raising.

    Returns ``(facts, diagnostics)`` -- ``facts`` is None whenever
    ``diagnostics`` is non-empty.  ``node_ptr_slots`` / ``scratch_ptr_slots``
    optionally declare which node words / scratch slots hold pointers
    (declaring them makes the provenance lattice exact; leaving them None
    treats every loaded word as ANY, so only forged MOVI/ALU pointers are
    rejected).
    """
    code = np.asarray(prog.code)
    if code.size == 0:
        return None, [Diagnostic(E_EMPTY, -1, "program has no instructions")]

    diags = _scan_syntax(code, prog.scratch_words, prog.node_words)
    if any(d.code in (E_BAD_OPCODE, E_JUMP_RANGE) for d in diags):
        return None, diags  # CFG is unbuildable past this point

    reachable, cfg_diags = _build_cfg(code)
    diags.extend(cfg_diags)
    if any(d.code == E_LOOP for d in cfg_diags):
        return None, diags  # dataflow needs an acyclic reachable CFG

    diags.extend(_dataflow(
        code, reachable,
        scratch_words=prog.scratch_words,
        node_ptr_slots=node_ptr_slots,
        scratch_ptr_slots=scratch_ptr_slots,
    ))
    if diags:
        return None, diags

    reachable_ops = frozenset(int(code[pc, 0]) for pc in reachable)
    mutates = any(op in isa._MUTATORS for op in reachable_ops)
    scratch_used = 0
    for pc in sorted(reachable):
        op, _, _, imm = (int(x) for x in code[pc])
        if op in (isa.LOADS, isa.STORES, isa.ALLOC):
            scratch_used = max(scratch_used, imm + 1)
    facts = ProgramFacts(
        name=prog.name,
        reachable_ops=reachable_ops,
        mutates=mutates,
        allocs=isa.ALLOC in reachable_ops,
        frees=isa.FREE in reachable_ops,
        scratch_words_used=scratch_used,
        perm_mask=PERM_READ | (PERM_WRITE if mutates else 0),
        max_path_len=_longest_path(code, reachable),
    )
    return facts, []


def verify_program(prog, **kwargs) -> ProgramFacts:
    """Verify ``prog``; return its :class:`ProgramFacts` certificate or
    raise :class:`VerifyError` with instruction-pointed diagnostics."""
    facts, diags = analyze_program(prog, **kwargs)
    if diags:
        raise VerifyError(prog.name, diags)
    return facts


# --------------------------------------------------------------------------
# annotated disassembly (the CLI / golden-file format)

def _decode(op: int, a: int, b: int, imm: int) -> str:
    name = isa.OP_NAMES.get(op, f"?{op}")
    if op == isa.LOADN:
        return f"{name:9s} r{a} <- NODE[{imm}]"
    if op == isa.LOADS:
        return f"{name:9s} r{a} <- SP[{imm}]"
    if op == isa.STORES:
        return f"{name:9s} SP[{imm}] <- r{a}"
    if op in _ALU_3REG:
        return f"{name:9s} r{a} <- r{b}, r{imm}"
    if op == isa.NOT:
        return f"{name:9s} r{a} <- ~r{b}"
    if op == isa.MOVE:
        return f"{name:9s} r{a} <- r{b}"
    if op == isa.MOVI:
        return f"{name:9s} r{a} <- {imm}"
    if op in _COND_JUMPS:
        return f"{name:9s} r{a}, r{b} -> {imm}"
    if op == isa.JMP:
        return f"{name:9s} -> {imm}"
    if op == isa.NEXT_ITER:
        return f"{name:9s} r{a}"
    if op == isa.GETPTR:
        return f"{name:9s} r{a} <- CUR_PTR"
    if op == isa.STOREN:
        return f"{name:9s} NODE[{imm}] <- r{a}"
    if op == isa.ALLOC:
        return f"{name:9s} SP[{imm}] <- new"
    if op == isa.SETPTR:
        return f"{name:9s} NODE[{imm}] <- r{a} if == r{b}"
    if op == isa.FREE:
        return f"{name:9s} r{a}"
    return name  # HALT, RETURN


def annotate_disasm(prog, **kwargs) -> str:
    """Annotated disassembly + verdict, the ``tools/pulse_verify.py`` (and
    golden file) format: one line per instruction with the decoded operands,
    diagnostics attached to their pcs, and a header with the verdict."""
    facts, diags = analyze_program(prog, **kwargs)
    code = np.asarray(prog.code)
    by_pc: dict[int, list] = {}
    for d in diags:
        by_pc.setdefault(d.pc, []).append(d)

    lines = [
        f"program {prog.name}: {code.shape[0]} instrs, "
        f"scratch={prog.scratch_words}, node={prog.node_words}",
    ]
    if facts is not None:
        ops = "/".join(sorted(isa.OP_NAMES[o] for o in facts.reachable_ops))
        lines.append(f"verdict: OK  ({facts.summary()})")
        lines.append(f"reachable ops: {ops}")
    else:
        codes = "/".join(sorted({d.code for d in diags}))
        lines.append(f"verdict: REJECTED  ({len(diags)} finding(s): {codes})")
    for d in by_pc.get(-1, ()):
        lines.append(f"  !! {d}")
    for pc in range(code.shape[0]):
        op, a, b, imm = (int(x) for x in code[pc])
        lines.append(f"{pc:4d}: {_decode(op, a, b, imm)}")
        for d in by_pc.get(pc, ()):
            lines.append(f"      !! [{d.code}] {d.message}")
    return "\n".join(lines) + "\n"
