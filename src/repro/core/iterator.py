"""PULSE iterator programming model (paper S3) in JAX.

A traversal is ``init() / next() / end()`` plus a fixed-size int32
``scratch_pad``; *all* mutable state lives in ``(cur_ptr, scratch_pad)`` so a
traversal can be suspended, shipped across the network, and resumed anywhere
(S5 "continuing stateful iterator execution").

Per-iteration semantics (Listing 1 + S4.1):

    node = LOAD(cur_ptr)                 # ONE aggregated <=256 B load
    done, scratch = end(node, cur_ptr, scratch)
    if not done:
        cur_ptr, scratch = next(node, cur_ptr, scratch)

``execute_batched`` runs a *batch* of traversals with ``jax.lax.while_loop``
(the accelerator multiplexes m+n concurrent iterators; a SIMD batch is the
TPU-native analogue of that multiplexing).  Bounded computation is enforced
structurally: ``next``/``end`` are traced, loop-free-at-trace-time functions
(unbounded data-dependent loops cannot be expressed), and ``max_iters`` caps
the iteration count — on overrun the request returns STATUS_MAXED with its
scratch pad, and the caller may resume it (continuation semantics).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import translation
from repro.core.arena import M_NONE, NULL, PERM_READ, Arena, load_node

# Request status codes (wire format field; identical for request & response).
STATUS_ACTIVE = 0  # still traversing
STATUS_DONE = 1  # end() returned true; scratch_pad is the result
STATUS_MAXED = 2  # hit max_iters; resumable continuation
STATUS_FAULT = 3  # translation/protection failure
STATUS_EMPTY = 4  # free slot (routing pools only)

# Serving-layer terminal codes (negative: never appear on the wire; assigned
# host-side by PulseService before a request ever reaches a device pool).
STATUS_SHED = -2  # rejected at admission (bounded queue / rate limit)
STATUS_RETRY = -3  # retry budget exhausted while a shard was dead; the
#                    client should resubmit once recovery completes


@dataclasses.dataclass(frozen=True)
class PulseIterator:
    """A traversal program: the developer supplies next()/end() (+ optional
    host-side init()); the framework supplies execute().

    Attributes:
      scratch_words: fixed scratch_pad width (int32 words).
      next_fn:  (node (W,), ptr (), scratch (S,)) -> (new_ptr (), scratch (S,))
      end_fn:   (node (W,), ptr (), scratch (S,)) -> (done (), scratch (S,))
      init_fn:  optional host-side (query pytree) -> (ptr (B,), scratch (B,S))
      step_fn:  optional fused (node, ptr, scratch) -> (done, new_ptr, scratch)
                (used by the ISA VM, whose single pass yields both answers).
      mut_fn:   optional *mutating* fused step:
                (node, ptr, scratch) -> (done, new_ptr, scratch,
                                         (m_op, m_tgt, m_mask, m_expect,
                                          m_data (W,)))
                -- the write path (core.commit).  A step that stages a
                mutation (m_op != M_NONE) stalls its record until the owning
                shard's commit phase applies it; ``done`` is force-gated off
                while a mutation is staged, so programs terminate only on a
                clean (no-write) iteration after observing their commit.
      name:     for dispatch-engine reports.
      facts:    optional ``verify.ProgramFacts`` certificate (ISA programs
                admitted through pulse-verify).  Excluded from eq/hash so
                executable caches keyed on the iterator are unaffected; the
                engine/routing layers read it to specialize hot paths
                (mutation-lane skip, access-check elision) -- absent facts
                mean "unverified": every conservative runtime check stays.
    """

    scratch_words: int
    next_fn: Callable
    end_fn: Callable
    init_fn: Callable | None = None
    step_fn: Callable | None = None
    mut_fn: Callable | None = None
    name: str = "iterator"
    facts: object | None = dataclasses.field(default=None, compare=False)

    @property
    def mutates(self) -> bool:
        return self.mut_fn is not None

    def init(self, *args, **kwargs):
        if self.init_fn is None:
            raise ValueError(f"iterator {self.name} has no init()")
        return self.init_fn(*args, **kwargs)


def _step_one(it: PulseIterator, node, ptr, scratch):
    """One iteration for ONE request (after the node has been fetched)."""
    if it.step_fn is not None:
        done, new_ptr, new_scratch = it.step_fn(node, ptr, scratch)
        new_ptr = jnp.where(done, ptr, new_ptr).astype(jnp.int32)
        return done, new_ptr, jnp.asarray(new_scratch, jnp.int32)
    done, scratch = it.end_fn(node, ptr, scratch)
    nptr, nscratch = it.next_fn(node, ptr, scratch)
    new_ptr = jnp.where(done, ptr, nptr).astype(jnp.int32)
    new_scratch = jnp.where(done, scratch, nscratch).astype(jnp.int32)
    return done, new_ptr, new_scratch


def step_batch(
    it: PulseIterator,
    arena_data: jax.Array,
    ptr: jax.Array,  # (B,) int32 global (or pre-translated local) addresses
    scratch: jax.Array,  # (B, S) int32
    status: jax.Array,  # (B,) int32
    iters: jax.Array,  # (B,) int32
    *,
    max_iters: int,
    local_lo: jax.Array | int = 0,
    local_hi: jax.Array | int | None = None,
    perm_ok: jax.Array | bool = True,
    logic_fn=None,
    rep_data: jax.Array | None = None,
    rep_lo: jax.Array | int = 0,
    rep_hi: jax.Array | int = 0,
    rep_base: jax.Array | int = 0,
    rep_on: jax.Array | bool = False,
    rep_perm_ok: jax.Array | bool = True,
):
    """Advance every ACTIVE request by one iteration (vectorized).

    ``local_lo/local_hi`` bound the addresses this executor can serve (the
    memory node's translation range); an ACTIVE request pointing elsewhere is
    left untouched (the router will move it).  ``perm_ok`` is the node-level
    protection check result for this shard.

    ``logic_fn`` optionally substitutes a pre-vectorized fused next+end body
    (``kernels.pulse_chase.ops.iterator_logic``) for the per-lane vmap --
    the same compiled iterator the accelerator kernel runs, with identical
    done-gating, so results are bit-identical.

    ``rep_data``/``rep_lo``/``rep_hi`` declare a *second* servable address
    range: the replica rows this executor holds for another shard (hot-shard
    replication, read fan-out).  When ``rep_on`` is true a record whose
    pointer lands in ``[rep_lo, rep_hi)`` is chased from ``rep_data`` at
    offset ``ptr - rep_lo + rep_base`` -- bit-identical to the primary by
    construction, so results never depend on which copy served the read.
    """
    if local_hi is None:
        local_hi = arena_data.shape[0]
    own = (ptr >= local_lo) & (ptr < local_hi)
    if rep_data is not None:
        rep = jnp.asarray(rep_on) & (ptr >= rep_lo) & (ptr < rep_hi)
    else:
        rep = jnp.zeros_like(own)
    local = own | rep
    null = ptr == NULL
    active = status == STATUS_ACTIVE

    # Faults: NULL or non-translatable-anywhere pointers are the router's
    # business; here a *local* request with a protection failure faults.
    # Replica-served records check the *primary's* permission grant.
    grant = jnp.where(rep, jnp.asarray(rep_perm_ok), jnp.asarray(perm_ok))
    fault = active & local & ~grant & ~null
    runnable = active & local & ~fault & ~null

    offset = jnp.asarray(ptr, jnp.int32) - jnp.asarray(local_lo, jnp.int32)
    node = load_node(arena_data, jnp.where(runnable & own, offset, 0))
    if rep_data is not None:
        rep_off = (
            jnp.asarray(ptr, jnp.int32)
            - jnp.asarray(rep_lo, jnp.int32)
            + jnp.asarray(rep_base, jnp.int32)
        )
        rep_node = load_node(rep_data, jnp.where(runnable & rep, rep_off, 0))
        node = jnp.where(rep[:, None], rep_node, node)
    if logic_fn is not None:
        done, nptr, nscr = logic_fn(node, ptr, scratch)
        # the kernel's logic pipeline leaves done-gating of the pointer to
        # the caller (kernel.py's logic_wave); gate it here exactly like
        # _step_one so both backends advance records identically
        new_ptr_off = jnp.where(done, ptr, nptr).astype(jnp.int32)
        new_scratch = jnp.asarray(nscr, jnp.int32)
    else:
        done, new_ptr_off, new_scratch = jax.vmap(partial(_step_one, it))(
            node, ptr, scratch
        )
    # next_fn operates on *global* pointers stored in the records; nothing to
    # rebase (records in the arena hold global addresses).
    new_ptr = new_ptr_off

    ptr = jnp.where(runnable, new_ptr, ptr)
    scratch = jnp.where(runnable[:, None], new_scratch, scratch)
    iters = jnp.where(runnable, iters + 1, iters)
    status = jnp.where(runnable & done, STATUS_DONE, status)
    status = jnp.where(fault, STATUS_FAULT, status)
    status = jnp.where(
        (status == STATUS_ACTIVE) & (iters >= max_iters), STATUS_MAXED, status
    )
    # A finished-by-NULL-dereference is a fault too (walked off the structure).
    status = jnp.where(active & null, STATUS_FAULT, status)
    return ptr, scratch, status, iters


def mut_step_batch(
    it: PulseIterator,
    arena_data: jax.Array,
    ptr: jax.Array,  # (B,) int32 global addresses
    scratch: jax.Array,  # (B, S) int32
    status: jax.Array,  # (B,) int32
    iters: jax.Array,  # (B,) int32
    mut: jax.Array,  # (B, MUT_EXTRA + W) staged-mutation payload block
    *,
    max_iters: int,
    local_lo: jax.Array | int = 0,
    local_hi: jax.Array | int | None = None,
    perm_ok: jax.Array | bool = True,
):
    """Advance every runnable request of a *mutating* iterator by one step.

    Write-path twin of ``step_batch`` with three extra rules (core.commit):

      * a record with a staged mutation (``mut[:, 0] != M_NONE``) is
        **stalled** -- it executes nothing until the owning shard's commit
        phase applies the mutation and clears the payload;
      * a step that stages a mutation cannot also terminate: ``done`` is
        forced off, so programs finish on a clean post-commit iteration
        (observing their commit -- the validate step of an optimistic
        insert/delete);
      * a record never goes MAXED while a mutation is staged, so MAXED
        continuations are always resumable from ``(cur_ptr, scratch)`` alone
        (the payload invariant: only ACTIVE records carry staged mutations);
      * a record whose budget is exhausted (``iters >= max_iters``) never
        takes another step.  A record can be ACTIVE at the boundary only via
        the pending-mutation suppression above; once its commit clears it
        MAXes on the next touch.  Without this guard the outcome would
        depend on *when* each schedule next touches the record (a wavefront
        in flight lands straight into a chase and would overshoot the
        budget), breaking cross-schedule bit-identity.
    """
    if local_hi is None:
        local_hi = arena_data.shape[0]
    stalled = mut[:, 0] != M_NONE
    exhausted = iters >= max_iters
    local = (ptr >= local_lo) & (ptr < local_hi)
    null = ptr == NULL
    active = status == STATUS_ACTIVE
    fault = active & local & ~jnp.asarray(perm_ok) & ~null & ~stalled
    runnable = active & local & ~fault & ~null & ~stalled & ~exhausted

    offset = jnp.asarray(ptr, jnp.int32) - jnp.asarray(local_lo, jnp.int32)
    node = load_node(arena_data, jnp.where(runnable, offset, 0))
    done, nptr, nscr, staged = jax.vmap(it.mut_fn)(node, ptr, scratch)
    m_op, m_tgt, m_mask, m_expect, m_data = (
        jnp.asarray(x, jnp.int32) for x in staged
    )
    stages = m_op != M_NONE
    done = done & ~stages  # the commit is part of the traversal
    new_ptr = jnp.where(done, ptr, nptr).astype(jnp.int32)
    new_scratch = jnp.asarray(nscr, jnp.int32)

    ptr = jnp.where(runnable, new_ptr, ptr)
    scratch = jnp.where(runnable[:, None], new_scratch, scratch)
    iters = jnp.where(runnable, iters + 1, iters)
    new_payload = jnp.concatenate(
        [m_op[:, None], m_tgt[:, None], m_mask[:, None], m_expect[:, None], m_data],
        axis=1,
    )
    mut = jnp.where((runnable & stages)[:, None], new_payload, mut)
    pending = mut[:, 0] != M_NONE

    status = jnp.where(runnable & done, STATUS_DONE, status)
    status = jnp.where(fault, STATUS_FAULT, status)
    status = jnp.where(
        (status == STATUS_ACTIVE) & (iters >= max_iters) & ~pending,
        STATUS_MAXED,
        status,
    )
    status = jnp.where(active & null & ~stalled, STATUS_FAULT, status)
    return ptr, scratch, status, iters, mut


def execute_batched(
    it: PulseIterator,
    arena: Arena,
    ptr0: jax.Array,  # (B,)
    scratch0: jax.Array,  # (B, S)
    *,
    max_iters: int,
    unroll: int = 1,
    elide_access_check: bool = False,
):
    """Run a batch of traversals to completion on a single (unsharded) arena.

    This is the single-memory-node executor and the pure-JAX oracle the
    distributed engine (core.routing) is tested against.

    ``elide_access_check=True`` drops the per-step owner-lookup +
    access-table probe entirely.  Callers may set it only when the check is
    statically constant-true: the iterator's pulse-verify certificate proves
    PERM_READ suffices AND every shard of ``arena.perms`` grants PERM_READ
    (see ``engine.can_elide_access_check``) -- then ``perm_ok=True`` is the
    value the probe would have computed for every reachable pointer, so
    results are bit-identical.

    Returns ``(ptr, scratch, status, iters)``.
    """
    if it.mutates:
        raise ValueError(
            f"iterator {it.name} mutates: execute_batched is the read-only "
            f"executor and would silently drop its staged writes -- use "
            f"commit.sequential_commit_execute or PulseEngine.execute"
        )
    B = ptr0.shape[0]
    ptr = jnp.asarray(ptr0, jnp.int32)
    scratch = jnp.asarray(scratch0, jnp.int32).reshape(B, it.scratch_words)
    status = jnp.full((B,), STATUS_ACTIVE, jnp.int32)
    iters = jnp.zeros((B,), jnp.int32)

    # The per-shard grant table is loop-invariant: hoist it once instead of
    # re-deriving the permission bitmask from ``arena.perms`` on every unroll
    # step (only the owner lookup depends on the moving pointer).
    readable = None if elide_access_check else translation.access_table(
        arena.perms, PERM_READ
    )

    def cond(state):
        _, _, status, _ = state
        return jnp.any(status == STATUS_ACTIVE)

    def body(state):
        ptr, scratch, status, iters = state
        for _ in range(unroll):
            if readable is None:
                perm = True
            else:
                perm = translation.check_access_table(
                    readable, translation.owner_of(arena.bounds, ptr)
                )
            ptr, scratch, status, iters = step_batch(
                it,
                arena.data,
                ptr,
                scratch,
                status,
                iters,
                max_iters=max_iters,
                perm_ok=perm,
            )
        return ptr, scratch, status, iters

    ptr, scratch, status, iters = jax.lax.while_loop(
        cond, body, (ptr, scratch, status, iters)
    )
    return ptr, scratch, status, iters


def resume(status: jax.Array) -> jax.Array:
    """Continuation restart: MAXED requests become ACTIVE again (the CPU node
    re-issues the request from the returned (cur_ptr, scratch_pad))."""
    return jnp.where(status == STATUS_MAXED, STATUS_ACTIVE, status)
