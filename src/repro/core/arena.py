"""Arena: the disaggregated-memory heap PULSE traverses.

The paper's memory nodes export flat DRAM regions; pointers are physical
addresses into them.  We model the rack's pooled memory as a single flat
*arena* of fixed-width node records:

  * ``data``    -- ``(capacity, node_words)`` int32.  One row == one node
                   record.  ``node_words <= MAX_NODE_WORDS`` (64) so a whole
                   record fits the paper's single aggregated <=256 B LOAD
                   (S4.1: the dispatch engine fuses every access relative to
                   ``cur_ptr`` into one load at the top of each iteration).
  * pointer     -- int32 row index (a *global address*).  ``NULL == -1``.
  * partition   -- the address space is **range partitioned** across memory
                   nodes (mesh shards): shard ``s`` owns rows
                   ``[bounds[s], bounds[s+1])``.  ``bounds`` is the switch's
                   hierarchical-translation base table (S5).

Values are int32 words; floats are carried bitcast (``f2i``/``i2f``) exactly
like raw bytes in the paper's scratch pad.

Host-side construction uses numpy (``ArenaBuilder``) so tests/benchmarks can
build multi-million-node structures quickly, then ``device_put`` once.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

NULL = -1
MAX_NODE_WORDS = 64  # 256 B of int32 words: the paper's max aggregated LOAD.

# Protection bits (per shard / translation range).
PERM_READ = 1
PERM_WRITE = 2

# ---------------------------------------------------------------------------
# Write path: staged-mutation opcodes (S4.1 footnote 4 / the modification
# iterators).  A mutating traversal never writes the heap directly -- it
# *stages* one mutation per iteration into its request record and stalls; the
# owning shard applies staged mutations in a serialized per-shard commit
# phase at the end of each superstep (core.commit), which is how concurrent
# writers to one shard serialize deterministically while readers in the same
# superstep still see the pre-commit snapshot.
M_NONE = 0  # no pending mutation
M_STORE = 1  # blind masked store: node[m_tgt][w] <- m_data[w] for mask bits w
M_CAS = 2  # conditional store: applies iff node[m_tgt][lowest mask bit]
#            == m_expect (the link-swing primitive; failure is observed by
#            the iterator's validate iteration, never by a status code)
M_ALLOC = 3  # claim a free-list slot on the record's HOME shard, write the
#            masked m_data into it, and deposit the new global address into
#            scratch[m_tgt]
M_FREE = 4  # push node m_tgt onto its owning shard's free list (slot is
#            zeroed; word 0 becomes the free-list link)

MUT_EXTRA = 4  # payload words beyond node data: [m_op, m_tgt, m_mask, m_expect]

# Per-shard heap registers carried through mutating supersteps:
# [free_head (global addr | NULL), bump (next never-used global addr),
#  epoch (commit phases that applied >=1 mutation -- the paper's per-node
#  lock generation stand-in), commits (mutations applied)]
HEAP_WORDS = 4
H_FREE, H_BUMP, H_EPOCH, H_COMMITS = 0, 1, 2, 3


def mut_width(node_words: int) -> int:
    """Mutation-payload words a write-capable record carries."""
    return MUT_EXTRA + node_words


def f2i(x):
    """Bitcast float32 -> int32 (store a float in an int32 arena/scratch word)."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.int32)


def i2f(x):
    """Bitcast int32 -> float32 (read a float out of an int32 word)."""
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.int32), jnp.float32)


def nf2i(x) -> np.ndarray:
    return np.asarray(x, np.float32).view(np.int32)


def ni2f(x) -> np.ndarray:
    return np.asarray(x, np.int32).view(np.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Arena:
    """A (possibly sharded) flat heap of fixed-width int32 node records."""

    data: jax.Array  # (capacity, node_words) int32
    bounds: jax.Array  # (num_shards + 1,) int32, sorted; switch base table
    perms: jax.Array  # (num_shards,) int32 permission bitmask
    heap: jax.Array  # (num_shards, HEAP_WORDS) int32 allocator/commit state

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @property
    def node_words(self) -> int:
        return self.data.shape[1]

    @property
    def num_shards(self) -> int:
        return self.bounds.shape[0] - 1


def make_arena(
    data: jax.Array | np.ndarray,
    num_shards: int = 1,
    bounds: Sequence[int] | None = None,
    perms: Sequence[int] | None = None,
    heap: jax.Array | np.ndarray | None = None,
) -> Arena:
    data = jnp.asarray(data, jnp.int32)
    if data.ndim != 2:
        raise ValueError(f"arena data must be (capacity, node_words), got {data.shape}")
    if data.shape[1] > MAX_NODE_WORDS:
        raise ValueError(
            f"node_words={data.shape[1]} exceeds the {MAX_NODE_WORDS}-word "
            f"(256 B) single-LOAD limit (PULSE S4.1)"
        )
    cap = data.shape[0]
    if bounds is None:
        if cap % num_shards != 0:
            raise ValueError(f"capacity {cap} not divisible by num_shards {num_shards}")
        per = cap // num_shards
        bounds = [i * per for i in range(num_shards)] + [cap]
    if perms is None:
        perms = [PERM_READ | PERM_WRITE] * (len(bounds) - 1)
    if heap is None:
        # raw arenas are treated as fully occupied: no free list, bump at the
        # shard end, so ALLOC commits fault instead of clobbering live rows.
        # Builders that know their occupancy pass real cursors (ArenaBuilder).
        heap = np.zeros((len(bounds) - 1, HEAP_WORDS), np.int32)
        heap[:, H_FREE] = NULL
        heap[:, H_BUMP] = np.asarray(bounds[1:], np.int32)
    return Arena(
        data=data,
        bounds=jnp.asarray(bounds, jnp.int32),
        perms=jnp.asarray(perms, jnp.int32),
        heap=jnp.asarray(heap, jnp.int32),
    )


def remap_shards(arena: Arena, new_num_shards: int) -> Arena:
    """Re-partition an arena to ``new_num_shards`` (exact 2x grow or shrink).

    Pointers are *global* row indices and the partition is by address range,
    so resharding never rewrites a pointer: growing 2x splits every shard's
    range at its midpoint and only the translation base table (``bounds``),
    the permission table, and the per-shard allocator registers change.
    The one data mutation is free-chain surgery: a parent's intrusive
    free list is partitioned between the two children preserving relative
    LIFO (pop) order, which rewrites the link word of free (dead) slots.

    Shrinking 2x merges adjacent pairs: the merged free chain is the left
    child's chain then the right's, and a left-child bump hole below the
    midpoint is pushed onto the free chain when the right child has
    allocations (the bump register cannot represent a hole).  Epoch/commit
    registers are bookkeeping: a split duplicates them, a merge takes the
    max, so grow-then-shrink round-trips.

    Returns a new Arena; the input is never modified.
    """
    P = arena.num_shards
    Q = int(new_num_shards)
    if Q == P:
        return arena
    if Q != 2 * P and P != 2 * Q:
        raise ValueError(f"remap_shards supports exact 2x changes, {P} -> {Q}")
    bounds = np.asarray(arena.bounds, np.int64)
    data = np.array(arena.data)  # private copy: free-chain links may move
    heap_old = np.asarray(arena.heap)
    perms_old = np.asarray(arena.perms)

    def walk(head: int) -> list[int]:
        out, p = [], int(head)
        while p != NULL:
            out.append(p)
            p = int(data[p, 0])
        return out

    def relink(slots: list[int]) -> int:
        for i, p in enumerate(slots):
            data[p, 0] = slots[i + 1] if i + 1 < len(slots) else NULL
        return slots[0] if slots else NULL

    new_bounds = np.zeros(Q + 1, np.int64)
    new_bounds[-1] = bounds[-1]
    new_perms = np.zeros(Q, np.int32)
    new_heap = np.zeros((Q, HEAP_WORDS), np.int32)
    if Q == 2 * P:  # grow: split each range at its midpoint
        for s in range(P):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if (hi - lo) % 2:
                raise ValueError(f"shard {s} range has odd size {hi - lo}")
            mid = (lo + hi) // 2
            new_bounds[2 * s], new_bounds[2 * s + 1] = lo, mid
            new_perms[2 * s] = new_perms[2 * s + 1] = perms_old[s]
            slots = walk(heap_old[s, H_FREE])
            new_heap[2 * s, H_FREE] = relink([p for p in slots if p < mid])
            new_heap[2 * s + 1, H_FREE] = relink([p for p in slots if p >= mid])
            b = int(heap_old[s, H_BUMP])
            new_heap[2 * s, H_BUMP] = min(b, mid)
            new_heap[2 * s + 1, H_BUMP] = max(b, mid)
            for w in (H_EPOCH, H_COMMITS):
                new_heap[2 * s, w] = new_heap[2 * s + 1, w] = heap_old[s, w]
    else:  # shrink: merge adjacent pairs
        for t in range(Q):
            s0, s1 = 2 * t, 2 * t + 1
            lo, mid = int(bounds[s0]), int(bounds[s1])
            if perms_old[s0] != perms_old[s1]:
                raise ValueError(
                    f"cannot merge shards {s0}/{s1}: permission mismatch"
                )
            new_bounds[t] = lo
            new_perms[t] = perms_old[s0]
            b0, b1 = int(heap_old[s0, H_BUMP]), int(heap_old[s1, H_BUMP])
            slots = walk(heap_old[s0, H_FREE]) + walk(heap_old[s1, H_FREE])
            if b1 > mid:
                if b0 < mid:  # hole below the midpoint: representable only
                    for p in range(b0, mid):  # as free-chain slots
                        data[p] = 0
                    slots = slots + list(range(b0, mid))
                nb = b1
            else:
                nb = b0
            new_heap[t, H_FREE] = relink(slots)
            new_heap[t, H_BUMP] = nb
            for w in (H_EPOCH, H_COMMITS):
                new_heap[t, w] = max(heap_old[s0, w], heap_old[s1, w])
    return Arena(
        data=jnp.asarray(data),
        bounds=jnp.asarray(new_bounds, jnp.int32),
        perms=jnp.asarray(new_perms, jnp.int32),
        heap=jnp.asarray(new_heap, jnp.int32),
    )


def load_node(arena_data: jax.Array, ptr: jax.Array) -> jax.Array:
    """The single aggregated LOAD of one iteration (PULSE S4.1).

    ``ptr`` may be NULL/out-of-range (a request that already terminated or
    faulted); we clamp the row index so the gather stays in bounds and leave
    fault detection to the translation layer.  Works for scalar or batched
    ``ptr`` (leading batch dims broadcast).
    """
    cap = arena_data.shape[0]
    safe = jnp.clip(ptr, 0, cap - 1)
    return jnp.take(arena_data, safe, axis=0)


def store_node(arena_data: jax.Array, ptr: jax.Array, record: jax.Array) -> jax.Array:
    """STORE counterpart (used by modification iterators; S4.1 footnote 4)."""
    cap = arena_data.shape[0]
    safe = jnp.clip(ptr, 0, cap - 1)
    return arena_data.at[safe].set(record)


class ArenaBuilder:
    """Host-side numpy allocator for building linked structures fast.

    Allocation policies (Appendix Fig. 5):
      * ``sequential``  -- bump allocator; range partitioning then gives the
        paper's *partitioned* allocation (subtrees land on one node).
      * ``interleaved`` -- round-robins consecutive allocations across shards
        (glibc-style *uniform* allocation; maximizes cross-node traversals).
    """

    def __init__(
        self,
        capacity: int,
        node_words: int,
        num_shards: int = 1,
        policy: str = "sequential",
    ):
        if node_words > MAX_NODE_WORDS:
            raise ValueError(f"node_words > {MAX_NODE_WORDS}")
        if capacity % num_shards != 0:
            raise ValueError("capacity must divide evenly across shards")
        self.capacity = capacity
        self.node_words = node_words
        self.num_shards = num_shards
        self.policy = policy
        self.data = np.zeros((capacity, node_words), np.int32)
        self.per_shard = capacity // num_shards
        self._free: list[int] = []  # LIFO free list (host twin of M_FREE)
        if policy == "sequential":
            self._next = 0
        elif policy == "interleaved":
            self._cursor = np.array(
                [s * self.per_shard for s in range(num_shards)], np.int64
            )
            self._rr = 0
        else:
            raise ValueError(f"unknown allocation policy {policy!r}")

    def free(self, ptrs) -> None:
        """Host twin of the device FREE commit: zero the slots and push them
        onto the free list (LIFO), so a later ``alloc`` reuses them before
        touching never-used capacity -- exactly the device allocator's
        pop-free-then-bump order."""
        for p in np.atleast_1d(np.asarray(ptrs, np.int64)):
            p = int(p)
            if not (0 <= p < self.capacity):
                raise ValueError(f"free of out-of-range slot {p}")
            self.data[p] = 0
            self._free.append(p)

    def alloc(self, n: int = 1) -> np.ndarray:
        """Returns the global addresses of ``n`` new nodes."""
        if self._free:
            take = min(n, len(self._free))
            out = np.asarray(
                [self._free.pop() for _ in range(take)], np.int32
            )
            if take == n:
                return out
            return np.concatenate([out, self.alloc(n - take)])
        if self.policy == "sequential":
            if self._next + n > self.capacity:
                raise MemoryError("arena exhausted")
            out = np.arange(self._next, self._next + n, dtype=np.int32)
            self._next += n
            return out
        # interleaved: one address per round-robin'd shard
        out = np.empty(n, np.int32)
        for i in range(n):
            s = self._rr
            tried = 0
            while self._cursor[s] >= (s + 1) * self.per_shard:
                s = (s + 1) % self.num_shards
                tried += 1
                if tried > self.num_shards:
                    raise MemoryError("arena exhausted")
            out[i] = self._cursor[s]
            self._cursor[s] += 1
            self._rr = (s + 1) % self.num_shards
        return out

    def write(self, ptrs: np.ndarray, records: np.ndarray) -> None:
        """Write node records; records narrower than ``node_words`` are
        zero-padded (several structure families with different record widths
        can share one pooled heap, as in the paper's memory nodes)."""
        records = np.asarray(records, np.int32)
        w = records.shape[-1]
        if w > self.node_words:
            raise ValueError(f"record width {w} > arena node_words {self.node_words}")
        self.data[np.asarray(ptrs), :w] = records
        if w < self.node_words:
            self.data[np.asarray(ptrs), w:] = 0

    def finish(self, perms: Sequence[int] | None = None) -> Arena:
        """Freeze into an Arena, threading the allocator state into the
        per-shard heap registers so device-side ALLOC/FREE commits continue
        exactly where host-side construction stopped."""
        heap = np.zeros((self.num_shards, HEAP_WORDS), np.int32)
        heap[:, H_FREE] = NULL
        for s in range(self.num_shards):
            lo, hi = s * self.per_shard, (s + 1) * self.per_shard
            if self.policy == "sequential":
                heap[s, H_BUMP] = min(max(self._next, lo), hi)
            else:
                heap[s, H_BUMP] = int(self._cursor[s])
        # thread outstanding host frees into the intrusive per-shard chains
        # (word 0 of a freed slot is the next-free link); LIFO order is
        # preserved so device pops mirror host pops
        for p in self._free:
            s = p // self.per_shard
            self.data[p] = 0
            self.data[p, 0] = heap[s, H_FREE]
            heap[s, H_FREE] = p
        return make_arena(
            self.data, num_shards=self.num_shards, perms=perms, heap=heap
        )
