"""PULSE dispatch engine: the offload cost model (paper S4.1).

The CPU node offloads an iterator iff its per-iteration compute time fits
under the accelerator's memory time: ``t_c <= eta * t_d`` with
``t_c = t_i * N`` (N instructions, t_i per-instruction time at the logic
pipeline clock) and ``t_d`` the single aggregated LOAD's latency + transfer.
``eta = m/n`` mirrors the provisioned logic:memory pipeline ratio (S4.2).

Two N estimators:
  * ISA programs: exact upper bound = program length (forward-only jumps).
  * traced JAX iterators: jaxpr equation count of next+end on abstract
    values -- the static-analysis stand-in.

Defaults mirror the paper's prototype: 250 MHz pipelines (t_i = 4 ns),
132 ns memory pipeline latency (TCAM 22 + controller 110, Fig. 10), 25 GB/s
per-node bandwidth, eta = 0.75 (m=3, n=4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.iterator import PulseIterator


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    t_i_ns: float = 4.0  # per-instruction time (250 MHz logic pipeline)
    mem_latency_ns: float = 132.0  # TCAM + memory controller (Fig. 10)
    mem_bw_gbps: float = 25.0  # per-node bandwidth cap (S6 setup)
    eta: float = 0.75  # m/n = 3/4 in the prototype (S4.2)
    network_ns: float = 426.3  # network stack traversal (Fig. 10)
    scheduler_ns: float = 5.1
    interconnect_ns: float = 47.0
    logic_ns: float = 10.0  # per-iteration logic latency (Fig. 10)

    def t_d_ns(self, node_bytes: int) -> float:
        return self.mem_latency_ns + node_bytes / self.mem_bw_gbps


@dataclasses.dataclass(frozen=True)
class OffloadDecision:
    offload: bool
    t_c_ns: float
    t_d_ns: float
    ratio: float  # t_c / t_d  (Table 3's column)
    n_instructions: int
    reason: str


# Per-primitive issue cost on the logic pipeline.  The FPGA pipeline operates
# on whole registers/words per cycle: data movement and layout ops are wires
# (cost 0); scalar/elementwise ALU ops cost one issue slot; reductions over
# the <=64-word node record are a pipelined compare tree (cost 2).
_ALU = {
    "add", "sub", "mul", "div", "rem", "neg", "sign", "abs", "and", "or",
    "xor", "not", "min", "max", "eq", "ne", "lt", "le", "gt", "ge",
    "select_n", "clamp", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "integer_pow", "nextafter",
}
_REDUCE = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_and", "reduce_or",
    "argmax", "argmin", "reduce_prod", "cumsum", "cummax", "cummin",
}
_MEMLIKE = {"gather", "scatter", "dynamic_slice", "dynamic_update_slice",
            "scatter-add", "scatter_add", "sort"}


def _op_cost(prim_name: str) -> int:
    if prim_name in _ALU:
        return 1
    if prim_name in _REDUCE:
        return 2
    if prim_name in _MEMLIKE:
        return 1
    return 0  # broadcast/reshape/convert/slice/concat/iota/...: wires


def count_instructions(it: PulseIterator, node_words: int) -> int:
    """Static instruction-count analysis for the t_c model.

    ISA programs: longest path through the forward-jump-only CFG (exact
    worst-case issue count -- forward edges make this a DAG).
    Traced iterators: weighted jaxpr op count (see _op_cost).
    """
    # ISA path: exact DAG longest path.
    for fn in (getattr(it, "step_fn", None), getattr(it, "mut_fn", None)):
        if fn is not None and hasattr(fn, "__wrapped_program__"):
            return isa_longest_path(fn.__wrapped_program__)

    node = jax.ShapeDtypeStruct((node_words,), jnp.int32)
    ptr = jax.ShapeDtypeStruct((), jnp.int32)
    scratch = jax.ShapeDtypeStruct((it.scratch_words,), jnp.int32)

    def depth(fn) -> int:
        jaxpr = jax.make_jaxpr(fn)(node, ptr, scratch)
        return _critical_path(jaxpr.jaxpr)

    # mutating iterators: the fused read-modify-stage body is the circuit
    if getattr(it, "mut_fn", None) is not None:
        return depth(it.mut_fn) + 2

    # end() and next() share the fetched node: the circuit evaluates them
    # side by side; latency adds only along the dependency chain.  We charge
    # the max depth plus a 2-op epilogue (done-mux + pointer-mux).
    return max(depth(it.end_fn), depth(it.next_fn)) + 2


def isa_longest_path(prog) -> int:
    """Worst-case instructions per iteration: longest path in the forward CFG."""
    from repro.core import isa as isa_mod

    code = prog.code
    T = code.shape[0]
    cost = [0] * (T + 1)
    for i in range(T - 1, -1, -1):
        op, a, b, imm = (int(x) for x in code[i])
        if op in (isa_mod.RETURN, isa_mod.NEXT_ITER, isa_mod.HALT):
            cost[i] = 1
        elif op == isa_mod.JMP:
            cost[i] = 1 + cost[imm]
        elif op in (isa_mod.JEQ, isa_mod.JNE, isa_mod.JLT, isa_mod.JLE,
                    isa_mod.JGT, isa_mod.JGE):
            cost[i] = 1 + max(cost[i + 1], cost[imm])
        else:
            cost[i] = 1 + cost[i + 1]
    return cost[0]


def _critical_path(jaxpr) -> int:
    """Weighted critical-path depth of the dataflow graph: the logic pipeline
    is a pipelined circuit, so per-iteration latency follows the longest
    dependency chain, not the op count."""
    depth: dict = {}

    def d_of(v) -> int:
        return depth.get(id(v), 0)

    worst = 0
    for eqn in jaxpr.eqns:
        base = max((d_of(v) for v in eqn.invars), default=0)
        inner = 0
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):  # cond/scan bodies add their own depth
                inner = max(inner, _critical_path(v.jaxpr))
            elif isinstance(v, (list, tuple)):
                for u in v:
                    if hasattr(u, "jaxpr"):
                        inner = max(inner, _critical_path(u.jaxpr))
        d = base + _op_cost(eqn.primitive.name) + inner
        for o in eqn.outvars:
            depth[id(o)] = d
        worst = max(worst, d)
    return worst


def offload_decision(
    it: PulseIterator,
    node_words: int,
    accel: AcceleratorSpec | None = None,
    *,
    eta: float | None = None,
) -> OffloadDecision:
    accel = accel or AcceleratorSpec()
    eta = accel.eta if eta is None else eta
    n = count_instructions(it, node_words)
    t_c = accel.t_i_ns * n
    t_d = accel.t_d_ns(node_words * 4)
    ratio = t_c / t_d
    ok = t_c <= eta * t_d
    reason = (
        f"t_c={t_c:.1f}ns (N={n}) {'<=' if ok else '>'} eta*t_d="
        f"{eta * t_d:.1f}ns -> {'offload' if ok else 'run at CPU node'}"
    )
    return OffloadDecision(ok, t_c, t_d, ratio, n, reason)


@dataclasses.dataclass(frozen=True)
class ScheduleDecision:
    """Which distributed superstep schedule the dispatch engine picks.

    Mirrors the offload decision's shape: a closed-form model of where a
    superstep's time goes, and the schedule that hides the larger share.
    ``overlap_frac`` is the fraction of a serialized superstep the
    wavefront-pipelined schedule can hide (min of the two phases over their
    sum): >0 whenever both phases are nonzero, so multi-shard traversals
    default to ``pipelined`` unless one phase fully dominates.
    """

    schedule: str  # "pipelined" | "fused" | "local"
    t_local_ns: float  # modeled local-chase time per superstep
    t_fabric_ns: float  # modeled fabric time per superstep
    overlap_frac: float  # serialized time hidden by overlapping the two
    reason: str


def schedule_decision(
    it: PulseIterator,
    node_words: int,
    num_shards: int,
    accel: AcceleratorSpec | None = None,
    *,
    k_local: int = 4,
    min_overlap: float = 0.05,
) -> ScheduleDecision:
    """Pick the superstep schedule for a distributed traversal (S5 + the
    rack-scale overlap lever).

    The local phase runs ``k_local`` iterations, each bounded by the larger
    of compute (t_i * N) and the aggregated LOAD (t_d); the fabric phase is
    the network-stack traversal plus per-link interconnect time.  When
    neither phase dominates, pipelining the two wavefronts hides
    ``min(t_local, t_fabric)`` of every superstep, so the engine picks
    ``pipelined``; below ``min_overlap`` the double-buffered schedule's
    extra bookkeeping is not worth the hidden time and the serialized fused
    loop wins.
    """
    accel = accel or AcceleratorSpec()
    if num_shards <= 1:
        return ScheduleDecision(
            "local", 0.0, 0.0, 0.0, "single memory node: nothing to overlap"
        )
    n = count_instructions(it, node_words)
    t_local = k_local * max(accel.t_i_ns * n, accel.t_d_ns(node_words * 4))
    t_fabric = (
        accel.network_ns
        + accel.scheduler_ns
        + accel.interconnect_ns * (num_shards - 1)
    )
    overlap = min(t_local, t_fabric) / (t_local + t_fabric)
    schedule = "pipelined" if overlap >= min_overlap else "fused"
    reason = (
        f"t_local={t_local:.0f}ns t_fabric={t_fabric:.0f}ns -> overlap hides "
        f"{overlap:.0%} of a serialized superstep -> {schedule}"
    )
    return ScheduleDecision(schedule, t_local, t_fabric, overlap, reason)


def workload_table(entries):
    """Reproduce the shape of paper Table 3: name, t_c/t_d, iterations.

    ``entries`` is a list of (name, iterator, node_words, iters).
    """
    rows = []
    accel = AcceleratorSpec()
    for name, it, node_words, iters in entries:
        d = offload_decision(it, node_words, accel)
        rows.append(
            dict(name=name, tc_td=round(d.ratio, 3), iterations=iters,
                 offload=d.offload, n_instructions=d.n_instructions)
        )
    return rows
