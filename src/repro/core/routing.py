"""Distributed pointer traversals: the in-network switch as supersteps (S5).

The paper routes in-flight traversal requests between memory nodes with a
programmable switch that holds only the range-partition base table.  On a TPU
mesh the ICI collectives *are* the programmable fabric, so we route **batches**
of fixed-size request records with ``all_to_all`` in bulk-synchronous
supersteps.  The paper's key properties are preserved exactly:

  * a cross-node hop never bounces through the CPU node (compare
    ``return_to_cpu=True``, the paper's PULSE-ACC ablation, Fig. 9);
  * the request and the response share one wire format, so any shard can
    continue any traversal it receives (S5 "continuing stateful iterator
    execution");
  * the switch knows only ``bounds`` (hierarchical translation, Fig. 6);
    per-shard translation/protection happens at the owning shard.

Record wire format (R = 6 + S [+ 4 + W] int32 words):
  [id, home_shard, cur_ptr, status, iters, hops, scratch_pad...,
   m_op, m_tgt, m_mask, m_expect, m_data...]

The trailing mutation payload exists only for *mutating* iterators (the
write path): a staged mutation rides the same all_to_all/ring fabric as the
traversal itself, routed to the shard that owns its commit target, where the
per-shard commit phase applies it (``_commit_phase``).  Read-only records
keep the original 6 + S layout, so the read path's wire accounting is
untouched.
"""

from __future__ import annotations

import dataclasses
import time
import weakref

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map, shard_map_unchecked
from repro.core import translation
from repro.core.arena import (
    H_BUMP,
    H_COMMITS,
    H_EPOCH,
    H_FREE,
    M_ALLOC,
    M_CAS,
    M_FREE,
    M_NONE,
    M_STORE,
    NULL,
    PERM_READ,
    PERM_WRITE,
    Arena,
    mut_width,
)
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_EMPTY,
    STATUS_FAULT,
    STATUS_MAXED,
    PulseIterator,
    mut_step_batch,
    step_batch,
)

F_ID, F_HOME, F_PTR, F_STATUS, F_ITERS, F_HOPS, F_SCRATCH = 0, 1, 2, 3, 4, 5, 6


def record_width(scratch_words: int, mut_words: int = 0) -> int:
    return F_SCRATCH + scratch_words + mut_words


@dataclasses.dataclass(frozen=True)
class ReplicaPlan:
    """Static hot-shard replication wiring (R=2) for the READ path.

    ``primary_map[r]`` names the primary shard whose rows replica-holder
    ``r`` mirrors (-1: r holds no replica); ``replica_map[p]`` is the
    inverse (-1: p is unreplicated).  Both are tuples so the plan is
    hashable and can key the compiled-superstep caches.

    ``policy`` is the read fan-out rule the switch applies per record:

      * ``"primary"``  -- never redirect (replicas are cold standbys);
      * ``"failover"`` -- redirect a read to the replica only while the
        primary is marked dead in the traced ``dead_mask``;
      * ``"spread"``   -- load-balance: odd request ids read from the
        replica, even ids from the primary (dead primaries always
        redirect).  Replicas are bit-identical by construction, so the
        copy that serves a read never changes its result.
    """

    primary_map: tuple
    replica_map: tuple
    policy: str = "failover"

    def __post_init__(self):
        if self.policy not in ("primary", "failover", "spread"):
            raise ValueError(f"unknown replica policy {self.policy!r}")
        if len(self.primary_map) != len(self.replica_map):
            raise ValueError("primary_map / replica_map length mismatch")

    @property
    def num_shards(self) -> int:
        return len(self.primary_map)

    @property
    def replicated(self) -> tuple:
        """Primaries that have a live replica."""
        return tuple(p for p, r in enumerate(self.replica_map) if r >= 0)


def make_replica_plan(
    num_shards: int, primaries=None, *, policy: str = "failover"
) -> ReplicaPlan:
    """Build an R=2 plan: primary ``p``'s rows are mirrored on shard
    ``(p + num_shards // 2) % num_shards`` (the antipode -- a correlated
    rack failure of neighbours never takes both copies).  ``primaries``
    defaults to every shard; each holder mirrors at most one primary."""
    if primaries is None:
        primaries = range(num_shards)
    primary_map = [-1] * num_shards
    replica_map = [-1] * num_shards
    for p in primaries:
        r = (p + max(1, num_shards // 2)) % num_shards
        if primary_map[r] != -1:
            raise ValueError(
                f"replica holder {r} already mirrors shard {primary_map[r]}"
            )
        primary_map[r] = int(p)
        replica_map[p] = int(r)
    return ReplicaPlan(tuple(primary_map), tuple(replica_map), policy)


@dataclasses.dataclass
class ReplicaContext:
    """Per-call replication operands for ``distributed_execute``.

    ``rep_rows`` mirrors the arena-data layout ``(capacity, node_words)``
    sharded over the mesh axis: replica-holder ``r``'s slice is a copy of
    ``primary_map[r]``'s rows (zeros when r holds none) -- each shard
    stores at most one extra shard's rows, the R=2 memory budget.
    ``dead_mask`` is the traced per-call failure-detector verdict, so the
    same compiled superstep serves healthy and degraded rounds.
    """

    plan: ReplicaPlan
    rep_rows: object  # (capacity, node_words) int32, holder-sharded
    dead_mask: object  # (P,) bool


def _serve_shard(owner, rec_id, rep_ctx):
    """The switch's serve map: which shard answers a read at ``owner``'s
    range under the fan-out policy.  Identity when replication is off."""
    if rep_ctx is None:
        return owner
    replica_arr, dead_mask, policy = rep_ctx
    num = replica_arr.shape[0]
    safe = jnp.clip(owner, 0, num - 1)
    alt = replica_arr[safe]
    # a dead replica holder is no fallback: its copy died with it
    has_alt = (alt >= 0) & (owner >= 0) & ~dead_mask[jnp.clip(alt, 0, num - 1)]
    dead = dead_mask[safe]
    if policy == "spread":
        redirect = has_alt & (dead | ((rec_id % 2) == 1))
    elif policy == "failover":
        redirect = has_alt & dead
    else:  # "primary"
        redirect = jnp.zeros_like(has_alt)
    return jnp.where(redirect, alt, owner).astype(jnp.int32)


def pack_requests(ids, home, ptr, scratch, mut_words: int = 0) -> jnp.ndarray:
    B, S = scratch.shape
    rec = jnp.zeros((B, record_width(S, mut_words)), jnp.int32)
    rec = rec.at[:, F_ID].set(ids)
    rec = rec.at[:, F_HOME].set(home)
    rec = rec.at[:, F_PTR].set(ptr)
    rec = rec.at[:, F_STATUS].set(STATUS_ACTIVE)
    return rec.at[:, F_SCRATCH : F_SCRATCH + S].set(scratch)


def empty_records(n: int, scratch_words: int) -> jnp.ndarray:
    rec = jnp.zeros((n, record_width(scratch_words)), jnp.int32)
    return rec.at[:, F_STATUS].set(STATUS_EMPTY)


@dataclasses.dataclass
class RoutingStats:
    supersteps: int
    crossings: np.ndarray  # (B,) network crossings per request (Fig. 2c/9)
    routed_per_step: list  # valid records exchanged per superstep
    active_per_step: list = dataclasses.field(default_factory=list)
    wire_words_per_step: list = dataclasses.field(default_factory=list)
    # int32 words shipped across off-shard links per superstep (the BSP
    # all_to_all payload: num_shards * (num_shards-1) * link_capacity * R;
    # 0 for compacted local-only supersteps that skip the fabric entirely)
    capacity_per_step: list = dataclasses.field(default_factory=list)
    local_only_steps: int = 0  # supersteps that skipped the all_to_all
    # Fused executions stay device-resident for the whole loop, so the
    # per-step lists above are empty and only this aggregate (decoded from
    # traced counters after the while_loop exits) is available.  NOTE: wire
    # words are the *modeled* switch payload (the paper's BSP accounting at
    # the scheduled capacity rung) on both paths; physically, the dispatched
    # path compiles a buffer per rung while the fused path always exchanges
    # the static base-capacity buffer (shapes cannot be traced) -- fused
    # trades that physical shrinkage for zero per-hop host dispatch, and
    # only its local-only lax.cond skips remove real transfers.
    wire_words_total: int | None = None
    fused: bool = False
    # which superstep schedule produced this run ("dispatched" | "fused" |
    # "pipelined") and which fabric carried the records ("dense" all_to_all
    # | "ring" ppermute distance classes).  The pipelined schedule overlaps
    # the in-flight wavefront's fabric time with the resident wavefront's
    # local chase; scheduling decisions, wire accounting, and results are
    # bit-identical to the fused schedule.
    schedule: str = "dispatched"
    fabric: str = "dense"
    # write path: mutations applied by per-shard commit phases during this
    # execution (CAS misses included -- they consumed a serialized commit
    # slot), and commit epochs advanced (the per-shard lock-generation
    # counter; one per superstep that applied >= 1 mutation on some shard)
    commits: int = 0
    epochs: int = 0

    @property
    def total_wire_words(self) -> int:
        if self.wire_words_total is not None:
            return int(self.wire_words_total)
        return int(sum(self.wire_words_per_step))

    @property
    def ring_hops(self) -> int:
        """Physical ppermute hops a ring fabric executed (P-1 distance
        classes per routed superstep; 0 on the dense fabric)."""
        if self.fabric != "ring":
            return 0
        routed = self.supersteps - self.local_only_steps
        return routed * max(0, self._num_shards - 1) if self._num_shards else 0

    _num_shards: int = 0


@dataclasses.dataclass
class ExecutableCacheStats:
    """Counters for the compiled-superstep caches (regression-tested: serving
    quanta and repeated engine calls with same-shaped pools must not retrace).

    ``traces`` counts actual Python traces of the step/loop bodies (bumped
    from inside the traced function, so it only moves when XLA recompiles);
    ``hits``/``misses`` count executable-cache lookups.
    """

    hits: int = 0
    misses: int = 0
    traces: int = 0

    def reset(self) -> None:
        self.hits = self.misses = self.traces = 0


CACHE_STATS = ExecutableCacheStats()


# Kernel-backend iterator bodies: the vectorized fused next+end `logic_fn`
# compiled by kernels/pulse_chase (one entry per iterator; lazily imported to
# avoid a routing <-> kernels import cycle).  Threading the distributed local
# superstep through this shares the exact iterator body the accelerator
# kernel executes, so the overlapped local step is the kernel fast path
# end-to-end (engine backend="kernel" on a mesh).
_KERNEL_LOGIC: dict = {}


def _is_vm_backed(it: PulseIterator) -> bool:
    """True for iterators whose step/mut function is the ISA VM (carries the
    ``__wrapped_program__`` marker the dispatch cost model also keys on)."""
    return any(
        hasattr(fn, "__wrapped_program__") for fn in (it.step_fn, it.mut_fn)
    )


def _kernel_logic(it: PulseIterator):
    fn = _KERNEL_LOGIC.get(it)
    if fn is None:
        from repro.kernels.pulse_chase import ops as chase_ops

        fn = _KERNEL_LOGIC[it] = chase_ops.iterator_logic(it)
    return fn


def _local_superstep(
    it: PulseIterator,
    pool: jnp.ndarray,  # (L, R) local request pool
    arena_rows: jnp.ndarray,  # (rows_per_shard, W) this shard's arena rows
    bounds: jnp.ndarray,  # (P+1,) switch base table (replicated)
    perms: jnp.ndarray,  # (P,)   protection bits (replicated)
    my_shard: jnp.ndarray,  # () int32
    *,
    k_local: int,
    max_iters: int,
    adaptive: bool = False,
    logic_fn=None,
    rep=None,
    elide_access_check: bool = False,
):
    """Run up to ``k_local`` iterations for locally-owned ACTIVE requests.

    ``adaptive=True`` exits as soon as no record can make local progress
    (active, locally owned, non-NULL): the remaining iterations would be
    identities, so results are bit-identical while remote-heavy supersteps
    stop paying for dead chase work.  ``logic_fn`` substitutes the
    pulse_chase kernel's vectorized iterator body for the per-lane vmap.

    ``rep = (rep_rows, primary_arr, dead_mask, policy)`` enables hot-shard
    replica serving: this shard additionally chases records whose pointer
    lands in its mirrored primary's range (always under ``"spread"``, only
    while the primary is dead under ``"failover"``), reading from its
    replica rows.  A shard marked dead in ``dead_mask`` refuses service on
    its *own* range -- its arena is the one that failed.

    ``elide_access_check=True`` replaces the per-shard PERM_READ probe with
    constant True.  Only ``distributed_execute`` sets it, and only when the
    iterator's pulse-verify certificate proves the traversal read-only AND
    the host has checked every shard grants PERM_READ -- then the probe is
    constant-true by construction and eliding it is bit-identical.
    """
    S = it.scratch_words
    lo = bounds[my_shard]
    hi = bounds[my_shard + 1]
    if elide_access_check:
        perm_ok = True
    else:
        perm_ok = translation.check_access(perms, my_shard, PERM_READ)
    rep_kwargs = {}
    if rep is not None:
        rep_rows, primary_arr, dead_mask, policy = rep
        num = primary_arr.shape[0]
        prim = primary_arr[my_shard]
        prim_safe = jnp.clip(prim, 0, num - 1)
        holds = (prim >= 0) & ~dead_mask[my_shard]
        rep_on = holds if policy == "spread" else (holds & dead_mask[prim_safe])
        rep_kwargs = dict(
            rep_data=rep_rows,
            rep_lo=bounds[prim_safe],
            rep_hi=bounds[prim_safe + 1],
            rep_base=jnp.int32(0),
            rep_on=rep_on,
            rep_perm_ok=translation.check_access(perms, prim_safe, PERM_READ),
        )
        # a dead shard's own arena is gone: collapse its servable range
        hi = jnp.where(dead_mask[my_shard], lo, hi)

    def step(st):
        ptr, scratch, status, iters = st
        return step_batch(
            it,
            arena_rows,
            ptr,
            scratch,
            status,
            iters,
            max_iters=max_iters,
            local_lo=lo,
            local_hi=hi,
            perm_ok=perm_ok,
            logic_fn=logic_fn,
            **rep_kwargs,
        )

    ptr = pool[:, F_PTR]
    scratch = pool[:, F_SCRATCH:]
    status = pool[:, F_STATUS]
    iters = pool[:, F_ITERS]
    if adaptive:
        # chaseable = records a step_batch call could touch (including ones
        # that would fault on the protection check): skipping is only legal
        # when the iteration is an identity for every record in the pool
        def chaseable(ptr, status):
            return jnp.any(
                (status == STATUS_ACTIVE) & (ptr >= lo) & (ptr < hi) & (ptr != NULL)
            )

        def cond(st):
            i, (ptr, _, status, _) = st
            return (i < k_local) & chaseable(ptr, status)

        def body(st):
            i, inner = st
            return i + 1, step(inner)

        _, (ptr, scratch, status, iters) = jax.lax.while_loop(
            cond, body, (jnp.int32(0), (ptr, scratch, status, iters))
        )
    else:
        ptr, scratch, status, iters = jax.lax.fori_loop(
            0, k_local, lambda _, st: step(st), (ptr, scratch, status, iters)
        )
    pool = pool.at[:, F_PTR].set(ptr)
    pool = pool.at[:, F_SCRATCH:].set(scratch)
    pool = pool.at[:, F_STATUS].set(status)
    return pool.at[:, F_ITERS].set(iters)


def _commit_phase(pool, rows, heap_row, lo, hi, my_shard, perm_w, *, S, W):
    """Per-shard commit phase: apply every locally-committable staged
    mutation, one at a time, in deterministic (class, slot, id) order.

    This is the write path's serialization point -- the stand-in for the
    paper's per-node lock.  All chases in a superstep ran *before* this
    phase, so readers see a consistent pre-commit snapshot; concurrent
    writers to one shard serialize through the sorted scatter below
    (stores/CAS first by target slot then request id, then frees, then
    allocs by id -- so a slot freed this phase is immediately reusable by a
    later alloc, exactly like the sequential oracle).  A shard whose range
    lost PERM_WRITE faults every eligible commit instead of applying it.

    Returns ``(pool, rows, heap_row)`` -- arena rows and the heap registers
    [free_head, bump, epoch, commits] are carried state, not loop
    invariants, from here on.
    """
    MB = F_SCRATCH + S
    L = pool.shape[0]
    m_op = pool[:, MB]
    m_tgt = pool[:, MB + 1]
    status = pool[:, F_STATUS]
    pend = (m_op != M_NONE) & (status != STATUS_EMPTY)
    is_alloc = m_op == M_ALLOC
    tgt_local = (m_tgt >= lo) & (m_tgt < hi)
    eligible = pend & jnp.where(is_alloc, pool[:, F_HOME] == my_shard, tgt_local)
    ok = jnp.asarray(perm_w)

    def apply_one(order, i, carry):
        pool, rows, free_head, bump = carry
        r = order[i]
        rec = jax.lax.dynamic_index_in_dim(pool, r, 0, keepdims=False)
        act = eligible[r] & ok
        op = rec[MB]
        tgt = rec[MB + 1]
        data = jax.lax.dynamic_slice(rec, (MB + 4,), (W,))
        maskb = ((rec[MB + 2] >> jnp.arange(W, dtype=jnp.int32)) & 1).astype(bool)

        # STORE / CAS: masked write; CAS guards on the lowest masked word
        toff = jnp.clip(tgt - lo, 0, rows.shape[0] - 1)
        old = jax.lax.dynamic_index_in_dim(rows, toff, 0, keepdims=False)
        cas_ok = old[jnp.argmax(maskb).astype(jnp.int32)] == rec[MB + 3]
        do_store = act & ((op == M_STORE) | ((op == M_CAS) & cas_ok))
        # FREE: zero the slot, word 0 becomes the free-list link
        do_free = act & (op == M_FREE)
        freed = jnp.zeros((W,), jnp.int32).at[0].set(free_head)
        newrow = jnp.where(do_store, jnp.where(maskb, data, old),
                           jnp.where(do_free, freed, old))
        rows = jax.lax.dynamic_update_index_in_dim(rows, newrow, toff, 0)
        free_head = jnp.where(do_free, tgt, free_head)

        # ALLOC: pop the free list, else bump; exhaustion faults the record
        do_alloc = act & (op == M_ALLOC)
        have_free = free_head != NULL
        slot = jnp.where(have_free, free_head, bump)
        can = have_free | (bump < hi)
        aoff = jnp.clip(slot - lo, 0, rows.shape[0] - 1)
        arow = jax.lax.dynamic_index_in_dim(rows, aoff, 0, keepdims=False)
        next_free = arow[0]
        fresh = jnp.where(maskb, data, 0)
        rows = jax.lax.dynamic_update_index_in_dim(
            rows, jnp.where(do_alloc & can, fresh, arow), aoff, 0
        )
        free_head = jnp.where(do_alloc & can & have_free, next_free, free_head)
        bump = jnp.where(do_alloc & can & ~have_free, bump + 1, bump)
        # the claimed global address lands in scratch[m_tgt]
        sidx = F_SCRATCH + jnp.clip(tgt, 0, S - 1)
        rec = rec.at[sidx].set(jnp.where(do_alloc & can, slot, rec[sidx]))
        rec = rec.at[F_STATUS].set(
            jnp.where(do_alloc & ~can, jnp.int32(STATUS_FAULT), rec[F_STATUS])
        )
        rec = rec.at[MB].set(jnp.where(act, jnp.int32(M_NONE), rec[MB]))
        pool = jax.lax.dynamic_update_index_in_dim(pool, rec, r, 0)
        return pool, rows, free_head, bump

    # the serialized scatter (and its 4-pass stable lexsort) only runs when
    # this shard actually has work: commit-free supersteps (most of them, in
    # mixed batches) skip it entirely, the way the read path's lax.cond
    # skips the fabric -- applying zero commits is the identity, so results
    # are unchanged
    def run_commits(carry):
        # lexsort via successive stable sorts, least-significant key first:
        # final order = (ineligible-last, class, slot, id)
        klass = jnp.where(
            is_alloc, 2, jnp.where(m_op == M_FREE, 1, 0)
        ).astype(jnp.int32)
        slot_key = jnp.where(is_alloc, 0, m_tgt)
        order = jnp.arange(L, dtype=jnp.int32)
        for key in (pool[:, F_ID], slot_key, klass, (~eligible).astype(jnp.int32)):
            order = order[jnp.argsort(key[order], stable=True)]
        return jax.lax.fori_loop(
            0, L, lambda i, c: apply_one(order, i, c), carry
        )

    pool, rows, free_head, bump = jax.lax.cond(
        eligible.any(),
        run_commits,
        lambda carry: carry,
        (pool, rows, heap_row[H_FREE], heap_row[H_BUMP]),
    )
    # write-permission fault: eligible commits on a write-revoked shard
    denied = eligible & ~ok
    pool = pool.at[:, F_STATUS].set(
        jnp.where(denied, jnp.int32(STATUS_FAULT), pool[:, F_STATUS])
    )
    pool = pool.at[:, MB].set(jnp.where(denied, jnp.int32(M_NONE), pool[:, MB]))
    n_applied = (eligible & ok).sum().astype(jnp.int32)
    heap_row = heap_row.at[H_FREE].set(free_head)
    heap_row = heap_row.at[H_BUMP].set(bump)
    heap_row = heap_row.at[H_EPOCH].add((n_applied > 0).astype(jnp.int32))
    heap_row = heap_row.at[H_COMMITS].add(n_applied)
    return pool, rows, heap_row


def _local_superstep_mut(
    it: PulseIterator,
    pool: jnp.ndarray,  # (L, R) local request pool (with mutation payload)
    arena_rows: jnp.ndarray,  # (rows_per_shard, W): carried state, not invariant
    heap_row: jnp.ndarray,  # (HEAP_WORDS,) this shard's allocator registers
    bounds: jnp.ndarray,
    perms: jnp.ndarray,
    my_shard: jnp.ndarray,
    *,
    k_local: int,
    max_iters: int,
    adaptive: bool = False,
    commit: bool = True,
):
    """Write-path twin of ``_local_superstep``: chase with write-stalls, then
    (optionally) run this shard's commit phase.

    ``commit=False`` runs the chase only -- the wavefront-pipelined schedule
    chases its two wavefronts separately, merges, and commits the merged
    pool, which is bit-identical to the fused chase-then-commit because the
    commit order is keyed on (class, slot, id), never on pool layout.
    """
    S = it.scratch_words
    W = arena_rows.shape[1]
    MB = F_SCRATCH + S
    lo = bounds[my_shard]
    hi = bounds[my_shard + 1]
    perm_ok = translation.check_access(perms, my_shard, PERM_READ)

    def step(st):
        ptr, scratch, status, iters, mut = st
        return mut_step_batch(
            it, arena_rows, ptr, scratch, status, iters, mut,
            max_iters=max_iters, local_lo=lo, local_hi=hi, perm_ok=perm_ok,
        )

    ptr = pool[:, F_PTR]
    scratch = pool[:, F_SCRATCH:MB]
    status = pool[:, F_STATUS]
    iters = pool[:, F_ITERS]
    mut = pool[:, MB:]
    if adaptive:
        def chaseable(ptr, status, mut):
            return jnp.any(
                (status == STATUS_ACTIVE) & (ptr >= lo) & (ptr < hi)
                & (ptr != NULL) & (mut[:, 0] == M_NONE)
            )

        def cond(st):
            i, (ptr, _, status, _, mut) = st
            return (i < k_local) & chaseable(ptr, status, mut)

        def body(st):
            i, inner = st
            return i + 1, step(inner)

        _, (ptr, scratch, status, iters, mut) = jax.lax.while_loop(
            cond, body, (jnp.int32(0), (ptr, scratch, status, iters, mut))
        )
    else:
        ptr, scratch, status, iters, mut = jax.lax.fori_loop(
            0, k_local, lambda _, st: step(st), (ptr, scratch, status, iters, mut)
        )
    # exhausted-budget sweep: a record can sit ACTIVE at iters >= max_iters
    # only via the pending-mutation MAXED suppression; once its commit
    # clears it must retire before the router sees it again.  The fixed
    # k_local chase touches the whole pool every call so mut_step_batch's
    # own check covers it, but the adaptive chase legally runs *zero*
    # iterations when nothing is locally chaseable -- without this sweep
    # the record would take one more (schedule-dependent) fabric hop before
    # a chase finally touches it, breaking cross-schedule bit-identity.
    status = jnp.where(
        (status == STATUS_ACTIVE) & (iters >= max_iters) & (mut[:, 0] == M_NONE),
        jnp.int32(STATUS_MAXED),
        status,
    )
    pool = pool.at[:, F_PTR].set(ptr)
    pool = pool.at[:, F_SCRATCH:MB].set(scratch)
    pool = pool.at[:, F_STATUS].set(status)
    pool = pool.at[:, F_ITERS].set(iters)
    pool = pool.at[:, MB:].set(mut)
    if not commit:
        return pool
    perm_w = translation.check_access(perms, my_shard, PERM_WRITE)
    return _commit_phase(
        pool, arena_rows, heap_row, lo, hi, my_shard, perm_w, S=S, W=W
    )


def _drop_mask(
    L: int, drop_prob: float, drop_seed: int, my_shard, step_idx
) -> jnp.ndarray:
    """Fault-injection fabric loss: each pool slot is independently 'lost'
    with probability ``drop_prob`` this superstep.  The mask is a pure
    function of (seed, shard, superstep), so injected-loss runs replay
    bit-identically.  A dropped record parks on its source shard and is
    retransmitted next superstep (link-level loss + retransmit), so no
    traversal state is ever lost -- only superstep counts grow."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(drop_seed), my_shard),
        jnp.asarray(step_idx, jnp.int32),
    )
    return jax.random.uniform(key, (L,)) < drop_prob


def _route_decide(
    pool: jnp.ndarray,  # (L, R)
    bounds: jnp.ndarray,
    my_shard: jnp.ndarray,
    num_shards: int,
    *,
    return_to_cpu: bool,
    link_capacity=None,
    phys_capacity: int | None = None,
    drain_done: bool = False,
    mut_base: int | None = None,
    drop_mask: jnp.ndarray | None = None,
    rep_ctx=None,
):
    """Switch decision + leaver extraction: the collective-free half of a
    routed superstep.

    ``rep_ctx = (replica_arr, dead_mask, policy)`` applies the replica
    serve map (``_serve_shard``) to ACTIVE reads: a record bound for a
    dead (or spread-balanced) primary is delivered to the shard holding
    its replica instead.  Faults are still judged on the raw owner -- an
    unmappable pointer is a switch fault regardless of replication.

    Computes each record's next shard, marks switch-level faults, packs the
    records that fit under the per-link capacity into a ``(P, Cp, R)`` send
    buffer, and strips them from the local pool.  Returns ``(kept, send,
    n_routed)`` where ``kept`` is the pool with departed records blanked.
    The wavefront-pipelined schedule calls this directly so the send buffer
    can stay in flight across a loop tick; ``_route`` composes it with
    ``_exchange`` + ``_merge_pools`` for the bulk-synchronous schedule.

    ``mut_base`` (write path) is the column where the mutation payload
    starts: a record with a staged mutation routes to the shard that owns
    its *commit target* (the ALLOC target is the record's home shard), not
    to ``cur_ptr``'s owner -- the staged write rides the fabric to where it
    can serialize.  An unmappable commit target is a switch-level fault.
    """
    L, R = pool.shape
    if phys_capacity is None:
        phys_capacity = L // num_shards if link_capacity is None else int(link_capacity)
    Cp = int(phys_capacity)  # static: buffer rows per destination link
    C = Cp if link_capacity is None else link_capacity  # may be traced
    status = pool[:, F_STATUS]
    valid = status != STATUS_EMPTY
    active = status == STATUS_ACTIVE

    if mut_base is not None:
        m_op = pool[:, mut_base]
        pendm = m_op != M_NONE
        is_alloc = m_op == M_ALLOC
        towner = translation.owner_of(bounds, pool[:, mut_base + 1])
    else:
        pendm = jnp.zeros((L,), bool)

    owner = translation.owner_of(bounds, pool[:, F_PTR])
    # invalid pointer (owner == NULL) on an active request -> the switch
    # notifies the CPU node (Fig. 6 step 6): mark FAULT, send home.  A
    # write-pending record is judged on its commit target instead.
    bad = active & (owner == NULL) & ~pendm
    if mut_base is not None:
        bad_mut = active & pendm & ~is_alloc & (towner == NULL)
        bad = bad | bad_mut
        pool = pool.at[:, mut_base].set(
            jnp.where(bad_mut, jnp.int32(M_NONE), m_op)
        )
        pendm = pendm & ~bad_mut
    status = jnp.where(bad, jnp.int32(3), status)  # STATUS_FAULT
    pool = pool.at[:, F_STATUS].set(status)
    active = status == STATUS_ACTIVE

    serve = _serve_shard(owner, pool[:, F_ID], rep_ctx)
    if return_to_cpu:
        # PULSE-ACC (Fig. 9): a traversal leaving this node must return to its
        # home (CPU) node, which re-issues it -- route non-local actives home.
        stay = active & (owner == my_shard)
        dest = jnp.where(stay, my_shard, pool[:, F_HOME])
        dest = jnp.where(active & (owner != my_shard), pool[:, F_HOME], dest)
        # once home, re-issue toward the owner
        at_home = active & (pool[:, F_HOME] == my_shard) & (owner != my_shard)
        dest = jnp.where(at_home, owner, dest)
    elif drain_done:
        dest = jnp.where(active, serve, my_shard)
    else:
        dest = jnp.where(active, serve, pool[:, F_HOME])
    if mut_base is not None:
        # staged mutations route to their commit shard (ALLOC -> home)
        cdest = jnp.where(is_alloc, pool[:, F_HOME], towner)
        dest = jnp.where(active & pendm, cdest, dest)
    dest = jnp.where(valid, dest, my_shard).astype(jnp.int32)

    moves = valid & (dest != my_shard)

    # pack into (P, Cp+1, R): overflow beyond per-link capacity parks in the
    # trash row (Cp) and stays local for the next superstep.
    onehot = (dest[:, None] == jnp.arange(num_shards, dtype=jnp.int32)[None, :]) & (
        moves[:, None]
    )
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - onehot.astype(jnp.int32)
    pos = jnp.take_along_axis(pos, jnp.clip(dest, 0, num_shards - 1)[:, None], axis=1)[
        :, 0
    ]
    fits = moves & (pos < C)
    if drop_mask is not None:
        # injected fabric loss: a dropped record parks locally exactly like
        # capacity overflow and retransmits next superstep -- hops do not
        # advance, so the eventual successful crossing keeps the record's
        # final state bit-identical to a loss-free run
        fits = fits & ~drop_mask
    # a crossing is a record that actually leaves this shard: parked overflow
    # (pos >= C) stays local and must not count toward Fig. 2c/9 crossings
    pool = pool.at[:, F_HOPS].set(pool[:, F_HOPS] + fits.astype(jnp.int32))
    d_idx = jnp.where(fits, dest, 0)
    p_idx = jnp.where(fits, pos, Cp)
    send = jnp.broadcast_to(
        empty_records(1, R - F_SCRATCH)[0], (num_shards, Cp + 1, R)
    ).astype(jnp.int32)
    send = send.at[d_idx, p_idx].set(jnp.where(fits[:, None], pool, send[d_idx, p_idx]))
    send = send[:, :Cp]

    # what leaves this shard is removed from the local pool
    kept = pool.at[:, F_STATUS].set(
        jnp.where(fits, jnp.int32(STATUS_EMPTY), pool[:, F_STATUS])
    )
    return kept, send, fits.sum()


def _exchange(
    send: jnp.ndarray,  # (P, Cp, R) per-destination send buffer
    axis_name: str,
    num_shards: int,
    *,
    fabric: str = "dense",
    my_shard=None,
):
    """Carry the packed send buffer across the fabric; returns arrivals
    ``(P * Cp, R)`` ordered by source shard (dense all_to_all layout).

    ``fabric="dense"`` is the paper's programmable-switch model: one
    all_to_all carries every link at once.  ``fabric="ring"`` decomposes the
    same exchange into ``P - 1`` ``lax.ppermute`` distance classes -- hop h
    carries exactly the records travelling h shards forward, so each hop's
    live payload shrinks with the compaction ladder (the capacity rung gates
    how many records occupy each (Cp, R) hop buffer).  Arrivals are
    assembled into the dense layout, so downstream merges (and therefore
    results, pool layouts, and stats) are bit-identical across fabrics.
    """
    Cp, R = send.shape[1], send.shape[2]
    if fabric == "dense":
        arrivals = jax.lax.all_to_all(
            send, axis_name, split_axis=0, concat_axis=0, tiled=True
        )
        return arrivals.reshape(num_shards * Cp, R)
    if fabric != "ring":
        raise ValueError(f"unknown fabric {fabric!r}")
    # ring: my own (always-empty) self block stays in place; distance class h
    # ships send[(me+h) % P] forward h hops and receives from (me-h) % P.
    arrivals = jnp.broadcast_to(
        empty_records(1, R - F_SCRATCH)[0], (num_shards, Cp, R)
    ).astype(jnp.int32)
    me = my_shard
    for h in range(1, num_shards):
        out = jax.lax.dynamic_index_in_dim(
            send, (me + h) % num_shards, axis=0, keepdims=False
        )
        got = jax.lax.ppermute(
            out, axis_name, perm=[(i, (i + h) % num_shards) for i in range(num_shards)]
        )
        arrivals = jax.lax.dynamic_update_index_in_dim(
            arrivals, got, (me - h) % num_shards, axis=0
        )
    return arrivals.reshape(num_shards * Cp, R)


def _merge_pools(kept: jnp.ndarray, arrivals: jnp.ndarray, L: int):
    """Merge arrivals into the local pool: valid records first, then empties;
    keep L slots (conservation: total valid records across the mesh is
    constant == B <= sum of pools).  Returns ``(merged, n_dropped_valid)``.
    """
    both = jnp.concatenate([kept, arrivals], axis=0)
    is_empty = both[:, F_STATUS] == STATUS_EMPTY
    order = jnp.argsort(is_empty, stable=True)
    merged = both[order][:L]
    n_dropped_valid = (~is_empty).sum() - (merged[:, F_STATUS] != STATUS_EMPTY).sum()
    return merged, n_dropped_valid


def _route(
    pool: jnp.ndarray,  # (L, R)
    bounds: jnp.ndarray,
    my_shard: jnp.ndarray,
    num_shards: int,
    axis_name: str,
    *,
    return_to_cpu: bool,
    link_capacity=None,
    phys_capacity: int | None = None,
    drain_done: bool = False,
    fabric: str = "dense",
    mut_base: int | None = None,
    drop_mask: jnp.ndarray | None = None,
    rep_ctx=None,
):
    """Switch routing: deliver records to their next shard in one superstep.

    ``link_capacity`` is the per-destination link budget C (records per
    superstep); the default is the worst-case L // num_shards.  Compacted
    execution passes a shrunken C once most of the batch has finished, so the
    BSP payload tracks the live set instead of the original batch.  It may be
    a *traced* scalar (the fused loop carries the capacity-ladder rung as
    state); then ``phys_capacity`` fixes the static buffer shape and C only
    gates which records fit -- the parking schedule is identical to a
    host-dispatched superstep compiled at capacity C, so results (and even
    pool layouts) match bit-for-bit.

    ``drain_done`` is the active-set compaction: finished (DONE/FAULT/MAXED)
    records retire *in place* instead of being routed to their home shard --
    the final gather collects them from wherever they stopped, so shipping
    them home only burned link capacity (exactly the waste the paper's switch
    design avoids by keeping only live traversals in the fabric).
    """
    L = pool.shape[0]
    kept, send, n_routed = _route_decide(
        pool, bounds, my_shard, num_shards,
        return_to_cpu=return_to_cpu,
        link_capacity=link_capacity,
        phys_capacity=phys_capacity,
        drain_done=drain_done,
        mut_base=mut_base,
        drop_mask=drop_mask,
        rep_ctx=rep_ctx,
    )
    arrivals = _exchange(
        send, axis_name, num_shards, fabric=fabric, my_shard=my_shard
    )
    merged, n_dropped_valid = _merge_pools(kept, arrivals, L)
    return merged, n_routed, n_dropped_valid


def _remote_active(pool, bounds, my_shard, mut_base: int | None = None, rep_ctx=None):
    """Active records this shard cannot serve (owner elsewhere / invalid).

    A write-pending record's effective destination is its commit shard
    (ALLOC -> home), so a staged remote write keeps the fabric scheduled
    even when every cur_ptr is local.  Under replication the serve map
    decides remoteness, so a record bound for a dead primary's replica
    keeps the fabric scheduled too."""
    active = pool[:, F_STATUS] == STATUS_ACTIVE
    owner = translation.owner_of(bounds, pool[:, F_PTR])
    if mut_base is not None:
        m_op = pool[:, mut_base]
        pendm = m_op != M_NONE
        towner = jnp.where(
            m_op == M_ALLOC,
            pool[:, F_HOME],
            translation.owner_of(bounds, pool[:, mut_base + 1]),
        )
        owner = jnp.where(pendm, towner, owner)
    else:
        owner = _serve_shard(owner, pool[:, F_ID], rep_ctx)
    return (active & (owner != my_shard)).sum()


def make_superstep(
    it: PulseIterator,
    num_shards: int,
    axis_name: str,
    *,
    k_local: int,
    max_iters: int,
    return_to_cpu: bool = False,
    link_capacity: int | None = None,
    drain_done: bool = False,
    do_route: bool = True,
    fabric: str = "dense",
    local_backend: str = "xla",
    mutate: bool = False,
    drop_prob: float = 0.0,
    drop_seed: int = 0,
    replication: ReplicaPlan | None = None,
    elide_access_check: bool = False,
):
    """Builds the jittable per-shard superstep: local run -> switch route.

    ``replication`` (read path only) adds two operands after ``perms`` --
    the holder-sharded replica rows and the traced ``dead_mask`` -- and
    applies the plan's serve map to chase and route decisions.

    ``do_route=False`` builds the compacted *local-only* superstep: when every
    surviving traversal is already at its owning shard, the fabric has nothing
    to carry, so the all_to_all is skipped entirely (wire payload 0).  The
    step still reports how many actives turned remote so the driver knows
    when to re-enter the routed variant.

    Returns ``(pool, n_active, n_routed, n_drop, n_remote)`` -- all counters
    globally psum'd.  ``mutate=True`` builds the write-path superstep: the
    arena rows and heap registers become carried state (chase -> commit ->
    route), and the step signature grows to
    ``(pool, arena_rows, heap, bounds, perms) -> (pool, arena_rows, heap,
    counters...)``.

    ``drop_prob > 0`` (fault injection) adds one trailing traced ``step_idx``
    operand: each routed record is parked with probability ``drop_prob``
    under a (drop_seed, shard, step_idx)-keyed mask (see ``_drop_mask``).
    Production callers leave the default and pay nothing.
    """
    logic_fn = _kernel_logic(it) if local_backend == "kernel" else None
    mut_base = F_SCRATCH + it.scratch_words if mutate else None
    inject_drop = drop_prob > 0.0 and do_route
    if replication is not None and mutate:
        raise ValueError("replication is a read-path feature (writes park)")
    if replication is not None:
        primary_arr = jnp.asarray(replication.primary_map, jnp.int32)
        replica_arr = jnp.asarray(replication.replica_map, jnp.int32)

    def _mask(pool, my_shard, fault_args):
        if not inject_drop:
            return None
        return _drop_mask(
            pool.shape[0], drop_prob, drop_seed, my_shard, fault_args[0]
        )

    def superstep(pool, arena_rows, bounds, perms, *extra):
        CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        if replication is not None:
            rep_rows, dead_mask, *fault_args = extra
            rep = (rep_rows, primary_arr, dead_mask, replication.policy)
            rep_ctx = (replica_arr, dead_mask, replication.policy)
        else:
            fault_args = extra
            rep = rep_ctx = None
        pool = _local_superstep(
            it, pool, arena_rows, bounds, perms, my_shard,
            k_local=k_local, max_iters=max_iters, logic_fn=logic_fn, rep=rep,
            elide_access_check=elide_access_check,
        )
        if do_route:
            pool, n_routed, n_drop = _route(
                pool, bounds, my_shard, num_shards, axis_name,
                return_to_cpu=return_to_cpu,
                link_capacity=link_capacity,
                drain_done=drain_done,
                fabric=fabric,
                drop_mask=_mask(pool, my_shard, fault_args),
                rep_ctx=rep_ctx,
            )
        else:
            n_routed = jnp.int32(0)
            n_drop = jnp.int32(0)
        n_active = (pool[:, F_STATUS] == STATUS_ACTIVE).sum()
        n_remote = _remote_active(pool, bounds, my_shard, rep_ctx=rep_ctx)
        n_active = jax.lax.psum(n_active, axis_name)
        n_routed = jax.lax.psum(n_routed, axis_name)
        n_drop = jax.lax.psum(n_drop, axis_name)
        n_remote = jax.lax.psum(n_remote, axis_name)
        return pool, n_active, n_routed, n_drop, n_remote

    def superstep_mut(pool, arena_rows, heap, bounds, perms, *fault_args):
        CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        pool, arena_rows, heap_row = _local_superstep_mut(
            it, pool, arena_rows, heap[0], bounds, perms, my_shard,
            k_local=k_local, max_iters=max_iters,
        )
        heap = heap_row[None, :]
        if do_route:
            pool, n_routed, n_drop = _route(
                pool, bounds, my_shard, num_shards, axis_name,
                return_to_cpu=return_to_cpu,
                link_capacity=link_capacity,
                drain_done=drain_done,
                fabric=fabric,
                mut_base=mut_base,
                drop_mask=_mask(pool, my_shard, fault_args),
            )
        else:
            n_routed = jnp.int32(0)
            n_drop = jnp.int32(0)
        n_active = (pool[:, F_STATUS] == STATUS_ACTIVE).sum()
        n_remote = _remote_active(pool, bounds, my_shard, mut_base)
        n_active = jax.lax.psum(n_active, axis_name)
        n_routed = jax.lax.psum(n_routed, axis_name)
        n_drop = jax.lax.psum(n_drop, axis_name)
        n_remote = jax.lax.psum(n_remote, axis_name)
        return pool, arena_rows, heap, n_active, n_routed, n_drop, n_remote

    return superstep_mut if mutate else superstep


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def _pow2_at_least_traced(n: jnp.ndarray) -> jnp.ndarray:
    """Traced twin of ``_pow2_at_least``: exact integer bit-length (no float
    log2, whose rounding at exact powers of two would desync the fused
    capacity ladder from the host-dispatched one)."""
    bl = jnp.sum(
        (jnp.asarray(n, jnp.int32) - 1) >= (1 << jnp.arange(31, dtype=jnp.int32))
    ).astype(jnp.int32)
    return jnp.left_shift(jnp.int32(1), bl)


def _ladder_traced(
    n_active, n_remote, *, num_shards: int, base_capacity: int,
    min_link_capacity: int, compact: bool,
):
    """The host loop's capacity ladder on traced stale-by-one counts --
    the ONE definition every device-resident schedule (fused, pipelined)
    must share, or their wire accounting and pool layouts desync.
    Returns ``(capacity, do_route)``."""
    if not compact:
        return jnp.int32(base_capacity), jnp.bool_(True)
    demand = (n_active + num_shards - 1) // num_shards
    capacity = jnp.minimum(
        jnp.int32(base_capacity),
        jnp.maximum(jnp.int32(min_link_capacity), _pow2_at_least_traced(demand)),
    )
    return capacity, n_remote > 0


# Compiled-executable caches, shared by every distributed_execute caller
# (PulseEngine.execute, PulseService quanta, benchmarks): per-hop supersteps
# keyed by (iterator, mesh, capacity rung, ...), fused whole-traversal loops
# keyed by (iterator, mesh, pool shape, record width, ...).  CACHE_STATS
# tracks hits/misses/traces for the retracing regression tests.
_STEP_CACHE: dict = {}
_FUSED_CACHE: dict = {}

# Device-resident arenas: (id(arena), mesh, axis_name) -> sharded
# (data, bounds, perms).  A PulseService quantum re-enters distributed_execute
# every scheduling round with the same arena; placing the pool once and
# reusing the resident buffers removes the per-quantum H2D re-upload.
_RESIDENT: dict = {}


def reset_executable_caches() -> None:
    """Drop every cached executable / resident buffer (test isolation)."""
    _STEP_CACHE.clear()
    _FUSED_CACHE.clear()
    _RESIDENT.clear()
    CACHE_STATS.reset()


def _resident_arena(arena: Arena, mesh: Mesh, axis_name: str):
    key = (id(arena), mesh, axis_name)
    ent = _RESIDENT.get(key)
    if ent is None:
        ent = (
            jax.device_put(arena.data, NamedSharding(mesh, P(axis_name, None))),
            jax.device_put(arena.bounds, NamedSharding(mesh, P())),
            jax.device_put(arena.perms, NamedSharding(mesh, P())),
        )
        _RESIDENT[key] = ent
        # evict when the arena dies so a recycled id() cannot alias stale data
        weakref.finalize(arena, _RESIDENT.pop, key, None)
    return ent


def make_fused_loop(
    it: PulseIterator,
    num_shards: int,
    axis_name: str,
    *,
    k_local: int,
    max_supersteps: int,
    base_capacity: int,
    min_link_capacity: int,
    return_to_cpu: bool,
    compact: bool,
    fabric: str = "dense",
    local_backend: str = "xla",
    mutate: bool = False,
    drop_prob: float = 0.0,
    drop_seed: int = 0,
    elide_access_check: bool = False,
):
    """Builds the whole-traversal device-resident loop (one shard's view).

    The entire superstep schedule -- local execution, the local-vs-fabric
    decision, the power-of-two capacity ladder, and termination -- runs as a
    single ``lax.while_loop``; the host only sees the final pool and a handful
    of aggregate counters.  Scheduling decisions mirror ``distributed_execute``
    's host loop exactly (same stale-by-one active/remote counts, same ladder
    arithmetic), so the fused execution is bit-identical to the dispatched
    one, down to pool layouts and crossing counts.

    Returned state: ``(pool, n_active, steps, routed, dropped, cap_counts,
    local_only)`` -- every counter globally psum'd/replicated.  ``cap_counts``
    is a histogram of routed supersteps per capacity rung (the ladder has at
    most 31 distinct values, precomputed in ``capacity_rungs``); the host
    turns it into a wire-word total with Python integer arithmetic, so the
    traced counters never multiply capacity into an int32 (which would wrap
    at production batch sizes where the dispatched path's per-step Python
    sums would not).

    The per-record iteration budget (the serving layer's *quantum*) is a
    traced ``iter_budget`` operand, not a trace constant: SLO-aware quantum
    sizing re-enters the same compiled executable every scheduling round
    with a different budget, so baking it into the trace would recompile
    per quantum value.

    ``halt`` (the second trailing traced operand, fault injection) caps the
    loop at ``halt`` supersteps: an armed shard-kill passes
    ``kill_superstep - 1`` so the loop exits cleanly with records still
    ACTIVE, and the host raises ``ShardFailure`` instead of the
    still-ACTIVE error.  Unarmed callers pass ``max_supersteps``, which the
    loop condition already enforces -- zero-cost default.

    ``drop_prob > 0`` parks each routed record with that probability under
    a (drop_seed, shard, superstep)-keyed mask (see ``_drop_mask``).
    """
    drain_done = compact
    rungs = capacity_rungs(base_capacity, min_link_capacity) if compact else (
        base_capacity,
    )
    rungs_arr = jnp.asarray(rungs, jnp.int32)
    logic_fn = _kernel_logic(it) if local_backend == "kernel" else None
    mut_base = F_SCRATCH + it.scratch_words if mutate else None
    inject_drop = drop_prob > 0.0

    def _mask(L, my_shard, steps):
        if not inject_drop:
            return None
        return _drop_mask(L, drop_prob, drop_seed, my_shard, steps)

    def fused_mut(pool, arena_rows, heap, bounds, perms, iter_budget, halt):
        """Write-path fused loop: arena rows + heap registers are carried
        ``lax.while_loop`` state -- each superstep is chase -> commit ->
        route, with the same ladder decisions as the read path."""
        CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        n0 = jax.lax.psum(
            (pool[:, F_STATUS] == STATUS_ACTIVE).sum().astype(jnp.int32), axis_name
        )

        def cond(carry):
            _, _, _, n_active, steps, _, n_drop, _, _, _ = carry
            return (
                (n_active > 0) & (steps < max_supersteps) & (n_drop == 0)
                & (steps < halt)
            )

        def body(carry):
            (pool, rows, heap, n_active, steps, n_routed_tot, n_drop_tot,
             cap_counts, local_only, n_remote) = carry
            pool, rows, heap_row = _local_superstep_mut(
                it, pool, rows, heap[0], bounds, perms, my_shard,
                k_local=k_local, max_iters=iter_budget,
            )
            heap = heap_row[None, :]
            capacity, do_route = _ladder_traced(
                n_active, n_remote, num_shards=num_shards,
                base_capacity=base_capacity,
                min_link_capacity=min_link_capacity, compact=compact,
            )

            def routed(p):
                return _route(
                    p, bounds, my_shard, num_shards, axis_name,
                    return_to_cpu=return_to_cpu,
                    link_capacity=capacity, phys_capacity=base_capacity,
                    drain_done=drain_done, fabric=fabric, mut_base=mut_base,
                    drop_mask=_mask(p.shape[0], my_shard, steps),
                )

            def local_only_step(p):
                return p, jnp.int32(0), jnp.int32(0)

            if compact:
                pool, n_routed, n_drop = jax.lax.cond(
                    do_route, routed, local_only_step, pool
                )
            else:
                pool, n_routed, n_drop = routed(pool)
            n_active = jax.lax.psum(
                (pool[:, F_STATUS] == STATUS_ACTIVE).sum().astype(jnp.int32),
                axis_name,
            )
            n_remote = jax.lax.psum(
                _remote_active(pool, bounds, my_shard, mut_base).astype(jnp.int32),
                axis_name,
            )
            n_routed = jax.lax.psum(n_routed.astype(jnp.int32), axis_name)
            n_drop = jax.lax.psum(n_drop.astype(jnp.int32), axis_name)
            cap_counts = cap_counts + jnp.where(
                do_route, (rungs_arr == capacity).astype(jnp.int32), 0
            )
            local_only = local_only + jnp.where(do_route, 0, 1).astype(jnp.int32)
            return (
                pool, rows, heap, n_active, steps + 1, n_routed_tot + n_routed,
                n_drop_tot + n_drop, cap_counts, local_only, n_remote,
            )

        init = (
            pool, arena_rows, heap, n0, jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros(len(rungs), jnp.int32), jnp.int32(0), n0,
        )
        (pool, rows, heap, n_active, steps, n_routed, n_drop, cap_counts,
         local_only, _) = jax.lax.while_loop(cond, body, init)
        return pool, rows, heap, n_active, steps, n_routed, n_drop, cap_counts, local_only

    def fused(pool, arena_rows, bounds, perms, iter_budget, halt):
        CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        n0 = jax.lax.psum(
            (pool[:, F_STATUS] == STATUS_ACTIVE).sum().astype(jnp.int32), axis_name
        )

        def cond(carry):
            _, n_active, steps, _, n_drop, _, _, _ = carry
            return (
                (n_active > 0) & (steps < max_supersteps) & (n_drop == 0)
                & (steps < halt)
            )

        def body(carry):
            pool, n_active, steps, n_routed_tot, n_drop_tot, cap_counts, local_only, n_remote = carry
            pool = _local_superstep(
                it, pool, arena_rows, bounds, perms, my_shard,
                k_local=k_local, max_iters=iter_budget, logic_fn=logic_fn,
                elide_access_check=elide_access_check,
            )
            # the host loop's ladder on stale-by-one counts (shared with the
            # pipelined schedule -- see _ladder_traced)
            capacity, do_route = _ladder_traced(
                n_active, n_remote, num_shards=num_shards,
                base_capacity=base_capacity,
                min_link_capacity=min_link_capacity, compact=compact,
            )

            def routed(p):
                return _route(
                    p, bounds, my_shard, num_shards, axis_name,
                    return_to_cpu=return_to_cpu,
                    link_capacity=capacity, phys_capacity=base_capacity,
                    drain_done=drain_done, fabric=fabric,
                    drop_mask=_mask(p.shape[0], my_shard, steps),
                )

            def local_only_step(p):
                return p, jnp.int32(0), jnp.int32(0)

            if compact:
                # conditional collective: every shard takes the same branch
                # (the predicate is a psum), so the fabric is skipped entirely
                # on local-only supersteps
                pool, n_routed, n_drop = jax.lax.cond(
                    do_route, routed, local_only_step, pool
                )
            else:
                pool, n_routed, n_drop = routed(pool)
            n_active = jax.lax.psum(
                (pool[:, F_STATUS] == STATUS_ACTIVE).sum().astype(jnp.int32),
                axis_name,
            )
            n_remote = jax.lax.psum(
                _remote_active(pool, bounds, my_shard).astype(jnp.int32), axis_name
            )
            n_routed = jax.lax.psum(n_routed.astype(jnp.int32), axis_name)
            n_drop = jax.lax.psum(n_drop.astype(jnp.int32), axis_name)
            cap_counts = cap_counts + jnp.where(
                do_route, (rungs_arr == capacity).astype(jnp.int32), 0
            )
            local_only = local_only + jnp.where(do_route, 0, 1).astype(jnp.int32)
            return (
                pool, n_active, steps + 1, n_routed_tot + n_routed,
                n_drop_tot + n_drop, cap_counts, local_only, n_remote,
            )

        # before the first superstep the host loop assumes everything is
        # active and remote (n_active = n_remote = B); mirror that exactly
        init = (
            pool, n0, jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros(len(rungs), jnp.int32), jnp.int32(0), n0,
        )
        pool, n_active, steps, n_routed, n_drop, cap_counts, local_only, _ = (
            jax.lax.while_loop(cond, body, init)
        )
        return pool, n_active, steps, n_routed, n_drop, cap_counts, local_only

    return fused_mut if mutate else fused


def capacity_rungs(base_capacity: int, min_link_capacity: int) -> tuple:
    """The distinct values the compacted capacity ladder can take: powers of
    two clamped to [min_link_capacity, base_capacity] -- at most 31 rungs."""
    return tuple(
        sorted({
            min(base_capacity, max(min_link_capacity, 1 << i)) for i in range(31)
        })
    )


def make_pipelined_loop(
    it: PulseIterator,
    num_shards: int,
    axis_name: str,
    *,
    k_local: int,
    max_supersteps: int,
    base_capacity: int,
    min_link_capacity: int,
    return_to_cpu: bool,
    compact: bool,
    fabric: str = "dense",
    local_backend: str = "xla",
    mutate: bool = False,
    drop_prob: float = 0.0,
    drop_seed: int = 0,
    elide_access_check: bool = False,
):
    """Wavefront-pipelined whole-traversal loop (one shard's view).

    The fused loop (``make_fused_loop``) still executes each superstep as a
    strict sequence -- chase, then exchange, then wait -- so the fabric idles
    while lanes chase pointers and vice versa.  This schedule splits the
    active set into two wavefronts and double-buffers them across loop
    ticks:

      * **wavefront A (in flight)** -- the records extracted by superstep
        s-1's routing decision ride the fabric as carried loop state (the
        packed send buffer), landing at the *start* of tick s;
      * **wavefront B (resident)** -- everything still in the local pool
        runs superstep s's local chase while A is in flight.  The two have
        no data dependence, so the collective overlaps the chase.

    Then they swap: the landed wavefront chases, merges back, and superstep
    s's routing decision extracts the next in-flight wavefront.  Because a
    record's trajectory is elementwise (chase commutes with the merge
    permutation) and every scheduling decision -- the pow2 capacity ladder,
    the local-vs-fabric cond, parking -- is re-derived from the same merged
    stale-by-one counts as the fused loop, results, pool layouts, superstep
    counts, and wire accounting are bit-identical to the fused schedule and
    the BSP oracle.

    Fabric-side coordination is also leaner: the four per-superstep psums
    collapse into one stacked psum of the two counts the scheduler actually
    needs next tick (active, remote); routed/dropped totals accumulate
    per-wavefront in local registers and merge in a single psum after the
    loop exits.  ``RoutingStats`` wire accounting (cap_counts histogram) is
    identical -- it tracks routing *decisions*, which are schedule-invariant.

    ``fabric="ring"`` carries the in-flight wavefront on ppermute distance
    classes instead of the dense all_to_all; ``local_backend="kernel"``
    threads the local chase through the pulse_chase kernel's vectorized
    iterator body.  Both compose with the overlap schedule.
    """
    drain_done = compact
    rungs = capacity_rungs(base_capacity, min_link_capacity) if compact else (
        base_capacity,
    )
    rungs_arr = jnp.asarray(rungs, jnp.int32)
    Cp = base_capacity
    logic_fn = _kernel_logic(it) if local_backend == "kernel" else None
    mut_base = F_SCRATCH + it.scratch_words if mutate else None
    inject_drop = drop_prob > 0.0

    def _mask(L, my_shard, steps):
        if not inject_drop:
            return None
        return _drop_mask(L, drop_prob, drop_seed, my_shard, steps)

    def pipelined_mut(pool, arena_rows, heap, bounds, perms, iter_budget, halt):
        """Write-path pipelined loop.  The two wavefronts chase separately
        (stalling on staged writes), merge, and THEN the merged pool runs
        this shard's commit phase -- bit-identical to the fused
        chase-then-commit because the commit order is keyed on
        (class, slot, id), never on pool layout.  The in-flight wavefront
        can carry staged mutations: they ride the same send buffer and
        commit where they land."""
        CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        L, R = pool.shape
        S = it.scratch_words
        W = arena_rows.shape[1]
        lo = bounds[my_shard]
        hi = bounds[my_shard + 1]
        perm_w = translation.check_access(perms, my_shard, PERM_WRITE)
        n0 = jax.lax.psum(
            (pool[:, F_STATUS] == STATUS_ACTIVE).sum().astype(jnp.int32), axis_name
        )
        empty_send = jnp.broadcast_to(
            empty_records(1, R - F_SCRATCH)[0], (num_shards, Cp, R)
        ).astype(jnp.int32)

        def cond(carry):
            _, _, _, _, _, n_active, _, steps, *_ = carry
            return (n_active > 0) & (steps < max_supersteps) & (steps < halt)

        def body(carry):
            (kept, send, rows, heap, did_route, n_active, n_remote, steps,
             routed_acc, drop_acc, cap_counts, local_only) = carry

            def chase(p):
                return _local_superstep_mut(
                    it, p, rows, heap[0], bounds, perms, my_shard,
                    k_local=k_local, max_iters=iter_budget,
                    adaptive=True, commit=False,
                )

            def land(ops_):
                kept, send = ops_
                arrivals = _exchange(
                    send, axis_name, num_shards, fabric=fabric, my_shard=my_shard
                )
                landed = chase(arrivals)
                resident = chase(kept)
                return _merge_pools(resident, landed, L)

            def stay(ops_):
                kept, _ = ops_
                return chase(kept), jnp.int32(0)

            pool_s, n_drop = jax.lax.cond(did_route, land, stay, (kept, send))

            # the merged pool commits exactly once per tick (the fused
            # schedule's chase-then-commit, reordered across the overlap)
            pool_s, rows, heap_row = _commit_phase(
                pool_s, rows, heap[0], lo, hi, my_shard, perm_w, S=S, W=W
            )
            heap = heap_row[None, :]

            capacity, do_route = _ladder_traced(
                n_active, n_remote, num_shards=num_shards,
                base_capacity=base_capacity,
                min_link_capacity=min_link_capacity, compact=compact,
            )

            def extract(p):
                return _route_decide(
                    p, bounds, my_shard, num_shards,
                    return_to_cpu=return_to_cpu,
                    link_capacity=capacity, phys_capacity=base_capacity,
                    drain_done=drain_done, mut_base=mut_base,
                    drop_mask=_mask(p.shape[0], my_shard, steps),
                )

            def hold(p):
                return p, empty_send, jnp.int32(0)

            if compact:
                kept, send, n_routed = jax.lax.cond(do_route, extract, hold, pool_s)
            else:
                kept, send, n_routed = extract(pool_s)

            inflight = send.reshape(num_shards * Cp, R)
            na_local = (
                (kept[:, F_STATUS] == STATUS_ACTIVE).sum()
                + (inflight[:, F_STATUS] == STATUS_ACTIVE).sum()
            ).astype(jnp.int32)
            nr_local = _remote_active(kept, bounds, my_shard, mut_base).astype(
                jnp.int32
            )
            counts = jax.lax.psum(jnp.stack([na_local, nr_local]), axis_name)

            cap_counts = cap_counts + jnp.where(
                do_route, (rungs_arr == capacity).astype(jnp.int32), 0
            )
            local_only = local_only + jnp.where(do_route, 0, 1).astype(jnp.int32)
            return (
                kept, send, rows, heap, do_route, counts[0], counts[1], steps + 1,
                routed_acc + n_routed, drop_acc + n_drop, cap_counts, local_only,
            )

        init = (
            pool, empty_send, arena_rows, heap, jnp.bool_(False), n0, n0,
            jnp.int32(0), jnp.int32(0), jnp.int32(0),
            jnp.zeros(len(rungs), jnp.int32), jnp.int32(0),
        )
        (kept, send, rows, heap, did_route, n_active, _, steps,
         routed_acc, drop_acc, cap_counts, local_only) = jax.lax.while_loop(
            cond, body, init
        )

        def final_land(ops_):
            kept, send = ops_
            arrivals = _exchange(
                send, axis_name, num_shards, fabric=fabric, my_shard=my_shard
            )
            return _merge_pools(kept, arrivals, kept.shape[0])

        def final_stay(ops_):
            return ops_[0], jnp.int32(0)

        pool_out, n_drop = jax.lax.cond(did_route, final_land, final_stay, (kept, send))

        n_routed = jax.lax.psum(routed_acc, axis_name)
        n_dropped = jax.lax.psum(drop_acc + n_drop, axis_name)
        return (
            pool_out, rows, heap, n_active, steps, n_routed, n_dropped,
            cap_counts, local_only,
        )

    def pipelined(pool, arena_rows, bounds, perms, iter_budget, halt):
        CACHE_STATS.traces += 1  # trace-time side effect: counts recompiles
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        L, R = pool.shape
        n0 = jax.lax.psum(
            (pool[:, F_STATUS] == STATUS_ACTIVE).sum().astype(jnp.int32), axis_name
        )
        empty_send = jnp.broadcast_to(
            empty_records(1, R - F_SCRATCH)[0], (num_shards, Cp, R)
        ).astype(jnp.int32)

        def chase(p):
            return _local_superstep(
                it, p, arena_rows, bounds, perms, my_shard,
                k_local=k_local, max_iters=iter_budget,
                adaptive=True, logic_fn=logic_fn,
                elide_access_check=elide_access_check,
            )

        def cond(carry):
            _, _, _, n_active, _, steps, *_ = carry
            return (n_active > 0) & (steps < max_supersteps) & (steps < halt)

        def body(carry):
            (kept, send, did_route, n_active, n_remote, steps,
             routed_acc, drop_acc, cap_counts, local_only) = carry

            # --- land wavefront A while wavefront B chases ----------------
            # Inside the routed branch the exchange consumes only the
            # carried send buffer and the resident chase only the kept
            # pool: independent dataflow, so the collective and the local
            # superstep overlap.  Chase commutes with the merge permutation,
            # so merging after (instead of before, as the fused loop does)
            # is bit-identical.
            def land(ops_):
                kept, send = ops_
                arrivals = _exchange(
                    send, axis_name, num_shards, fabric=fabric, my_shard=my_shard
                )
                landed = chase(arrivals)  # wavefront A chases where it landed
                resident = chase(kept)  # wavefront B chases concurrently
                return _merge_pools(resident, landed, L)

            def stay(ops_):
                kept, _ = ops_
                return chase(kept), jnp.int32(0)

            pool_s, n_drop = jax.lax.cond(did_route, land, stay, (kept, send))

            # --- superstep s's routing decision (the shared ladder) -------
            capacity, do_route = _ladder_traced(
                n_active, n_remote, num_shards=num_shards,
                base_capacity=base_capacity,
                min_link_capacity=min_link_capacity, compact=compact,
            )

            def extract(p):
                return _route_decide(
                    p, bounds, my_shard, num_shards,
                    return_to_cpu=return_to_cpu,
                    link_capacity=capacity, phys_capacity=base_capacity,
                    drain_done=drain_done,
                    drop_mask=_mask(p.shape[0], my_shard, steps),
                )

            def hold(p):
                return p, empty_send, jnp.int32(0)

            if compact:
                kept, send, n_routed = jax.lax.cond(do_route, extract, hold, pool_s)
            else:
                kept, send, n_routed = extract(pool_s)

            # --- one stacked psum carries both scheduler counts -----------
            # n_active spans both wavefronts (in-flight records keep their
            # status in transit); in-flight records head to their owning
            # shard, so they contribute nothing remote under compaction
            # (and n_remote is schedule-dead otherwise).
            inflight = send.reshape(num_shards * Cp, R)
            na_local = (
                (kept[:, F_STATUS] == STATUS_ACTIVE).sum()
                + (inflight[:, F_STATUS] == STATUS_ACTIVE).sum()
            ).astype(jnp.int32)
            nr_local = _remote_active(kept, bounds, my_shard).astype(jnp.int32)
            counts = jax.lax.psum(jnp.stack([na_local, nr_local]), axis_name)

            cap_counts = cap_counts + jnp.where(
                do_route, (rungs_arr == capacity).astype(jnp.int32), 0
            )
            local_only = local_only + jnp.where(do_route, 0, 1).astype(jnp.int32)
            return (
                kept, send, do_route, counts[0], counts[1], steps + 1,
                routed_acc + n_routed, drop_acc + n_drop, cap_counts, local_only,
            )

        init = (
            pool, empty_send, jnp.bool_(False), n0, n0, jnp.int32(0),
            jnp.int32(0), jnp.int32(0), jnp.zeros(len(rungs), jnp.int32),
            jnp.int32(0),
        )
        (kept, send, did_route, n_active, _, steps,
         routed_acc, drop_acc, cap_counts, local_only) = jax.lax.while_loop(
            cond, body, init
        )

        # land the final in-flight wavefront (loop exit leaves the last
        # routing decision's records on the wire; no chase -- either nothing
        # is active, or we hit max_supersteps and the host raises anyway)
        def final_land(ops_):
            kept, send = ops_
            arrivals = _exchange(
                send, axis_name, num_shards, fabric=fabric, my_shard=my_shard
            )
            return _merge_pools(kept, arrivals, kept.shape[0])

        def final_stay(ops_):
            return ops_[0], jnp.int32(0)

        pool_out, n_drop = jax.lax.cond(did_route, final_land, final_stay, (kept, send))

        # per-wavefront accumulators merge in one post-loop psum
        n_routed = jax.lax.psum(routed_acc, axis_name)
        n_dropped = jax.lax.psum(drop_acc + n_drop, axis_name)
        return pool_out, n_active, steps, n_routed, n_dropped, cap_counts, local_only

    return pipelined_mut if mutate else pipelined


def get_fused_runner(
    it: PulseIterator,
    mesh: Mesh,
    axis_name: str,
    *,
    num_shards: int,
    pool_rows: int,
    scratch_words: int,
    k_local: int,
    max_supersteps: int,
    base_capacity: int,
    min_link_capacity: int,
    return_to_cpu: bool,
    compact: bool,
    schedule: str = "fused",
    fabric: str = "dense",
    local_backend: str = "xla",
    mutate: bool = False,
    drop_prob: float = 0.0,
    drop_seed: int = 0,
    elide_access_check: bool = False,
):
    """Cached, jitted, donated whole-traversal executable (fused or
    wavefront-pipelined schedule).

    Key = (iterator, mesh, pool shape, record width, schedule knobs,
    mutability); the capacity rung is *traced state* inside the loop, so the
    ladder costs one executable instead of O(log L).  ``donate_argnums=(0,)``
    hands the request pool's buffer to XLA (it is rebuilt per call, and the
    while_loop aliases it in place); the arena buffers are NOT donated on
    either path -- read-only runs keep them device-resident across calls,
    and mutating runs deliberately leave the *input* snapshot intact (the
    updated rows/heap come back as fresh outputs), so a caller can replay
    the same pre-state through several schedules (the determinism oracle's
    contract).

    The iteration budget (the serving quantum) is NOT part of the key: it
    rides into the executable as a traced int32 operand (the trailing
    argument), so SLO-aware quantum sizing reuses one compiled program for
    every budget value.
    """
    key = (
        it, mesh, axis_name, num_shards, pool_rows, scratch_words, k_local,
        max_supersteps, base_capacity, min_link_capacity,
        return_to_cpu, compact, schedule, fabric, local_backend, mutate,
        drop_prob, drop_seed, elide_access_check,
    )
    fn = _FUSED_CACHE.get(key)
    if fn is None:
        CACHE_STATS.misses += 1
        if schedule == "pipelined":
            loop = make_pipelined_loop(
                it, num_shards, axis_name,
                k_local=k_local,
                max_supersteps=max_supersteps,
                base_capacity=base_capacity,
                min_link_capacity=min_link_capacity,
                return_to_cpu=return_to_cpu, compact=compact,
                fabric=fabric, local_backend=local_backend, mutate=mutate,
                drop_prob=drop_prob, drop_seed=drop_seed,
                elide_access_check=elide_access_check,
            )
        else:
            loop = make_fused_loop(
                it, num_shards, axis_name,
                k_local=k_local,
                max_supersteps=max_supersteps,
                base_capacity=base_capacity,
                min_link_capacity=min_link_capacity,
                return_to_cpu=return_to_cpu, compact=compact,
                fabric=fabric, local_backend=local_backend, mutate=mutate,
                drop_prob=drop_prob, drop_seed=drop_seed,
                elide_access_check=elide_access_check,
            )
        # trailing P() pair: the traced iter_budget and halt scalars
        if mutate:
            in_specs = (
                P(axis_name), P(axis_name), P(axis_name), P(), P(), P(), P(),
            )
            out_specs = (
                P(axis_name), P(axis_name), P(axis_name),
                P(), P(), P(), P(), P(), P(),
            )
        else:
            in_specs = (P(axis_name), P(axis_name), P(), P(), P(), P())
            out_specs = (P(axis_name), P(), P(), P(), P(), P(), P())
        fn = jax.jit(
            shard_map_unchecked(
                loop, mesh=mesh, in_specs=in_specs, out_specs=out_specs
            ),
            donate_argnums=(0,),
        )
        _FUSED_CACHE[key] = fn
    else:
        CACHE_STATS.hits += 1
    return fn


def can_elide_access_check(it: PulseIterator, arena: Arena) -> bool:
    """True when the per-hop PERM_READ probe is statically constant-true.

    Two proofs combine: the iterator's pulse-verify certificate
    (``it.facts``) shows the traversal only ever reads (``facts.read_only``
    -- no store-class op on any reachable path, so PERM_READ is the entire
    required mask), and a host-side scan shows every shard of
    ``arena.perms`` grants PERM_READ.  Under both, ``check_access`` would
    return True for every pointer the traversal can present -- local,
    remote, or faulting-on-NULL alike -- so replacing the probe with the
    constant is bit-identical.  Unverified iterators (``facts is None``)
    never qualify: absence of a certificate means every conservative
    runtime check stays.
    """
    facts = it.facts
    if facts is None or not getattr(facts, "read_only", False) or it.mutates:
        return False
    perms = np.asarray(arena.perms)
    return bool(np.all((perms & PERM_READ) == PERM_READ))


def distributed_execute(
    it: PulseIterator,
    arena: Arena,
    ptr0: jax.Array,
    scratch0: jax.Array,
    *,
    mesh: Mesh,
    axis_name: str = "mem",
    max_iters: int = 1 << 30,
    k_local: int = 4,
    max_supersteps: int = 1 << 16,
    return_to_cpu: bool = False,
    compact: bool = False,
    min_link_capacity: int = 8,
    fused: bool = False,
    schedule: str | None = None,
    fabric: str = "dense",
    local_backend: str = "xla",
    fault_injector=None,
    replication: ReplicaContext | None = None,
    elide_access_check: bool | None = None,
):
    """Run a batch of traversals over a range-partitioned arena on a mesh.

    ``replication`` (read path, dispatched schedule) threads a
    ``ReplicaContext`` through every superstep: the serve map redirects
    reads bound for dead (or spread-balanced) primaries to their replica
    holders, which chase them from their mirrored rows -- replicas are
    bit-identical by construction, so final ``(ptr, scratch, status,
    iters)`` match the failure-free run exactly; only ``hops`` and
    superstep counts may differ (the redirect changes *where* records are
    served, never their state trajectory).  A dead shard with no replica
    simply cannot serve its range -- callers must not route reads there.

    ``schedule`` selects the superstep engine (``fused`` is the boolean
    shorthand kept for callers predating the pipelined schedule):

      * ``"dispatched"`` -- one jitted superstep per hop, scheduling on host;
      * ``"fused"``      -- whole traversal as one device-resident
        ``lax.while_loop`` (chase, then exchange, strictly in sequence);
      * ``"pipelined"``  -- the fused loop's active set split into two
        wavefronts, double-buffered so the in-flight wavefront's collective
        overlaps the resident wavefront's local chase
        (``make_pipelined_loop``), with fabric-side coordination collapsed
        to one stacked psum per superstep.

    All three produce bit-identical records, pool layouts, superstep counts,
    and wire accounting.  ``fabric="ring"`` swaps the dense all_to_all for
    ``lax.ppermute`` distance classes on any schedule (see ``_exchange``);
    ``local_backend="kernel"`` threads the device-resident local chase
    through the pulse_chase kernel's vectorized iterator body.

    ``fused=True`` runs the *entire* traversal as one device-resident
    program: the superstep loop becomes a ``lax.while_loop`` inside a single
    jitted ``shard_map`` executable (cached in ``_FUSED_CACHE``, pool buffer
    donated), with the local-vs-fabric decision taken on-device by a
    ``lax.cond`` around the all_to_all and the capacity ladder carried as
    traced state.  No host round-trip per hop: the host sees only the final
    pool plus aggregate counters, so ``RoutingStats`` carries totals instead
    of per-step lists.  Results are bit-identical to the dispatched schedule.
    Wire words stay the modeled ladder payload on both paths (see
    RoutingStats): the fused all_to_all buffer is fixed at base capacity
    (shapes cannot be traced), so the ladder's shrinkage is physical only
    when dispatched, while the local-only fabric skip is physical on both.

    ``compact=True`` enables active-set compaction of the supersteps:

      * finished records retire in place instead of being shipped home
        (``drain_done``), so only live traversals occupy link capacity;
      * the per-destination link capacity C adapts each superstep to a
        power-of-two envelope of the surviving active count, shrinking the
        all_to_all payload as the batch drains (a smaller C only parks
        overflow locally for one superstep -- correctness is unaffected);
      * supersteps where every active record already sits at its owning
        shard skip the all_to_all entirely (local-only fast path).

    Results are bit-identical to the uncompacted schedule (ptr/scratch/
    status/iters are scheduling-independent); only ``crossings`` differs,
    since finished records no longer hop home.  With ``return_to_cpu`` the
    home bounce IS the semantics being ablated (Fig. 9's crossings count),
    and both drain-in-place and the local-only/adaptive-capacity schedule
    would strand or delay exactly the hops that ablation measures -- so
    ``compact`` is ignored on that path.

    Returns (records (B, R) ordered by request id, RoutingStats) -- plus the
    post-commit ``Arena`` as a third element when ``it.mutates`` (the input
    arena object is left untouched, so the same pre-state can be replayed
    through several schedules and compared bit-for-bit).

    ``fault_injector`` (test-only, ``core.faults.FaultInjector``) threads an
    injected failure schedule through every schedule x fabric: a targeted
    kill raises ``ShardFailure`` *before* the named superstep executes (the
    input arena buffers are never mutated in place, so the observable heap
    stays at the pre-call state -- the recovery anchor), fabric loss parks
    and retransmits records under a seeded mask, and a straggler delay
    sleeps the dispatched host loop per superstep.

    ``elide_access_check=None`` (default) auto-specializes: when the
    iterator carries a pulse-verify certificate proving it read-only and
    every shard grants PERM_READ (``can_elide_access_check``), the per-hop
    protection probe compiles away -- bit-identical by construction, since
    the probe would have been constant True.  ``False`` forces the
    unspecialized path (the oracle for the bit-identity gate); ``True``
    asserts the caller's own proof and raises if the iterator mutates or
    replication is active.
    """
    kill_at = None
    delay_s = 0.0
    delay_shard = None
    drop_prob = 0.0
    drop_seed = 0
    if fault_injector is not None:
        call_idx = fault_injector.begin_call()
        kill_at = fault_injector.kill_step(call_idx)
        plan = fault_injector.plan
        drop_prob, drop_seed = float(plan.drop_prob), int(plan.drop_seed)
        if plan.delay_shard is not None:
            delay_s = float(plan.delay_s)
            delay_shard = int(plan.delay_shard)
    if schedule is None:
        schedule = "fused" if fused else "dispatched"
    if schedule not in ("dispatched", "fused", "pipelined"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if fabric not in ("dense", "ring"):
        raise ValueError(f"unknown fabric {fabric!r}")
    if local_backend not in ("xla", "kernel"):
        raise ValueError(f"unknown local_backend {local_backend!r}")
    mutate = it.mutates
    if mutate and return_to_cpu:
        raise ValueError(
            "mutating iterators cannot run under the return_to_cpu ablation: "
            "the home bounce would reorder commits against the write path's "
            "superstep contract"
        )
    if mutate and local_backend == "kernel":
        raise ValueError(
            "mutating iterators are not supported on the pulse_chase kernel "
            "local backend yet; use local_backend='xla'"
        )
    if replication is not None:
        if mutate:
            raise ValueError(
                "replication serves the READ path only: writes to a dead "
                "shard park under backoff until recovery rebuilds it"
            )
        if return_to_cpu:
            raise ValueError(
                "replication is incompatible with the return_to_cpu ablation"
            )
        if schedule in ("fused", "pipelined"):
            raise ValueError(
                "replication runs on the dispatched schedule (results are "
                "schedule-invariant, so degraded rounds fall back to it)"
            )
    if elide_access_check is None:
        # analysis-driven specialization: drop the per-hop PERM_READ probe
        # when the pulse-verify certificate + a host-side perms scan prove it
        # constant-true.  Replication rounds keep the probe: the replica path
        # carries its own primary-grant check and degraded-mode perms may
        # change between rounds.
        elide_access_check = replication is None and can_elide_access_check(
            it, arena
        )
    elif elide_access_check:
        if mutate or replication is not None:
            raise ValueError(
                "elide_access_check=True is only sound for verified "
                "read-only traversals without replication"
            )
    fused = schedule in ("fused", "pipelined")
    num_shards = arena.num_shards
    P_axis = mesh.shape[axis_name]
    if P_axis != num_shards:
        raise ValueError(f"arena has {num_shards} shards but mesh axis has {P_axis}")
    rows = arena.capacity
    if rows % num_shards:
        raise ValueError("distributed arena must have uniform shard sizes")

    B = ptr0.shape[0]
    Bp = ((B + num_shards - 1) // num_shards) * num_shards
    S = it.scratch_words
    MW = mut_width(arena.node_words) if mutate else 0
    ids = jnp.arange(B, dtype=jnp.int32)
    home = ids % num_shards
    rec = pack_requests(
        ids, home, jnp.asarray(ptr0, jnp.int32), jnp.asarray(scratch0, jnp.int32),
        mut_words=MW,
    )
    if Bp != B:
        rec = jnp.concatenate([rec, empty_records(Bp - B, S + MW)], axis=0)
        home_p = jnp.concatenate([home, jnp.arange(Bp - B, dtype=jnp.int32) % num_shards])
    else:
        home_p = home
    # place each request at its home shard; pool size L = Bp per shard is the
    # safe upper bound (all requests could, transiently, sit on one shard)
    L = Bp
    order = jnp.argsort(home_p, stable=True)
    rec_sorted = rec[order]
    counts = np.bincount(np.asarray(home_p), minlength=num_shards)
    pools = []
    off = 0
    for s in range(num_shards):
        c = int(counts[s])
        pools.append(
            jnp.concatenate(
                [rec_sorted[off : off + c], empty_records(L - c, S + MW)], axis=0
            )
        )
        off += c
    pool_global = jnp.stack(pools)  # (P, L, R)

    sharding = NamedSharding(mesh, P(axis_name))
    pool_global = jax.device_put(pool_global.reshape(num_shards * L, -1), sharding)
    if mutate:
        # no resident-arena cache on the write path: the arena is the value
        # being transformed, so place this call's snapshot explicitly (a
        # no-op when the caller chains the returned arena back in) and hand
        # the updated buffers back as a fresh Arena
        arena_data = jax.device_put(arena.data, NamedSharding(mesh, P(axis_name, None)))
        bounds = jax.device_put(arena.bounds, NamedSharding(mesh, P()))
        perms = jax.device_put(arena.perms, NamedSharding(mesh, P()))
        heap = jax.device_put(arena.heap, NamedSharding(mesh, P(axis_name, None)))
        commits0 = int(np.asarray(arena.heap)[:, H_COMMITS].sum())
        epochs0 = int(np.asarray(arena.heap)[:, H_EPOCH].sum())
    else:
        arena_data, bounds, perms = _resident_arena(arena, mesh, axis_name)

    base_capacity = L // num_shards
    compact = compact and not return_to_cpu
    drain_done = compact
    R = record_width(S, MW)

    if fused:
        runner = get_fused_runner(
            it, mesh, axis_name,
            num_shards=num_shards, pool_rows=num_shards * L, scratch_words=S,
            k_local=k_local, max_supersteps=max_supersteps,
            base_capacity=base_capacity, min_link_capacity=min_link_capacity,
            return_to_cpu=return_to_cpu, compact=compact,
            schedule=schedule, fabric=fabric, local_backend=local_backend,
            mutate=mutate, drop_prob=drop_prob, drop_seed=drop_seed,
            elide_access_check=elide_access_check,
        )
        # the quantum rides in as a traced operand: every budget value is a
        # cache hit on the same executable (int32 is safe -- callers cap
        # max_iters at 1 << 30)
        iter_budget = jnp.int32(min(max_iters, (1 << 31) - 1))
        # an armed kill caps the device loop at kill_superstep - 1 supersteps
        # via the traced halt operand; the unarmed value duplicates the
        # loop's own max_supersteps bound (same executable either way)
        halt = jnp.int32(kill_at - 1 if kill_at is not None else max_supersteps)
        if mutate:
            (pool_global, arena_data, heap, n_active, steps, n_routed, n_drop,
             cap_counts, local_only) = runner(
                pool_global, arena_data, heap, bounds, perms, iter_budget, halt
            )
        else:
            pool_global, n_active, steps, n_routed, n_drop, cap_counts, local_only = (
                runner(pool_global, arena_data, bounds, perms, iter_budget, halt)
            )
        if int(n_drop) != 0:  # not assert: must survive python -O
            raise RuntimeError(
                f"request records lost in routing (pool overflow): {int(n_drop)}"
            )
        if (
            kill_at is not None
            and int(n_active) > 0
            and int(steps) >= kill_at - 1
        ):
            # the loop halted at the injected death point with work left:
            # this call dies here, outputs discarded.  The input arena
            # buffers were never donated or mutated, so the caller's
            # observable state is exactly the pre-call snapshot.
            fault_injector.fire(kill_at)
        if int(n_active) != 0:
            raise RuntimeError(
                f"distributed_execute: {int(n_active)} records still ACTIVE after "
                f"max_supersteps={max_supersteps}; raise the cap or lower max_iters "
                f"(records would be returned with partial state otherwise)"
            )
        # decode the per-rung superstep histogram into a wire total with
        # Python integer arithmetic (exact at any batch size; a traced int32
        # product would wrap for production-scale pools)
        rungs = capacity_rungs(base_capacity, min_link_capacity) if compact else (
            base_capacity,
        )
        wire_total = sum(
            int(c) * num_shards * (num_shards - 1) * cap * R
            for c, cap in zip(np.asarray(cap_counts), rungs)
        )
        out = _decode_results(
            pool_global, B, S,
            mut_words=MW,
            supersteps=int(steps),
            local_only_steps=int(local_only),
            wire_words_total=wire_total,
            fused=True,
            schedule=schedule,
            fabric=fabric,
            num_shards=num_shards,
        )
        if mutate:
            heap_np = np.asarray(heap)
            out[1].commits = int(heap_np[:, H_COMMITS].sum()) - commits0
            out[1].epochs = int(heap_np[:, H_EPOCH].sum()) - epochs0
            new_arena = Arena(
                data=arena_data, bounds=arena.bounds, perms=arena.perms, heap=heap
            )
            return out[0], out[1], new_arena
        return out

    rep_plan = replication.plan if replication is not None else None

    def get_step(capacity: int | None, do_route: bool):
        # cached across calls: the serving loop re-enters distributed_execute
        # every scheduling round with identical parameters, and a per-call
        # cache would recompile the shard_map superstep each round
        key = (
            it, mesh, axis_name, num_shards, k_local, max_iters,
            return_to_cpu, drain_done, capacity, do_route, fabric,
            local_backend, mutate, drop_prob, drop_seed, rep_plan,
            elide_access_check,
        )
        if key not in _STEP_CACHE:
            CACHE_STATS.misses += 1
            superstep = make_superstep(
                it, num_shards, axis_name,
                k_local=k_local, max_iters=max_iters,
                return_to_cpu=return_to_cpu,
                link_capacity=capacity, drain_done=drain_done,
                do_route=do_route, fabric=fabric, local_backend=local_backend,
                mutate=mutate, drop_prob=drop_prob, drop_seed=drop_seed,
                replication=rep_plan,
                elide_access_check=elide_access_check,
            )
            # replication adds (holder-sharded replica rows, replicated
            # dead mask); fault-injected fabric loss adds one trailing
            # traced step_idx operand (the drop mask is keyed on it)
            rep_specs = (P(axis_name), P()) if rep_plan is not None else ()
            drop_specs = (P(),) if (drop_prob > 0.0 and do_route) else ()
            if mutate:
                in_specs = (
                    P(axis_name), P(axis_name), P(axis_name), P(), P(),
                ) + drop_specs
                out_specs = (
                    P(axis_name), P(axis_name), P(axis_name), P(), P(), P(), P(),
                )
            else:
                in_specs = (
                    (P(axis_name), P(axis_name), P(), P()) + rep_specs + drop_specs
                )
                out_specs = (P(axis_name), P(), P(), P(), P())
            # ISA-VM iterators run a lax.while_loop per step (the bounded
            # bytecode interpreter), which shard_map's replication checker
            # cannot analyze -- use the unchecked shim for those, exactly as
            # the fused/pipelined loops always do; traced iterators keep the
            # checked wrapper as a free structural safety net.
            sm = shard_map_unchecked if _is_vm_backed(it) else shard_map
            _STEP_CACHE[key] = jax.jit(
                sm(superstep, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
            )
        else:
            CACHE_STATS.hits += 1
        return _STEP_CACHE[key]

    if replication is not None:
        rep_rows_dev = jax.device_put(
            jnp.asarray(replication.rep_rows, jnp.int32),
            NamedSharding(mesh, P(axis_name, None)),
        )
        dead_mask_dev = jax.device_put(
            jnp.asarray(replication.dead_mask, bool), NamedSharding(mesh, P())
        )
        rep_args = (rep_rows_dev, dead_mask_dev)
    else:
        rep_args = ()

    if delay_s > 0.0:
        _bnp = np.asarray(arena.bounds)
        _dlo, _dhi = int(_bnp[delay_shard]), int(_bnp[delay_shard + 1])

    routed_per_step = []
    active_per_step = []
    wire_words_per_step = []
    capacity_per_step = []
    local_only_steps = 0
    steps = 0
    # before the first superstep everything is active and sitting at home
    n_active, n_remote = B, B
    for _ in range(max_supersteps):
        # injected shard death: fires before the targeted (1-based) superstep
        # executes, so exactly kill_at - 1 supersteps of this call completed
        # and the caller's observable arena is the pre-call snapshot
        if kill_at is not None and steps + 1 >= kill_at:
            fault_injector.fire(steps + 1)
        if delay_s > 0.0:
            # attributable straggler: the slow memory node extends the BSP
            # barrier only on supersteps where it actually serves work (an
            # ACTIVE record pointing into its range).  Reads fanned out to
            # its replica cost it nothing -- which is what makes a per-shard
            # watchdog probe attributable: the probe to the straggler is
            # slow, probes elsewhere are not.
            serving = True
            if replication is not None and int(
                replication.plan.replica_map[delay_shard]
            ) >= 0:
                serving = not bool(np.asarray(replication.dead_mask)[delay_shard])
            if serving:
                pg = np.asarray(pool_global)
                act = pg[:, F_STATUS] == STATUS_ACTIVE
                ptrs = pg[:, F_PTR]
                serving = bool(np.any(act & (ptrs >= _dlo) & (ptrs < _dhi)))
            if serving:
                time.sleep(delay_s)
        if compact:
            # power-of-two envelope of the per-link demand; the ladder keeps
            # the number of distinct compiled supersteps at O(log L)
            demand = (int(n_active) + num_shards - 1) // num_shards
            capacity = min(
                base_capacity, max(min_link_capacity, _pow2_at_least(demand))
            )
            do_route = int(n_remote) > 0
        else:
            capacity, do_route = base_capacity, True
        # link_capacity is dead in the local-only step: collapse those cache
        # keys to one so the capacity ladder doesn't compile duplicate steps
        step_capacity = capacity if (compact and do_route) else None
        drop_args = (
            (jnp.int32(steps),) if (drop_prob > 0.0 and do_route) else ()
        )
        if mutate:
            (pool_global, arena_data, heap, n_active, n_routed, n_drop,
             n_remote) = get_step(step_capacity, do_route)(
                pool_global, arena_data, heap, bounds, perms, *drop_args
            )
        else:
            pool_global, n_active, n_routed, n_drop, n_remote = get_step(
                step_capacity, do_route
            )(pool_global, arena_data, bounds, perms, *rep_args, *drop_args)
        steps += 1
        routed_per_step.append(int(n_routed))
        active_per_step.append(int(n_active))
        capacity_per_step.append(capacity if do_route else 0)
        wire_words_per_step.append(
            num_shards * (num_shards - 1) * capacity * R if do_route else 0
        )
        local_only_steps += int(not do_route)
        if int(n_drop) != 0:  # not assert: must survive python -O
            raise RuntimeError(
                f"request records lost in routing (pool overflow): {int(n_drop)}"
            )
        if int(n_active) == 0:
            break
    else:
        raise RuntimeError(
            f"distributed_execute: {int(n_active)} records still ACTIVE after "
            f"max_supersteps={max_supersteps}; raise the cap or lower max_iters "
            f"(records would be returned with partial state otherwise)"
        )

    out = _decode_results(
        pool_global, B, S,
        mut_words=MW,
        supersteps=steps,
        routed_per_step=routed_per_step,
        active_per_step=active_per_step,
        wire_words_per_step=wire_words_per_step,
        capacity_per_step=capacity_per_step,
        local_only_steps=local_only_steps,
        schedule=schedule,
        fabric=fabric,
        num_shards=num_shards,
    )
    if mutate:
        heap_np = np.asarray(heap)
        out[1].commits = int(heap_np[:, H_COMMITS].sum()) - commits0
        out[1].epochs = int(heap_np[:, H_EPOCH].sum()) - epochs0
        new_arena = Arena(
            data=arena_data, bounds=arena.bounds, perms=arena.perms, heap=heap
        )
        return out[0], out[1], new_arena
    return out


def _decode_results(
    pool_global,
    B: int,
    scratch_words: int,
    *,
    mut_words: int = 0,
    supersteps: int,
    routed_per_step: list | None = None,
    active_per_step: list | None = None,
    wire_words_per_step: list | None = None,
    capacity_per_step: list | None = None,
    local_only_steps: int = 0,
    wire_words_total: int | None = None,
    fused: bool = False,
    schedule: str = "dispatched",
    fabric: str = "dense",
    num_shards: int = 0,
):
    """Gather the final pools, order records by request id, build stats."""
    all_rec = np.asarray(pool_global).reshape(
        -1, record_width(scratch_words, mut_words)
    )
    valid = all_rec[:, F_STATUS] != STATUS_EMPTY
    all_rec = all_rec[valid]
    all_rec = all_rec[all_rec[:, F_ID] < B]
    order = np.argsort(all_rec[:, F_ID], kind="stable")
    all_rec = all_rec[order]
    stats = RoutingStats(
        supersteps=supersteps,
        crossings=all_rec[:, F_HOPS].copy(),
        routed_per_step=routed_per_step or [],
        active_per_step=active_per_step or [],
        wire_words_per_step=wire_words_per_step or [],
        capacity_per_step=capacity_per_step or [],
        local_only_steps=local_only_steps,
        wire_words_total=wire_words_total,
        fused=fused,
        schedule=schedule,
        fabric=fabric,
        _num_shards=num_shards,
    )
    return all_rec, stats
