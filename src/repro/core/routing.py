"""Distributed pointer traversals: the in-network switch as supersteps (S5).

The paper routes in-flight traversal requests between memory nodes with a
programmable switch that holds only the range-partition base table.  On a TPU
mesh the ICI collectives *are* the programmable fabric, so we route **batches**
of fixed-size request records with ``all_to_all`` in bulk-synchronous
supersteps.  The paper's key properties are preserved exactly:

  * a cross-node hop never bounces through the CPU node (compare
    ``return_to_cpu=True``, the paper's PULSE-ACC ablation, Fig. 9);
  * the request and the response share one wire format, so any shard can
    continue any traversal it receives (S5 "continuing stateful iterator
    execution");
  * the switch knows only ``bounds`` (hierarchical translation, Fig. 6);
    per-shard translation/protection happens at the owning shard.

Record wire format (R = 6 + S int32 words):
  [id, home_shard, cur_ptr, status, iters, hops, scratch_pad...]
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import translation
from repro.core.arena import NULL, PERM_READ, Arena
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_EMPTY,
    PulseIterator,
    step_batch,
)

F_ID, F_HOME, F_PTR, F_STATUS, F_ITERS, F_HOPS, F_SCRATCH = 0, 1, 2, 3, 4, 5, 6


def record_width(scratch_words: int) -> int:
    return F_SCRATCH + scratch_words


def pack_requests(ids, home, ptr, scratch) -> jnp.ndarray:
    B, S = scratch.shape
    rec = jnp.zeros((B, record_width(S)), jnp.int32)
    rec = rec.at[:, F_ID].set(ids)
    rec = rec.at[:, F_HOME].set(home)
    rec = rec.at[:, F_PTR].set(ptr)
    rec = rec.at[:, F_STATUS].set(STATUS_ACTIVE)
    rec = rec.at[:, F_SCRATCH:].set(scratch)
    return rec


def empty_records(n: int, scratch_words: int) -> jnp.ndarray:
    rec = jnp.zeros((n, record_width(scratch_words)), jnp.int32)
    return rec.at[:, F_STATUS].set(STATUS_EMPTY)


@dataclasses.dataclass
class RoutingStats:
    supersteps: int
    crossings: np.ndarray  # (B,) network crossings per request (Fig. 2c/9)
    routed_per_step: list  # valid records exchanged per superstep
    active_per_step: list = dataclasses.field(default_factory=list)
    wire_words_per_step: list = dataclasses.field(default_factory=list)
    # int32 words shipped across off-shard links per superstep (the BSP
    # all_to_all payload: num_shards * (num_shards-1) * link_capacity * R;
    # 0 for compacted local-only supersteps that skip the fabric entirely)
    capacity_per_step: list = dataclasses.field(default_factory=list)
    local_only_steps: int = 0  # supersteps that skipped the all_to_all

    @property
    def total_wire_words(self) -> int:
        return int(sum(self.wire_words_per_step))


def _local_superstep(
    it: PulseIterator,
    pool: jnp.ndarray,  # (L, R) local request pool
    arena_rows: jnp.ndarray,  # (rows_per_shard, W) this shard's arena rows
    bounds: jnp.ndarray,  # (P+1,) switch base table (replicated)
    perms: jnp.ndarray,  # (P,)   protection bits (replicated)
    my_shard: jnp.ndarray,  # () int32
    *,
    k_local: int,
    max_iters: int,
):
    """Run up to ``k_local`` iterations for locally-owned ACTIVE requests."""
    S = it.scratch_words
    lo = bounds[my_shard]
    hi = bounds[my_shard + 1]
    perm_ok = translation.check_access(perms, my_shard, PERM_READ)

    def body(_, st):
        ptr, scratch, status, iters = st
        return step_batch(
            it,
            arena_rows,
            ptr,
            scratch,
            status,
            iters,
            max_iters=max_iters,
            local_lo=lo,
            local_hi=hi,
            perm_ok=perm_ok,
        )

    ptr = pool[:, F_PTR]
    scratch = pool[:, F_SCRATCH:]
    status = pool[:, F_STATUS]
    iters = pool[:, F_ITERS]
    ptr, scratch, status, iters = jax.lax.fori_loop(
        0, k_local, body, (ptr, scratch, status, iters)
    )
    pool = pool.at[:, F_PTR].set(ptr)
    pool = pool.at[:, F_SCRATCH:].set(scratch)
    pool = pool.at[:, F_STATUS].set(status)
    pool = pool.at[:, F_ITERS].set(iters)
    return pool


def _route(
    pool: jnp.ndarray,  # (L, R)
    bounds: jnp.ndarray,
    my_shard: jnp.ndarray,
    num_shards: int,
    axis_name: str,
    *,
    return_to_cpu: bool,
    link_capacity: int | None = None,
    drain_done: bool = False,
):
    """Switch routing: deliver records to their next shard via all_to_all.

    ``link_capacity`` is the per-destination link budget C (records per
    superstep); the default is the worst-case L // num_shards.  Compacted
    execution passes a shrunken C once most of the batch has finished, so the
    BSP payload tracks the live set instead of the original batch.

    ``drain_done`` is the active-set compaction: finished (DONE/FAULT/MAXED)
    records retire *in place* instead of being routed to their home shard --
    the final gather collects them from wherever they stopped, so shipping
    them home only burned link capacity (exactly the waste the paper's switch
    design avoids by keeping only live traversals in the fabric).
    """
    L, R = pool.shape
    C = L // num_shards if link_capacity is None else int(link_capacity)
    status = pool[:, F_STATUS]
    valid = status != STATUS_EMPTY
    active = status == STATUS_ACTIVE

    owner = translation.owner_of(bounds, pool[:, F_PTR])
    # invalid pointer (owner == NULL) on an active request -> the switch
    # notifies the CPU node (Fig. 6 step 6): mark FAULT, send home.
    bad = active & (owner == NULL)
    status = jnp.where(bad, jnp.int32(3), status)  # STATUS_FAULT
    pool = pool.at[:, F_STATUS].set(status)
    active = status == STATUS_ACTIVE

    if return_to_cpu:
        # PULSE-ACC (Fig. 9): a traversal leaving this node must return to its
        # home (CPU) node, which re-issues it -- route non-local actives home.
        stay = active & (owner == my_shard)
        dest = jnp.where(stay, my_shard, pool[:, F_HOME])
        dest = jnp.where(active & (owner != my_shard), pool[:, F_HOME], dest)
        # once home, re-issue toward the owner
        at_home = active & (pool[:, F_HOME] == my_shard) & (owner != my_shard)
        dest = jnp.where(at_home, owner, dest)
    elif drain_done:
        dest = jnp.where(active, owner, my_shard)
    else:
        dest = jnp.where(active, owner, pool[:, F_HOME])
    dest = jnp.where(valid, dest, my_shard).astype(jnp.int32)

    moves = valid & (dest != my_shard)
    pool = pool.at[:, F_HOPS].set(pool[:, F_HOPS] + moves.astype(jnp.int32))

    # pack into (P, C+1, R): overflow beyond per-link capacity parks in the
    # trash row (C) and stays local for the next superstep.
    onehot = (dest[:, None] == jnp.arange(num_shards, dtype=jnp.int32)[None, :]) & (
        moves[:, None]
    )
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - onehot.astype(jnp.int32)
    pos = jnp.take_along_axis(pos, jnp.clip(dest, 0, num_shards - 1)[:, None], axis=1)[
        :, 0
    ]
    fits = moves & (pos < C)
    d_idx = jnp.where(fits, dest, 0)
    p_idx = jnp.where(fits, pos, C)
    send = jnp.broadcast_to(
        empty_records(1, R - F_SCRATCH)[0], (num_shards, C + 1, R)
    ).astype(jnp.int32)
    send = send.at[d_idx, p_idx].set(jnp.where(fits[:, None], pool, send[d_idx, p_idx]))
    send = send[:, :C]

    # what leaves this shard is removed from the local pool
    kept = pool.at[:, F_STATUS].set(
        jnp.where(fits, jnp.int32(STATUS_EMPTY), pool[:, F_STATUS])
    )

    arrivals = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=True)
    arrivals = arrivals.reshape(num_shards * C, R)

    # merge: valid records first, then empties; keep L slots (conservation:
    # total valid records across the mesh is constant == B <= sum of pools).
    both = jnp.concatenate([kept, arrivals], axis=0)
    is_empty = both[:, F_STATUS] == STATUS_EMPTY
    order = jnp.argsort(is_empty, stable=True)
    merged = both[order][:L]
    n_dropped_valid = (~is_empty).sum() - (merged[:, F_STATUS] != STATUS_EMPTY).sum()
    n_routed = fits.sum()
    return merged, n_routed, n_dropped_valid


def _remote_active(pool, bounds, my_shard):
    """Active records this shard cannot serve (owner elsewhere / invalid)."""
    active = pool[:, F_STATUS] == STATUS_ACTIVE
    owner = translation.owner_of(bounds, pool[:, F_PTR])
    return (active & (owner != my_shard)).sum()


def make_superstep(
    it: PulseIterator,
    num_shards: int,
    axis_name: str,
    *,
    k_local: int,
    max_iters: int,
    return_to_cpu: bool = False,
    link_capacity: int | None = None,
    drain_done: bool = False,
    do_route: bool = True,
):
    """Builds the jittable per-shard superstep: local run -> switch route.

    ``do_route=False`` builds the compacted *local-only* superstep: when every
    surviving traversal is already at its owning shard, the fabric has nothing
    to carry, so the all_to_all is skipped entirely (wire payload 0).  The
    step still reports how many actives turned remote so the driver knows
    when to re-enter the routed variant.

    Returns ``(pool, n_active, n_routed, n_drop, n_remote)`` -- all counters
    globally psum'd.
    """

    def superstep(pool, arena_rows, bounds, perms):
        my_shard = jax.lax.axis_index(axis_name).astype(jnp.int32)
        pool = _local_superstep(
            it, pool, arena_rows, bounds, perms, my_shard,
            k_local=k_local, max_iters=max_iters,
        )
        if do_route:
            pool, n_routed, n_drop = _route(
                pool, bounds, my_shard, num_shards, axis_name,
                return_to_cpu=return_to_cpu,
                link_capacity=link_capacity,
                drain_done=drain_done,
            )
        else:
            n_routed = jnp.int32(0)
            n_drop = jnp.int32(0)
        n_active = (pool[:, F_STATUS] == STATUS_ACTIVE).sum()
        n_remote = _remote_active(pool, bounds, my_shard)
        n_active = jax.lax.psum(n_active, axis_name)
        n_routed = jax.lax.psum(n_routed, axis_name)
        n_drop = jax.lax.psum(n_drop, axis_name)
        n_remote = jax.lax.psum(n_remote, axis_name)
        return pool, n_active, n_routed, n_drop, n_remote

    return superstep


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


# compiled supersteps, shared across distributed_execute calls (see get_step)
_STEP_CACHE: dict = {}


def distributed_execute(
    it: PulseIterator,
    arena: Arena,
    ptr0: jax.Array,
    scratch0: jax.Array,
    *,
    mesh: Mesh,
    axis_name: str = "mem",
    max_iters: int = 1 << 30,
    k_local: int = 4,
    max_supersteps: int = 1 << 16,
    return_to_cpu: bool = False,
    compact: bool = False,
    min_link_capacity: int = 8,
):
    """Run a batch of traversals over a range-partitioned arena on a mesh.

    ``compact=True`` enables active-set compaction of the supersteps:

      * finished records retire in place instead of being shipped home
        (``drain_done``), so only live traversals occupy link capacity;
      * the per-destination link capacity C adapts each superstep to a
        power-of-two envelope of the surviving active count, shrinking the
        all_to_all payload as the batch drains (a smaller C only parks
        overflow locally for one superstep -- correctness is unaffected);
      * supersteps where every active record already sits at its owning
        shard skip the all_to_all entirely (local-only fast path).

    Results are bit-identical to the uncompacted schedule (ptr/scratch/
    status/iters are scheduling-independent); only ``crossings`` differs,
    since finished records no longer hop home.  With ``return_to_cpu`` the
    home bounce IS the semantics being ablated (Fig. 9's crossings count),
    and both drain-in-place and the local-only/adaptive-capacity schedule
    would strand or delay exactly the hops that ablation measures -- so
    ``compact`` is ignored on that path.

    Returns (records (B, R) ordered by request id, RoutingStats).
    """
    num_shards = arena.num_shards
    P_axis = mesh.shape[axis_name]
    if P_axis != num_shards:
        raise ValueError(f"arena has {num_shards} shards but mesh axis has {P_axis}")
    rows = arena.capacity
    if rows % num_shards:
        raise ValueError("distributed arena must have uniform shard sizes")

    B = ptr0.shape[0]
    Bp = ((B + num_shards - 1) // num_shards) * num_shards
    S = it.scratch_words
    ids = jnp.arange(B, dtype=jnp.int32)
    home = ids % num_shards
    rec = pack_requests(ids, home, jnp.asarray(ptr0, jnp.int32), jnp.asarray(scratch0, jnp.int32))
    if Bp != B:
        rec = jnp.concatenate([rec, empty_records(Bp - B, S)], axis=0)
        home_p = jnp.concatenate([home, jnp.arange(Bp - B, dtype=jnp.int32) % num_shards])
    else:
        home_p = home
    # place each request at its home shard; pool size L = Bp per shard is the
    # safe upper bound (all requests could, transiently, sit on one shard)
    L = Bp
    order = jnp.argsort(home_p, stable=True)
    rec_sorted = rec[order]
    counts = np.bincount(np.asarray(home_p), minlength=num_shards)
    pools = []
    off = 0
    for s in range(num_shards):
        c = int(counts[s])
        pools.append(
            jnp.concatenate(
                [rec_sorted[off : off + c], empty_records(L - c, S)], axis=0
            )
        )
        off += c
    pool_global = jnp.stack(pools)  # (P, L, R)

    sharding = NamedSharding(mesh, P(axis_name))
    pool_global = jax.device_put(pool_global.reshape(num_shards * L, -1), sharding)
    arena_data = jax.device_put(arena.data, NamedSharding(mesh, P(axis_name, None)))
    bounds = jax.device_put(arena.bounds, NamedSharding(mesh, P()))
    perms = jax.device_put(arena.perms, NamedSharding(mesh, P()))

    base_capacity = L // num_shards
    compact = compact and not return_to_cpu
    drain_done = compact
    R = record_width(S)

    def get_step(capacity: int | None, do_route: bool):
        # cached across calls: the serving loop re-enters distributed_execute
        # every scheduling round with identical parameters, and a per-call
        # cache would recompile the shard_map superstep each round
        key = (
            it, mesh, axis_name, num_shards, k_local, max_iters,
            return_to_cpu, drain_done, capacity, do_route,
        )
        if key not in _STEP_CACHE:
            superstep = make_superstep(
                it, num_shards, axis_name,
                k_local=k_local, max_iters=max_iters,
                return_to_cpu=return_to_cpu,
                link_capacity=capacity, drain_done=drain_done,
                do_route=do_route,
            )
            _STEP_CACHE[key] = jax.jit(
                shard_map(
                    superstep,
                    mesh=mesh,
                    in_specs=(P(axis_name), P(axis_name), P(), P()),
                    out_specs=(P(axis_name), P(), P(), P(), P()),
                )
            )
        return _STEP_CACHE[key]

    routed_per_step = []
    active_per_step = []
    wire_words_per_step = []
    capacity_per_step = []
    local_only_steps = 0
    steps = 0
    # before the first superstep everything is active and sitting at home
    n_active, n_remote = B, B
    for _ in range(max_supersteps):
        if compact:
            # power-of-two envelope of the per-link demand; the ladder keeps
            # the number of distinct compiled supersteps at O(log L)
            demand = (int(n_active) + num_shards - 1) // num_shards
            capacity = min(
                base_capacity, max(min_link_capacity, _pow2_at_least(demand))
            )
            do_route = int(n_remote) > 0
        else:
            capacity, do_route = base_capacity, True
        # link_capacity is dead in the local-only step: collapse those cache
        # keys to one so the capacity ladder doesn't compile duplicate steps
        step_capacity = capacity if (compact and do_route) else None
        pool_global, n_active, n_routed, n_drop, n_remote = get_step(
            step_capacity, do_route
        )(pool_global, arena_data, bounds, perms)
        steps += 1
        routed_per_step.append(int(n_routed))
        active_per_step.append(int(n_active))
        capacity_per_step.append(capacity if do_route else 0)
        wire_words_per_step.append(
            num_shards * (num_shards - 1) * capacity * R if do_route else 0
        )
        local_only_steps += int(not do_route)
        assert int(n_drop) == 0, "request records lost in routing (pool overflow)"
        if int(n_active) == 0:
            break
    else:
        raise RuntimeError(
            f"distributed_execute: {int(n_active)} records still ACTIVE after "
            f"max_supersteps={max_supersteps}; raise the cap or lower max_iters "
            f"(records would be returned with partial state otherwise)"
        )

    # gather and order results by id
    all_rec = np.asarray(pool_global).reshape(-1, record_width(S))
    valid = all_rec[:, F_STATUS] != STATUS_EMPTY
    all_rec = all_rec[valid]
    all_rec = all_rec[all_rec[:, F_ID] < B]
    order = np.argsort(all_rec[:, F_ID], kind="stable")
    all_rec = all_rec[order]
    stats = RoutingStats(
        supersteps=steps,
        crossings=all_rec[:, F_HOPS].copy(),
        routed_per_step=routed_per_step,
        active_per_step=active_per_step,
        wire_words_per_step=wire_words_per_step,
        capacity_per_step=capacity_per_step,
        local_only_steps=local_only_steps,
    )
    return all_rec, stats
