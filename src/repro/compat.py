"""Version-compat shims for the JAX APIs this repo leans on.

The repo targets the installed ``jax`` (0.4.x today) but the public
spellings of two APIs moved across releases:

  * ``shard_map``: ``jax.experimental.shard_map.shard_map`` on 0.4.x,
    promoted to ``jax.shard_map`` later.
  * Pallas TPU memory spaces: ``pltpu.TPUMemorySpace.ANY`` (exported as
    ``pltpu.ANY``) on 0.4.x, renamed to ``pltpu.MemorySpace.ANY`` later.

Everything else imports these names from here so a JAX upgrade is a
one-file change.
"""

from __future__ import annotations

try:  # jax >= 0.5-era spelling
    from jax import shard_map as _shard_map

    shard_map = _shard_map
except (ImportError, AttributeError):  # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with the per-output replication check disabled.

    The fused routing loop puts a ``lax.while_loop`` inside ``shard_map``,
    which shard_map's replication checker cannot analyze; the flag that turns
    the check off was renamed across releases (``check_rep`` -> ``check_vma``),
    so callers go through this shim.
    """
    try:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )
    except TypeError:
        pass
    try:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    except TypeError:
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

from jax.experimental.pallas import tpu as _pltpu

if hasattr(_pltpu, "MemorySpace"):  # modern spelling
    TPU_ANY = _pltpu.MemorySpace.ANY
else:  # 0.4.x: TPUMemorySpace, with ANY re-exported at module level
    TPU_ANY = _pltpu.ANY
