"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -r requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.slow  # jit-heavy sweeps; full CI lane only

from repro.core import arena as arena_mod
from repro.core import translation
from repro.core.iterator import STATUS_DONE, STATUS_FAULT, execute_batched
from repro.core.structures import bst, btree, hash_table, linked_list
from repro.data.pipeline import pack_documents

SET = settings(max_examples=25, deadline=None)


# ---------------------- translation / ownership ------------------------------


@SET
@given(
    st.integers(2, 16),
    st.lists(st.integers(-64, 2**20), min_size=1, max_size=64),
    st.integers(4, 2**16),
)
def test_ownership_is_a_partition(num_shards, ptrs, per_shard):
    """Every valid address has exactly one owner; invalid -> NULL."""
    cap = per_shard * num_shards
    bounds = jnp.asarray([i * per_shard for i in range(num_shards)] + [cap])
    owners = np.asarray(translation.owner_of(bounds, jnp.asarray(ptrs, jnp.int32)))
    for p, o in zip(ptrs, owners):
        if 0 <= p < cap:
            assert o == p // per_shard
            assert bool(translation.is_local(bounds, int(o), p))
            # no other shard claims it
            for s in range(num_shards):
                if s != o:
                    assert not bool(translation.is_local(bounds, s, p))
        else:
            assert o == arena_mod.NULL


@SET
@given(st.integers(1, 12), st.data())
def test_local_offset_roundtrip(num_shards, data):
    per = data.draw(st.integers(2, 4096))
    bounds = jnp.asarray([i * per for i in range(num_shards)] + [num_shards * per])
    ptr = data.draw(st.integers(0, num_shards * per - 1))
    o = int(translation.owner_of(bounds, ptr))
    off = int(translation.local_offset(bounds, o, ptr))
    assert 0 <= off < per
    assert int(bounds[o]) + off == ptr


# ---------------------- scratch-pad round trip -------------------------------


@SET
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32), min_size=1, max_size=32))
def test_float_bitcast_roundtrip(xs):
    x = jnp.asarray(xs, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(arena_mod.i2f(arena_mod.f2i(x))), np.asarray(x)
    )


# ---------------------- structure invariants ---------------------------------


@SET
@given(st.data())
def test_btree_find_always_terminates_and_is_correct(data):
    n = data.draw(st.integers(1, 300))
    keys = data.draw(
        st.lists(st.integers(0, 10**6), min_size=n, max_size=n, unique=True)
    )
    keys = np.asarray(keys, np.int32)
    values = np.arange(n, dtype=np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    queries = data.draw(
        st.lists(st.integers(0, 10**6), min_size=1, max_size=32)
    )
    q = np.asarray(queries, np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=height + 1)
    # termination within height hops, DONE status, exact results
    assert (np.asarray(status) == STATUS_DONE).all()
    assert (np.asarray(iters) <= height).all()
    ref = btree.ref_find(keys, values, q)
    for i, (val, found) in enumerate(ref):
        assert int(scr[i, 2]) == found
        if found:
            assert int(scr[i, 1]) == val


@SET
@given(st.data())
def test_hash_chain_membership_complete(data):
    """Every inserted key is findable; chains cover all keys exactly once."""
    n = data.draw(st.integers(1, 200))
    keys = np.asarray(
        data.draw(st.lists(st.integers(0, 10**6), min_size=n, max_size=n, unique=True)),
        np.int32,
    )
    n_buckets = data.draw(st.sampled_from([4, 16, 64]))
    values = np.arange(n, dtype=np.int32)
    ar, heads = hash_table.build(keys, values, n_buckets)
    # chain coverage: walking every bucket touches each key exactly once
    seen = []
    dat = np.asarray(ar.data)
    for h in heads:
        p = int(h)
        hops = 0
        while p != arena_mod.NULL and hops <= n:
            seen.append(int(dat[p, hash_table.KEY]))
            p = int(dat[p, hash_table.NEXT])
            hops += 1
    assert sorted(seen) == sorted(keys.tolist())
    # findability
    it = hash_table.find_iterator(n_buckets)
    ptr0, scr0 = it.init(jnp.asarray(keys), jnp.asarray(heads))
    _, scr, status, _ = execute_batched(it, ar, ptr0, scr0, max_iters=n + 2)
    assert (np.asarray(scr)[:, 2] == 1).all()


@SET
@given(st.data())
def test_bst_lower_bound_invariant(data):
    """The traversal's y pointer is exactly the lower bound of the query."""
    n = data.draw(st.integers(1, 200))
    keys = np.asarray(
        data.draw(st.lists(st.integers(0, 10**5), min_size=n, max_size=n, unique=True)),
        np.int32,
    )
    values = np.arange(n, dtype=np.int32)
    ar, root, height = bst.build(keys, values)
    it = bst.find_iterator()
    q = np.asarray(data.draw(st.lists(st.integers(0, 10**5), min_size=1, max_size=16)), np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    _, scr, status, _ = execute_batched(it, ar, ptr0, scr0, max_iters=height + 1)
    ks = np.sort(keys)
    for i, query in enumerate(q):
        idx = np.searchsorted(ks, query)
        if idx < len(ks):  # lower bound exists
            assert int(scr[i, bst.S_YKEY]) == int(ks[idx])
        else:
            assert int(scr[i, bst.S_Y]) == arena_mod.NULL


# ---------------------- allocation / packing ---------------------------------


@SET
@given(st.integers(1, 8), st.integers(1, 64))
def test_interleaved_allocation_balanced(num_shards, n_alloc):
    per = 64
    b = arena_mod.ArenaBuilder(per * num_shards, 4, num_shards=num_shards, policy="interleaved")
    ptrs = b.alloc(min(n_alloc, per * num_shards))
    shards = ptrs // per
    counts = np.bincount(shards, minlength=num_shards)
    assert counts.max() - counts.min() <= 1  # perfectly balanced round robin
    assert len(np.unique(ptrs)) == len(ptrs)  # no double allocation


@SET
@given(st.lists(st.integers(1, 700), min_size=1, max_size=120), st.sampled_from([512, 1024]))
def test_packing_never_overflows(doc_lens, window):
    lens = np.asarray(doc_lens)
    assign, waste = pack_documents(lens, window)
    fill = {}
    for l, a in zip(lens, assign):
        fill[a] = fill.get(a, 0) + min(int(l), window)
    assert max(fill.values()) <= window
    assert 0.0 <= waste < 1.0
