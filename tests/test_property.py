"""Property-based tests (hypothesis) on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -r requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.slow  # jit-heavy sweeps; full CI lane only

from repro.core import arena as arena_mod
from repro.core import translation
from repro.core.iterator import STATUS_DONE, STATUS_FAULT, execute_batched
from repro.core.structures import bst, btree, hash_table, linked_list
from repro.data.pipeline import pack_documents

SET = settings(max_examples=25, deadline=None)


# ---------------------- translation / ownership ------------------------------


@SET
@given(
    st.integers(2, 16),
    st.lists(st.integers(-64, 2**20), min_size=1, max_size=64),
    st.integers(4, 2**16),
)
def test_ownership_is_a_partition(num_shards, ptrs, per_shard):
    """Every valid address has exactly one owner; invalid -> NULL."""
    cap = per_shard * num_shards
    bounds = jnp.asarray([i * per_shard for i in range(num_shards)] + [cap])
    owners = np.asarray(translation.owner_of(bounds, jnp.asarray(ptrs, jnp.int32)))
    for p, o in zip(ptrs, owners):
        if 0 <= p < cap:
            assert o == p // per_shard
            assert bool(translation.is_local(bounds, int(o), p))
            # no other shard claims it
            for s in range(num_shards):
                if s != o:
                    assert not bool(translation.is_local(bounds, s, p))
        else:
            assert o == arena_mod.NULL


@SET
@given(st.integers(1, 12), st.data())
def test_local_offset_roundtrip(num_shards, data):
    per = data.draw(st.integers(2, 4096))
    bounds = jnp.asarray([i * per for i in range(num_shards)] + [num_shards * per])
    ptr = data.draw(st.integers(0, num_shards * per - 1))
    o = int(translation.owner_of(bounds, ptr))
    off = int(translation.local_offset(bounds, o, ptr))
    assert 0 <= off < per
    assert int(bounds[o]) + off == ptr


# ---------------------- scratch-pad round trip -------------------------------


@SET
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32), min_size=1, max_size=32))
def test_float_bitcast_roundtrip(xs):
    x = jnp.asarray(xs, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(arena_mod.i2f(arena_mod.f2i(x))), np.asarray(x)
    )


# ---------------------- structure invariants ---------------------------------


@SET
@given(st.data())
def test_btree_find_always_terminates_and_is_correct(data):
    n = data.draw(st.integers(1, 300))
    keys = data.draw(
        st.lists(st.integers(0, 10**6), min_size=n, max_size=n, unique=True)
    )
    keys = np.asarray(keys, np.int32)
    values = np.arange(n, dtype=np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    queries = data.draw(
        st.lists(st.integers(0, 10**6), min_size=1, max_size=32)
    )
    q = np.asarray(queries, np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=height + 1)
    # termination within height hops, DONE status, exact results
    assert (np.asarray(status) == STATUS_DONE).all()
    assert (np.asarray(iters) <= height).all()
    ref = btree.ref_find(keys, values, q)
    for i, (val, found) in enumerate(ref):
        assert int(scr[i, 2]) == found
        if found:
            assert int(scr[i, 1]) == val


@SET
@given(st.data())
def test_hash_chain_membership_complete(data):
    """Every inserted key is findable; chains cover all keys exactly once."""
    n = data.draw(st.integers(1, 200))
    keys = np.asarray(
        data.draw(st.lists(st.integers(0, 10**6), min_size=n, max_size=n, unique=True)),
        np.int32,
    )
    n_buckets = data.draw(st.sampled_from([4, 16, 64]))
    values = np.arange(n, dtype=np.int32)
    ar, heads = hash_table.build(keys, values, n_buckets)
    # chain coverage: walking every bucket touches each key exactly once
    seen = []
    dat = np.asarray(ar.data)
    for h in heads:
        p = int(h)
        hops = 0
        while p != arena_mod.NULL and hops <= n:
            seen.append(int(dat[p, hash_table.KEY]))
            p = int(dat[p, hash_table.NEXT])
            hops += 1
    assert sorted(seen) == sorted(keys.tolist())
    # findability
    it = hash_table.find_iterator(n_buckets)
    ptr0, scr0 = it.init(jnp.asarray(keys), jnp.asarray(heads))
    _, scr, status, _ = execute_batched(it, ar, ptr0, scr0, max_iters=n + 2)
    assert (np.asarray(scr)[:, 2] == 1).all()


@SET
@given(st.data())
def test_bst_lower_bound_invariant(data):
    """The traversal's y pointer is exactly the lower bound of the query."""
    n = data.draw(st.integers(1, 200))
    keys = np.asarray(
        data.draw(st.lists(st.integers(0, 10**5), min_size=n, max_size=n, unique=True)),
        np.int32,
    )
    values = np.arange(n, dtype=np.int32)
    ar, root, height = bst.build(keys, values)
    it = bst.find_iterator()
    q = np.asarray(data.draw(st.lists(st.integers(0, 10**5), min_size=1, max_size=16)), np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    _, scr, status, _ = execute_batched(it, ar, ptr0, scr0, max_iters=height + 1)
    ks = np.sort(keys)
    for i, query in enumerate(q):
        idx = np.searchsorted(ks, query)
        if idx < len(ks):  # lower bound exists
            assert int(scr[i, bst.S_YKEY]) == int(ks[idx])
        else:
            assert int(scr[i, bst.S_Y]) == arena_mod.NULL


# ---------------------- allocation / packing ---------------------------------


@SET
@given(st.integers(1, 8), st.integers(1, 64))
def test_interleaved_allocation_balanced(num_shards, n_alloc):
    per = 64
    b = arena_mod.ArenaBuilder(per * num_shards, 4, num_shards=num_shards, policy="interleaved")
    ptrs = b.alloc(min(n_alloc, per * num_shards))
    shards = ptrs // per
    counts = np.bincount(shards, minlength=num_shards)
    assert counts.max() - counts.min() <= 1  # perfectly balanced round robin
    assert len(np.unique(ptrs)) == len(ptrs)  # no double allocation


# ---------------------- ISA VM vs reference interpreter ----------------------


def _wrap32(x: int) -> int:
    return ((int(x) + 2**31) % 2**32) - 2**31


def _ref_iteration(code, node, ptr, scratch):
    """Independent numpy/python reference interpreter for one VM iteration
    (forward-jump-only ISA): the oracle the JAX lax.switch VM must match."""
    from repro.core import isa

    regs = [0] * isa.NUM_REGS
    scratch = list(map(int, scratch))
    pc, done, out_ptr = 0, False, int(ptr)
    T = len(code)
    while pc < T:
        op, a, b, imm = (int(x) for x in code[pc])
        ra, rb = regs[a % 16], regs[b % 16]
        rimm = regs[imm % 16]
        if op == isa.HALT:
            break
        elif op == isa.LOADN:
            regs[a % 16] = int(node[min(max(imm, 0), len(node) - 1)])
        elif op == isa.LOADS:
            regs[a % 16] = scratch[min(max(imm, 0), len(scratch) - 1)]
        elif op == isa.STORES:
            scratch[min(max(imm, 0), len(scratch) - 1)] = ra
        elif op == isa.ADD:
            regs[a % 16] = _wrap32(rb + rimm)
        elif op == isa.SUB:
            regs[a % 16] = _wrap32(rb - rimm)
        elif op == isa.MUL:
            regs[a % 16] = _wrap32(rb * rimm)
        elif op == isa.DIV:
            regs[a % 16] = 0 if rimm == 0 else _wrap32(rb // rimm)
        elif op == isa.AND:
            regs[a % 16] = rb & rimm
        elif op == isa.OR:
            regs[a % 16] = rb | rimm
        elif op == isa.NOT:
            regs[a % 16] = _wrap32(~rb)
        elif op == isa.MOVE:
            regs[a % 16] = rb
        elif op == isa.MOVI:
            regs[a % 16] = imm
        elif op in (isa.JEQ, isa.JNE, isa.JLT, isa.JLE, isa.JGT, isa.JGE):
            taken = {
                isa.JEQ: ra == rb, isa.JNE: ra != rb, isa.JLT: ra < rb,
                isa.JLE: ra <= rb, isa.JGT: ra > rb, isa.JGE: ra >= rb,
            }[op]
            pc = imm if taken else pc + 1
            continue
        elif op == isa.JMP:
            pc = imm
            continue
        elif op == isa.NEXT_ITER:
            out_ptr = ra
            break
        elif op == isa.RETURN:
            done = True
            break
        elif op == isa.GETPTR:
            regs[a % 16] = int(ptr)
        pc += 1
    return done, out_ptr, scratch


@st.composite
def _random_program(draw):
    """A random *valid* forward-jump-only program over 4 node words and 3
    scratch words, always terminated."""
    from repro.core import isa

    T = draw(st.integers(2, 14))
    rows = []
    for i in range(T - 1):
        op = draw(st.sampled_from([
            isa.LOADN, isa.LOADS, isa.STORES, isa.ADD, isa.SUB, isa.MUL,
            isa.DIV, isa.AND, isa.OR, isa.NOT, isa.MOVE, isa.MOVI,
            isa.JEQ, isa.JNE, isa.JLT, isa.JLE, isa.JGT, isa.JGE, isa.JMP,
            isa.GETPTR,
        ]))
        a = draw(st.integers(0, isa.NUM_REGS - 1))
        b = draw(st.integers(0, isa.NUM_REGS - 1))
        if op in (isa.JEQ, isa.JNE, isa.JLT, isa.JLE, isa.JGT, isa.JGE, isa.JMP):
            imm = draw(st.integers(i + 1, T))  # forward only
        elif op == isa.LOADN:
            imm = draw(st.integers(0, 3))
        elif op in (isa.LOADS, isa.STORES):
            imm = draw(st.integers(0, 2))
        elif op == isa.MOVI:
            imm = draw(st.integers(-(2**20), 2**20))
        else:
            imm = draw(st.integers(0, isa.NUM_REGS - 1))
        rows.append([op, a, b, imm])
    term = draw(st.sampled_from([isa.RETURN, isa.NEXT_ITER]))
    rows.append([term, draw(st.integers(0, isa.NUM_REGS - 1)), 0, 0])
    return np.asarray(rows, np.int32)


@SET
@given(_random_program(), st.data())
def test_random_isa_program_vm_matches_reference(code, data):
    """Round-trip random forward-jump-only programs through the JAX VM and
    the independent python interpreter: identical (done, ptr, scratch)."""
    from repro.core import isa

    isa.validate(code, scratch_words=3, node_words=4)
    node = np.asarray(
        data.draw(st.lists(st.integers(-100, 100), min_size=4, max_size=4)),
        np.int32,
    )
    ptr = data.draw(st.integers(0, 100))
    scr = np.asarray(
        data.draw(st.lists(st.integers(-100, 100), min_size=3, max_size=3)),
        np.int32,
    )
    done_v, ptr_v, scr_v = isa.run_iteration(
        jnp.asarray(code), jnp.asarray(node), jnp.int32(ptr), jnp.asarray(scr)
    )
    done_r, ptr_r, scr_r = _ref_iteration(code, node, ptr, scr)
    assert bool(done_v) == done_r
    assert int(ptr_v) == _wrap32(ptr_r)
    assert list(map(int, np.asarray(scr_v))) == [_wrap32(x) for x in scr_r]


# ---------------------- write/read linearizability ---------------------------


@SET
@given(st.data())
def test_interleaved_insert_find_linearizable(data):
    """Interleaved insert+find racing in one batch on one shard must match a
    sequential-oracle explanation: pre-existing keys always found with their
    values, inserted keys' finds see either the pre- or post-insert state
    (never garbage), and the final heap contains every insert."""
    from repro.core import commit
    from repro.core.arena import ArenaBuilder
    from repro.core.structures import linked_list

    n = data.draw(st.integers(4, 24))
    n_ins = data.draw(st.integers(1, 8))
    n_find = data.draw(st.integers(1, 8))
    k_local = data.draw(st.sampled_from([1, 2, 4, 8]))
    keys = np.arange(100, 100 + n, dtype=np.int32)
    b = ArenaBuilder(128, 4)
    head = linked_list.build_into(b, keys, keys * 2)
    ar = b.finish()
    new_keys = np.arange(500, 500 + n_ins, dtype=np.int32)
    find_of_new = data.draw(st.booleans())
    find_keys = np.asarray(
        [
            int(data.draw(st.sampled_from(
                list(new_keys) if find_of_new else list(keys)
            )))
            for _ in range(n_find)
        ],
        np.int32,
    )
    ops = np.concatenate(
        [np.ones(n_ins, np.int32), np.zeros(n_find, np.int32)]
    )
    order = data.draw(st.permutations(range(n_ins + n_find)))
    ops = ops[list(order)]
    qk = np.concatenate([new_keys, find_keys])[list(order)]
    qv = (qk * 7).astype(np.int32)
    it = linked_list.rw_iterator()
    p0, s0 = it.init(ops, qk, qv, head)
    rec, _, ar2 = commit.sequential_commit_execute(
        it, ar, p0, s0, max_iters=2048, k_local=k_local
    )
    assert (rec[:, 3] == STATUS_DONE).all()
    scr = rec[:, 6:]
    for i in range(len(ops)):
        if ops[i] != 0:
            continue
        found = int(scr[i, linked_list.RW_RES])
        if int(qk[i]) < 500:  # pre-existing: must be found, exact value
            assert found == 1 and int(scr[i, linked_list.RW_VAL]) == qk[i] * 2
        elif found:  # racing find of an insert: if found, value is exact
            assert int(scr[i, linked_list.RW_VAL]) == qk[i] * 7
    # post-state: every insert present with its value (sequential witness)
    from repro.core.iterator import execute_batched

    fit = linked_list.find_iterator()
    fp, fs = fit.init(jnp.asarray(new_keys), head)
    _, fscr, _, _ = execute_batched(fit, ar2, fp, fs, max_iters=2048)
    assert (np.asarray(fscr)[:, 2] == 1).all()
    np.testing.assert_array_equal(np.asarray(fscr)[:, 1], new_keys * 7)


@SET
@given(st.lists(st.integers(1, 700), min_size=1, max_size=120), st.sampled_from([512, 1024]))
def test_packing_never_overflows(doc_lens, window):
    lens = np.asarray(doc_lens)
    assign, waste = pack_documents(lens, window)
    fill = {}
    for l, a in zip(lens, assign):
        fill[a] = fill.get(a, 0) + min(int(l), window)
    assert max(fill.values()) <= window
    assert 0.0 <= waste < 1.0
