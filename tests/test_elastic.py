"""Elastic arenas: live resharding, owner-map forwarding, hot-shard
replication plumbing, commit-log compaction, and failure detection.

Fast in-process tests cover the pure machinery (``remap_shards`` surgery,
``VersionedOwnerMap`` forwarding, the ``ReshardPlanner`` state machine, the
targeted-suspect detector semantics, and commit-log truncation incl. a
crash mid-compaction).  The service-level matrix -- replication failover,
read fan-out with zero retries, watchdog escalation of delay-only
stragglers, and the live 4 -> 8 reshard vs a cold 8-shard run -- needs a
real 8-shard mesh and runs in a subprocess with its own device count
(tests/helpers/elastic_checks.py), like the other distributed suites.
"""

import os
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import commit
from repro.core.arena import (
    H_BUMP,
    H_COMMITS,
    H_EPOCH,
    H_FREE,
    NULL,
    ArenaBuilder,
    remap_shards,
)
from repro.core.routing import F_ID, F_ITERS, F_PTR, F_SCRATCH, F_STATUS
from repro.core.structures import linked_list
from repro.distributed.arena_ft import ArenaStore, CommitLog
from repro.distributed.elastic import ReshardPlanner, ShardFailureDetector
from repro.distributed.sharding import VersionedOwnerMap

ROOT = Path(__file__).resolve().parents[1]
P = 4
KEYS = np.arange(100, 124, dtype=np.int32)


def _build(num_shards=P):
    b = ArenaBuilder(256, 4, num_shards=num_shards, policy="interleaved")
    head = linked_list.build_into(b, KEYS, KEYS * 2)
    return b.finish(), head


def _delete(arena, head, keys):
    it = linked_list.delete_iterator()
    p0, s0 = it.init(jnp.asarray(np.asarray(keys, np.int32)), head)
    _, _, ar = commit.sequential_commit_execute(it, arena, p0, s0, max_iters=4096)
    return ar


def _find(arena, head, keys):
    """Payload columns only: F_HOME/F_HOPS are partition metadata and
    legitimately change with the shard count."""
    it = linked_list.find_iterator()
    p0, s0 = it.init(jnp.asarray(np.asarray(keys, np.int32)), head)
    final, _ = commit.sequential_commit_execute(it, arena, p0, s0, max_iters=4096)
    rec = np.asarray(final)
    return rec[:, [F_ID, F_PTR, F_STATUS, F_ITERS] + list(range(F_SCRATCH, rec.shape[1]))]


def _free_chain(arena, shard):
    data = np.asarray(arena.data)
    out, p = [], int(np.asarray(arena.heap)[shard, H_FREE])
    while p != NULL:
        out.append(p)
        p = int(data[p, 0])
    return out


# ------------------------------ remap_shards ---------------------------------


def test_remap_grow_preserves_traversals_and_free_chains():
    arena, head = _build()
    arena = _delete(arena, head, KEYS[3:15:2])  # carve free slots
    grown = remap_shards(arena, 2 * P)
    assert grown.num_shards == 2 * P
    # pointers are global: every traversal answers identically
    np.testing.assert_array_equal(
        _find(grown, head, KEYS), _find(arena, head, KEYS)
    )
    b_old = np.asarray(arena.bounds)
    b_new = np.asarray(grown.bounds)
    for s in range(P):
        lo, hi = int(b_old[s]), int(b_old[s + 1])
        mid = (lo + hi) // 2
        assert int(b_new[2 * s]) == lo and int(b_new[2 * s + 1]) == mid
        # the parent's free chain is partitioned by the midpoint, pop
        # order preserved within each child
        parent = _free_chain(arena, s)
        left, right = _free_chain(grown, 2 * s), _free_chain(grown, 2 * s + 1)
        assert left == [p for p in parent if p < mid]
        assert right == [p for p in parent if p >= mid]


def test_remap_grow_shrink_roundtrip_bit_identical():
    arena, head = _build()
    arena = _delete(arena, head, KEYS[2:10])
    back = remap_shards(remap_shards(arena, 2 * P), P)
    for f in ("data", "bounds", "perms", "heap"):
        np.testing.assert_array_equal(
            np.asarray(getattr(back, f)), np.asarray(getattr(arena, f)), f
        )


def test_remap_splits_and_merges_allocator_registers():
    arena, _ = _build()
    h_old = np.asarray(arena.heap)
    grown = remap_shards(arena, 2 * P)
    h_new = np.asarray(grown.heap)
    for s in range(P):
        # epoch/commit bookkeeping duplicates on split...
        assert h_new[2 * s, H_EPOCH] == h_new[2 * s + 1, H_EPOCH] == h_old[s, H_EPOCH]
        assert (
            h_new[2 * s, H_COMMITS]
            == h_new[2 * s + 1, H_COMMITS]
            == h_old[s, H_COMMITS]
        )
        # ...and exactly one child inherits the parent's bump frontier
        mid = (
            int(np.asarray(arena.bounds)[s]) + int(np.asarray(arena.bounds)[s + 1])
        ) // 2
        bump = int(h_old[s, H_BUMP])
        if bump <= mid:
            assert int(h_new[2 * s, H_BUMP]) == bump
        else:
            assert int(h_new[2 * s + 1, H_BUMP]) == bump


def test_remap_rejects_non_2x():
    arena, _ = _build()
    assert remap_shards(arena, P) is arena
    for bad in (3, 16, 0):
        with pytest.raises(ValueError):
            remap_shards(arena, bad)


# ---------------------------- owner-map epochs -------------------------------


def test_owner_map_forwarding():
    m = VersionedOwnerMap([0, 64, 128, 192, 256])
    assert m.epoch == 0
    assert m.current.owner_of(70) == 1
    ep = m.advance([0, 32, 64, 96, 128, 160, 192, 224, 256])
    assert ep.epoch == m.epoch == 1
    # each old shard forwards to exactly its two children
    for s in range(4):
        assert m.forward_shard(s, from_epoch=0) == (2 * s, 2 * s + 1)
    # shrink direction: both children map back to the one parent
    for s in range(8):
        assert m.forward_shard(s, from_epoch=1, to_epoch=0) == (s // 2,)
    mask = m.forward_mask([False, True, False, True], from_epoch=0)
    np.testing.assert_array_equal(
        mask, [False, False, True, True, False, False, True, True]
    )


def test_owner_map_validates():
    m = VersionedOwnerMap([0, 64, 128])
    with pytest.raises(ValueError):
        m.advance([0, 32, 64, 96, 120])  # shrinks the address space
    with pytest.raises(KeyError):
        m.at(7)
    with pytest.raises(ValueError):
        m.forward_shard(2, from_epoch=0)
    with pytest.raises(ValueError):
        m.forward_mask([True], from_epoch=0)


# --------------------------- reshard state machine ---------------------------


def test_reshard_planner_lifecycle():
    pl = ReshardPlanner()
    assert pl.phase == "idle"
    with pytest.raises(ValueError):
        pl.request(6, current=4, rnd=0)  # not an exact 2x change
    pl.request(8, current=4, rnd=3)
    assert pl.phase == "draining"
    with pytest.raises(RuntimeError):
        pl.request(16, current=8, rnd=4)  # one at a time
    with pytest.raises(RuntimeError):
        pl.complete(rnd=4, old_shards=4, owner_epoch=1)  # barrier not cleared
    assert not pl.should_cutover(in_flight=2)
    assert not pl.should_cutover(in_flight=1)
    assert pl.should_cutover(in_flight=0)
    assert pl.phase == "cutover"
    ev = pl.complete(rnd=7, old_shards=4, owner_epoch=1)
    assert pl.phase == "idle" and pl.target is None
    assert (ev.old_shards, ev.new_shards) == (4, 8)
    assert ev.drain_rounds == 2 and ev.requested_round == 3
    assert pl.events == [ev]
    # shrink is also a legal 2x request
    pl.request(2, current=4, rnd=9)
    assert pl.target == 2


# ---------------------------- failure detection ------------------------------


def test_detector_suspect_is_targeted():
    """Regression: a mid-round suspect() advances the logical clock; the
    other shards' beats must advance with it or the next sweep takes
    every shard as a collateral victim (timeout_rounds=0)."""
    det = ShardFailureDetector(8)
    det.beat_all(5)
    det.suspect(3, rnd=6)  # failure signal lands before round 6's beat_all
    assert det.sweep() == [3]
    assert det.dead_shards() == [3]
    det.beat_all(7)
    assert det.sweep() == [] and det.dead_shards() == [3]
    det.revive(3)
    assert det.dead_shards() == []
    # multiple suspects accumulate without collateral
    det.suspect(1, rnd=8)
    det.suspect(6, rnd=8)
    assert sorted(det.sweep()) == [1, 6]
    assert sorted(det.dead_shards()) == [1, 6]


# --------------------------- commit-log compaction ---------------------------


def _logged_writes(tmp, n_quanta=3):
    """Serve ``n_quanta`` single-insert write quanta through the oracle,
    logging each, from a fresh baseline snapshot."""
    arena, head = _build()
    store = ArenaStore(tmp)
    it = linked_list.insert_iterator()
    store.register_iterator("list_ins", it)
    store.ensure_baseline(arena)
    for i in range(n_quanta):
        k = np.asarray([900 + i], np.int32)
        p0, s0 = it.init(jnp.asarray(k), jnp.asarray(k * 2), head)
        _, stats, arena = commit.sequential_commit_execute(
            it, arena, p0, s0, max_iters=4096
        )
        store.log_quantum(
            "list_ins", p0, s0, max_iters=4096, k_local=4, compact=True,
            commits=stats.commits, epochs=stats.epochs,
        )
    return store, arena, head, it


def test_snapshot_compacts_log_and_seq_survives(tmp_path):
    store, arena, head, it = _logged_writes(tmp_path)
    assert len(store.log.quanta()) == 3 and store.log.seq == 3
    store.snapshot(arena)  # compact_log=True by default
    # replay prefix folded into the snapshot; only the marker remains
    assert store.log.quanta() == []
    entries = store.log.entries()
    assert entries == [{"seq": 3, "kind": "truncated"}]
    # the high-water mark survives compaction AND reopen
    assert store.log.seq == 3
    rec, info = store.recover()
    assert info.replayed_quanta == 0
    np.testing.assert_array_equal(np.asarray(rec.data), np.asarray(arena.data))
    store.close()
    store2 = ArenaStore(tmp_path)
    assert store2.log.seq == 3
    seq = store2.log.append({"kind": "noop"})
    assert seq == 4  # numbering continues, no reuse of folded seqs
    store2.close()


def test_crash_mid_truncate_keeps_old_log(tmp_path):
    """A crash before ``os.replace`` leaves the full log plus a stray
    ``.tmp``; reopen ignores the tmp and recovery still replays."""
    store, arena, head, it = _logged_writes(tmp_path)
    log_path = store.log.path
    # crash simulation: the compacted survivor file exists but was never
    # swapped in (truncate_through died before os.replace)
    tmp = log_path.with_name(log_path.name + ".tmp")
    tmp.write_text('{"seq": 3, "kind": "truncated"}\n')
    store.close()

    reopened = CommitLog(log_path)
    assert len(reopened.quanta()) == 3 and reopened.seq == 3
    reopened.close()
    store2 = ArenaStore(tmp_path)
    store2.register_iterator("list_ins", it)
    rec, info = store2.recover()
    assert info.replayed_quanta == 3
    np.testing.assert_array_equal(np.asarray(rec.data), np.asarray(arena.data))
    np.testing.assert_array_equal(np.asarray(rec.heap), np.asarray(arena.heap))
    # a real truncate from the recovered position still works afterwards
    store2.snapshot(rec)
    assert store2.log.quanta() == [] and store2.log.seq == 3
    store2.close()


def test_truncate_noop_below_watermark(tmp_path):
    store, arena, _, _ = _logged_writes(tmp_path)
    assert store.log.truncate_through(0) == 0  # nothing <= 0: no rewrite
    assert len(store.log.quanta()) == 3
    assert store.log.truncate_through(2) == 2
    assert [e["seq"] for e in store.log.quanta()] == [3]
    assert store.log.seq == 3
    store.close()


# ------------------------ distributed elasticity matrix ----------------------


@pytest.mark.slow
def test_elasticity_distributed_subprocess():
    """8-shard service matrix: replication failover, zero-retry read
    fan-out, watchdog delay escalation, live 4 -> 8 reshard (sync+async)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "elastic_checks.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1800,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL ELASTICITY CHECKS PASSED" in proc.stdout
