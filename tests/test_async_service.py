"""Async device-runner pipeline: bit-identity with the synchronous loop,
SLO-aware quantum sizing, EDF preemption, and open-loop overload behavior
(rate limiting + bounded-queue shedding)."""

import numpy as np
import pytest

from repro.core.engine import PulseEngine
from repro.core.iterator import STATUS_DONE
from repro.core.structures import btree, linked_list
from repro.serving.admission import (
    AdmissionController,
    TenantRateLimiter,
    TraversalRequest,
)
from repro.serving.batching import DeviceRunner, QuantumWork
from repro.serving.traversal_service import (
    STATUS_SHED,
    PulseService,
    StructureSpec,
)

RNG = np.random.default_rng(11)


def _list_service(pipeline="sync", n=96, slots=8, **kw):
    keys = np.arange(n, dtype=np.int32)
    vals = (keys * 7 + 1).astype(np.int32)
    ar, head = linked_list.build(keys, vals)
    eng = PulseEngine(ar)
    svc = PulseService(
        eng,
        {"list": StructureSpec(linked_list.find_iterator(), (head,))},
        slots_per_structure=slots,
        quantum=4,
        pipeline=pipeline,
        **kw,
    )
    return svc, keys, vals


# ----------------------------- device runner ------------------------------


def test_device_runner_fifo_and_drain():
    runner = DeviceRunner(depth=2).start()
    seen = []
    for i in range(8):
        runner.submit(
            QuantumWork(
                label=f"w{i}", run=lambda i=i: i * 10, apply=seen.append
            )
        )
    runner.drain()
    assert seen == [i * 10 for i in range(8)]  # strict FIFO
    assert runner.quanta_run == 8
    assert runner.max_queue_depth <= 2
    runner.close()


def test_device_runner_propagates_errors():
    runner = DeviceRunner(depth=2).start()

    def boom():
        raise RuntimeError("quantum failed")

    runner.submit(QuantumWork(label="bad", run=boom, apply=lambda r: None))
    with pytest.raises(RuntimeError, match="quantum failed"):
        runner.drain()
    runner.close()


# ------------------------- async-vs-sync identity -------------------------


def test_async_matches_sync_bit_identical():
    """Same arrivals, same quantum policy: the async pipeline must retire
    every request with identical status/iters/round/result to sync."""

    def serve(pipeline):
        svc, keys, _ = _list_service(pipeline)
        reqs = [
            TraversalRequest(
                i,
                "list",
                int(keys[(i * 13) % len(keys)]),
                tenant=f"t{i % 3}",
                arrive_round=i // 10,
            )
            for i in range(50)
        ]
        m = svc.run(reqs)
        return reqs, m

    ra, ma = serve("sync")
    rb, mb = serve("async")
    assert ma.rounds == mb.rounds
    assert ma.engine_calls == mb.engine_calls
    assert ma.completed == mb.completed == 50
    for a, b in zip(ra, rb):
        assert (a.status, a.iters, a.finish_round, a.admit_round) == (
            b.status,
            b.iters,
            b.finish_round,
            b.admit_round,
        )
        np.testing.assert_array_equal(a.result, b.result)


def test_async_overlaps_accounting_with_device():
    """The emit queue drains while quanta are in flight: after a run the
    runner has executed every engine call and accounting is complete."""
    svc, keys, vals = _list_service("async")
    reqs = [TraversalRequest(i, "list", int(keys[i])) for i in range(24)]
    m = svc.run(reqs)
    assert m.completed == 24
    assert svc._runner is None  # run() closes the runner
    assert not svc._emit  # nothing left unaccounted
    for r in reqs:
        assert r.status == STATUS_DONE
        assert int(r.result[1]) == int(vals[r.query])


# --------------------------- SLO quantum sizing ---------------------------


def test_slo_quantum_bounds_and_ramp():
    """No deadlines in sight -> the quantum ramps multiplicatively to
    max_quantum; bounds are respected and recorded."""
    svc, keys, _ = _list_service(
        "async", min_quantum=2, max_quantum=64
    )
    reqs = [TraversalRequest(i, "list", int(keys[-1])) for i in range(4)]
    m = svc.run(reqs)
    assert m.completed == 4
    assert 2 <= m.quantum_min_used <= m.quantum_max_used <= 64
    assert m.quantum_max_used == 64  # ramp reached the cap


def test_slo_quantum_shrinks_under_deadline_pressure():
    """A tight queued deadline forces the quantum toward min_quantum."""
    svc, keys, _ = _list_service("sync", min_quantum=2, max_quantum=256)
    # seed the ms/iter estimate high so any finite headroom clamps low
    svc._ms_per_iter = 50.0
    svc._cur_quantum = 256
    svc.submit(TraversalRequest(0, "list", int(keys[1]), deadline_ms=10.0))
    svc.step()
    assert svc.metrics.quantum_min_used == 2


def test_fixed_quantum_default_unchanged():
    """Without min/max bounds the service must keep the legacy fixed
    quantum (the bit-identity precondition)."""
    svc, keys, _ = _list_service("async")
    m = svc.run([TraversalRequest(0, "list", int(keys[-1]))])
    assert m.quantum_min_used == m.quantum_max_used == 4


# ------------------------------ preemption --------------------------------


def test_edf_preemption_evicts_and_resumes():
    """A full group of long best-effort walks + one urgent deadline: the
    urgent request steals a slot; the evictee resumes from its saved
    continuation and still finishes with a correct result."""
    svc, keys, vals = _list_service("sync", slots=2, preempt=True)
    deep = [
        TraversalRequest(i, "list", int(keys[-1 - i]), tenant="bulk")
        for i in range(2)
    ]
    svc.submit(deep[0])
    svc.submit(deep[1])
    svc.step()  # both on device, each a MAXED continuation now
    urgent = TraversalRequest(
        9, "list", int(keys[1]), tenant="rt", deadline_ms=50.0
    )
    svc.submit(urgent)
    m = svc.run()
    assert m.preempted >= 1
    assert m.completed == 3
    evicted = [r for r in deep if r.preemptions > 0]
    assert evicted, "one long walk must have been evicted"
    for r in deep + [urgent]:
        assert r.status == STATUS_DONE
        assert int(r.result[1]) == int(vals[r.query])
    # the urgent request was admitted before the evictee finished
    assert urgent.finish_round <= max(r.finish_round for r in evicted)


# ------------------------ overload: shed + bounds -------------------------


def test_rate_limiter_token_bucket():
    rl = TenantRateLimiter(rate_rps=10.0, burst=2.0)
    assert rl.allow("a", 0.0) and rl.allow("a", 0.0)  # burst
    assert not rl.allow("a", 0.0)  # bucket empty
    assert rl.allow("a", 0.1)  # refilled one token at 10 rps
    assert rl.allow("b", 0.0)  # other tenants unaffected


def test_admission_requeue_restores_order():
    ac = AdmissionController()
    a = TraversalRequest(0, "s", 1, tenant="t")
    b = TraversalRequest(1, "s", 2, tenant="t")
    assert ac.submit(a, 0.0) and ac.submit(b, 0.0)
    (first,) = ac.admit({"s": 1})
    assert first is a
    ac.requeue(a)
    assert ac.pending() == 2
    assert ac.pending_by_structure() == {"s": 0}  # a's original seq
    (again,) = ac.admit({"s": 1})
    assert again is a  # front of the tenant queue again


def test_open_loop_burst_sheds_and_bounds_queue():
    """Open-loop burst beyond capacity: rejects are counted, queue depth
    stays bounded, and accepted requests still meet their EDF deadlines."""
    svc, keys, _ = _list_service(
        "async",
        slots=4,
        max_pending=8,
        rate_limit_rps=1e6,  # shedding comes from the bounded queue here
    )
    reqs = [
        TraversalRequest(i, "list", int(keys[i % 8]), deadline_ms=60_000.0)
        for i in range(64)
    ]
    m = svc.run(reqs)
    assert m.shed > 0
    assert m.completed + m.shed == 64
    assert m.queue_depth_max <= 8
    shed = [r for r in reqs if r.status == STATUS_SHED]
    assert len(shed) == m.shed
    for r in shed:
        assert r.result is None  # shed requests never execute
    assert m.deadlines_missed == 0  # accepted requests met their deadlines
    assert m.deadline_hit_rate == 1.0


def test_tenant_rate_limit_isolates_flood():
    """A flooding tenant is shed at its own token bucket; the trickle
    tenant's requests are all accepted."""
    svc, keys, _ = _list_service("sync", rate_limit_rps=1.0, rate_limit_burst=3.0)
    flood = [
        TraversalRequest(i, "list", int(keys[1]), tenant="flood")
        for i in range(20)
    ]
    trickle = [
        TraversalRequest(100 + i, "list", int(keys[1]), tenant="ok", arrive_round=i)
        for i in range(3)
    ]
    m = svc.run(flood + trickle)
    assert svc.admission.shed_by_tenant.get("flood", 0) > 0
    assert svc.admission.shed_by_tenant.get("ok", 0) == 0
    assert all(r.status == STATUS_DONE for r in trickle)
    assert m.completed + m.shed == 23


# ----------------------- mixed read/write identity ------------------------


def test_async_matches_sync_with_writes_single_node():
    """Mixed read/write stream on one node: async and sync must produce
    identical results, commits, and final arenas (ALLOC addresses depend on
    batch composition, so this checks the admission schedule too)."""
    n = 48
    keys = (np.arange(n, dtype=np.int32) * 2).astype(np.int32)
    vals = (keys * 5 + 3).astype(np.int32)

    def serve(pipeline):
        ar, root, _height = btree.build(keys, vals)
        eng = PulseEngine(ar)
        svc = PulseService(
            eng,
            {
                "bt": StructureSpec(btree.find_iterator(), (root,), group="b"),
                "bt_up": StructureSpec(
                    btree.update_iterator(), (root,), group="b", takes_value=True
                ),
            },
            slots_per_structure=4,
            quantum=6,
            pipeline=pipeline,
        )
        reqs = []
        for i in range(30):
            if i % 3 == 1:
                reqs.append(
                    TraversalRequest(
                        i,
                        "bt_up",
                        int(keys[(i * 7) % n]),
                        value=int(1000 + i),
                        arrive_round=i // 6,
                    )
                )
            else:
                reqs.append(
                    TraversalRequest(
                        i, "bt", int(keys[(i * 11) % n]), arrive_round=i // 6
                    )
                )
        m = svc.run(reqs)
        return reqs, m, eng.arena

    ra, ma, arena_a = serve("sync")
    rb, mb, arena_b = serve("async")
    assert ma.rounds == mb.rounds
    assert ma.commits == mb.commits
    assert ma.writes_retired == mb.writes_retired
    for a, b in zip(ra, rb):
        assert (a.status, a.iters, a.finish_round) == (
            b.status,
            b.iters,
            b.finish_round,
        )
        np.testing.assert_array_equal(a.result, b.result)
    np.testing.assert_array_equal(
        np.asarray(arena_a.data), np.asarray(arena_b.data)
    )
