"""Training substrate: optimizers, schedules, compression, loop, resume."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.models.model_zoo import build_model
from repro.data.pipeline import DataConfig, DataIterator, pack_documents, tokens_for
from repro.training import optimizer as opt_mod
from repro.training.compression import CompressionConfig, compress, ef_init
from repro.training.train_loop import (
    StragglerPolicy,
    TrainConfig,
    TrainLoop,
    init_state,
    make_train_step,
)

pytestmark = pytest.mark.slow  # optimizer/convergence loops; full CI lane only


def tiny_setup(arch="qwen3_0_6b", **tcfg_kw):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        opt=opt_mod.OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=100),
        **tcfg_kw,
    )
    data = DataIterator(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    return cfg, model, tcfg, data


class _Repeat:
    """Cycles a fixed set of batches: gives the optimizer something to fit."""

    def __init__(self, data, n=2):
        self.batches = [next(data) for _ in range(n)]
        self.i = 0

    def __next__(self):
        b = self.batches[self.i % len(self.batches)]
        self.i += 1
        return b


def run_steps(model, tcfg, data, steps, state=None, rng=0):
    state = state or init_state(model, tcfg, jax.random.PRNGKey(rng))
    step_fn = jax.jit(make_train_step(model, tcfg))
    losses = []
    for _ in range(steps):
        state, m = step_fn(state, next(data))
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases_adamw():
    _, model, tcfg, data = tiny_setup()
    _, losses = run_steps(model, tcfg, _Repeat(data), 30)
    assert losses[-1] < losses[0] - 1.0, losses[::10]


def test_loss_decreases_adafactor():
    _, model, _, data = tiny_setup()
    tcfg = TrainConfig(
        opt=opt_mod.OptimizerConfig(
            name="adafactor", lr=1e-2, warmup_steps=5, total_steps=100,
            factored_min_dim=8,
        )
    )
    _, losses = run_steps(model, tcfg, _Repeat(data), 30)
    assert losses[-1] < losses[0] - 1.0, losses[::10]


def test_microbatching_matches_full_batch():
    """Grad accumulation must equal the single-batch step (same math)."""
    cfg, model, _, _ = tiny_setup()
    data1 = DataIterator(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    data2 = DataIterator(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    t_full = TrainConfig(opt=opt_mod.OptimizerConfig(lr=1e-3), microbatches=1)
    t_micro = TrainConfig(opt=opt_mod.OptimizerConfig(lr=1e-3), microbatches=2)
    s1, _ = run_steps(model, t_full, data1, 3)
    s2, _ = run_steps(model, t_micro, data2, 3)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=3e-5, rtol=3e-4
        )


def test_schedule_shape():
    oc = opt_mod.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(opt_mod.schedule(oc, s)) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6 and abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_compression_error_feedback_roundtrip():
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)), jnp.float32)}
    ef = ef_init(g)
    cfg = CompressionConfig(scheme="topk", topk_frac=0.1)
    out, new_ef, wire = compress(cfg, g, ef)
    # decomposition: kept + residual == original
    np.testing.assert_allclose(
        np.asarray(out["a"]) + np.asarray(new_ef["a"]), np.asarray(g["a"]), atol=1e-6
    )
    # wire bytes ~10% of dense + indices
    assert wire < 64 * 64 * 4 * 0.25
    nz = (np.asarray(out["a"]) != 0).mean()
    assert 0.05 < nz < 0.15


def test_int8_compression_bounded_error():
    g = {"a": jnp.asarray(np.random.default_rng(1).standard_normal((128,)), jnp.float32)}
    out, new_ef, wire = compress(CompressionConfig(scheme="int8"), g, ef_init(g))
    err = np.abs(np.asarray(out["a"]) - np.asarray(g["a"])).max()
    scale = np.abs(np.asarray(g["a"])).max() / 127
    assert err <= scale * 0.51 + 1e-7
    assert wire == 128 + 4


@pytest.mark.slow
def test_compressed_training_converges():
    _, model, _, data = tiny_setup()
    tcfg = TrainConfig(
        opt=opt_mod.OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=200),
        compression=CompressionConfig(scheme="topk", topk_frac=0.2),
    )
    _, losses = run_steps(model, tcfg, _Repeat(data), 40)
    assert losses[-1] < losses[0] - 1.0, losses[::10]


def test_straggler_policy_flags_slow_steps():
    pol = StragglerPolicy(deadline_factor=2.0, window=10)
    for s in range(10):
        pol.observe(s, 0.1)
    assert not pol.flagged_steps
    pol.observe(10, 0.5)
    assert pol.flagged_steps == [10]


# ------------------------------ data pipeline -------------------------------


def test_data_deterministic_and_host_disjoint():
    c0 = DataConfig(vocab=1000, seq_len=16, global_batch=8, num_hosts=2, host_id=0)
    c1 = DataConfig(vocab=1000, seq_len=16, global_batch=8, num_hosts=2, host_id=1)
    a = tokens_for(c0, 7)
    b = tokens_for(c0, 7)
    np.testing.assert_array_equal(a, b)  # deterministic
    c = tokens_for(c1, 7)
    assert not np.array_equal(a, c)  # disjoint slices
    d = tokens_for(c0, 8)
    assert not np.array_equal(a, d)  # steps differ


def test_data_iterator_resume_exact():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    it = DataIterator(cfg)
    for _ in range(5):
        next(it)
    snap = it.state_dict()
    want = np.asarray(next(it)["tokens"])
    it2 = DataIterator(cfg)
    it2.load_state_dict(snap)
    got = np.asarray(next(it2)["tokens"])
    np.testing.assert_array_equal(want, got)


def test_packing_low_waste():
    rng = np.random.default_rng(0)
    lens = rng.integers(32, 512, 200)
    assign, waste = pack_documents(lens, 1024)
    assert waste < 0.15, waste
    # no window overflows
    fill = {}
    for l, a in zip(lens, assign):
        fill[a] = fill.get(a, 0) + min(int(l), 1024)
    assert max(fill.values()) <= 1024
