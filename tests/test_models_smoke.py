"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values (assignment requirement f)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model_zoo import build_model, make_batch

pytestmark = pytest.mark.slow  # ~80s of per-arch compiles; full CI lane only

LM_ARCHS = [a for a in ARCH_IDS if a != "pulse_paper"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_loss_and_grad_finite(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, "train", seq_len=32, batch=2)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)), f"{arch}: grad norm {gnorm}"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_then_decode_consistent(arch):
    """Prefill on L-1 tokens, then one decode step of the last token, must
    reproduce the full-prefill last-position logits (cache continuation
    correctness across every family -- KV ring, SSD state, cross-attn KV)."""
    cfg = get_reduced_config(arch)
    if cfg.family == "moe":
        # exact consistency needs drop-free routing (capacity >= worst case)
        cfg = cfg.replace(moe_capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, L, max_len = 2, 8, 16
    batch = make_batch(cfg, "prefill", seq_len=L, batch=B, rng=jax.random.PRNGKey(2))
    logits_full, _ = model.prefill(params, batch, max_len)
    assert np.isfinite(np.asarray(logits_full, np.float32)).all(), arch

    # prefill on the first L-1 tokens, then decode token L-1
    batch_m1 = dict(batch, tokens=batch["tokens"][:, : L - 1],
                    labels=batch["labels"][:, : L - 1])
    _, cache = model.prefill(params, batch_m1, max_len)
    n_prefix = cfg.n_patches if cfg.family == "vlm" else 0
    pos = jnp.full((B,), n_prefix + L - 1, jnp.int32)
    logits_dec, _ = model.decode_step(params, cache, batch["tokens"][:, L - 1], pos)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_dec, np.float32),
        atol=2e-3, rtol=2e-3,
        err_msg=f"{arch}: prefill/decode mismatch",
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, max_len = 2, 16
    cache = model.cache_init(B, max_len)
    logits, cache = model.decode_step(
        params, cache, jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32)
    )
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_counts_match_table():
    """Full configs' parameter counts sit near the published sizes."""
    import repro.configs as C

    expect = {
        "qwen3_0_6b": (0.4e9, 0.9e9),
        "qwen1_5_4b": (3.0e9, 5.0e9),
        "qwen3_4b": (3.0e9, 5.0e9),
        "olmo_1b": (0.9e9, 1.6e9),
        "internvl2_2b": (1.5e9, 2.6e9),
        "granite_moe_1b_a400m": (0.8e9, 1.7e9),
        "kimi_k2_1t_a32b": (0.7e12, 1.3e12),
        "mamba2_780m": (0.5e9, 1.0e9),
        "zamba2_7b": (5.0e9, 9.0e9),
        "whisper_large_v3": (1.2e9, 2.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    import repro.configs as C

    kimi = C.get_config("kimi_k2_1t_a32b")
    active = kimi.active_param_count()
    assert 20e9 <= active <= 45e9, f"kimi active {active/1e9:.1f}B (expect ~32B)"
