"""Fault tolerance: checkpoint save/restore exactness, crash atomicity,
elastic mesh re-planning."""

import json
import shutil
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.elastic import (
    ElasticCoordinator,
    HeartbeatMonitor,
    plan_mesh_shape,
)
from repro.models.model_zoo import build_model
from repro.training import optimizer as opt_mod
from repro.training.train_loop import TrainConfig, init_state, make_train_step


def _setup(tmp_path):
    cfg = get_reduced_config("qwen3_0_6b")
    model = build_model(cfg)
    tcfg = TrainConfig(opt=opt_mod.OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    data = DataIterator(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2))
    ckpt = CheckpointManager(tmp_path / "ckpt", async_save=False)
    return model, tcfg, data, ckpt


def test_resume_is_bitwise_exact(tmp_path):
    model, tcfg, data, ckpt = _setup(tmp_path)
    step_fn = jax.jit(make_train_step(model, tcfg))
    state = init_state(model, tcfg, jax.random.PRNGKey(0))

    # run 6 steps, checkpointing after step 3
    for s in range(6):
        if s == 3:
            ckpt.save(state, s, extra=data.state_dict())
        state, _ = step_fn(state, next(data))
    final_a = jax.tree.leaves(state["params"])

    # restore at step 3 and replay
    state_b = init_state(model, tcfg, jax.random.PRNGKey(42))  # different init
    state_b, extra, step = ckpt.restore(state_b)
    assert step == 3
    data_b = DataIterator(DataConfig(vocab=model.cfg.vocab, seq_len=16, global_batch=2))
    data_b.load_state_dict(extra)
    for s in range(3, 6):
        state_b, _ = step_fn(state_b, next(data_b))
    final_b = jax.tree.leaves(state_b["params"])
    for a, b in zip(final_a, final_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_during_save_never_corrupts(tmp_path):
    model, tcfg, data, ckpt = _setup(tmp_path)
    state = init_state(model, tcfg, jax.random.PRNGKey(0))
    ckpt.save(state, 1)
    # simulate a crash mid-save of step 2: partial temp dir, no LATEST flip
    tmp = ckpt.dir / ".tmp_save_crashed"
    tmp.mkdir()
    (tmp / "shard_0.npz").write_bytes(b"garbage")
    assert ckpt.latest_step() == 1
    restored, _, step = ckpt.restore(state)
    assert step == 1
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_last_k(tmp_path):
    model, tcfg, data, ckpt = _setup(tmp_path)
    ckpt.keep = 2
    state = init_state(model, tcfg, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        ckpt.save(state, s)
    assert sorted(ckpt.all_steps()) == [3, 4]


def test_async_save_matches_sync(tmp_path):
    model, tcfg, data, _ = _setup(tmp_path)
    state = init_state(model, tcfg, jax.random.PRNGKey(0))
    ck_a = CheckpointManager(tmp_path / "a", async_save=True)
    ck_b = CheckpointManager(tmp_path / "b", async_save=False)
    ck_a.save(state, 5)
    ck_b.save(state, 5)
    ck_a.wait()
    ra, _, _ = ck_a.restore(state)
    rb, _, _ = ck_b.restore(state)
    for a, b in zip(jax.tree.leaves(ra), jax.tree.leaves(rb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------- elastic ------------------------------------


def test_plan_mesh_shrink_keeps_model_axis():
    shape, names, used = plan_mesh_shape(512, model_parallel=16, prefer_pods=2)
    assert shape == (2, 16, 16) and used == 512
    # lose one pod's worth: 256 devices left
    shape, names, used = plan_mesh_shape(256, model_parallel=16, prefer_pods=2)
    assert shape[-1] == 16 and used == 256
    # odd loss: 480 devices -> keep model=16, data shrinks to 30
    shape, names, used = plan_mesh_shape(480, model_parallel=16, prefer_pods=2)
    assert shape[-1] == 16 and used == 480


def test_heartbeat_and_coordinator():
    clock = [0.0]
    mon = HeartbeatMonitor(num_hosts=8, timeout_s=10.0, clock=lambda: clock[0])
    coord = ElasticCoordinator(mon, model_parallel=2, devices_per_host=4, prefer_pods=1)
    for h in range(8):
        mon.beat(h)
    clock[0] = 5.0
    assert coord.check(step=10, current_shape=(16, 2)) is None
    # host 3 goes silent
    clock[0] = 20.0
    for h in range(8):
        if h != 3:
            mon.beat(h)
    clock[0] = 29.0  # host 3 last beat at t=0 -> 29 > 10s timeout; rest fresh
    ev = coord.check(step=20, current_shape=(16, 2))
    assert ev is not None and ev.lost_hosts == [3]
    assert ev.new_shape[-1] == 2  # model axis preserved
    assert ev.new_shape[0] * ev.new_shape[1] <= 28  # 7 hosts x 4 devices


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Checkpoint saved under one layout restores under another (the mesh
    here is 1 device, but the reshard path -- device_put with new shardings
    -- is exactly what a real shrink executes)."""
    model, tcfg, data, ckpt = _setup(tmp_path)
    state = init_state(model, tcfg, jax.random.PRNGKey(0))
    ckpt.save(state, 7)
    # "new mesh": default shardings (None) -> single device
    restored, _, step = ckpt.restore(state, shardings=None)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
