"""Serving layer: paged KV cache (PULSE-backed) + continuous batching."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.kernels.paged_attention.ops import paged_attention
from repro.models.model_zoo import build_model
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.kv_cache import PagedKVCache

pytestmark = pytest.mark.slow  # model-zoo decode loops; full CI lane only

RNG = np.random.default_rng(0)


def test_page_chain_walk_matches_host_truth():
    cfg = get_reduced_config("qwen3_0_6b")
    cache = PagedKVCache(cfg, n_pages=32, page_size=4, max_batch=4)
    lens = [10, 3, 0, 17]
    for b, ln in enumerate(lens):
        if ln:
            cache.ensure_capacity(b, ln)
        cache.lengths[b] = ln
    pt, lengths = cache.walk_page_tables(max_pages=8)
    pt = np.asarray(pt)
    assert np.asarray(lengths).tolist() == lens
    # host truth: follow chains in the arena
    for b, ln in enumerate(lens):
        want = []
        p = int(cache.heads[b])
        while p != -1:
            want.append(int(cache.builder.data[p, 0]))
            p = int(cache.builder.data[p, 1])
        got = pt[b][: len(want)].tolist()
        assert got == want, (b, got, want)


def test_page_alloc_free_recycles():
    cfg = get_reduced_config("qwen3_0_6b")
    cache = PagedKVCache(cfg, n_pages=9, page_size=4, max_batch=2)
    cache.ensure_capacity(0, 16)  # 4 pages
    cache.ensure_capacity(1, 16)  # 4 pages -> pool exhausted (page 0 reserved)
    with pytest.raises(MemoryError):
        cache.ensure_capacity(0, 20)
    cache.reset_seq(1)
    cache.ensure_capacity(0, 20)  # page freed by seq 1 is reusable
    assert cache.n_alloc_pages(0) == 5


def test_paged_write_then_attend_equals_dense():
    """Write tokens through the paged path, then paged attention must equal
    dense attention over the same logical KV."""
    cfg = get_reduced_config("qwen3_4b")
    Hk, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    B, page, npages, T = 2, 4, 16, 10
    cache = PagedKVCache(cfg, n_pages=npages, page_size=page, max_batch=B)
    ks = RNG.standard_normal((T, L, B, Hk, hd)).astype(np.float32)
    vs = RNG.standard_normal((T, L, B, Hk, hd)).astype(np.float32)
    for t in range(T):
        for b in range(B):
            cache.ensure_capacity(b, t + 1)
        cache.write_token((jnp.asarray(ks[t]), jnp.asarray(vs[t])))
    pt, lengths = cache.walk_page_tables(max_pages=4)
    q = jnp.asarray(RNG.standard_normal((B, cfg.n_heads, hd)), jnp.float32)
    o_paged = paged_attention(
        q, cache.k_pages[0], cache.v_pages[0], pt, lengths, use_pallas=False
    )
    # dense reference over the logical KV
    from repro.kernels.flash_attention.ref import mha_reference

    kd = jnp.asarray(ks[:, 0].swapaxes(0, 1).swapaxes(1, 2))  # (B, Hk, T, hd)
    vd = jnp.asarray(vs[:, 0].swapaxes(0, 1).swapaxes(1, 2))
    o_dense = mha_reference(q[:, :, None, :].swapaxes(1, 1).reshape(B, cfg.n_heads, 1, hd), kd, vd, causal=False)[:, :, 0]
    np.testing.assert_allclose(
        np.asarray(o_paged), np.asarray(o_dense), atol=2e-5, rtol=2e-5
    )


def test_continuous_batching_serves_all_and_matches_isolated_decode():
    cfg = get_reduced_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [RNG.integers(2, cfg.vocab, 5).astype(np.int32) for _ in range(5)]
    reqs = [Request(req_id=i, prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)]
    b = ContinuousBatcher(model, max_batch=2, max_len=24)
    b.model_params = params
    m = b.serve(reqs)
    assert all(r.finished_step >= 0 for r in reqs)
    assert m.tokens_out >= 5 * 5

    # isolated greedy decode for request 0 must match its batched output
    cache = model.cache_init(1, 24)
    toks = prompts[0]
    out = []
    for t, tok in enumerate(toks):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([tok]), jnp.asarray([t], jnp.int32)
        )
    cur = int(np.asarray(logits)[0].argmax())
    out.append(cur)
    for t in range(len(toks), len(toks) + 5):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([cur], jnp.int32), jnp.asarray([t], jnp.int32)
        )
        cur = int(np.asarray(logits)[0].argmax())
        out.append(cur)
    assert reqs[0].output[: len(out)] == out
