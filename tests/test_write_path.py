"""Write path: staged mutations, commit supersteps, free-list allocator,
and the sequential-commit determinism oracle.

Fast in-process tests cover the single-shard executor, the allocator, and
the ISA store class; the 8-shard schedule x fabric bit-identity matrix
(acceptance criteria) runs in a subprocess with its own device count
(tests/helpers/write_checks.py), like the other distributed suites.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import commit, isa
from repro.core.arena import (
    H_BUMP,
    H_FREE,
    M_ALLOC,
    M_CAS,
    M_FREE,
    M_STORE,
    NULL,
    ArenaBuilder,
    mut_width,
)
from repro.core.iterator import (
    STATUS_ACTIVE,
    STATUS_DONE,
    STATUS_MAXED,
    execute_batched,
    mut_step_batch,
)
from repro.core.structures import bst, linked_list

ROOT = Path(__file__).resolve().parents[1]
RNG = np.random.default_rng(21)


# --------------------------- free-list allocator -----------------------------


def test_builder_free_list_reuses_slots():
    b = ArenaBuilder(16, 4)
    p = b.alloc(6)
    b.free(p[2:4])
    q = b.alloc(3)
    # LIFO: last freed first, then the bump region continues
    assert list(q) == [int(p[3]), int(p[2]), 6]
    ar = b.finish()
    heap = np.asarray(ar.heap)
    assert heap[0, H_FREE] == NULL and heap[0, H_BUMP] == 7


def test_builder_finish_threads_free_chain_into_heap():
    b = ArenaBuilder(16, 4)
    p = b.alloc(6)
    b.free([1, 3])
    ar = b.finish()
    heap = np.asarray(ar.heap)
    data = np.asarray(ar.data)
    assert heap[0, H_FREE] == 3  # LIFO head
    assert data[3, 0] == 1 and data[1, 0] == NULL  # intrusive chain


# --------------------------- single-shard oracle -----------------------------


def _small_list(n=12, cap=64):
    b = ArenaBuilder(cap, 4)
    keys = np.arange(100, 100 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys * 2)
    return b.finish(), head, keys


def test_sequential_insert_then_find():
    ar, head, keys = _small_list()
    it = linked_list.insert_iterator()
    newk = np.array([7, 8, 9], np.int32)
    p0, s0 = it.init(newk, newk * 5, head)
    rec, st, ar2 = commit.sequential_commit_execute(it, ar, p0, s0, max_iters=200)
    assert (rec[:, 3] == STATUS_DONE).all()
    assert st.commits >= 2 * len(newk)  # alloc + link swing per insert
    # the input arena is untouched (replayable snapshot)
    fit = linked_list.find_iterator()
    fp, fs = fit.init(jnp.asarray(newk), head)
    _, scr_old, _, _ = execute_batched(fit, ar, fp, fs, max_iters=200)
    assert (np.asarray(scr_old)[:, 2] == 0).all()
    _, scr_new, _, _ = execute_batched(fit, ar2, fp, fs, max_iters=200)
    assert (np.asarray(scr_new)[:, 2] == 1).all()
    np.testing.assert_array_equal(np.asarray(scr_new)[:, 1], newk * 5)


def test_sequential_delete_frees_and_realloc_reuses():
    ar, head, keys = _small_list()
    dit = linked_list.delete_iterator()
    dp, ds = dit.init(np.array([keys[3], keys[7]], np.int32), head)
    rec, st, ar2 = commit.sequential_commit_execute(dit, ar, dp, ds, max_iters=200)
    assert (rec[:, commit.F_SCRATCH + linked_list.RW_RES] == 1).all()
    heap = np.asarray(ar2.heap)
    assert heap[0, H_FREE] != NULL  # victims landed on the free list
    # a following insert must reuse the freed slot (LIFO), not burn capacity
    bump_before = int(heap[0, H_BUMP])
    iit = linked_list.insert_iterator()
    ip, isc = iit.init(np.array([999], np.int32), np.array([1], np.int32), head)
    rec2, _, ar3 = commit.sequential_commit_execute(iit, ar2, ip, isc, max_iters=200)
    assert int(np.asarray(ar3.heap)[0, H_BUMP]) == bump_before
    assert int(rec2[0, commit.F_SCRATCH + linked_list.RW_RES]) == int(heap[0, H_FREE])


def test_interleaved_rw_linearizable_single_shard():
    """Finds racing inserts in one batch: every outcome must be explainable
    by SOME serialization (found => correct value; final state holds all
    inserts), and pre-existing keys are always found."""
    ar, head, keys = _small_list(n=16, cap=128)
    it = linked_list.rw_iterator()
    ops = np.array([1, 0, 1, 0, 1, 0, 1, 0], np.int32)
    qk = np.where(ops == 1, np.arange(8) + 500, keys[: 8]).astype(np.int32)
    qv = (np.arange(8) + 40).astype(np.int32)
    p0, s0 = it.init(ops, qk, qv, head)
    rec, st, ar2 = commit.sequential_commit_execute(it, ar, p0, s0, max_iters=500)
    assert (rec[:, 3] == STATUS_DONE).all()
    scr = rec[:, commit.F_SCRATCH :]
    for i in range(8):
        if ops[i] == 0:  # pre-existing key: must be found with its value
            assert scr[i, linked_list.RW_RES] == 1
            assert scr[i, linked_list.RW_VAL] == qk[i] * 2
    # post-state: all inserted keys present with their values
    fit = linked_list.find_iterator()
    fp, fs = fit.init(jnp.asarray(qk[ops == 1]), head)
    _, fscr, _, _ = execute_batched(fit, ar2, fp, fs, max_iters=500)
    np.testing.assert_array_equal(np.asarray(fscr)[:, 1], qv[ops == 1])


def test_maxed_records_never_carry_staged_mutations():
    """The continuation invariant: a record is only MAXED once its payload is
    clear, so (cur_ptr, scratch) alone resumes it."""
    ar, head, _ = _small_list(n=32, cap=128)
    it = linked_list.insert_iterator()
    newk = np.arange(4, dtype=np.int32) + 700
    p0, s0 = it.init(newk, newk, head)
    W = ar.node_words
    ptr = jnp.asarray(p0)
    scr = jnp.asarray(s0)
    status = jnp.full((4,), STATUS_ACTIVE, jnp.int32)
    iters = jnp.zeros((4,), jnp.int32)
    mut = jnp.zeros((4, mut_width(W)), jnp.int32)
    for _ in range(64):  # tiny max_iters forces the MAXED boundary mid-insert
        ptr, scr, status, iters, mut = mut_step_batch(
            it, ar.data, ptr, scr, status, iters, mut, max_iters=2
        )
    maxed = np.asarray(status) == STATUS_MAXED
    assert maxed.any()
    assert (np.asarray(mut)[maxed, 0] == 0).all()


# ------------------------------- ISA store class -----------------------------


def test_vm_storen_stages_masked_store():
    a = isa.Asm(scratch_words=1, node_words=4)
    a.movi(1, 42)
    a.storen(2, 1)
    a.movi(2, 5)
    a.next_iter(2)
    prog = a.finish()
    assert prog.mutates
    done, ptr, scr, (op, tgt, mask, exp, data) = isa.run_iteration_mut(
        jnp.asarray(prog.code), jnp.zeros(4, jnp.int32), jnp.int32(9),
        jnp.zeros(1, jnp.int32),
    )
    assert int(op) == M_STORE and int(tgt) == 9
    assert int(mask) == 1 << 2 and int(data[2]) == 42
    assert int(ptr) == 5 and not bool(done)


def test_vm_alloc_takes_over_storen_image():
    a = isa.Asm(scratch_words=2, node_words=4)
    a.movi(1, 7)
    a.storen(0, 1)
    a.alloc(1)  # result address -> SP[1]
    a.getptr(2)
    a.next_iter(2)
    prog = a.finish()
    _, _, _, (op, tgt, mask, _, data) = isa.run_iteration_mut(
        jnp.asarray(prog.code), jnp.zeros(4, jnp.int32), jnp.int32(0),
        jnp.zeros(2, jnp.int32),
    )
    assert int(op) == M_ALLOC and int(tgt) == 1
    assert int(mask) == 1 and int(data[0]) == 7


def test_vm_setptr_stages_cas():
    a = isa.Asm(scratch_words=1, node_words=4)
    a.movi(1, 33)  # new value
    a.movi(2, 11)  # expected
    a.setptr(2, 1, 2)
    a.getptr(3)
    a.next_iter(3)
    prog = a.finish()
    _, _, _, (op, tgt, mask, exp, data) = isa.run_iteration_mut(
        jnp.asarray(prog.code), jnp.zeros(4, jnp.int32), jnp.int32(4),
        jnp.zeros(1, jnp.int32),
    )
    assert int(op) == M_CAS and int(tgt) == 4
    assert int(mask) == 1 << 2 and int(exp) == 11 and int(data[2]) == 33


def test_vm_free_stages_release():
    a = isa.Asm(scratch_words=1, node_words=4)
    a.movi(1, 13)
    a.free(1)
    a.ret()
    prog = a.finish()
    done, _, _, (op, tgt, mask, _, _) = isa.run_iteration_mut(
        jnp.asarray(prog.code), jnp.zeros(4, jnp.int32), jnp.int32(0),
        jnp.zeros(1, jnp.int32),
    )
    assert int(op) == M_FREE and int(tgt) == 13 and int(mask) == 0
    assert bool(done)  # VM-level done; the executors gate it on the commit


def test_isa_bst_update_matches_traced():
    n = 48
    keys = np.sort(
        RNG.choice(np.arange(10**4), n, replace=False).astype(np.int32)
    )
    vals = np.arange(n, dtype=np.int32)
    b = ArenaBuilder(64, 4)
    root, _ = bst.build_into(b, keys, vals)
    ar = b.finish()
    q = np.concatenate([keys[:10], [77777]]).astype(np.int32)
    nv = (np.arange(len(q)) + 300).astype(np.int32)
    traced = bst.update_iterator()
    from repro.core.structures import isa_programs

    vm = isa.as_pulse_iterator(isa_programs.bst_update_program())
    assert vm.mutates
    p0, s0 = traced.init(jnp.asarray(q), jnp.asarray(nv), root)
    rec_t, st_t, ar_t = commit.sequential_commit_execute(traced, ar, p0, s0, max_iters=200)
    rec_v, st_v, ar_v = commit.sequential_commit_execute(vm, ar, p0, s0, max_iters=200)
    np.testing.assert_array_equal(rec_t, rec_v)
    np.testing.assert_array_equal(np.asarray(ar_t.data), np.asarray(ar_v.data))
    assert st_t.commits == st_v.commits


# ------------------------ distributed acceptance matrix ----------------------


@pytest.mark.slow
def test_write_path_distributed_subprocess():
    """8-shard bit-identity of every schedule x fabric vs the oracle:
    records, supersteps, wire words, final arena contents."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "write_checks.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1800,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL WRITE-PATH CHECKS PASSED" in proc.stdout
