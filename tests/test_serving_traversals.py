"""PulseService serving layer: admission, fairness, continuations, compacted
supersteps, and the variable-depth pulse_chase wave scheduler."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.arena import NULL, ArenaBuilder
from repro.core.engine import PulseEngine
from repro.core.iterator import STATUS_DONE, execute_batched
from repro.core.structures import btree, hash_table, linked_list, skiplist
from repro.serving.admission import AdmissionController, TraversalRequest
from repro.serving.traversal_service import PulseService, ServiceMetrics, StructureSpec

ROOT = Path(__file__).resolve().parents[1]
RNG = np.random.default_rng(123)


# ------------------------------- admission -----------------------------------


def _req(rid, structure="s", tenant="t", deadline_ms=None):
    return TraversalRequest(rid, structure, query=rid, tenant=tenant, deadline_ms=deadline_ms)


def test_admission_preserves_fifo_within_tenant():
    ac = AdmissionController()
    for i in range(6):
        ac.submit(_req(i, tenant="a"), now_s=float(i))
    got = [r.req_id for r in ac.admit({"s": 4})]
    assert got == [0, 1, 2, 3]
    got = [r.req_id for r in ac.admit({"s": 4})]
    assert got == [4, 5]
    assert ac.pending() == 0


def test_admission_edf_across_tenants():
    ac = AdmissionController()
    ac.submit(_req(0, tenant="lazy"), now_s=0.0)  # no deadline
    ac.submit(_req(1, tenant="urgent", deadline_ms=10.0), now_s=0.0)
    ac.submit(_req(2, tenant="soon", deadline_ms=100.0), now_s=0.0)
    got = [r.req_id for r in ac.admit({"s": 3})]
    assert got == [1, 2, 0]  # earliest deadline first; best-effort last


def test_admission_fairness_no_starvation():
    """A flooding tenant must not starve a trickle tenant (credits alternate
    service when no deadlines differentiate)."""
    ac = AdmissionController()
    for i in range(20):
        ac.submit(_req(i, tenant="flood"), now_s=0.0)
    for i in range(20, 24):
        ac.submit(_req(i, tenant="trickle"), now_s=0.0)
    admitted = [ac.admit({"s": 2}) for _ in range(4)]
    tenants_per_round = [[r.tenant for r in batch] for batch in admitted]
    # every admission round serves both tenants while the trickle has work
    for round_tenants in tenants_per_round:
        assert set(round_tenants) == {"flood", "trickle"}, tenants_per_round


def test_admission_respects_per_structure_capacity():
    ac = AdmissionController()
    ac.submit(TraversalRequest(0, "full", 0, tenant="a"), now_s=0.0)
    ac.submit(TraversalRequest(1, "free", 1, tenant="b"), now_s=0.0)
    got = [r.req_id for r in ac.admit({"full": 0, "free": 1})]
    assert got == [1]
    assert ac.pending() == 1  # the blocked head keeps its queue position


# ----------------------------- service loop ----------------------------------


def _mixed_service(slots=8, quantum=4, backend="xla", seed=9):
    n = 128
    rng = np.random.default_rng(seed)
    b = ArenaBuilder(2048, 20)
    lkeys = np.arange(n, dtype=np.int32)
    lvals = rng.integers(0, 10**6, n).astype(np.int32)
    head = linked_list.build_into(b, lkeys, lvals)
    bkeys = rng.choice(np.arange(10**4, 10**5), n, replace=False).astype(np.int32)
    bvals = rng.integers(0, 10**6, n).astype(np.int32)
    root, _ = btree.build_into(b, bkeys, bvals)
    hkeys = rng.choice(np.arange(10**5, 2 * 10**5), n, replace=False).astype(np.int32)
    hvals = rng.integers(0, 10**6, n).astype(np.int32)
    heads = hash_table.build_into(b, hkeys, hvals, 32)
    skeys = rng.choice(np.arange(2 * 10**5, 3 * 10**5), n, replace=False).astype(np.int32)
    svals = rng.integers(0, 10**6, n).astype(np.int32)
    shead = skiplist.build_into(b, skeys, svals)
    svc = PulseService(
        PulseEngine(b.finish()),
        {
            "list": StructureSpec(linked_list.find_iterator(), (head,)),
            "btree": StructureSpec(btree.find_iterator(), (root,)),
            "hash": StructureSpec(hash_table.find_iterator(32), (jnp.asarray(heads),)),
            "skip": StructureSpec(skiplist.find_iterator(), (shead,)),
        },
        slots_per_structure=slots,
        quantum=quantum,
        backend=backend,
    )
    data = {
        "list": (lkeys, lvals),
        "btree": (bkeys, bvals),
        "hash": (hkeys, hvals),
        "skip": (skeys, svals),
    }
    return svc, data


def test_service_mixed_workload_end_to_end():
    svc, data = _mixed_service()
    reqs = []
    rid = 0
    for s, (keys, _) in data.items():
        for _ in range(12):
            reqs.append(TraversalRequest(rid, s, int(keys[RNG.integers(0, len(keys))])))
            rid += 1
        reqs.append(TraversalRequest(rid, s, 5 * 10**6))  # guaranteed miss
        rid += 1
    m = svc.run(reqs)
    assert m.completed == len(reqs)
    assert np.isfinite(m.p50_ms) and np.isfinite(m.p99_ms)
    assert m.throughput_rps > 0
    for r in reqs:
        keys, values = data[r.structure]
        hit = r.query in keys
        found = bool(r.result[2])  # every find iterator reports [_, value, found]
        assert found == hit, (r.structure, r.query, r.result)
        if hit and r.structure != "btree":
            assert r.result[1] == values[list(keys).index(r.query)]


def test_service_mixed_read_write_tenants():
    """Write path end to end through the service: a write tenant's inserts
    commit under the per-group barrier, and a read tenant's finds observe
    them once the barrier releases the group."""
    from repro.core.structures import hash_table

    b = ArenaBuilder(512, 4)
    keys = np.arange(100, 132, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys * 2)
    sent = hash_table.build_writable(
        b, np.arange(200, 216, dtype=np.int32), np.arange(16, dtype=np.int32), 8
    )
    svc = PulseService(
        PulseEngine(b.finish()),
        {
            "list": StructureSpec(linked_list.find_iterator(), (head,), group="list"),
            "list_ins": StructureSpec(
                linked_list.insert_iterator(), (head,), group="list",
                takes_value=True,
            ),
            "list_del": StructureSpec(
                linked_list.delete_iterator(), (head,), group="list"
            ),
            "hash": StructureSpec(
                hash_table.find_iterator(8), (jnp.asarray(sent),), group="hash"
            ),
            "hash_ins": StructureSpec(
                hash_table.insert_iterator(8), (sent,), group="hash",
                takes_value=True,
            ),
        },
        slots_per_structure=8,
        quantum=8,
    )
    assert svc.groups["list_ins"].spec.writes and not svc.groups["list"].spec.writes
    reqs, rid = [], 0
    for k in range(300, 308):
        reqs.append(TraversalRequest(rid, "list_ins", query=k, value=k * 3, tenant="w"))
        rid += 1
    for k in [104, 110, 300, 305]:
        reqs.append(TraversalRequest(rid, "list", query=k, tenant="r"))
        rid += 1
    for k in [106, 115]:  # non-adjacent victims (head key 100 is the sentinel)
        reqs.append(TraversalRequest(rid, "list_del", query=int(k), tenant="w"))
        rid += 1
    for k in range(400, 406):
        reqs.append(TraversalRequest(rid, "hash_ins", query=k, value=k + 9, tenant="w"))
        rid += 1
    for k in [400, 403, 205]:
        reqs.append(TraversalRequest(rid, "hash", query=k, tenant="r"))
        rid += 1
    m = svc.run(reqs)
    assert m.completed == len(reqs)
    assert m.commits > 0 and m.writes_retired == 16
    for r in reqs:
        if r.structure == "list" and r.query >= 300:
            assert r.result[1] == r.query * 3  # find scratch: [key, value, found]
        if r.structure == "hash" and r.query >= 400:
            assert r.result[1] == r.query + 9
    # deletes took effect: a fresh find through the engine's updated arena
    fit = linked_list.find_iterator()
    p0, s0 = fit.init(jnp.asarray(np.array([106, 115], np.int32)), head)
    _, scr, _, _ = execute_batched(
        fit, svc.engine.arena, p0, s0, max_iters=4096
    )
    assert (np.asarray(scr)[:, 2] == 0).all()


def test_write_barrier_excludes_concurrent_readers():
    """While a write slot-group of a structure group is occupied, reads of
    that group are not admitted (and vice versa); other groups are free."""
    from repro.serving.admission import apply_write_barriers

    group_of = {"list": "list", "list_ins": "list", "hash": "hash"}
    writes = {"list": False, "list_ins": True, "hash": False}
    # writer occupied -> reads of 'list' blocked, 'hash' untouched
    free = apply_write_barriers(
        {"list": 4, "list_ins": 4, "hash": 4}, group_of, writes,
        {"list": False, "list_ins": True, "hash": False}, {},
    )
    assert free == {"list": 0, "list_ins": 4, "hash": 4}
    # readers occupied -> writer blocked
    free = apply_write_barriers(
        {"list": 4, "list_ins": 4, "hash": 4}, group_of, writes,
        {"list": True, "list_ins": False, "hash": False}, {},
    )
    assert free == {"list": 4, "list_ins": 0, "hash": 4}
    # queued writer drains readers out (anti-starvation)
    free = apply_write_barriers(
        {"list": 4, "list_ins": 4, "hash": 4}, group_of, writes,
        {"list": False, "list_ins": False, "hash": False}, {"list_ins": 2},
    )
    assert free == {"list": 0, "list_ins": 4, "hash": 4}
    # two writers of one group both pending: exactly ONE wins the round --
    # the one whose queued request arrived first (seq order, FIFO-consistent)
    group_of2 = {**group_of, "list_del": "list"}
    writes2 = {**writes, "list_del": True}
    free = apply_write_barriers(
        {"list": 4, "list_ins": 4, "list_del": 4, "hash": 4},
        group_of2, writes2,
        {n: False for n in group_of2}, {"list_ins": 0, "list_del": 5},
    )
    assert free == {"list": 0, "list_ins": 4, "list_del": 0, "hash": 4}
    # an occupied writer keeps the group against a pending rival
    free = apply_write_barriers(
        {"list": 4, "list_ins": 4, "list_del": 4, "hash": 4},
        group_of2, writes2,
        {"list": False, "list_ins": True, "list_del": False, "hash": False},
        {"list_del": 2},
    )
    assert free == {"list": 0, "list_ins": 4, "list_del": 0, "hash": 4}


def test_service_continuations_preempt_long_walks():
    """quantum << walk depth: deep list walks must span several rounds as
    MAXED continuations yet finish with exact hop counts."""
    svc, data = _mixed_service(slots=4, quantum=4)
    lkeys, lvals = data["list"]
    deep = int(lkeys[-1])  # deepest key: ~128 hops at quantum 4
    shallow = int(lkeys[2])
    reqs = [
        TraversalRequest(0, "list", deep),
        TraversalRequest(1, "list", shallow),
    ]
    m = svc.run(reqs)
    assert m.completed == 2
    r_deep, r_shallow = reqs
    assert r_deep.status == STATUS_DONE and bool(r_deep.result[2])
    assert r_deep.finish_round - r_deep.admit_round >= 2  # resumed repeatedly
    assert r_shallow.finish_round <= r_deep.finish_round
    assert r_deep.iters == len(lkeys) - 1 + 1  # hops to reach the deepest key
    # early retirement freed the shallow slot long before the deep one
    assert r_shallow.iters < r_deep.iters


def test_service_backfills_retired_slots():
    """More requests than slots: retirement must backfill so everything
    completes, and occupancy never exceeds the slot budget."""
    svc, data = _mixed_service(slots=2, quantum=8)
    lkeys, _ = data["list"]
    reqs = [
        TraversalRequest(i, "list", int(lkeys[RNG.integers(0, 32)]))
        for i in range(11)
    ]
    m = svc.run(reqs)
    assert m.completed == 11
    assert m.rounds > 1  # could not have fit in one round with 2 slots


def test_service_tenant_fairness_under_flood():
    svc, data = _mixed_service(slots=2, quantum=64)
    lkeys, _ = data["list"]
    reqs = [
        TraversalRequest(i, "list", int(lkeys[RNG.integers(0, 16)]), tenant="flood")
        for i in range(12)
    ] + [
        TraversalRequest(100 + i, "list", int(lkeys[RNG.integers(0, 16)]), tenant="trickle")
        for i in range(3)
    ]
    m = svc.run(reqs)
    assert m.per_tenant["trickle"]["completed"] == 3
    # the trickle tenant's requests all finish before the flood drains
    trickle_done = max(r.finish_round for r in reqs if r.tenant == "trickle")
    flood_done = max(r.finish_round for r in reqs if r.tenant == "flood")
    assert trickle_done < flood_done


def test_service_kernel_backend_matches_xla():
    svc_x, data = _mixed_service(slots=4, quantum=16)
    svc_k, _ = _mixed_service(slots=4, quantum=16, backend="kernel")
    lkeys, lvals = data["list"]
    qs = [int(lkeys[i]) for i in (3, 17, 60)]
    rx = [TraversalRequest(i, "list", q) for i, q in enumerate(qs)]
    rk = [TraversalRequest(i, "list", q) for i, q in enumerate(qs)]
    svc_x.run(rx)
    svc_k.run(rk)
    for a, b in zip(rx, rk):
        np.testing.assert_array_equal(a.result, b.result)


# --------------------- variable-depth wave scheduler -------------------------


def test_pulse_chase_waves_matches_fixed_depth():
    from repro.kernels.pulse_chase import ops

    keys = RNG.choice(np.arange(10**5), size=256, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, 256).astype(np.int32)
    ar, heads = hash_table.build(keys, values, 8)  # long skewed chains
    it = hash_table.find_iterator(8)
    q = np.concatenate([keys[:24], RNG.integers(10**5, 10**6, 8).astype(np.int32)])
    ptr0, scr0 = it.init(jnp.asarray(q), jnp.asarray(heads))
    st0 = jnp.zeros(32, jnp.int32)
    logic = ops.iterator_logic(it)
    MAX = 64
    r_ref = ops.pulse_chase(
        ar.data, ptr0, scr0, st0, logic_fn=logic, num_steps=MAX, use_pallas=False
    )
    p, s, st, stats = ops.pulse_chase_waves(
        ar.data, ptr0, scr0, st0,
        logic_fn=logic, max_steps=MAX, depth_quantum=8, wave=8, interpret=True,
    )
    np.testing.assert_array_equal(p, np.asarray(r_ref[0]))
    np.testing.assert_array_equal(s, np.asarray(r_ref[1]))
    np.testing.assert_array_equal(st, np.asarray(r_ref[2]))
    # skewed chains -> early lanes retire -> strictly less issued work
    assert stats.savings > 0.2, stats
    assert stats.lanes_per_chunk == sorted(stats.lanes_per_chunk, reverse=True)
    assert stats.retire_step.max() <= MAX


def test_pulse_chase_waves_null_entry_retires_immediately():
    from repro.kernels.pulse_chase import ops

    keys = np.arange(16, dtype=np.int32)
    values = np.arange(16, dtype=np.int32)
    ar, head = linked_list.build(keys, values)
    it = linked_list.find_iterator()
    ptr0, scr0 = it.init(jnp.asarray(keys[:8]), head)
    ptr0 = jnp.asarray(np.where(np.arange(8) < 4, NULL, np.asarray(ptr0)))
    logic = ops.iterator_logic(it)
    p, s, st, stats = ops.pulse_chase_waves(
        ar.data, ptr0, scr0, jnp.zeros(8, jnp.int32),
        logic_fn=logic, max_steps=32, wave=8,
    )
    assert (st == 1).all()
    assert (stats.retire_step[:4] == 0).all()  # never entered a chunk
    np.testing.assert_array_equal(np.asarray(s)[:4, 1], np.zeros(4))  # untouched scratch


def test_engine_kernel_backend_fault_parity():
    """A mid-walk NULL dereference must report STATUS_FAULT on both the XLA
    executor and the kernel wave scheduler, never a successful DONE."""
    from repro.core.iterator import STATUS_FAULT, PulseIterator

    keys = np.arange(32, dtype=np.int32)
    values = np.arange(100, 132, dtype=np.int32)
    ar, head = linked_list.build(keys, values)

    # a "blind" find that only terminates on a hit: a missing key walks off
    # the tail into NULL (the fault path under test)
    def next_fn(node, ptr, scratch):
        return node[2], scratch

    def end_fn(node, ptr, scratch):
        hit = node[0] == scratch[0]
        return hit, scratch.at[1].set(jnp.where(hit, node[1], scratch[1]))

    def init(qs, head_ptr):
        s = jnp.zeros((qs.shape[0], 2), jnp.int32).at[:, 0].set(qs)
        return jnp.full((qs.shape[0],), head_ptr, jnp.int32), s

    it = PulseIterator(2, next_fn, end_fn, init, name="blind_find")
    eng = PulseEngine(ar)
    ptr0, scr0 = it.init(jnp.asarray([5, 10**6], jnp.int32), head)  # hit, miss
    res_x = eng.execute(it, ptr0, scr0, max_iters=64, backend="xla")
    res_k = eng.execute(it, ptr0, scr0, max_iters=64, backend="kernel")
    assert res_x.status[0] == STATUS_DONE and res_k.status[0] == STATUS_DONE
    assert res_x.status[1] == STATUS_FAULT and res_k.status[1] == STATUS_FAULT
    np.testing.assert_array_equal(res_x.scratch, res_k.scratch)


def test_engine_kernel_backend_translation_faults():
    """Out-of-range pointers and perm-revoked ranges must FAULT on the
    kernel backend (quantum-granular fault_fn), not chase clamped garbage."""
    import dataclasses as dc

    from repro.core.arena import PERM_WRITE
    from repro.core.iterator import STATUS_FAULT

    keys = np.arange(16, dtype=np.int32)
    ar, head = linked_list.build(keys, keys * 2)
    it = linked_list.find_iterator()
    eng = PulseEngine(ar)
    ptr0, scr0 = it.init(jnp.asarray([3, 7], jnp.int32), head)
    ptr0 = jnp.asarray(np.array([10**6, int(np.asarray(ptr0)[1])], np.int32))
    res = eng.execute(it, ptr0, scr0, max_iters=64, backend="kernel")
    assert res.status[0] == STATUS_FAULT
    assert res.status[1] == STATUS_DONE and res.scratch[1][2] == 1

    ar2 = dc.replace(ar, perms=jnp.asarray([PERM_WRITE], jnp.int32))  # no READ
    ptr0b, scr0b = it.init(jnp.asarray([3], jnp.int32), head)
    res2 = PulseEngine(ar2).execute(it, ptr0b, scr0b, max_iters=64, backend="kernel")
    assert res2.status[0] == STATUS_FAULT


def test_engine_kernel_backend_matches_executor():
    n = 128
    keys = RNG.choice(np.arange(10**5), size=n, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    q = np.concatenate([keys[:16], RNG.integers(10**5, 10**6, 16).astype(np.int32)])
    ptr0, scr0 = it.init(jnp.asarray(q), root)
    eng = PulseEngine(ar)
    o = execute_batched(it, ar, ptr0, scr0, max_iters=64)
    res = eng.execute(it, ptr0, scr0, max_iters=64, backend="kernel")
    np.testing.assert_array_equal(res.ptr, np.asarray(o[0]))
    np.testing.assert_array_equal(res.scratch, np.asarray(o[1]))
    assert (res.status == STATUS_DONE).all()


# --------------------- compacted supersteps (multi-device) -------------------


def test_compacted_supersteps_subprocess():
    """Equivalence + wire-reduction checks need >1 XLA device, so they run in
    a subprocess with their own XLA_FLAGS (same isolation rule as
    test_distributed_routing)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "compaction_checks.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL COMPACTION CHECKS PASSED" in proc.stdout
