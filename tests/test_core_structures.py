"""Unit tests: arena + iterator executor + every ported data structure
against its pure-Python oracle (single memory node)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import arena as arena_mod
from repro.core.iterator import (
    STATUS_DONE,
    STATUS_FAULT,
    STATUS_MAXED,
    execute_batched,
    resume,
)
from repro.core.structures import bst, btree, hash_table, linked_list, skiplist

RNG = np.random.default_rng(0)


def _unique_keys(n, lo=0, hi=10**6):
    keys = RNG.choice(np.arange(lo, hi, dtype=np.int64), size=n, replace=False)
    return keys.astype(np.int32)


# ------------------------------ arena ---------------------------------------


def test_arena_bitcast_roundtrip():
    x = jnp.asarray([1.5, -2.25, 0.0, 3.14159], jnp.float32)
    back = arena_mod.i2f(arena_mod.f2i(x))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_arena_node_word_limit():
    with pytest.raises(ValueError):
        arena_mod.make_arena(np.zeros((4, 65), np.int32))


def test_interleaved_allocation_spreads_shards():
    b = arena_mod.ArenaBuilder(16, 4, num_shards=4, policy="interleaved")
    ptrs = b.alloc(8)
    shards = ptrs // 4
    assert sorted(shards.tolist()) == [0, 0, 1, 1, 2, 2, 3, 3]


# --------------------------- linked list ------------------------------------


def test_list_find_matches_oracle():
    keys = _unique_keys(200)
    values = RNG.integers(0, 10**6, 200).astype(np.int32)
    ar, head = linked_list.build(keys, values)
    it = linked_list.find_iterator()
    queries = np.concatenate([keys[:50], _unique_keys(50, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), head)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=1000)
    ref = linked_list.ref_find(keys, values, queries)
    scr = np.asarray(scr)
    for i, (val, found, hops) in enumerate(ref):
        assert int(scr[i, 1]) == val, f"query {i}"
        assert int(scr[i, 2]) == found
    assert (np.asarray(status) == STATUS_DONE).all()


def test_list_sum_stateful_scratch():
    keys = np.arange(64, dtype=np.int32)
    values = RNG.integers(0, 100, 64).astype(np.int32)
    ar, head = linked_list.build(keys, values)
    it = linked_list.sum_iterator()
    ptr0, scr0 = it.init(jnp.asarray([head, head], jnp.int32))
    _, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=1000)
    assert int(scr[0, 0]) == int(values.sum())
    assert int(scr[0, 1]) == 64
    assert int(iters[0]) == 64  # one iteration per node


def test_max_iters_continuation_resume():
    """Paper S3: a request hitting max_iterations returns its scratch_pad and
    the CPU node re-issues it from that point (continuation)."""
    keys = np.arange(100, dtype=np.int32)
    values = np.ones(100, np.int32)
    ar, head = linked_list.build(keys, values)
    it = linked_list.sum_iterator()
    ptr0, scr0 = it.init(jnp.asarray([head], jnp.int32))
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=30)
    assert int(status[0]) == STATUS_MAXED
    assert int(scr[0, 0]) == 30  # partial sum so far
    # resume from the continuation: same record, fresh iteration budget
    ptr2, scr2, status2, iters2 = execute_batched(
        it, ar, ptr, scr, max_iters=1000
    )
    assert int(status2[0]) == STATUS_DONE
    assert int(scr2[0, 0]) == 100


# ---------------------------- hash table ------------------------------------


def test_hash_find_matches_oracle():
    n, n_buckets = 500, 64
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, heads = hash_table.build(keys, values, n_buckets)
    it = hash_table.find_iterator(n_buckets)
    queries = np.concatenate([keys[:100], _unique_keys(100, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), jnp.asarray(heads))
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=1000)
    ref = hash_table.ref_find(keys, values, n_buckets, queries)
    scr = np.asarray(scr)
    status = np.asarray(status)
    for i, (val, found, hops) in enumerate(ref):
        if status[i] == STATUS_FAULT:  # empty bucket -> NULL head
            assert found == 0
        else:
            assert int(scr[i, 1]) == val, f"query {i}"
            assert int(scr[i, 2]) == found


# ------------------------------ b+tree --------------------------------------


def test_btree_find_matches_oracle():
    n = 3000
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, height = btree.build(keys, values)
    it = btree.find_iterator()
    queries = np.concatenate([keys[:200], _unique_keys(200, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), root)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=100)
    ref = btree.ref_find(keys, values, queries)
    scr = np.asarray(scr)
    for i, (val, found) in enumerate(ref):
        assert int(scr[i, 1]) == val, f"query {i}"
        assert int(scr[i, 2]) == found
    assert (np.asarray(iters) == height).all()  # descent = height hops


def test_btree_range_aggregate_matches_oracle():
    n = 2000
    keys = np.sort(_unique_keys(n, hi=10**5))
    values = RNG.integers(0, 1000, n).astype(np.int32)
    ar, root, _ = btree.build(keys, values)
    it = btree.range_aggregate_iterator()
    los = np.asarray([0, 500, 40_000, 99_999], np.int32)
    his = np.asarray([10**5, 45_000, 40_000, 10**5], np.int32)
    ptr0, scr0 = it.init(jnp.asarray(los), jnp.asarray(his), root)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=5000)
    ref = btree.ref_range_aggregate(keys, values, los, his)
    scr = np.asarray(scr)
    for i, (s, mn, mx, c) in enumerate(ref):
        assert int(scr[i, btree.RA_SUM]) % (2**32) == s, f"range {i} sum"
        assert int(scr[i, btree.RA_COUNT]) == c, f"range {i} count"
        if c:
            assert int(scr[i, btree.RA_MIN]) == mn
            assert int(scr[i, btree.RA_MAX]) == mx


# ------------------------------- bst ----------------------------------------


def test_bst_find_matches_oracle():
    n = 1500
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, height = bst.build(keys, values)
    it = bst.find_iterator()
    queries = np.concatenate([keys[:200], _unique_keys(200, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), root)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=100)
    value, found = bst.result(jnp.asarray(scr))
    ref = bst.ref_find(keys, values, queries)
    for i, (val, fnd) in enumerate(ref):
        assert int(found[i]) == fnd, f"query {i}"
        if fnd:
            assert int(value[i]) == val, f"query {i}"
    assert int(np.asarray(iters).max()) <= height


# ----------------------------- skiplist -------------------------------------


def test_skiplist_find_matches_oracle():
    n = 1000
    keys = _unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = skiplist.build(keys, values)
    it = skiplist.find_iterator()
    queries = np.concatenate([keys[:150], _unique_keys(150, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), head)
    ptr, scr, status, iters = execute_batched(it, ar, ptr0, scr0, max_iters=3000)
    ref = skiplist.ref_find(keys, values, queries)
    scr = np.asarray(scr)
    for i, (val, found) in enumerate(ref):
        assert int(scr[i, 2]) == found, f"query {i}"
        if found:
            assert int(scr[i, 1]) == val
    # skip levels must beat a plain list walk by a wide margin
    assert float(np.asarray(iters).mean()) < n / 8
