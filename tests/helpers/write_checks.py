"""Write-path determinism checks (8 emulated devices -- the acceptance
configuration): every distributed schedule x fabric must match the
sequential-commit oracle bit for bit on records, supersteps, wire words,
AND final arena contents (data + heap registers)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import commit, routing  # noqa: E402
from repro.core.arena import PERM_READ, ArenaBuilder, make_arena  # noqa: E402
from repro.core.iterator import STATUS_DONE, STATUS_FAULT  # noqa: E402
from repro.core.structures import (  # noqa: E402
    bst,
    btree,
    hash_table,
    linked_list,
    skiplist,
)

RNG = np.random.default_rng(11)
P = 8

SCHEDULES = (
    ("dispatched", "dense"),
    ("fused", "dense"),
    ("fused", "ring"),
    ("pipelined", "dense"),
    ("pipelined", "ring"),
)


def _assert_matches_oracle(name, it, arena, p0, s0, *, max_iters):
    """Replay one pre-state through the oracle and every schedule x fabric."""
    rec_o, st_o, ar_o = commit.sequential_commit_execute(
        it, arena, p0, s0, max_iters=max_iters
    )
    mesh = jax.make_mesh((P,), ("mem",))
    for schedule, fabric in SCHEDULES:
        rec_d, st_d, ar_d = routing.distributed_execute(
            it, arena, p0, s0, mesh=mesh, max_iters=max_iters,
            compact=True, schedule=schedule, fabric=fabric,
        )
        tag = f"{name}/{schedule}/{fabric}"
        np.testing.assert_array_equal(rec_d, rec_o, err_msg=tag)
        np.testing.assert_array_equal(
            np.asarray(ar_d.data), np.asarray(ar_o.data), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(ar_d.heap), np.asarray(ar_o.heap), err_msg=tag
        )
        assert st_d.supersteps == st_o.supersteps, (tag, st_d, st_o)
        assert st_d.total_wire_words == st_o.total_wire_words, (tag, st_d, st_o)
        assert st_d.commits == st_o.commits, (tag, st_d.commits, st_o.commits)
        assert st_d.epochs == st_o.epochs, (tag, st_d.epochs, st_o.epochs)
    return rec_o, st_o, ar_o


def check_chain_mixed_rw():
    """Mixed find/insert/delete racing in ONE batch on an interleaved list."""
    n, B = 64, 48
    b = ArenaBuilder(256, 4, num_shards=P, policy="interleaved")
    keys = np.arange(10, 10 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys * 3)
    ar = b.finish()
    it = linked_list.rw_iterator()
    ops = np.tile([1, 0, 2, 0], B // 4).astype(np.int32)
    # victim discipline (per-node locks are future work): racing deletes must
    # not target list-adjacent nodes, the head, or the tail region where the
    # racing inserts CAS -- pick every 4th middle key
    del_keys = keys[4 : 4 + 4 * (B // 4) : 4]
    find_keys = keys[np.setdiff1d(RNG.permutation(n)[: B], np.arange(4, n, 4))][: B // 2]
    qk = np.empty(B, np.int32)
    qk[ops == 1] = np.arange(B // 4) + 1000  # fresh keys to insert
    qk[ops == 2] = del_keys[: B // 4]
    qk[ops == 0] = np.resize(find_keys, B // 2)
    qv = (np.arange(B) + 7).astype(np.int32)
    p0, s0 = it.init(ops, qk, qv, head)
    rec, st, ar_o = _assert_matches_oracle("list-rw", it, ar, p0, s0, max_iters=4096)
    assert (rec[:, routing.F_STATUS] == STATUS_DONE).all()
    assert st.commits > 0 and st.epochs > 0
    # every inserted key findable, every deleted key gone, on the final heap
    fit = linked_list.find_iterator()
    ins_keys = qk[ops == 1]
    del_keys = qk[ops == 2]
    fp, fs = fit.init(jnp.asarray(np.concatenate([ins_keys, del_keys])), head)
    from repro.core.iterator import execute_batched

    _, fscr, _, _ = execute_batched(fit, ar_o, fp, fs, max_iters=4096)
    fscr = np.asarray(fscr)
    assert (fscr[: len(ins_keys), 2] == 1).all(), "inserted keys must be findable"
    assert (fscr[len(ins_keys):, 2] == 0).all(), "deleted keys must be gone"
    print(
        f"chain mixed-rw ok: steps={st.supersteps} commits={st.commits} "
        f"epochs={st.epochs} wire={st.total_wire_words}"
    )


def check_hash_mixed_rw():
    """Mixed ops against the sentinel-headed writable hash table."""
    n, B, NB = 48, 32, 16
    b = ArenaBuilder(256, 4, num_shards=P, policy="interleaved")
    keys = RNG.choice(np.arange(100, 10_000), n, replace=False).astype(np.int32)
    sent = hash_table.build_writable(b, keys, keys + 1, NB)
    ar = b.finish()
    it = hash_table.rw_iterator(NB)
    ops = np.tile([1, 0, 2, 0], B // 4).astype(np.int32)
    # victim discipline: one delete per bucket (chain-adjacent victims race),
    # and inserts target buckets disjoint from the delete buckets (a racing
    # insert CASes its bucket's tail, which must not be getting freed)
    kb = hash_table._np_hash(keys, NB)
    del_keys, used = [], set()
    for k, bk in zip(keys, kb):
        if int(bk) not in used:
            del_keys.append(int(k))
            used.add(int(bk))
        if len(del_keys) == B // 4:
            break
    ins_keys = []
    cand = 20_000
    while len(ins_keys) < B // 4:
        if int(hash_table._np_hash(np.asarray([cand], np.int32), NB)[0]) not in used:
            ins_keys.append(cand)
        cand += 1
    find_keys = [int(k) for k in keys if int(k) not in set(del_keys)][: B // 2]
    qk = np.empty(B, np.int32)
    qk[ops == 1] = ins_keys
    qk[ops == 2] = del_keys
    qk[ops == 0] = np.resize(np.asarray(find_keys, np.int32), B // 2)
    qv = (np.arange(B) + 5).astype(np.int32)
    p0, s0 = it.init(ops, qk, qv, sent)
    rec, st, ar_o = _assert_matches_oracle("hash-rw", it, ar, p0, s0, max_iters=4096)
    assert (rec[:, routing.F_STATUS] == STATUS_DONE).all()
    fit = hash_table.find_iterator(NB)
    fp, fs = fit.init(jnp.asarray(qk[ops == 1]), jnp.asarray(sent))
    from repro.core.iterator import execute_batched

    _, fscr, _, _ = execute_batched(fit, ar_o, fp, fs, max_iters=4096)
    assert (np.asarray(fscr)[:, 2] == 1).all()
    print(f"hash mixed-rw ok: steps={st.supersteps} commits={st.commits}")


def check_skiplist_insert_delete():
    """Sequenced skiplist workload: racing inserts, then non-adjacent racing
    deletes, each phase replayed through every schedule vs the oracle."""
    n = 40
    b = ArenaBuilder(256, 12, num_shards=P, policy="interleaved")
    keys = np.sort(RNG.choice(np.arange(0, 5000, 2), n, replace=False)).astype(np.int32)
    head = skiplist.build_into(b, keys, keys * 2)
    ar = b.finish()
    newk = (keys[:16] + 1).astype(np.int32)  # odd keys: absent, never adjacent
    it = skiplist.insert_iterator()
    p0, s0 = it.init(jnp.asarray(newk), jnp.asarray(newk * 2), head)
    rec, st, ar1 = _assert_matches_oracle("skip-insert", it, ar, p0, s0, max_iters=4096)
    assert (rec[:, routing.F_STATUS] == STATUS_DONE).all()
    # delete every other inserted key (victims separated by surviving keys)
    vict = newk[::2]
    dit = skiplist.delete_iterator()
    dp, ds = dit.init(jnp.asarray(vict), head)
    rec2, st2, ar2 = _assert_matches_oracle("skip-delete", dit, ar1, dp, ds, max_iters=4096)
    assert (rec2[:, routing.F_SCRATCH + skiplist.SD_RES] == 1).all()
    fit = skiplist.find_iterator()
    fp, fs = fit.init(jnp.asarray(np.concatenate([keys, newk[1::2]])), head)
    from repro.core.iterator import execute_batched

    _, fscr, _, _ = execute_batched(fit, ar2, fp, fs, max_iters=4096)
    assert (np.asarray(fscr)[:, 2] == 1).all()
    fp, fs = fit.init(jnp.asarray(vict), head)
    _, fscr, _, _ = execute_batched(fit, ar2, fp, fs, max_iters=4096)
    assert (np.asarray(fscr)[:, 2] == 0).all()
    print(
        f"skiplist insert+delete ok: commits={st.commits}+{st2.commits} "
        f"steps={st.supersteps}/{st2.supersteps}"
    )


def check_tree_updates():
    """bst + btree update-in-place, including racing writers to one key."""
    n = 96
    keys = np.sort(RNG.choice(np.arange(10**5), n, replace=False)).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    for name, mod, W in (("bst", bst, 4), ("btree", btree, 20)):
        b = ArenaBuilder(256, W, num_shards=P, policy="interleaved")
        root, _ = mod.build_into(b, keys, vals)
        ar = b.finish()
        it = mod.update_iterator()
        # 24 updates; three writers race on keys[0] -- the commit order's
        # (slot, id) serialization decides the survivor deterministically
        q = np.concatenate([[keys[0]] * 3, keys[1:20], keys[-2:]]).astype(np.int32)
        nv = (np.arange(len(q)) + 9000).astype(np.int32)
        p0, s0 = it.init(jnp.asarray(q), jnp.asarray(nv), root)
        rec, st, ar_o = _assert_matches_oracle(
            f"{name}-update", it, ar, p0, s0, max_iters=1024
        )
        assert (rec[:, routing.F_SCRATCH + mod.U_FOUND] == 1).all()
        fit = mod.find_iterator()
        fp, fs = fit.init(jnp.asarray(q[3:]), root)
        from repro.core.iterator import execute_batched

        _, fscr, fstatus, _ = execute_batched(fit, ar_o, fp, fs, max_iters=1024)
        if name == "bst":
            value, found = mod.result(jnp.asarray(fscr))
            np.testing.assert_array_equal(np.asarray(value), nv[3:])
        else:
            np.testing.assert_array_equal(np.asarray(fscr)[:, 1], nv[3:])
        print(f"{name} update ok: steps={st.supersteps} commits={st.commits}")


def check_write_permission_fault():
    """Commits on a PERM_WRITE-revoked shard must FAULT, identically on the
    oracle and every schedule."""
    n = 32
    b = ArenaBuilder(128, 4, num_shards=P, policy="interleaved")
    keys = np.arange(10, 10 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys)
    data = b.data.copy()
    heap = np.asarray(b.finish().heap)
    # revoke write on every shard: all ALLOC commits (home shards) fault
    perms = [PERM_READ] * P
    ar = make_arena(data, num_shards=P, perms=perms, heap=heap)
    it = linked_list.insert_iterator()
    p0, s0 = it.init(np.arange(8, dtype=np.int32) + 500, np.arange(8, dtype=np.int32), head)
    rec, st, ar_o = _assert_matches_oracle("perm-fault", it, ar, p0, s0, max_iters=512)
    assert (rec[:, routing.F_STATUS] == STATUS_FAULT).all()
    np.testing.assert_array_equal(np.asarray(ar_o.data), data)  # nothing written
    assert st.commits == 0
    print("write-permission fault ok")


def check_alloc_exhaustion_faults():
    """ALLOC on a full arena faults the record instead of clobbering rows."""
    n = 16
    cap = ((n + P - 1) // P) * P  # arena exactly full after the build
    b = ArenaBuilder(cap, 4, num_shards=P, policy="interleaved")
    keys = np.arange(10, 10 + n, dtype=np.int32)
    head = linked_list.build_into(b, keys, keys)
    ar = b.finish()
    it = linked_list.insert_iterator()
    p0, s0 = it.init(np.arange(4, dtype=np.int32) + 900, np.arange(4, dtype=np.int32), head)
    rec, st, ar_o = _assert_matches_oracle("alloc-exhaust", it, ar, p0, s0, max_iters=512)
    assert (rec[:, routing.F_STATUS] == STATUS_FAULT).all()
    np.testing.assert_array_equal(np.asarray(ar_o.data), np.asarray(ar.data))
    print("alloc exhaustion fault ok")


def check_service_async_sync_identity():
    """PulseService over the 8-shard mesh serving a mixed read/write quantum
    stream: the async device-runner pipeline must match the synchronous loop
    bit for bit on results, commits, and the final arena (data + heap).
    ALLOC addresses depend on write-batch composition, so this also pins the
    admission schedule itself."""
    from repro.core.engine import PulseEngine  # noqa: E402
    from repro.serving.admission import TraversalRequest  # noqa: E402
    from repro.serving.traversal_service import (  # noqa: E402
        PulseService,
        StructureSpec,
    )

    keys = np.arange(100, 164, dtype=np.int32)

    def serve(pipeline):
        b = ArenaBuilder(512, 4, num_shards=P, policy="interleaved")
        head = linked_list.build_into(b, keys, keys * 2)
        eng = PulseEngine(b.finish(), mesh=jax.make_mesh((P,), ("mem",)))
        svc = PulseService(
            eng,
            {
                "list": StructureSpec(
                    linked_list.find_iterator(), (head,), group="list"
                ),
                "list_ins": StructureSpec(
                    linked_list.insert_iterator(), (head,), group="list",
                    takes_value=True,
                ),
            },
            slots_per_structure=8,
            quantum=6,
            pipeline=pipeline,
        )
        reqs = []
        for i in range(36):
            if i % 4 == 2:
                reqs.append(
                    TraversalRequest(
                        i, "list_ins", 1000 + i, value=i * 11,
                        tenant="w", arrive_round=i // 8,
                    )
                )
            else:
                reqs.append(
                    TraversalRequest(
                        i, "list", int(keys[(i * 7) % len(keys)]),
                        tenant="r", arrive_round=i // 8,
                    )
                )
        m = svc.run(reqs)
        return reqs, m, eng.arena

    ra, ma, ar_a = serve("sync")
    rb, mb, ar_b = serve("async")
    assert ma.rounds == mb.rounds, (ma.rounds, mb.rounds)
    assert ma.engine_calls == mb.engine_calls
    assert ma.commits == mb.commits and ma.commits > 0, (ma.commits, mb.commits)
    assert ma.writes_retired == mb.writes_retired == 9
    for a, b_ in zip(ra, rb):
        assert (a.status, a.iters, a.finish_round) == (
            b_.status, b_.iters, b_.finish_round,
        ), a.req_id
        np.testing.assert_array_equal(a.result, b_.result, err_msg=str(a.req_id))
    np.testing.assert_array_equal(np.asarray(ar_a.data), np.asarray(ar_b.data))
    np.testing.assert_array_equal(np.asarray(ar_a.heap), np.asarray(ar_b.heap))
    print(
        f"service async/sync identity ok: rounds={ma.rounds} "
        f"commits={ma.commits} retired={ma.retired}"
    )


def check_service_chaos_recovery():
    """Kill a shard mid-stream under the full serving stack (8-shard mesh,
    mixed read/write, snapshots + commit log): after snapshot-restore + log
    replay + in-flight re-execution, the final arena AND every request's
    (status, result) must be bit-identical to the failure-free run -- zero
    acknowledged commits lost."""
    import tempfile

    from repro.core.engine import PulseEngine  # noqa: E402
    from repro.core.faults import FaultInjector, FaultPlan  # noqa: E402
    from repro.distributed.arena_ft import (  # noqa: E402
        ArenaStore,
        FaultToleranceConfig,
    )
    from repro.serving.admission import TraversalRequest  # noqa: E402
    from repro.serving.traversal_service import (  # noqa: E402
        PulseService,
        StructureSpec,
    )

    keys = np.arange(100, 164, dtype=np.int32)

    def serve(tmp, plan, pipeline):
        b = ArenaBuilder(512, 4, num_shards=P, policy="interleaved")
        head = linked_list.build_into(b, keys, keys * 2)
        inj = FaultInjector(plan) if plan is not None else None
        eng = PulseEngine(
            b.finish(), mesh=jax.make_mesh((P,), ("mem",)), fault_injector=inj
        )
        # baseline-only snapshots (cadence larger than the workload): every
        # acknowledged write quantum sits in the commit log, so recovery
        # MUST replay -- replayed_commits > 0 is then deterministic, not a
        # kill-point/snapshot-cadence alignment accident
        ft = FaultToleranceConfig(store=ArenaStore(tmp), snapshot_every=100)
        svc = PulseService(
            eng,
            {
                "list": StructureSpec(
                    linked_list.find_iterator(), (head,), group="list"
                ),
                "list_ins": StructureSpec(
                    linked_list.insert_iterator(), (head,), group="list",
                    takes_value=True,
                ),
            },
            slots_per_structure=8,
            quantum=6,
            pipeline=pipeline,
            fault_tolerance=ft,
        )
        reqs = []
        for i in range(36):
            if i % 4 == 2:
                reqs.append(
                    TraversalRequest(
                        i, "list_ins", 1000 + i, value=i * 11,
                        tenant="w", arrive_round=i // 8,
                    )
                )
            else:
                reqs.append(
                    TraversalRequest(
                        i, "list", int(keys[(i * 7) % len(keys)]),
                        tenant="r", arrive_round=i // 8,
                    )
                )
        m = svc.run(reqs)
        ft.store.close()
        return reqs, m, eng.arena

    # kill late enough that acknowledged commits sit in the log past the
    # latest snapshot: recovery must actually replay them (replayed > 0)
    plan = FaultPlan(kill_shard=3, kill_call=30, kill_superstep=2)
    for pipeline in ("sync", "async"):
        with tempfile.TemporaryDirectory() as d0, \
                tempfile.TemporaryDirectory() as d1:
            r0, m0, ar0 = serve(d0, None, pipeline)
            r1, m1, ar1 = serve(d1, plan, pipeline)
            tag = f"chaos/{pipeline}"
            assert m1.recoveries == 1, (tag, m1.recoveries)
            assert m1.retries > 0, tag
            assert m1.replayed_commits > 0, tag
            assert m0.recoveries == 0 and m0.retries == 0
            assert m1.completed == m0.completed == 36, tag
            assert m1.commits == m0.commits and m1.commits > 0, tag
            for a, b_ in zip(r0, r1):
                assert a.status == b_.status, (tag, a.req_id)
                np.testing.assert_array_equal(
                    a.result, b_.result, err_msg=f"{tag}/{a.req_id}"
                )
            np.testing.assert_array_equal(
                np.asarray(ar0.data), np.asarray(ar1.data), err_msg=tag
            )
            np.testing.assert_array_equal(
                np.asarray(ar0.heap), np.asarray(ar1.heap), err_msg=tag
            )
            print(
                f"service chaos recovery ok ({pipeline}): recoveries=1 "
                f"retries={m1.retries} replayed={m1.replayed_commits} "
                f"mean_recovery={m1.mean_recovery_ms:.0f}ms"
            )


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_chain_mixed_rw()
    check_hash_mixed_rw()
    check_skiplist_insert_delete()
    check_tree_updates()
    check_write_permission_fault()
    check_alloc_exhaustion_faults()
    check_service_async_sync_identity()
    check_service_chaos_recovery()
    print("ALL WRITE-PATH CHECKS PASSED")
