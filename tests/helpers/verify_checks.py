"""pulse-verify specialization checks on an 8-shard mesh.

Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so in-process tests keep seeing 1 device (per the dry-run isolation rule).

The acceptance gate for the analysis-driven hot-path specialization: a
verified read-only ISA program runs with the per-hop access-table probe
elided (and without mutation record lanes), and the results are
bit-identical to

  * the unspecialized distributed path (``elide_access_check=False``),
  * the single-device batched oracle (``iterator.execute_batched``),
  * the sequential-commit oracle (``commit.sequential_commit_execute``),

across dispatched/fused/pipelined schedules x dense/ring fabrics.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import isa, routing  # noqa: E402
from repro.core.commit import sequential_commit_execute  # noqa: E402
from repro.core.iterator import execute_batched  # noqa: E402
from repro.core.routing import F_ID, F_ITERS, F_PTR, F_SCRATCH, F_STATUS  # noqa: E402
from repro.core.structures import isa_programs, linked_list  # noqa: E402

RNG = np.random.default_rng(23)
P = 8
# payload columns per the bit-identity protocol: F_HOME/F_HOPS are routing
# metadata and may differ across schedules; everything else must match
PAYLOAD = [F_ID, F_PTR, F_STATUS, F_ITERS]


def mesh():
    return jax.make_mesh((P,), ("mem",))


def payload(rec, S):
    rec = np.asarray(rec)
    return np.concatenate(
        [rec[:, PAYLOAD], rec[:, F_SCRATCH : F_SCRATCH + S]], axis=1
    )


def build_list(n=400):
    keys = RNG.choice(np.arange(0, 10**6), size=n, replace=False).astype(np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P)
    queries = np.concatenate(
        [keys[:: max(1, n // 64)][:64], RNG.integers(0, 10**4, 64).astype(np.int32)]
    )
    ptr0, scr0 = linked_list.find_iterator().init(jnp.asarray(queries), head)
    return ar, ptr0, scr0


def check_readonly_specialization_bit_identity():
    """Elided vs unspecialized vs both oracles, all schedules x fabrics."""
    ar, ptr0, scr0 = build_list()
    vm = isa.as_pulse_iterator(isa_programs.list_find_program())
    S = vm.scratch_words
    assert vm.facts is not None and vm.facts.read_only
    assert routing.can_elide_access_check(vm, ar)

    o_ptr, o_scr, o_status, o_iters = execute_batched(
        vm, ar, ptr0, scr0, max_iters=1024
    )
    rec_sc, _ = sequential_commit_execute(
        vm, ar, ptr0, scr0, max_iters=1024, k_local=4, compact=True
    )
    base = payload(rec_sc, S)
    np.testing.assert_array_equal(base[:, 1], np.asarray(o_ptr))
    np.testing.assert_array_equal(base[:, 2], np.asarray(o_status))
    np.testing.assert_array_equal(base[:, 3], np.asarray(o_iters))
    np.testing.assert_array_equal(base[:, 4:], np.asarray(o_scr))

    m = mesh()
    for sched in ("dispatched", "fused", "pipelined"):
        for fabric in ("dense", "ring"):
            rec_e, st_e = routing.distributed_execute(
                vm, ar, ptr0, scr0, mesh=m, axis_name="mem", max_iters=1024,
                k_local=4, compact=True, schedule=sched, fabric=fabric,
            )
            rec_u, st_u = routing.distributed_execute(
                vm, ar, ptr0, scr0, mesh=m, axis_name="mem", max_iters=1024,
                k_local=4, compact=True, schedule=sched, fabric=fabric,
                elide_access_check=False,
            )
            np.testing.assert_array_equal(np.asarray(rec_e), np.asarray(rec_u))
            np.testing.assert_array_equal(payload(rec_e, S), base)
            assert st_e.supersteps == st_u.supersteps
            assert st_e.total_wire_words == st_u.total_wire_words
            print(f"  {sched}/{fabric}: bit-identical "
                  f"({st_e.supersteps} supersteps)")
    print("readonly specialization bit-identity: PASS")


def check_dead_store_lane_skip():
    """A dead store-class op must not force the mutating record format.

    ``verify=False`` (the conservative ``Program.mutates`` opcode scan)
    routes the dead-store variant down the write path -- wider records on
    every fabric crossing, write barriers armed -- yet the store never
    executes, so results match the verified read path exactly.  The wire
    gap IS the lane-skip saving; pulse-verify itself rejects the variant
    (dead code), pointing at the dead store.
    """
    from repro.core.verify import E_UNREACHABLE, VerifyError, verify_program

    prog = isa_programs.list_find_program()
    dead = isa.Program(
        code=np.vstack([prog.code, [[isa.STOREN, 2, 0, 1]]]),
        scratch_words=prog.scratch_words,
        node_words=prog.node_words,
        name="list_find_dead_store",
    )
    assert dead.mutates  # the conservative opcode scan over-approximates
    try:
        verify_program(dead)
        raise AssertionError("dead-store program must be rejected")
    except VerifyError as e:
        assert E_UNREACHABLE in e.codes
        assert any(d.pc == len(dead) - 1 for d in e.diagnostics)

    ar, ptr0, scr0 = build_list(200)
    vm_ro = isa.as_pulse_iterator(prog)
    vm_rw = isa.as_pulse_iterator(dead, verify=False)
    assert not vm_ro.mutates and vm_rw.mutates
    S = vm_ro.scratch_words

    m = mesh()
    rec_ro, st_ro = routing.distributed_execute(
        vm_ro, ar, ptr0, scr0, mesh=m, axis_name="mem", max_iters=1024,
        k_local=4, compact=True, schedule="fused",
    )
    rec_rw, st_rw, ar_rw = routing.distributed_execute(
        vm_rw, ar, ptr0, scr0, mesh=m, axis_name="mem", max_iters=1024,
        k_local=4, compact=True, schedule="fused",
    )
    np.testing.assert_array_equal(payload(rec_ro, S), payload(rec_rw, S))
    np.testing.assert_array_equal(np.asarray(ar_rw.data), np.asarray(ar.data))
    assert st_ro.total_wire_words < st_rw.total_wire_words, (
        st_ro.total_wire_words, st_rw.total_wire_words,
    )
    saved = 1 - st_ro.total_wire_words / st_rw.total_wire_words
    print(f"dead-store lane skip: PASS (wire words -{saved:.0%})")


def check_elision_refused_when_unprovable():
    """No certificate, revoked perms, or a mutating program => no elision."""
    from repro.core.arena import PERM_WRITE

    ar, _, _ = build_list(100)
    vm = isa.as_pulse_iterator(isa_programs.list_find_program())
    traced = linked_list.find_iterator()  # hand-written JAX: facts is None
    assert not routing.can_elide_access_check(traced, ar)
    unverified = isa.as_pulse_iterator(
        isa_programs.list_find_program(), verify=False
    )
    assert not routing.can_elide_access_check(unverified, ar)
    mut = isa.as_pulse_iterator(isa_programs.bst_update_program())
    assert not routing.can_elide_access_check(mut, ar)
    # revoke PERM_READ on one shard: the probe is no longer constant-true
    import dataclasses as _dc

    perms = np.asarray(ar.perms).copy()
    perms[3] = PERM_WRITE
    ar_revoked = _dc.replace(ar, perms=jnp.asarray(perms))
    assert not routing.can_elide_access_check(vm, ar_revoked)
    assert routing.can_elide_access_check(vm, ar)
    print("elision refusal (no proof): PASS")


if __name__ == "__main__":
    check_elision_refused_when_unprovable()
    check_dead_store_lane_skip()
    check_readonly_specialization_bit_identity()
    print("ALL VERIFY SPECIALIZATION CHECKS PASSED")
