"""Compacted-superstep equivalence checks (4 emulated devices, small sizes --
the fast-lane companion to helpers/distributed_checks.py)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import routing  # noqa: E402
from repro.core.iterator import execute_batched  # noqa: E402
from repro.core.structures import (  # noqa: E402
    bst,
    btree,
    hash_table,
    linked_list,
    skiplist,
)

RNG = np.random.default_rng(5)
P = 4


def check_compact_equals_uncompacted():
    """Compaction must be schedule-only: identical ptr/scratch/status/iters,
    strictly less total wire, on a skewed (half-shallow/half-deep) workload."""
    n, B = 192, 64
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = np.concatenate(
        [RNG.integers(0, 8, B // 2), RNG.integers(n - 32, n, B // 2)]
    ).astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))

    o_ptr, o_scr, o_status, o_iters = execute_batched(it, ar, ptr0, scr0, max_iters=4096)

    rec_u, st_u = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, k_local=4, compact=False
    )
    rec_c, st_c = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, k_local=4, compact=True
    )
    for rec in (rec_u, rec_c):
        np.testing.assert_array_equal(rec[:, routing.F_SCRATCH:], np.asarray(o_scr))
        np.testing.assert_array_equal(rec[:, routing.F_STATUS], np.asarray(o_status))
        np.testing.assert_array_equal(rec[:, routing.F_ITERS], np.asarray(o_iters))
    assert st_c.total_wire_words < st_u.total_wire_words, (
        st_c.total_wire_words,
        st_u.total_wire_words,
    )
    # once half the batch has finished, the compacted payload must shrink:
    # every routed superstep past that point ships at a reduced capacity
    half_idx = next(i for i, a in enumerate(st_c.active_per_step) if a <= B // 2)
    base = st_u.wire_words_per_step[0]
    tail = [w for w in st_c.wire_words_per_step[half_idx:]]
    assert float(np.mean(tail)) <= 0.7 * base, (np.mean(tail), base)
    print(
        f"compact ok: wire {st_c.total_wire_words} < {st_u.total_wire_words}, "
        f"local_only={st_c.local_only_steps}/{st_c.supersteps}"
    )


def check_compact_handles_faults():
    """FAULTed traversals must retire in place without being lost."""
    n, B = 64, 16
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 100, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P)
    it = linked_list.find_iterator()
    q = keys[RNG.integers(0, n, B)].astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    # corrupt half the start pointers -> switch-level fault
    ptr0 = jnp.asarray(np.where(np.arange(B) % 2 == 0, 10**6, np.asarray(ptr0)))
    mesh = jax.make_mesh((P,), ("mem",))
    rec, st = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=256, compact=True
    )
    assert rec.shape[0] == B, "conservation under compaction"
    from repro.core.iterator import STATUS_DONE, STATUS_FAULT

    assert (rec[::2, routing.F_STATUS] == STATUS_FAULT).all()
    assert (rec[1::2, routing.F_STATUS] == STATUS_DONE).all()
    print("compact fault ok")


def _five_structures(n=96, B=32):
    """(name, iterator, arena, ptr0, scratch0, max_iters) for every structure
    family, interleaved across shards, with a hit/miss query mix."""
    vals = RNG.integers(0, 10**6, n).astype(np.int32)
    cases = []

    keys = np.arange(n, dtype=np.int32)
    ar, head = linked_list.build(keys, vals, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = np.concatenate([keys[RNG.integers(0, n, B - 4)], np.full(4, 10**6)])
    p0, s0 = it.init(jnp.asarray(q.astype(np.int32)), head)
    cases.append(("list", it, ar, p0, s0, 4096))

    keys = np.sort(RNG.choice(np.arange(10**6), n, replace=False).astype(np.int32))
    ar, root, _ = bst.build(keys, vals, num_shards=P, policy="interleaved")
    it = bst.find_iterator()
    q = np.concatenate([keys[: B // 2], RNG.integers(10**6, 2 * 10**6, B // 2)])
    p0, s0 = it.init(jnp.asarray(q.astype(np.int32)), root)
    cases.append(("bst", it, ar, p0, s0, 256))

    ar, root, _ = btree.build(keys, vals, num_shards=P, policy="interleaved")
    it = btree.find_iterator()
    p0, s0 = it.init(jnp.asarray(q.astype(np.int32)), root)
    cases.append(("btree", it, ar, p0, s0, 64))

    ar, heads = hash_table.build(keys, vals, 16, num_shards=P, policy="interleaved")
    it = hash_table.find_iterator(16)
    p0, s0 = it.init(jnp.asarray(q.astype(np.int32)), jnp.asarray(heads))
    cases.append(("hash", it, ar, p0, s0, 1024))

    ar, shead = skiplist.build(keys, vals, num_shards=P, policy="interleaved")
    it = skiplist.find_iterator()
    p0, s0 = it.init(jnp.asarray(q.astype(np.int32)), shead)
    cases.append(("skip", it, ar, p0, s0, 1024))
    return cases


def check_fused_equivalence_all_structures():
    """The fused device-resident loop must be bit-identical to the PR 1
    host-dispatched compacted schedule AND to the BSP oracle, for all five
    structure families -- including crossings and the schedule itself
    (supersteps / wire words / local-only counts), since the fused loop
    re-derives the exact same ladder decisions on-device."""
    mesh = jax.make_mesh((P,), ("mem",))
    for name, it, ar, p0, s0, max_iters in _five_structures():
        o_ptr, o_scr, o_status, o_iters = execute_batched(
            it, ar, p0, s0, max_iters=max_iters
        )
        rec_d, st_d = routing.distributed_execute(
            it, ar, p0, s0, mesh=mesh, max_iters=max_iters, compact=True, fused=False
        )
        rec_f, st_f = routing.distributed_execute(
            it, ar, p0, s0, mesh=mesh, max_iters=max_iters, compact=True, fused=True
        )
        # full wire records (id/home/ptr/status/iters/hops/scratch) identical
        np.testing.assert_array_equal(rec_f, rec_d, err_msg=name)
        np.testing.assert_array_equal(
            rec_f[:, routing.F_SCRATCH:], np.asarray(o_scr), err_msg=name
        )
        np.testing.assert_array_equal(
            rec_f[:, routing.F_STATUS], np.asarray(o_status), err_msg=name
        )
        np.testing.assert_array_equal(
            rec_f[:, routing.F_ITERS], np.asarray(o_iters), err_msg=name
        )
        assert st_f.supersteps == st_d.supersteps, (name, st_f, st_d)
        assert st_f.total_wire_words == st_d.total_wire_words, (name, st_f, st_d)
        assert st_f.local_only_steps == st_d.local_only_steps, (name, st_f, st_d)
        print(
            f"fused {name} ok: steps={st_f.supersteps} "
            f"wire={st_f.total_wire_words} local_only={st_f.local_only_steps}"
        )


def check_pipelined_equivalence_all_structures():
    """The wavefront-pipelined schedule (and its ppermute-ring fabric) must
    be bit-identical to the fused serialized schedule AND the BSP oracle for
    all five structure families: full wire records (id/home/ptr/status/iters/
    hops/scratch), superstep counts, wire words, and local-only counts.  The
    pipelined loop re-derives the exact same ladder decisions from the same
    stale-by-one merged counts; overlap only reorders independent dataflow."""
    mesh = jax.make_mesh((P,), ("mem",))
    for name, it, ar, p0, s0, max_iters in _five_structures():
        o_ptr, o_scr, o_status, o_iters = execute_batched(
            it, ar, p0, s0, max_iters=max_iters
        )
        rec_f, st_f = routing.distributed_execute(
            it, ar, p0, s0, mesh=mesh, max_iters=max_iters, compact=True,
            schedule="fused",
        )
        for fabric in ("dense", "ring"):
            rec_p, st_p = routing.distributed_execute(
                it, ar, p0, s0, mesh=mesh, max_iters=max_iters, compact=True,
                schedule="pipelined", fabric=fabric,
            )
            tag = f"{name}/{fabric}"
            np.testing.assert_array_equal(rec_p, rec_f, err_msg=tag)
            np.testing.assert_array_equal(
                rec_p[:, routing.F_SCRATCH:], np.asarray(o_scr), err_msg=tag
            )
            np.testing.assert_array_equal(
                rec_p[:, routing.F_STATUS], np.asarray(o_status), err_msg=tag
            )
            np.testing.assert_array_equal(
                rec_p[:, routing.F_ITERS], np.asarray(o_iters), err_msg=tag
            )
            assert st_p.supersteps == st_f.supersteps, (tag, st_p, st_f)
            assert st_p.total_wire_words == st_f.total_wire_words, (tag, st_p, st_f)
            assert st_p.local_only_steps == st_f.local_only_steps, (tag, st_p, st_f)
            assert st_p.schedule == "pipelined" and st_p.fabric == fabric
        print(
            f"pipelined {name} ok (dense+ring): steps={st_p.supersteps} "
            f"wire={st_p.total_wire_words} local_only={st_p.local_only_steps}"
        )


def check_pipelined_kernel_local_backend():
    """Threading the local chase through the pulse_chase kernel's vectorized
    iterator body must not change a bit (list exercises the next/end pair,
    btree the step_fn ISA path)."""
    mesh = jax.make_mesh((P,), ("mem",))
    for name, it, ar, p0, s0, max_iters in _five_structures()[:3]:
        rec_x, st_x = routing.distributed_execute(
            it, ar, p0, s0, mesh=mesh, max_iters=max_iters, compact=True,
            schedule="pipelined", local_backend="xla",
        )
        rec_k, st_k = routing.distributed_execute(
            it, ar, p0, s0, mesh=mesh, max_iters=max_iters, compact=True,
            schedule="pipelined", local_backend="kernel",
        )
        np.testing.assert_array_equal(rec_k, rec_x, err_msg=name)
        assert st_k.supersteps == st_x.supersteps, name
    print("pipelined kernel local-backend ok (3 structures)")


def check_pipelined_handles_faults():
    """Switch-level faults retire identically on the pipelined path."""
    n, B = 64, 16
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 100, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P)
    it = linked_list.find_iterator()
    q = keys[RNG.integers(0, n, B)].astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    ptr0 = jnp.asarray(np.where(np.arange(B) % 2 == 0, 10**6, np.asarray(ptr0)))
    mesh = jax.make_mesh((P,), ("mem",))
    rec_f, _ = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=256, compact=True, schedule="fused"
    )
    rec_p, _ = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=256, compact=True,
        schedule="pipelined",
    )
    np.testing.assert_array_equal(rec_p, rec_f)
    from repro.core.iterator import STATUS_FAULT

    assert (rec_p[::2, routing.F_STATUS] == STATUS_FAULT).all()
    print("pipelined fault ok")


def check_fused_handles_faults():
    """Switch-level faults retire identically on the fused path."""
    n, B = 64, 16
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 100, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P)
    it = linked_list.find_iterator()
    q = keys[RNG.integers(0, n, B)].astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    ptr0 = jnp.asarray(np.where(np.arange(B) % 2 == 0, 10**6, np.asarray(ptr0)))
    mesh = jax.make_mesh((P,), ("mem",))
    rec_d, _ = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=256, compact=True, fused=False
    )
    rec_f, _ = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=256, compact=True, fused=True
    )
    np.testing.assert_array_equal(rec_f, rec_d)
    from repro.core.iterator import STATUS_FAULT

    assert (rec_f[::2, routing.F_STATUS] == STATUS_FAULT).all()
    print("fused fault ok")


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_compact_equals_uncompacted()
    check_compact_handles_faults()
    check_fused_equivalence_all_structures()
    check_fused_handles_faults()
    check_pipelined_equivalence_all_structures()
    check_pipelined_kernel_local_backend()
    check_pipelined_handles_faults()
    print("ALL COMPACTION CHECKS PASSED")
