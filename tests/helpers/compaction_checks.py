"""Compacted-superstep equivalence checks (4 emulated devices, small sizes --
the fast-lane companion to helpers/distributed_checks.py)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import routing  # noqa: E402
from repro.core.iterator import execute_batched  # noqa: E402
from repro.core.structures import linked_list  # noqa: E402

RNG = np.random.default_rng(5)
P = 4


def check_compact_equals_uncompacted():
    """Compaction must be schedule-only: identical ptr/scratch/status/iters,
    strictly less total wire, on a skewed (half-shallow/half-deep) workload."""
    n, B = 192, 64
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P, policy="interleaved")
    it = linked_list.find_iterator()
    q = np.concatenate(
        [RNG.integers(0, 8, B // 2), RNG.integers(n - 32, n, B // 2)]
    ).astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    mesh = jax.make_mesh((P,), ("mem",))

    o_ptr, o_scr, o_status, o_iters = execute_batched(it, ar, ptr0, scr0, max_iters=4096)

    rec_u, st_u = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, k_local=4, compact=False
    )
    rec_c, st_c = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=4096, k_local=4, compact=True
    )
    for rec in (rec_u, rec_c):
        np.testing.assert_array_equal(rec[:, routing.F_SCRATCH:], np.asarray(o_scr))
        np.testing.assert_array_equal(rec[:, routing.F_STATUS], np.asarray(o_status))
        np.testing.assert_array_equal(rec[:, routing.F_ITERS], np.asarray(o_iters))
    assert st_c.total_wire_words < st_u.total_wire_words, (
        st_c.total_wire_words,
        st_u.total_wire_words,
    )
    # once half the batch has finished, the compacted payload must shrink:
    # every routed superstep past that point ships at a reduced capacity
    half_idx = next(i for i, a in enumerate(st_c.active_per_step) if a <= B // 2)
    base = st_u.wire_words_per_step[0]
    tail = [w for w in st_c.wire_words_per_step[half_idx:]]
    assert float(np.mean(tail)) <= 0.7 * base, (np.mean(tail), base)
    print(
        f"compact ok: wire {st_c.total_wire_words} < {st_u.total_wire_words}, "
        f"local_only={st_c.local_only_steps}/{st_c.supersteps}"
    )


def check_compact_handles_faults():
    """FAULTed traversals must retire in place without being lost."""
    n, B = 64, 16
    keys = np.arange(n, dtype=np.int32)
    values = RNG.integers(0, 100, n).astype(np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P)
    it = linked_list.find_iterator()
    q = keys[RNG.integers(0, n, B)].astype(np.int32)
    ptr0, scr0 = it.init(jnp.asarray(q), head)
    # corrupt half the start pointers -> switch-level fault
    ptr0 = jnp.asarray(np.where(np.arange(B) % 2 == 0, 10**6, np.asarray(ptr0)))
    mesh = jax.make_mesh((P,), ("mem",))
    rec, st = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh, max_iters=256, compact=True
    )
    assert rec.shape[0] == B, "conservation under compaction"
    from repro.core.iterator import STATUS_DONE, STATUS_FAULT

    assert (rec[::2, routing.F_STATUS] == STATUS_FAULT).all()
    assert (rec[1::2, routing.F_STATUS] == STATUS_DONE).all()
    print("compact fault ok")


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_compact_equals_uncompacted()
    check_compact_handles_faults()
    print("ALL COMPACTION CHECKS PASSED")
