"""Elasticity + replication service matrix (8 host devices, subprocess).

Covers the service-level contracts that need a real 8-shard mesh:

  * hot-shard replication under a mid-stream primary kill -- recovery is
    log-shipped, the replica stays bit-identical to the primary, and the
    mixed run matches the failure-free run bit-for-bit;
  * read fan-out -- a read-only workload rides out a primary kill with
    ZERO retries (no STATUS_RETRY ever surfaces to a read tenant);
  * watchdog escalation -- an attributable-delay straggler (no kill, so
    the positive ShardFailure signal never fires) is probed, suspected,
    and fanned around, again with zero read retries;
  * live 2x reshard -- a 4 -> 8 shard change mid-stream (sync + async
    pipelines, read-only + read-write) drains, cuts over, and finishes
    bit-identical to a cold run at 8 shards.

Run via ``tests/test_elastic.py`` (subprocess, own XLA device count) or
directly: ``PYTHONPATH=src python tests/helpers/elastic_checks.py``.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile

import numpy as np
import jax

from repro.core.arena import ArenaBuilder, remap_shards
from repro.core.engine import PulseEngine
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.iterator import STATUS_DONE
from repro.core.structures import bst, linked_list
from repro.distributed.arena_ft import (
    ArenaStore,
    FaultToleranceConfig,
    ReplicationConfig,
)
from repro.serving.admission import TraversalRequest
from repro.serving.traversal_service import PulseService, StructureSpec

P = 8
KEYS = np.arange(100, 164, dtype=np.int32)


def build_list():
    b = ArenaBuilder(512, 4, num_shards=P, policy="interleaved")
    head = linked_list.build_into(b, KEYS, KEYS * 2)
    return b.finish(), head


def make_reqs(n=36):
    reqs = []
    for i in range(n):
        if i % 4 == 2:
            reqs.append(TraversalRequest(
                i, "list_ins", 1000 + i, value=i * 11,
                tenant="w", arrive_round=i // 8,
            ))
        else:
            reqs.append(TraversalRequest(
                i, "list", int(KEYS[(i * 7) % len(KEYS)]),
                tenant="r", arrive_round=i // 8,
            ))
    return reqs


def serve_rep(tmp, plan, pipeline, *, dead_rounds=3, watchdog=0.0,
              reads_only=False):
    arena, head = build_list()
    inj = FaultInjector(plan) if plan is not None else None
    eng = PulseEngine(arena, mesh=jax.make_mesh((P,), ("mem",)),
                      fault_injector=inj)
    ft = FaultToleranceConfig(
        store=ArenaStore(tmp), snapshot_every=100, dead_rounds=dead_rounds,
        replication=ReplicationConfig(policy="failover"),
        watchdog_timeout_s=watchdog,
    )
    svc = PulseService(
        eng,
        {
            "list": StructureSpec(linked_list.find_iterator(), (head,),
                                  group="list"),
            "list_ins": StructureSpec(linked_list.insert_iterator(), (head,),
                                      group="list", takes_value=True),
        },
        slots_per_structure=8, quantum=6, pipeline=pipeline,
        fault_tolerance=ft,
    )
    reqs = make_reqs()
    if reads_only:
        reqs = [r for r in reqs if r.tenant == "r"]
    m = svc.run(reqs)
    rep = svc._replicas
    ft.store.close()
    return reqs, m, eng.arena, rep


def check_replication_failover(pipeline):
    """Mixed read/write workload, primary killed mid-stream: recovery +
    log-shipped replica, everything bit-identical to the clean run."""
    plan = FaultPlan(kill_shard=3, kill_call=4, kill_superstep=2)
    with tempfile.TemporaryDirectory() as d0, \
            tempfile.TemporaryDirectory() as d1:
        r0, m0, ar0, rep0 = serve_rep(d0, None, pipeline)
        r1, m1, ar1, rep1 = serve_rep(d1, plan, pipeline)
    tag = f"rep-failover/{pipeline}"
    assert m1.recoveries == 1, (tag, m1.recoveries)
    assert m1.replica_quanta > 0 and m0.replica_quanta > 0
    # read-only tenants: zero retries, all DONE
    for r in r1:
        if r.tenant == "r":
            assert r.status == STATUS_DONE, (tag, r.req_id, r.status)
            assert r.retries == 0, (tag, r.req_id, r.retries)
    assert m1.completed == m0.completed == 36, (tag, m1.completed)
    for a, b in zip(r0, r1):
        assert a.status == b.status, (tag, a.req_id)
        np.testing.assert_array_equal(a.result, b.result,
                                      err_msg=f"{tag}/{a.req_id}")
    np.testing.assert_array_equal(np.asarray(ar0.data), np.asarray(ar1.data),
                                  err_msg=tag)
    np.testing.assert_array_equal(np.asarray(ar0.heap), np.asarray(ar1.heap),
                                  err_msg=tag)
    # replica is still bit-identical to the primary after everything
    rep1.verify(ar1)
    print(f"{tag} ok: retries={m1.retries} recoveries={m1.recoveries} "
          f"replica_quanta={m1.replica_quanta}")


def check_readonly_zero_retry(pipeline):
    """Kill a primary while only read tenants are in flight: reads fan out
    to the replica with zero STATUS_RETRY / zero retries charged."""
    plan = FaultPlan(kill_shard=3, kill_call=4, kill_superstep=2)
    with tempfile.TemporaryDirectory() as d0, \
            tempfile.TemporaryDirectory() as d1:
        r0, m0, ar0, _ = serve_rep(d0, None, pipeline, reads_only=True)
        r1, m1, ar1, _ = serve_rep(d1, plan, pipeline, dead_rounds=6,
                                   reads_only=True)
    tag = f"rep-zero-retry/{pipeline}"
    assert m1.recoveries == 1, (tag, m1.recoveries)
    assert m1.failover_quanta >= 1, (tag, m1.failover_quanta)
    assert m1.retries == 0, (tag, m1.retries)
    assert m1.retry_exhausted == 0 and m1.shed == 0, tag
    for a, b in zip(r0, r1):
        assert a.status == b.status == STATUS_DONE, (tag, a.req_id, b.status)
        assert b.retries == 0, (tag, b.req_id)
        np.testing.assert_array_equal(a.result, b.result,
                                      err_msg=f"{tag}/{a.req_id}")
    np.testing.assert_array_equal(np.asarray(ar0.data), np.asarray(ar1.data),
                                  err_msg=tag)
    print(f"{tag} ok: failover_quanta={m1.failover_quanta} "
          f"completed={m1.completed}")


def check_watchdog_delay(pipeline):
    """Delay-only straggler (the fail-stop blind spot): the per-round
    watchdog probe escalates it to suspected-dead and reads fan out --
    no recovery, no retries, results identical to the clean run."""
    plan = FaultPlan(delay_shard=2, delay_s=0.15)
    with tempfile.TemporaryDirectory() as d0, \
            tempfile.TemporaryDirectory() as d1:
        r0, m0, ar0, _ = serve_rep(d0, None, pipeline, reads_only=True)
        r1, m1, ar1, _ = serve_rep(d1, plan, pipeline, dead_rounds=1000,
                                   watchdog=0.05, reads_only=True)
    tag = f"watchdog-delay/{pipeline}"
    assert m1.watchdog_probes > 0, tag
    assert m1.watchdog_suspects >= 1, (tag, m1.watchdog_suspects)
    assert m1.failover_quanta >= 1, (tag, m1.failover_quanta)
    assert m1.retries == 0 and m1.recoveries == 0, (tag, m1.retries)
    for a, b in zip(r0, r1):
        assert a.status == b.status == STATUS_DONE, (tag, a.req_id)
        np.testing.assert_array_equal(a.result, b.result,
                                      err_msg=f"{tag}/{a.req_id}")
    print(f"{tag} ok: suspects={m1.watchdog_suspects} "
          f"probes={m1.watchdog_probes} failover_quanta={m1.failover_quanta}")


# ------------------------------- resharding ---------------------------------


def build_bst4():
    b = ArenaBuilder(512, 4, num_shards=4, policy="interleaved")
    root, _h = bst.build_into(b, KEYS, KEYS * 2)
    return b.finish(), root


def bst_reqs(n=40, writes=True):
    # updates are alloc-free (bst.update_iterator), so the committed state
    # is partition-independent -- the cold-equivalence check stays exact
    reqs = []
    for i in range(n):
        if writes and i % 4 == 3:
            k = int(KEYS[(i * 5) % len(KEYS)])
            reqs.append(TraversalRequest(
                i, "bst_upd", k, value=9000 + i, tenant="w",
                arrive_round=i // 6,
            ))
        else:
            reqs.append(TraversalRequest(
                i, "bst", int(KEYS[(i * 7) % len(KEYS)]), tenant="r",
                arrive_round=i // 6,
            ))
    return reqs


def serve_reshard(arena, root, nshards, pipeline, *, reshard_at=None,
                  writes=True):
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:nshards]), ("mem",))
    eng = PulseEngine(arena, mesh=mesh)
    svc = PulseService(
        eng,
        {
            "bst": StructureSpec(bst.find_iterator(), (root,), group="bst"),
            "bst_upd": StructureSpec(bst.update_iterator(), (root,),
                                     group="bst", takes_value=True),
        },
        slots_per_structure=8, quantum=6, pipeline=pipeline,
    )
    reqs = bst_reqs(writes=writes)
    for r in reqs:
        svc.submit(r)
    try:
        while svc._busy():
            if reshard_at is not None and svc.metrics.rounds == reshard_at:
                svc.request_reshard(8)
            if svc.metrics.rounds > 10000:
                raise RuntimeError("no drain")
            svc.step()
    finally:
        svc.close()
        svc._drain_emit()
    return reqs, svc.metrics, eng.arena


def check_live_reshard(pipeline, writes):
    """Mid-stream 4 -> 8 reshard vs a cold run at 8 shards (the cold arena
    is the offline ``remap_shards`` of the same 4-shard build, which is the
    partition the live path converges to)."""
    a4, root = build_bst4()
    cold8 = remap_shards(a4, 8)
    rc, mc, arc = serve_reshard(cold8, root, 8, pipeline, writes=writes)
    a4b, root_b = build_bst4()
    assert root_b == root
    rm, mm, arm = serve_reshard(a4b, root, 4, pipeline, reshard_at=3,
                                writes=writes)
    tag = f"reshard/{pipeline}/{'rw' if writes else 'ro'}"
    assert mm.reshards == 1, tag
    assert arm.num_shards == 8, tag
    for a, b in zip(rc, rm):
        assert a.status == b.status == STATUS_DONE, (tag, a.req_id, a.status,
                                                     b.status)
        np.testing.assert_array_equal(a.result, b.result,
                                      err_msg=f"{tag}/{a.req_id}")
    np.testing.assert_array_equal(np.asarray(arc.data), np.asarray(arm.data),
                                  err_msg=tag)
    np.testing.assert_array_equal(np.asarray(arc.bounds),
                                  np.asarray(arm.bounds), err_msg=tag)
    np.testing.assert_array_equal(np.asarray(arc.perms),
                                  np.asarray(arm.perms), err_msg=tag)
    # allocator registers match; epoch/commit counters are commit-placement
    # metadata and legitimately differ when early quanta committed at 4
    hc, hm = np.asarray(arc.heap), np.asarray(arm.heap)
    np.testing.assert_array_equal(hc[:, :2], hm[:, :2], err_msg=tag)
    if not writes:
        np.testing.assert_array_equal(hc, hm, err_msg=tag)
        assert mm.commits == mc.commits == 0
    else:
        assert mm.commits == mc.commits > 0, (tag, mm.commits, mc.commits)
    print(f"{tag} ok: drain_rounds={mm.reshard_drain_rounds} "
          f"commits={mm.commits} rounds {mc.rounds}->{mm.rounds}")


if __name__ == "__main__":
    assert jax.device_count() == P, jax.device_count()
    for pipe in ("sync", "async"):
        check_replication_failover(pipe)
        check_readonly_zero_retry(pipe)
        check_watchdog_delay(pipe)
        check_live_reshard(pipe, writes=False)
        check_live_reshard(pipe, writes=True)
    print("ALL ELASTICITY CHECKS PASSED")
