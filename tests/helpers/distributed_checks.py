"""Multi-device routing checks. Run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so in-process tests keep
seeing 1 device (per the dry-run isolation rule)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import routing  # noqa: E402
from repro.core.engine import PulseEngine  # noqa: E402
from repro.core.iterator import STATUS_DONE, STATUS_FAULT, execute_batched  # noqa: E402
from repro.core.structures import btree, hash_table, linked_list  # noqa: E402

RNG = np.random.default_rng(11)
P = 8


def mesh():
    return jax.make_mesh((P,), ("mem",))


def unique_keys(n, lo=0, hi=10**6):
    return RNG.choice(np.arange(lo, hi, dtype=np.int64), size=n, replace=False).astype(
        np.int32
    )


def check_btree_distributed_vs_oracle():
    """Distributed supersteps must equal the single-node executor exactly."""
    n = 4000
    keys = unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, height = btree.build(keys, values, num_shards=P, policy="sequential")
    it = btree.find_iterator()
    queries = np.concatenate([keys[:256], unique_keys(256, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), root)

    # oracle: single-device batched executor over the unsharded arena
    o_ptr, o_scr, o_status, o_iters = execute_batched(
        it, ar, ptr0, scr0, max_iters=64
    )

    rec, stats = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=64, k_local=2
    )
    assert rec.shape[0] == queries.shape[0], "conservation: every request returns"
    np.testing.assert_array_equal(rec[:, routing.F_SCRATCH:], np.asarray(o_scr))
    np.testing.assert_array_equal(rec[:, routing.F_STATUS], np.asarray(o_status))
    np.testing.assert_array_equal(rec[:, routing.F_ITERS], np.asarray(o_iters))
    assert stats.crossings.max() >= 1, "multi-shard traversal must cross nodes"

    # compacted supersteps: identical results, strictly less fabric traffic
    rec_c, stats_c = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=64, k_local=2,
        compact=True,
    )
    np.testing.assert_array_equal(rec_c[:, routing.F_SCRATCH:], np.asarray(o_scr))
    np.testing.assert_array_equal(rec_c[:, routing.F_STATUS], np.asarray(o_status))
    np.testing.assert_array_equal(rec_c[:, routing.F_ITERS], np.asarray(o_iters))
    assert stats_c.total_wire_words < stats.total_wire_words
    print(
        f"btree ok: supersteps={stats.supersteps} "
        f"mean_crossings={stats.crossings.mean():.2f} "
        f"wire compact/base={stats_c.total_wire_words}/{stats.total_wire_words}"
    )


def check_pulse_acc_matches_but_costs_more():
    """Fig. 9: PULSE-ACC returns identical results with ~2x crossings."""
    n = 2000
    keys = unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, _ = btree.build(keys, values, num_shards=P, policy="interleaved")
    it = btree.find_iterator()
    queries = keys[:128]
    ptr0, scr0 = it.init(jnp.asarray(queries), root)
    rec_a, st_a = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=64
    )
    rec_b, st_b = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=64,
        return_to_cpu=True,
    )
    np.testing.assert_array_equal(rec_a[:, routing.F_SCRATCH:], rec_b[:, routing.F_SCRATCH:])
    assert st_b.crossings.sum() > st_a.crossings.sum(), (
        "PULSE-ACC must incur strictly more network crossings "
        f"({st_b.crossings.sum()} vs {st_a.crossings.sum()})"
    )
    print(
        f"pulse-acc ok: crossings {st_a.crossings.sum()} (switch) vs "
        f"{st_b.crossings.sum()} (via CPU node)"
    )


def check_hash_distributed():
    n, n_buckets = 3000, 256
    keys = unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, heads = hash_table.build(keys, values, n_buckets, num_shards=P)
    it = hash_table.find_iterator(n_buckets)
    queries = np.concatenate([keys[:200], unique_keys(200, hi=10**4)])
    ptr0, scr0 = it.init(jnp.asarray(queries), jnp.asarray(heads))
    o = execute_batched(it, ar, ptr0, scr0, max_iters=256)
    rec, stats = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=256
    )
    np.testing.assert_array_equal(rec[:, routing.F_SCRATCH:], np.asarray(o[1]))
    np.testing.assert_array_equal(rec[:, routing.F_STATUS], np.asarray(o[2]))
    print(f"hash ok: supersteps={stats.supersteps}")


def check_allocation_policy_effect():
    """Appendix Fig. 5: interleaved (uniform) allocation must cause more
    cross-node traversals than partitioned (sequential) allocation."""
    n = 4000
    keys = np.sort(unique_keys(n))
    values = RNG.integers(0, 1000, n).astype(np.int32)
    it = btree.find_iterator()
    crossings = {}
    for policy in ("sequential", "interleaved"):
        ar, root, _ = btree.build(keys, values, num_shards=P, policy=policy)
        queries = keys[RNG.integers(0, n, 256)]
        ptr0, scr0 = it.init(jnp.asarray(queries), root)
        rec, stats = routing.distributed_execute(
            it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=64
        )
        crossings[policy] = stats.crossings.mean()
    assert crossings["interleaved"] > crossings["sequential"], crossings
    print(f"allocation ok: {crossings}")


def check_protection_fault_routes_home():
    """A traversal touching a no-read range must FAULT and return home."""
    keys = np.arange(64, dtype=np.int32)
    values = np.ones(64, np.int32)
    ar, head = linked_list.build(keys, values, num_shards=P)
    # revoke read on shard 4 (the chain passes through every shard)
    perms = np.asarray(ar.perms).copy()
    perms[4] = 0
    import dataclasses

    ar = dataclasses.replace(ar, perms=jnp.asarray(perms))
    it = linked_list.sum_iterator()
    ptr0, scr0 = it.init(jnp.asarray([head], jnp.int32))
    rec, stats = routing.distributed_execute(
        it, ar, ptr0, scr0, mesh=mesh(), axis_name="mem", max_iters=1000
    )
    assert int(rec[0, routing.F_STATUS]) == STATUS_FAULT
    # progressed through shards 0..3 (8 nodes per shard) then faulted
    assert int(rec[0, routing.F_SCRATCH + 1]) == 32, rec[0]
    print("protection ok")


def check_engine_front_door():
    n = 1000
    keys = unique_keys(n)
    values = RNG.integers(0, 10**6, n).astype(np.int32)
    ar, root, _ = btree.build(keys, values, num_shards=P)
    eng = PulseEngine(ar, mesh=mesh(), axis_name="mem")
    it = btree.find_iterator()
    ptr0, scr0 = it.init(jnp.asarray(keys[:64]), root)
    res = eng.execute(it, ptr0, scr0, max_iters=64)
    assert res.offloaded
    assert (res.status == STATUS_DONE).all()
    assert (res.scratch[:, 2] == 1).all()  # all found
    print("engine ok")


if __name__ == "__main__":
    assert jax.device_count() == P, jax.devices()
    check_btree_distributed_vs_oracle()
    check_pulse_acc_matches_but_costs_more()
    check_hash_distributed()
    check_allocation_policy_effect()
    check_protection_fault_routes_home()
    check_engine_front_door()
    print("ALL DISTRIBUTED CHECKS PASSED")
